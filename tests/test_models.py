"""Per-architecture smoke tests (reduced configs, CPU): forward/train/decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, runnable_cells
from repro.nn import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, with_targets=True):
    batch = {}
    if cfg.frontend == "audio":
        batch["embeds"] = jax.random.normal(
            KEY, (B, S, cfg.d_model)).astype(jnp.dtype(cfg.dtype))
        if with_targets:
            batch["targets"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        return batch
    if cfg.frontend == "vision":
        fs = cfg.frontend_seq
        batch["tokens"] = jax.random.randint(KEY, (B, S - fs), 0, cfg.vocab)
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, fs, cfg.d_model)).astype(jnp.dtype(cfg.dtype))
        if with_targets:
            batch["targets"] = batch["tokens"]
        return batch
    batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if with_targets:
        batch["targets"] = batch["tokens"]
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, aux = T.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "stablelm_3b",
                                  "rwkv6_1_6b", "recurrentgemma_2b",
                                  "moonshot_v1_16b_a3b"])
def test_decode_matches_forward(arch):
    """Greedy decode continues exactly where prefill left off (f32)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              moe_capacity_factor=8.0)
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg, with_targets=False)
    last, cache = T.prefill(cfg, params, batch)
    if cfg.family not in ("ssm",) and cfg.rglru_pattern == 0:
        from repro.serving.engine import pad_cache
        cache = pad_cache(cache, S + 4)
    nxt = jnp.argmax(last, -1)
    logits, cache = T.decode_step(cfg, params, nxt, S, cache)
    ext = jnp.concatenate([batch["tokens"], nxt[:, None]], 1)
    want, _ = T.forward(cfg, params, {"tokens": ext})
    rel = float(jnp.max(jnp.abs(logits - want[:, -1]))) \
        / (float(jnp.max(jnp.abs(want[:, -1]))) + 1e-9)
    assert rel < 1e-4, rel


def test_remat_matches_no_remat():
    cfg = dataclasses.replace(get_config("qwen2_0_5b").reduced(),
                              dtype="float32")
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    l1, _ = T.loss_fn(cfg, params, batch)
    l2, _ = T.loss_fn(dataclasses.replace(cfg, remat="block"), params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_scan_matches_unroll():
    cfg = dataclasses.replace(get_config("qwen2_1_5b").reduced(),
                              dtype="float32")
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    l1, _ = T.loss_fn(cfg, params, batch)
    l2, _ = T.loss_fn(dataclasses.replace(cfg, scan_layers=False), params,
                      batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_pallas_attention_impl_matches_xla():
    cfg = dataclasses.replace(get_config("stablelm_3b").reduced(),
                              dtype="float32", head_dim=32)
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    l_xla, _ = T.forward(cfg, params, batch)
    l_pl, _ = T.forward(dataclasses.replace(cfg, attention_impl="pallas"),
                        params, batch)
    np.testing.assert_allclose(np.asarray(l_xla), np.asarray(l_pl),
                               atol=2e-4, rtol=2e-4)


def test_pallas_rwkv_impl_matches_xla():
    cfg = dataclasses.replace(get_config("rwkv6_1_6b").reduced(),
                              dtype="float32")
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    l_xla, _ = T.forward(cfg, params, batch)
    l_pl, _ = T.forward(dataclasses.replace(cfg, attention_impl="pallas"),
                        params, batch)
    np.testing.assert_allclose(np.asarray(l_xla), np.asarray(l_pl),
                               atol=2e-4, rtol=2e-4)


def test_cell_accounting():
    assert len(runnable_cells()) == 32
    from repro.configs.base import skipped_cells
    assert len(skipped_cells()) == 8
    assert len(runnable_cells()) + len(skipped_cells()) == 40


def test_param_counts_sane():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count
        assert n > 1e8, arch
        assert cfg.active_param_count <= n


def test_moe_scatter_matches_einsum():
    """Scatter/gather dispatch must equal the Mesh-TF einsum formulation."""
    cfg = dataclasses.replace(get_config("moonshot_v1_16b_a3b").reduced(),
                              dtype="float32")
    params = T.init_params(cfg, KEY)
    batch = make_batch(cfg)
    l1, _ = T.forward(cfg, params, batch)
    l2, _ = T.forward(dataclasses.replace(cfg, moe_impl="scatter"),
                      params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-4, rtol=1e-4)
    # gradients flow through the scatter path too
    g = jax.grad(lambda p: T.loss_fn(
        dataclasses.replace(cfg, moe_impl="scatter"), p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
