"""DRAM layout model (Fig. 6) + analytic cost model properties."""

import math

import numpy as np
import pytest
from hypothesis_compat import given, strategies as st

from repro.core.costmodel import part_layer_cost
from repro.core.hardware import PAPER_4X4, PAPER_BEST, HwConfig
from repro.core.ir import conv, matmul
from repro.core.layout import (DataLayout, enumerate_layouts, mean_bursts,
                               sequential_access_cost, tile_access_cost)


def test_fig6_burst_counts():
    """Paper Fig. 6: 3x3 window over 2 of 4 channels, 4 values/burst."""
    fm, tile = (1, 4, 5, 5), (1, 2, 3, 3)
    b_bchw, _ = tile_access_cost(fm, tile, DataLayout("BCHW", 1), 4, 512)
    b_c2, _ = tile_access_cost(fm, tile, DataLayout("BCHW", 2), 4, 512)
    assert b_bchw == 9.0      # 6 runs of 3 @ 1.5 bursts
    assert b_c2 == 6.0        # 3 runs of 6 @ 2 bursts (2-aligned)
    assert b_c2 < b_bchw


def test_contiguous_tile_is_sequential():
    """A whole-fmap tile in BCHW must cost the sequential minimum."""
    fm = (4, 16, 8, 8)
    n = 4 * 16 * 8 * 8
    bursts, rows = tile_access_cost(fm, fm, DataLayout("BCHW", 1), 32, 1024)
    sb, sr = sequential_access_cost(n, 32, 1024)
    # tile model averages over start alignments: within one burst of ideal
    assert sb <= bursts <= sb + 1
    assert rows == sr


@given(st.integers(1, 16), st.integers(1, 32), st.integers(1, 12),
       st.integers(1, 12))
def test_burst_bounds(g, c, th, tw):
    """bursts >= values/burst_width and <= values (one value per burst)."""
    fm = (1, 32, 16, 16)
    tile = (1, c, th, tw)
    burst = 8
    for dl in (DataLayout("BCHW", g), DataLayout("BHWC")):
        bursts, rows = tile_access_cost(fm, tile, dl, burst, 2048)
        vals = min(c, 32) * min(th, 16) * min(tw, 16)
        assert bursts >= vals / burst - 1e-6
        assert bursts <= vals + burst
        assert rows >= 1.0


@given(st.sampled_from([1, 2, 3, 5, 8, 13, 21]), st.integers(1, 8))
def test_mean_bursts_monotone(run, align):
    a = mean_bursts(run, align, 8)
    b = mean_bursts(run + 8, align, 8)
    assert b >= a + 1 - 1e-9  # 8 more values = at least one more burst


def test_cost_model_compute_napkin():
    """64x64 3x3 conv on 56x56 @ 32x32 PEs -> exact cycle count."""
    l = conv("c", 1, 64, 56, 56, 64)
    pc = part_layer_cost(PAPER_4X4, l, DataLayout("BCHW", 8),
                         DataLayout("BCHW", 8))
    want_cycles = 2 * 2 * 9 * 56 * 56  # ceil(64/32)^2 * HKWK * P*Q
    assert abs(pc.compute_s * PAPER_4X4.cons.freq_hz - want_cycles) < 1
    assert pc.latency_s >= pc.compute_s
    assert pc.latency_s >= pc.dram_s


def test_bigger_pe_array_not_slower():
    l = conv("c", 1, 128, 28, 28, 128)
    dl = DataLayout("BCHW", 8)
    small = part_layer_cost(PAPER_4X4.replace(pea_row=16, pea_col=16), l, dl, dl)
    big = part_layer_cost(PAPER_4X4.replace(pea_row=64, pea_col=64), l, dl, dl)
    assert big.compute_s <= small.compute_s


def test_bigger_buffers_not_more_dram():
    l = conv("c", 1, 256, 28, 28, 256)
    dl = DataLayout("BCHW", 8)
    small = part_layer_cost(PAPER_4X4.replace(wbuf_kib=8, ibuf_kib=8,
                                              obuf_kib=8), l, dl, dl)
    big = part_layer_cost(PAPER_4X4.replace(wbuf_kib=512, ibuf_kib=512,
                                            obuf_kib=512), l, dl, dl)
    assert big.dram_bytes <= small.dram_bytes + 1


def test_dl_changes_dram_cost():
    l = conv("c", 1, 32, 112, 112, 32)
    costs = {dl.short(): part_layer_cost(PAPER_4X4, l, dl, dl).dram_s
             for dl in enumerate_layouts(32, 16)}
    assert len(set(costs.values())) > 1  # layout matters


def test_area_model_anchors():
    assert PAPER_BEST.area_legal()
    assert PAPER_4X4.area_legal()
    big = HwConfig(16, 16, 256, 256, 2048, 2048, 2048)
    assert not big.area_legal()
    assert big.area_mm2() > 1000
