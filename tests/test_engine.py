"""Batched DSE engine: parity, Pareto, cache, campaign, determinism."""

import json
import random

import numpy as np
import pytest

from repro.core.costmodel import part_layer_cost
from repro.core.hardware import (PAPER_4X4, PAPER_16X16, PAPER_BEST,
                                 DEFAULT_CONSTRAINTS, HwConfig)
from repro.core.ir import Layer, conv, matmul
from repro.core.layout import DataLayout
from repro.core.noc import MeshNoc
from repro.core.scheduler import solve_ilp_ls
from repro.core.tuner import sample_configs
from repro.core.workloads import googlenet
from repro.engine import (Campaign, EvalCache, ParetoFront, ParetoPoint,
                          PartSpec, batch_area_mm2, batch_max_link_load,
                          batch_part_cost, graph_digest, hw_digest)

RTOL = 1e-6
COST_FIELDS = ("latency_s", "energy_pj", "compute_s", "dram_s", "dram_bytes",
               "e_mac_pj", "e_sram_pj", "e_dram_pj")


def _specs():
    layers = [
        conv("c1", 1, 64, 56, 56, 64),
        conv("c2", 4, 3, 224, 224, 32, stride=2),
        conv("c3", 1, 256, 14, 14, 512, HK=1),
        matmul("m1", 64, 768, 768),
        Layer("dw", "dwconv", B=1, C=128, H=28, W=28, K=128, HK=3, WK=3,
              stride=1, pad=1),
        Layer("aux", "add", B=1, C=64, H=56, W=56, K=64),
        conv("wideq", 1, 32, 112, 112, 64),   # exercises the Q > 64 path
    ]
    dls = [DataLayout("BCHW", 1), DataLayout("BCHW", 8), DataLayout("BHWC"),
           DataLayout("BCHW", 16)]
    return [PartSpec(l, dls[i % 4], dls[(i + 1) % 4])
            for i, l in enumerate(layers)]


# ---------------------------------------------------------------------------
# batch_cost vs scalar costmodel
# ---------------------------------------------------------------------------


def test_batched_matches_scalar_on_randomized_configs():
    rng = np.random.default_rng(42)
    configs = [PAPER_BEST, PAPER_4X4, PAPER_16X16] + sample_configs(6, rng)
    specs = _specs()
    res = batch_part_cost(configs, specs, chunk=4)
    for i, cfg in enumerate(configs):
        for j, s in enumerate(specs):
            ref = part_layer_cost(cfg, s.layer, s.dl_in, s.dl_out)
            got = res.part_cost(i, j)
            for f in COST_FIELDS:
                a, b = getattr(ref, f), getattr(got, f)
                assert a == pytest.approx(b, rel=RTOL, abs=1e-30), \
                    (cfg.as_tuple(), s.layer.name, f)
            assert ref.tiling == got.tiling, (cfg.as_tuple(), s.layer.name)
            assert ref.loop_order == got.loop_order


def test_batched_aux_layer_is_zero():
    res = batch_part_cost([PAPER_4X4], _specs(), chunk=2)
    j = next(i for i, s in enumerate(res.specs) if not s.layer.is_heavy)
    assert res.latency_s[0, j] == 0.0
    assert res.energy_pj[0, j] == 0.0
    assert tuple(res.tiling[0, j]) == (1, 1, 1, 1, 1)


def test_batched_chunking_invariant():
    rng = np.random.default_rng(3)
    configs = sample_configs(5, rng)
    specs = _specs()[:3]
    a = batch_part_cost(configs, specs, chunk=2)
    b = batch_part_cost(configs, specs, chunk=5)
    np.testing.assert_allclose(a.latency_s, b.latency_s, rtol=0)
    np.testing.assert_allclose(a.energy_pj, b.energy_pj, rtol=0)


def test_batch_area_matches_scalar():
    rng = np.random.default_rng(7)
    configs = sample_configs(16, rng)
    areas = batch_area_mm2(configs)
    for c, a in zip(configs, areas):
        assert c.area_mm2() == pytest.approx(float(a), rel=1e-12)


def test_batch_max_link_load_matches_noc():
    noc = MeshNoc(4, 4)
    rng = random.Random(0)
    loads = []
    refs = []
    for _ in range(8):
        transfers = [(rng.randrange(16), rng.randrange(16),
                      float(rng.randrange(1, 100)))
                     for _ in range(12)]
        loads.append(noc.link_loads(transfers))
        refs.append(noc.max_link_load(transfers))
    got = batch_max_link_load(np.array(loads))
    np.testing.assert_allclose(got, refs, rtol=0)


# ---------------------------------------------------------------------------
# Pareto front
# ---------------------------------------------------------------------------


def _rand_points(rng, n=60):
    return [ParetoPoint(rng.uniform(1, 10), rng.uniform(1, 10),
                        rng.uniform(1, 10), payload=i) for i in range(n)]


def test_pareto_no_dominated_point_survives():
    rng = random.Random(1)
    pts = _rand_points(rng)
    fr = ParetoFront()
    fr.offer_all(pts)
    front = fr.front()
    for a in front:
        assert not any(b.dominates(a) for b in front)
    # everything excluded is dominated by (or duplicates) the front
    kept = {p.key for p in front}
    for p in pts:
        if p.key not in kept:
            assert fr.dominated(p) or p.key in kept


def test_pareto_insertion_order_invariance():
    rng = random.Random(2)
    pts = _rand_points(rng)
    keys = None
    for order_seed in range(4):
        shuffled = list(pts)
        random.Random(order_seed).shuffle(shuffled)
        fr = ParetoFront()
        fr.offer_all(shuffled)
        got = sorted(p.key for p in fr.front())
        if keys is None:
            keys = got
        assert got == keys


def test_pareto_offer_semantics_and_roundtrip(tmp_path):
    fr = ParetoFront()
    assert fr.offer(ParetoPoint(1, 1, 1))
    assert not fr.offer(ParetoPoint(2, 2, 2))      # dominated
    assert not fr.offer(ParetoPoint(1, 1, 1))      # duplicate
    assert fr.offer(ParetoPoint(0.5, 2, 1))        # trade-off joins
    assert fr.offer(ParetoPoint(0.4, 0.4, 0.4))    # dominates everything
    assert len(fr) == 1
    fr.save(tmp_path / "front.json")
    back = ParetoFront.load(tmp_path / "front.json")
    assert [p.key for p in back.front()] == [p.key for p in fr.front()]


# ---------------------------------------------------------------------------
# content-addressed cache
# ---------------------------------------------------------------------------


def test_digests_content_addressed():
    a = HwConfig(4, 8, 128, 8, 16, 144, 32)
    b = HwConfig(4, 8, 128, 8, 16, 144, 32)
    assert a is not b and hw_digest(a) == hw_digest(b)
    assert hw_digest(a) != hw_digest(a.replace(pea_col=16))
    g1, g2 = googlenet(1, scale=8), googlenet(1, scale=8)
    assert graph_digest(g1) == graph_digest(g2)
    assert graph_digest(g1) != graph_digest(googlenet(1, scale=4))


def test_eval_cache_roundtrip(tmp_path):
    cache = EvalCache()
    key = EvalCache.key(PAPER_4X4, [googlenet(1, scale=8)])
    assert cache.get(key) is None
    cache.put(key, (1.5, {"g": 2.0}, {"g": 3.0}))
    assert cache.get(key)[0] == 1.5
    assert cache.stats == {"hits": 1, "misses": 1, "entries": 1,
                           "flight_waits": 0}
    cache.save(tmp_path / "cache.json")
    back = EvalCache.load(tmp_path / "cache.json")
    assert back.get(key)[0] == 1.5


# ---------------------------------------------------------------------------
# campaign orchestration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_workloads():
    return [googlenet(1, scale=8)]


MAPPER_KW = dict(max_optim_iter=1, lm_cap=20, n_wr=2)


def test_campaign_runs_and_checkpoints(tiny_workloads, tmp_path):
    ckpt = tmp_path / "campaign.json"
    camp = Campaign(tiny_workloads, ("random", "gp"), iterations=2,
                    propose_k=4, seed=0, n_sample=64,
                    evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW),
                    checkpoint=ckpt)
    out = camp.run()
    assert set(out.results) == {"random", "gp"}
    assert not out.resumed
    assert out.best().cost > 0
    assert len(out.pareto) >= 1
    state = json.loads(ckpt.read_text())
    assert set(state["strategies"]) == {"random", "gp"}

    # resume: everything is complete, nothing re-evaluates
    camp2 = Campaign(tiny_workloads, ("random", "gp"), iterations=2,
                     propose_k=4, seed=0, n_sample=64,
                     evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW),
                     checkpoint=ckpt)
    out2 = camp2.run()
    assert sorted(out2.resumed) == ["gp", "random"]
    assert out2.cache_stats["misses"] == 0
    for name in ("random", "gp"):
        a = [o.cfg.as_tuple() for o in out.results[name].observations]
        b = [o.cfg.as_tuple() for o in out2.results[name].observations]
        assert a == b


def test_campaign_partial_resume_continues(tiny_workloads, tmp_path):
    ckpt = tmp_path / "partial.json"
    kw = dict(iterations=3, propose_k=4, seed=1, n_sample=64,
              evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW), checkpoint=ckpt)
    camp = Campaign(tiny_workloads, ("random",), **kw)
    out_full = camp.run()
    # simulate a mid-run kill: drop every observation after iteration 0
    state = json.loads(ckpt.read_text())
    state["strategies"]["random"] = [
        o for o in state["strategies"]["random"] if o["iteration"] == 0]
    ckpt.write_text(json.dumps(state))
    camp2 = Campaign(tiny_workloads, ("random",), **kw)
    out = camp2.run()
    assert out.resumed == ["random"]
    iters = {o.iteration for o in out.results["random"].observations}
    assert max(iters) == 2 and 0 in iters
    # the saved iteration-0 observation survives verbatim (and its Pareto
    # contribution is re-offered on resume)
    assert (out.results["random"].observations[0].cfg.as_tuple()
            == out_full.results["random"].observations[0].cfg.as_tuple())
    assert len(out.pareto) >= 1


def test_campaign_checkpoint_rejected_on_workload_change(tiny_workloads,
                                                         tmp_path):
    ckpt = tmp_path / "wl.json"
    kw = dict(iterations=1, propose_k=4, seed=1, n_sample=64,
              evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW), checkpoint=ckpt)
    Campaign(tiny_workloads, ("random",), **kw).run()
    other = Campaign([googlenet(1, scale=4)], ("random",), **kw)
    assert other._load_checkpoint() == {}   # stale workloads: start over


def test_run_dse_feeds_pareto(tiny_workloads):
    from repro.core.dse import WorkloadEvaluator, run_dse
    from repro.core.surrogates import make_strategy
    ev = WorkloadEvaluator(tiny_workloads, mapper_kwargs=MAPPER_KW)
    fr = ParetoFront()
    res = run_dse(make_strategy("random", seed=0, n_sample=64), ev,
                  iterations=2, propose_k=4, pareto=fr)
    n_eval = sum(o.cost is not None for o in res.observations)
    assert fr.offered == n_eval
    assert len(fr) >= (1 if n_eval else 0)


# ---------------------------------------------------------------------------
# scheduler determinism (threaded RNG)
# ---------------------------------------------------------------------------


def test_solve_ilp_ls_seed_reproducible():
    noc = MeshNoc(4, 4)
    sets = [[0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15]]
    chunks = [1000.0, 2000.0]
    a = solve_ilp_ls(noc, sets, chunks, 3.2e9, 400e6, 1.1, seed=5)
    b = solve_ilp_ls(noc, sets, chunks, 3.2e9, 400e6, 1.1, seed=5)
    assert a.cycles == b.cycles
    assert a.max_link_bytes == b.max_link_bytes
    c = solve_ilp_ls(noc, sets, chunks, 3.2e9, 400e6, 1.1,
                     rng=random.Random(5))
    assert c.cycles == a.cycles


def test_evaluate_mapping_deterministic(tiny_workloads):
    from repro.core.mapper import PimMapper, evaluate_mapping
    mapper = PimMapper(PAPER_4X4, **MAPPER_KW)
    m = mapper.map(tiny_workloads[0])
    r1 = evaluate_mapping(m, seed=3)
    from repro.core.mapper import _sharing_latency
    _sharing_latency.cache_clear()
    r2 = evaluate_mapping(m, seed=3)
    assert r1.latency_s == r2.latency_s
    assert r1.energy_pj == r2.energy_pj
