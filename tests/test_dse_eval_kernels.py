"""Numpy-parity sweeps for the dse_eval row reductions (interpret=True).

Covers the kernels the PIM006 kernel-parity lint rule tracks: every public
export of ``kernels/dse_eval.py`` must match its numpy oracle, including the
ragged-row paths the block padding has to mask out.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dse_eval import argmin_rows, max_rows, tile_select

KEY = jax.random.PRNGKey(41)


def _case(r, t, seed, p_valid=0.8):
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    comp = jax.random.uniform(ks[0], (r, t), jnp.float32, 1.0, 100.0)
    dram = jax.random.uniform(ks[1], (r, t), jnp.float32, 1.0, 100.0)
    valid = jax.random.uniform(ks[2], (r, t)) < p_valid
    # every row keeps at least one valid candidate (the engine's contract)
    valid = valid.at[:, 0].set(True)
    return comp, dram, valid


def _ref_tile_select(comp, dram, valid):
    total = np.where(np.asarray(valid), np.maximum(np.asarray(comp),
                                                   np.asarray(dram)), np.inf)
    return total.min(axis=-1), total.argmin(axis=-1)


CASES = [(1, 1, 0), (7, 5, 1), (8, 16, 2), (33, 12, 3), (128, 40, 4)]


@pytest.mark.parametrize("r,t,seed", CASES)
def test_tile_select_parity(r, t, seed):
    comp, dram, valid = _case(r, t, seed)
    tot, idx = tile_select(comp, dram, valid, block_r=8, interpret=True)
    want_tot, want_idx = _ref_tile_select(comp, dram, valid)
    np.testing.assert_allclose(np.asarray(tot), want_tot, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), want_idx)


def test_tile_select_all_invalid_row():
    comp, dram, _ = _case(4, 6, 9)
    valid = jnp.zeros((4, 6), dtype=bool).at[1:, 0].set(True)
    tot, idx = tile_select(comp, dram, valid, block_r=4, interpret=True)
    assert np.isinf(np.asarray(tot)[0]) and np.asarray(idx)[0] == 0


@pytest.mark.parametrize("r,t,seed", CASES)
def test_argmin_rows_parity(r, t, seed):
    x, _, valid = _case(r, t, seed)
    mn, idx = argmin_rows(x, valid, block_r=8, interpret=True)
    ref = np.where(np.asarray(valid), np.asarray(x), np.inf)
    np.testing.assert_allclose(np.asarray(mn), ref.min(axis=-1), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), ref.argmin(axis=-1))


def test_argmin_rows_first_occurrence_and_default_mask():
    # duplicated minima: must return the FIRST index, like the scalar DP
    x = jnp.asarray([[3.0, 1.0, 1.0, 2.0], [5.0, 5.0, 5.0, 5.0]])
    mn, idx = argmin_rows(x, interpret=True)
    np.testing.assert_allclose(np.asarray(mn), [1.0, 5.0])
    np.testing.assert_array_equal(np.asarray(idx), [1, 0])


@pytest.mark.parametrize("r,t,seed", CASES)
def test_max_rows_parity(r, t, seed):
    x, _, valid = _case(r, t, seed)
    got = max_rows(x, valid, block_r=8, interpret=True)
    ref = np.where(np.asarray(valid), np.asarray(x), -np.inf).max(axis=-1)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-6)


def test_max_rows_default_mask():
    x, _, _ = _case(16, 7, 5)
    got = max_rows(x, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(x).max(axis=-1), rtol=1e-6)
