"""PIM001 fixture: host syncs on jit-produced values in an engine hot path."""

import jax
import numpy as np


@jax.jit
def _score(x):
    return x * 2


_JITTED = {"score": _score}


def run(xs):
    total = 0.0
    for x in xs:
        y = _score(x)
        total += float(y)            # line 17: float() on tainted value
    arr = np.asarray(_score(xs))     # line 18: sync directly on a jit call
    z = _score(xs)
    s = z.item()                     # line 20: .item() on tainted value
    return total, arr, s
