"""PIM007 fixture: host syncs collapsing the overlapped wave window."""

import jax
import numpy as np


def dispatch_node_fill(engine, pairs):
    pending = engine.dispatch_paired(pairs)
    rows = np.asarray(pending)        # line 9: pull on an in-flight value
    return rows


def dispatch_and_wait(engine, pairs):
    pending = engine.dispatch_paired(pairs)
    jax.block_until_ready(pending)    # line 15: hard sync in a dispatch fn
    return pending


def map_phases(engine, waves):
    for wave in waves:
        pending = engine.dispatch_paired(wave)
        yield
        lat = float(pending)          # line 23: float() on a pending value
        wave.ingest(lat)
