"""PIM003 fixture: a donated buffer read after the call that donated it."""

from functools import partial

import jax


@partial(jax.jit, donate_argnums=(0,))
def _step(state, grad):
    return state - grad


_JITTED = {"step": _step}


def train(state, grads):
    for g in grads:
        out = _step(state, g)
        print(state)                 # line 16: read after donation
        state = out
    return state
