"""PIM002 fixture: weak-type pin, bucket bypass, unregistered jit."""

import jax
import jax.numpy as jnp


@jax.jit
def _forward(params, x):
    scale = jnp.asarray(x)           # line 9: no dtype pin on a param
    return params * scale


_JITTED = {"forward": _forward}

_kernel = jax.jit(lambda a: a.sum())  # line 15: not in _JITTED


def dispatch(data):
    return _kernel(jnp.zeros(len(data)))  # line 19: raw len() into a jit
