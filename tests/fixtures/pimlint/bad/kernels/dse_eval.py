"""PIM006 fixture: an exported kernel with no parity test reference."""


def orphan_kernel(x):                # line 4: nothing under tests/ names it
    return x


def _private_helper(x):
    return x
