"""PIM005 fixture: unseeded randomness in benchmark code."""

import random

import numpy as np


def sample(n):
    vals = [random.random() for _ in range(n)]   # line 9: global stdlib RNG
    noise = np.random.rand(n)                    # line 10: legacy np global
    rng = random.Random()                        # line 11: unseeded Random
    gen = np.random.default_rng()                # line 12: unseeded rng
    return vals, noise, rng, gen
