"""PIM004 fixture: an unbounded memo and one missing from the registry."""

from functools import lru_cache


@lru_cache(maxsize=None)             # line 6: unbounded
def slow(n):
    return n * n


class _BoundedCache:
    def __init__(self, maxsize):
        self._d = {}
        self.maxsize = maxsize

    def clear(self):
        self._d.clear()


_GOOD = _BoundedCache(16)
_ORPHAN = _BoundedCache(16)          # line 21: not in clear/stats below


def clear_mapper_caches():
    _GOOD.clear()
    slow.cache_clear()


def mapper_cache_stats():
    return {"good": len(_GOOD._d), "slow": slow.cache_info().currsize}
