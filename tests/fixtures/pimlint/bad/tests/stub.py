"""Test corpus of the bad tree: deliberately references no kernel name."""
