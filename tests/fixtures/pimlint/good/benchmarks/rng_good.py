"""Clean twin of rng_bad: every generator carries an explicit seed."""

import random

import numpy as np


def sample(n, seed):
    rng = random.Random(seed)
    gen = np.random.default_rng(seed)
    return [rng.random() for _ in range(n)], gen.random(n)
