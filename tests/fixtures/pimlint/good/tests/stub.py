"""Parity corpus of the good tree: references covered_kernel by name."""
