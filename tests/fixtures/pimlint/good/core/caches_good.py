"""Clean twin of caches_bad: bounded memos, all registered."""

from functools import lru_cache


@lru_cache(maxsize=4096)
def slow(n):
    return n * n


class _BoundedCache:
    def __init__(self, maxsize):
        self._d = {}
        self.maxsize = maxsize

    def clear(self):
        self._d.clear()


_GOOD = _BoundedCache(16)
_OTHER = _BoundedCache(16)


def clear_mapper_caches():
    _GOOD.clear()
    _OTHER.clear()
    slow.cache_clear()


def mapper_cache_stats():
    return {"good": len(_GOOD._d), "other": len(_OTHER._d),
            "slow": slow.cache_info().currsize}
