"""Clean twin of overlap_bad: syncs live at the observation boundary."""


def dispatch_node_fill(engine, pairs):
    return engine.dispatch_paired(pairs)   # stays in flight for the caller


def map_phases(engine, waves):
    out = []
    for wave in waves:
        pending = engine.dispatch_paired(wave)
        yield
        rows = pending.resolve()      # sanctioned resolver: value is host
        out.append(float(rows[0]))
    return out
