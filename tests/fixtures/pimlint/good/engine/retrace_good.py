"""Clean twin of retrace_bad: dtype pins, bucketing, full registry."""

import jax
import jax.numpy as jnp


def pow2_bucket(n):
    b = 1
    while b < n:
        b *= 2
    return b


@jax.jit
def _forward(params, x):
    scale = jnp.asarray(x, dtype=jnp.float32)
    return params * scale


_kernel = jax.jit(lambda a: a.sum())

_JITTED = {"forward": _forward, "kernel": _kernel}


def dispatch(data):
    return _kernel(jnp.zeros(pow2_bucket(len(data))))
