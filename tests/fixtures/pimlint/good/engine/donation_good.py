"""Clean twin of donation_bad: the canonical same-line rebind."""

from functools import partial

import jax


@partial(jax.jit, donate_argnums=(0,))
def _step(state, grad):
    return state - grad


_JITTED = {"step": _step}


def train(state, grads):
    for g in grads:
        state = _step(state, g)
    return state
