"""Clean twin of host_sync_bad: device_get boundary + a justified suppress."""

import jax
import numpy as np


@jax.jit
def _score(x):
    return x * 2


_JITTED = {"score": _score}


def run(xs):
    ys = [_score(x) for x in xs]
    pulled = jax.device_get(ys)          # the sanctioned one-shot pull
    total = sum(float(y) for y in pulled)
    # pimlint: disable-next-line=host-sync -- per-item pull is the API here
    arr = np.asarray(_score(xs))
    return total, arr
