"""Clean twin of the kernel fixture: the export has a test reference."""


def covered_kernel(x):
    return x
