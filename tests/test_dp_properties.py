"""Property tests: Algorithm-2 DP vs brute force, layout enumeration."""

import itertools
import math

import numpy as np
from hypothesis_compat import given, settings, strategies as st

from repro.core.layout import enumerate_layouts
from repro.core.mapper import RegionTable, INF


@st.composite
def knapsack_instance(draw):
    n_layers = draw(st.integers(1, 4))
    layers = []
    for i in range(n_layers):
        n_cands = draw(st.integers(1, 3))
        cands = []
        for c in range(n_cands):
            perf = draw(st.floats(0.1, 10.0))
            size = draw(st.integers(0, 6)) * 1000.0
            cands.append((c, perf, size, None))  # (wr, perf, size, lm)
        # mimic mapper convention: sorted by size desc
        cands.sort(key=lambda t: -t[2])
        layers.append((f"l{i}", tuple(cands)))
    units = draw(st.integers(4, 12))
    return layers, units


@given(knapsack_instance())
@settings(max_examples=40)
def test_region_knapsack_matches_bruteforce(inst):
    layers, units = inst
    unit_bytes = 1000.0
    tab = RegionTable(layers, units, unit_bytes)

    # brute force: every combination of candidate choices
    best = INF
    spaces = [range(len(cands)) for _, cands in layers]
    for combo in itertools.product(*spaces):
        perf = 0.0
        size_units = 0
        for (name, cands), ci in zip(layers, combo):
            perf += cands[ci][1]
            size_units += math.ceil(cands[ci][2] / unit_bytes)
        if size_units <= units:
            best = min(best, perf)
    if best == INF:
        assert not np.isfinite(tab.perf[units])
        return
    assert tab.perf[units] <= best + 1e-9
    assert tab.perf[units] >= best - 1e-9
    # backtrack must reproduce the DP value and respect capacity
    picks = tab.backtrack(units)
    perf = sum(cands[picks[name]][1] for name, cands in layers)
    size = sum(math.ceil(cands[picks[name]][2] / unit_bytes)
               for name, cands in layers)
    assert perf <= best + 1e-9
    assert size <= units


@given(knapsack_instance())
@settings(max_examples=20)
def test_region_knapsack_monotone(inst):
    layers, units = inst
    tab = RegionTable(layers, units, 1000.0)
    p = tab.perf
    assert all(p[i + 1] <= p[i] + 1e-12 for i in range(units))


@given(st.integers(1, 512))
def test_enumerate_layouts_groups(c):
    outs = enumerate_layouts(c, max_group=32)
    assert outs[0].order == "BHWC"
    groups = [dl.group for dl in outs if dl.order == "BCHW"]
    assert groups[0] == 1
    assert all(g <= min(c, 32) for g in groups)
    assert all(b == 2 * a for a, b in zip(groups, groups[1:]))
