"""IR: layer math, cut points, segment/branch extraction."""

import pytest

from repro.core.ir import DnnGraph, Layer, conv, matmul
from repro.core.workloads import (bert_base, darknet53, googlenet,
                                  resnet152, vgg16)


def toy_inception():
    g = DnnGraph("toy")
    g.add(conv("stem", 1, 3, 32, 32, 16))
    g.add(conv("b1a", 1, 16, 32, 32, 8, HK=1), ["stem"])
    g.add(conv("b1b", 1, 8, 32, 32, 8), ["b1a"])
    g.add(conv("b2a", 1, 16, 32, 32, 8, HK=1), ["stem"])
    g.add(conv("b2b", 1, 8, 32, 32, 8, HK=5), ["b2a"])
    g.add(Layer("cat", "concat", B=1, C=16, H=32, W=32, K=16),
          ["b1b", "b2b"])
    g.add(conv("tail", 1, 16, 32, 32, 32), ["cat"])
    return g


def test_conv_dims():
    l = conv("c", 2, 16, 56, 56, 32, HK=3, stride=2, pad=1)
    assert (l.P, l.Q) == (28, 28)
    assert l.macs == 2 * 32 * 16 * 28 * 28 * 9
    assert l.weight_count == 32 * 16 * 9


def test_matmul_as_conv():
    l = matmul("m", 4, 128, 256)
    assert (l.P, l.Q, l.HK, l.WK) == (1, 1, 1, 1)
    assert l.macs == 4 * 128 * 256


def test_cut_points_and_segments():
    g = toy_inception()
    assert g.cut_points() == ["stem", "cat", "tail"]
    segs = g.segments()
    assert len(segs) == 3
    assert segs[1].n_branches == 2
    names = sorted(tuple(b.layers) for b in segs[1].branches)
    assert ["b1a", "b1b", "cat"] in [list(n) for n in names]


def test_resnet_shortcut_branches():
    g = resnet152(1, scale=4)
    segs = g.segments()
    # bottleneck blocks have at most 2 branches (chain + conv shortcut)
    assert max(s.n_branches for s in segs) == 2


def test_cycle_detection():
    g = DnnGraph("bad")
    g.add(conv("a", 1, 3, 8, 8, 8))
    g.add(conv("b", 1, 8, 8, 8, 8), ["a"])
    g._preds["a"].append("b")  # force a cycle
    g._succs["b"].append("a")
    with pytest.raises(ValueError):
        g.topo_order()


@pytest.mark.parametrize("builder,gmacs", [
    (vgg16, 15.47), (googlenet, 1.58), (resnet152, 11.28),
    (darknet53, 9.29), (bert_base, 11.17)])
def test_workload_mac_counts(builder, gmacs):
    g = builder(1)
    assert abs(g.total_macs / 1e9 - gmacs) / gmacs < 0.05


def test_bert_heads_are_branches():
    g = bert_base(1, n_layers=1)
    segs = g.segments()
    multi = max(s.n_branches for s in segs)
    assert multi >= 12  # 12 heads become parallel branches
