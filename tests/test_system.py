"""End-to-end behaviour tests for the whole system (paper Fig. 7 loop)."""

import numpy as np
import pytest

from repro.core.dse import WorkloadEvaluator, run_dse
from repro.core.surrogates import make_strategy
from repro.core.tuner import PimTuner
from repro.core.workloads import googlenet


@pytest.fixture(scope="module")
def evaluator():
    return WorkloadEvaluator(
        [googlenet(1, scale=8)],
        mapper_kwargs=dict(max_optim_iter=1, lm_cap=40, n_wr=3))


def test_dse_loop_runs_and_records(evaluator):
    tuner = PimTuner(n_sample=256, seed=0)
    res = run_dse(tuner, evaluator, iterations=4)
    evals = [o for o in res.observations if o.cost is not None]
    assert len(evals) >= 3
    best = res.best()
    assert best.area_mm2 <= 48.0
    assert best.cost > 0
    q = res.quality_curve()
    assert len(q) >= 3 and q[-1] >= q[0]  # best-3 quality is monotone


def test_dse_strategies_share_interface(evaluator):
    for name in ("random", "simanneal", "gp", "gbt"):
        strat = make_strategy(name, seed=1, n_sample=128)
        res = run_dse(strat, evaluator, iterations=2)
        assert any(o.cost is not None for o in res.observations), name


def test_evaluator_cache(evaluator):
    from repro.core.hardware import PAPER_4X4
    c1, _, _ = evaluator(PAPER_4X4)
    c2, _, _ = evaluator(PAPER_4X4)
    assert c1 == c2
    # the cache key folds the constraints in: same variable tuple under a
    # different PimConstraints must not alias this entry
    assert (PAPER_4X4.as_tuple(), PAPER_4X4.cons) in evaluator._cache
