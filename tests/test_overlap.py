"""Overlapped wave executor: async dispatch parity + in-flight hygiene.

Pins the PR 10 contracts:

* ``dispatch_paired_latency(...).latency_row()`` is bitwise identical to
  ``batch_part_cost_paired(...).latency_s[0]`` — the device-side
  cycles→seconds division reproduces the serial numpy division exactly;
* pendings survive a ``jax.transfer_guard("disallow")`` window while in
  flight (no hidden device->host pull before resolve) and resolve
  out of order without perturbing each other;
* ``serial_dispatch()`` restores sync-at-dispatch semantics;
* ``OverlapExecutor`` interleaves strictly FIFO and ``drive`` returns
  the generator's return value;
* ``map_many`` (which drives ``map_many_phases``) and
  ``evaluate_batch(overlap=True)`` / ``run_dse`` match their serial
  twins bitwise — Mappings, observation streams, and Pareto fronts.
"""

import math

import jax
import numpy as np
import pytest

from repro.core.dse import WorkloadEvaluator, run_dse
from repro.core.hardware import (PAPER_4X4, PAPER_16X16, PAPER_BEST,
                                 PimConstraints)
from repro.core.layout import DataLayout
from repro.core.mapper import PimMapper, clear_mapper_caches
from repro.core.tuner import PimTuner
from repro.core.workloads import googlenet
from repro.engine.batch_cost import PartSpec, batch_part_cost_paired
from repro.engine.overlap import (OverlapExecutor, dispatch_paired_latency,
                                  serial_dispatch)
from repro.engine.pareto import ParetoFront

MAPPER_KW = dict(max_optim_iter=1, lm_cap=20, n_wr=2)
CFGS = [PAPER_4X4, PAPER_BEST, PAPER_16X16]
TINY_CONS = PimConstraints(cap_bank_bytes=2048)   # capacity-infeasible


@pytest.fixture(scope="module")
def tiny_net():
    return googlenet(1, scale=8)


def _paired_inputs(net, n=9):
    layers = [l for l in net.layers if l.is_heavy][:n]
    specs = [PartSpec(l, DataLayout("BCHW", 4), DataLayout("BHWC"))
             for l in layers]
    cfgs = [CFGS[i % 3] for i in range(len(specs))]
    return cfgs, specs


# ---------------------------------------------------------------------------
# dispatch half: bitwise parity with the serial paired sweep
# ---------------------------------------------------------------------------


def test_dispatch_paired_latency_bitwise_matches_serial(tiny_net):
    cfgs, specs = _paired_inputs(tiny_net)
    ref = batch_part_cost_paired(cfgs, specs, spec_chunk=4).latency_s[0]
    pending = dispatch_paired_latency(cfgs, specs, spec_chunk=4)
    assert not pending.resolved
    got = pending.latency_row()
    assert got.dtype == np.float64
    assert got.shape == (len(specs),)
    np.testing.assert_array_equal(got, ref)   # bitwise


def test_pending_resolves_once_and_caches(tiny_net):
    cfgs, specs = _paired_inputs(tiny_net, n=4)
    pending = dispatch_paired_latency(cfgs, specs, spec_chunk=4)
    first = pending.latency_row()
    assert pending.resolved
    assert pending.latency_row() is first     # cached, no second pull


def test_serial_dispatch_resolves_at_dispatch_site(tiny_net):
    cfgs, specs = _paired_inputs(tiny_net, n=4)
    with serial_dispatch():
        pending = dispatch_paired_latency(cfgs, specs, spec_chunk=4)
        assert pending.resolved
    ref = batch_part_cost_paired(cfgs, specs, spec_chunk=4).latency_s[0]
    np.testing.assert_array_equal(pending.latency_row(), ref)


def test_pending_survives_transfer_guard_window(tiny_net):
    """In-flight pendings need no device->host traffic until resolve."""
    cfgs, specs = _paired_inputs(tiny_net)
    dispatch_paired_latency(cfgs, specs, spec_chunk=4).latency_row()  # warm
    pending = dispatch_paired_latency(cfgs, specs, spec_chunk=4)
    with jax.transfer_guard("disallow"):
        # host-side wave work happens here; the pending must stay silent
        acc = sum(range(1000))
        assert not pending.resolved
    assert acc == 499500
    ref = batch_part_cost_paired(cfgs, specs, spec_chunk=4).latency_s[0]
    np.testing.assert_array_equal(pending.latency_row(), ref)


def test_out_of_order_resolve(tiny_net):
    cfgs, specs = _paired_inputs(tiny_net)
    a_cfgs, a_specs = cfgs[:5], specs[:5]
    b_cfgs, b_specs = cfgs[5:], specs[5:]
    pa = dispatch_paired_latency(a_cfgs, a_specs, spec_chunk=4)
    pb = dispatch_paired_latency(b_cfgs, b_specs, spec_chunk=4)
    got_b = pb.latency_row()                  # resolve B before A
    got_a = pa.latency_row()
    ref_a = batch_part_cost_paired(a_cfgs, a_specs, spec_chunk=4).latency_s[0]
    ref_b = batch_part_cost_paired(b_cfgs, b_specs, spec_chunk=4).latency_s[0]
    np.testing.assert_array_equal(got_a, ref_a)
    np.testing.assert_array_equal(got_b, ref_b)


# ---------------------------------------------------------------------------
# executor semantics
# ---------------------------------------------------------------------------


def test_executor_fifo_interleave_and_return_value():
    log = []

    def phase(tag, steps):
        for i in range(steps):
            log.append((tag, i))
            yield
        return f"{tag}-done"

    ex = OverlapExecutor(enabled=True)
    ex.defer(phase("d1", 2))
    ex.defer(phase("d2", 2))
    assert ex.drive(phase("drv", 3)) == "drv-done"
    # each drive yield advanced the OLDEST deferred generator by one step;
    # d1 exhausts before d2 starts (strict FIFO)
    assert log == [("drv", 0), ("d1", 0), ("drv", 1), ("d1", 1),
                   ("drv", 2)]
    ex.drain()
    assert log == [("drv", 0), ("d1", 0), ("drv", 1), ("d1", 1),
                   ("drv", 2), ("d2", 0), ("d2", 1)]
    assert not ex.step()                      # queue empty


def test_executor_disabled_runs_defer_inline():
    log = []

    def phase(tag):
        log.append(tag)
        yield
        log.append(tag + "-end")

    ex = OverlapExecutor(enabled=False)
    ex.defer(phase("a"))
    assert log == ["a", "a-end"]              # exhausted inline
    assert ex.drive(iter(())) is None
    ex.drain()                                # no-op


# ---------------------------------------------------------------------------
# mapper + evaluator + DSE parity, overlapped vs serial
# ---------------------------------------------------------------------------


def test_map_many_phases_driven_matches_map_many(tiny_net):
    kw = dict(MAPPER_KW, backend="batched")
    clear_mapper_caches()
    mapper = PimMapper(CFGS[0], **kw)
    driven = OverlapExecutor(enabled=True).drive(
        mapper.map_many_phases(tiny_net, CFGS))
    clear_mapper_caches()
    ref = PimMapper(CFGS[0], **kw).map_many(tiny_net, CFGS)
    for a, b in zip(driven, ref):
        assert a.sm == b.sm
        assert set(a.choices) == set(b.choices)
        for name, ca in a.choices.items():
            cb = b.choices[name]
            assert (ca.lm, ca.wr, ca.region) == (cb.lm, cb.wr, cb.region)
            assert ca.perf_s == cb.perf_s, name       # bitwise
        assert a.est_latency_s == b.est_latency_s


def _batch(overlap: bool, cfgs, nets):
    clear_mapper_caches()
    import repro.core.mapper as mapper_mod
    mapper_mod._sharing_latency.cache_clear()
    ev = WorkloadEvaluator(nets, mapper_kwargs=MAPPER_KW, overlap=overlap)
    return ev.evaluate_batch(cfgs)


def test_evaluate_batch_overlap_matches_serial(tiny_net):
    nets = [tiny_net, googlenet(2, scale=8)]
    cfgs = CFGS + [PAPER_4X4.replace(cons=TINY_CONS)]   # mixed feasibility
    fast = _batch(True, cfgs, nets)
    slow = _batch(False, cfgs, nets)
    assert len(fast) == len(slow) == len(cfgs)
    for a, b in zip(fast, slow):
        assert a == b                         # bitwise (cost, lats, ens)
    assert math.isinf(fast[-1][0])            # infeasible contained


def _dse_stream(overlap: bool, pipeline: bool = True):
    clear_mapper_caches()
    import repro.core.mapper as mapper_mod
    mapper_mod._sharing_latency.cache_clear()
    ev = WorkloadEvaluator([googlenet(1, scale=8)], mapper_kwargs=MAPPER_KW,
                           overlap=overlap)
    front = ParetoFront()
    res = run_dse(PimTuner(seed=5, n_sample=128, backend="scan"), ev,
                  iterations=3, propose_k=6, pipeline=pipeline, pareto=front)
    stream = [(o.iteration, o.cfg.as_tuple(), o.area_mm2, o.legal, o.cost)
              for o in res.observations]
    pts = sorted((p.latency_s, p.energy_pj, p.area_mm2)
                 for p in front.points)
    return stream, pts


def test_run_dse_overlap_matches_serial_stream_and_pareto():
    fast_stream, fast_front = _dse_stream(overlap=True)
    slow_stream, slow_front = _dse_stream(overlap=False)
    assert fast_stream == slow_stream
    assert fast_front == slow_front
    assert any(cost is not None for *_, cost in fast_stream)
