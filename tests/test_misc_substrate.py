"""Data pipeline, serving engine, tuner models, roofline parser, shardings."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import ByteCorpus, DataConfig, Prefetcher, SyntheticLM
from repro.launch.roofline import collective_bytes, model_flops_for
from repro.nn import transformer as T
from repro.serving.engine import Engine, Request

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ data

def test_synthetic_stateless_deterministic():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4, seed=9)
    d = SyntheticLM(cfg)
    a, b = d.batch(5), d.batch(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(d.batch(5)["tokens"], d.batch(6)["tokens"])


def test_host_shards_differ():
    kw = dict(vocab=1000, seq_len=16, global_batch=8, seed=9, host_count=2)
    d0 = SyntheticLM(DataConfig(host_index=0, **kw))
    d1 = SyntheticLM(DataConfig(host_index=1, **kw))
    assert d0.cfg.host_batch == 4
    assert not np.array_equal(d0.batch(0)["tokens"], d1.batch(0)["tokens"])


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a tiny corpus for byte-level lm " * 20)
    d = ByteCorpus(str(p), DataConfig(vocab=512, seq_len=32, global_batch=2))
    b = d.batch(0)
    assert b["tokens"].shape == (2, 32)
    assert b["tokens"].max() <= 256


def test_prefetcher():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(SyntheticLM(cfg), depth=2)
    b0 = pf.next()
    b1 = pf.next()
    pf.close()
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ------------------------------------------------------------------ serving

def test_engine_greedy_matches_forward():
    cfg = dataclasses.replace(get_config("qwen2_0_5b").reduced(),
                              dtype="float32")
    params = T.init_params(cfg, KEY)
    eng = Engine(cfg, params, slots=2, max_len=64)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8], [9, 10, 11, 12, 13, 14, 15, 16]]
    reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
    eng.serve_batch(reqs)
    # reference: greedy continuation via repeated full forward
    toks = jnp.asarray(prompts)
    for t in range(4):
        logits, _ = T.forward(cfg, params, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], -1)
        toks = jnp.concatenate([toks, nxt[:, None]], 1)
    want = np.asarray(toks[:, 8:])
    got = np.array([r.out_tokens for r in reqs])
    assert np.array_equal(got, want), (got, want)


# ------------------------------------------------------------------ roofline

HLO_SAMPLE = """
  %ar = f32[1024,16]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = bf16[64,256]{1,0} all-gather(%p0), channel_id=2, replica_groups=[2,4]<=[8], dimensions={1}
  %rs = f32[8,8]{1,0} reduce-scatter(%p1), channel_id=3, replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  %cp = u32[128]{0} collective-permute(%p2), source_target_pairs={{0,1},{1,0}}
  %aa = s8[16,16]{1,0} all-to-all(%p3), replica_groups=[1,8]<=[8]
"""


def test_collective_bytes_parser():
    out = collective_bytes(HLO_SAMPLE)
    # all-reduce: 2*(g-1)/g*out, g=2 -> 1.0 * 1024*16*4
    assert out["all-reduce"] == pytest.approx(1024 * 16 * 4 * 1.0)
    # all-gather: (g-1)/g*out, g=4 -> 0.75 * 64*256*2
    assert out["all-gather"] == pytest.approx(64 * 256 * 2 * 0.75)
    # reduce-scatter: (g-1)*out, g=4 -> 3 * 8*8*4
    assert out["reduce-scatter"] == pytest.approx(8 * 8 * 4 * 3)
    assert out["collective-permute"] == pytest.approx(128 * 4)
    assert out["all-to-all"] == pytest.approx(16 * 16 * 7 / 8)
    assert out["total"] == pytest.approx(sum(
        v for k, v in out.items() if k != "total"))


def test_model_flops_moe_uses_active():
    from repro.configs.base import SHAPES
    dense = get_config("mistral_nemo_12b")
    moe = get_config("llama4_maverick_400b_a17b")
    sh = SHAPES["train_4k"]
    f_moe = model_flops_for(moe, sh, kind="train")
    assert f_moe == pytest.approx(6.0 * moe.active_param_count
                                  * sh.global_batch * sh.seq_len)
    assert f_moe < 6.0 * moe.param_count * sh.global_batch * sh.seq_len


# ------------------------------------------------------------------ tuner

def test_filter_model_learns_area():
    from repro.core.tuner import FilterModel, sample_configs
    rng = np.random.default_rng(0)
    cfgs = sample_configs(150, rng)
    fm = FilterModel()
    for c in cfgs[:120]:
        fm.add(c, c.area_mm2())
    fm.fit(200)
    pred = fm.predict_area(cfgs[120:])
    true = np.array([c.area_mm2() for c in cfgs[120:]])
    acc = np.mean((pred <= 48.0) == (true <= 48.0))
    assert acc >= 0.7


def test_dkl_ranks_synthetic_cost():
    from repro.core.tuner import DklSuggestionModel, sample_configs
    rng = np.random.default_rng(1)
    cfgs = sample_configs(120, rng)

    def cost(c):
        t = c.as_tuple()
        return abs(np.log2(t[2] * t[3]) - 10) + 0.2 * np.log2(t[4] + t[5])

    m = DklSuggestionModel()
    for c in cfgs[:90]:
        m.add(c, cost(c))
    m.fit(250)
    scores = m.rank(cfgs[90:])
    true = np.array([cost(c) for c in cfgs[90:]])
    corr = np.corrcoef(scores, np.log(true))[0, 1]
    assert corr > 0.3
