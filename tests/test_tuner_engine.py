"""Tuner engine contracts (repro/engine/tuner_train.py).

Pins the four parity surfaces of the jitted tuner engine:

* scan-vs-loop fit parity — the whole-trajectory ``lax.scan`` fits follow
  the per-step host-dispatch reference losses step-for-step (filter MSE and
  DKL NLML);
* pow2-padding invariance — the masked NLML and the masked GP predictions
  equal the unpadded exact values, independent of how much padding the
  bucket adds;
* Pallas-vs-numpy LCB kernel parity (``kernels.dse_eval.lcb_rows``);
* end-to-end ``PimTuner.propose`` determinism: per-backend reproducibility
  and scan-vs-loop agreement under a shared seed, plus the shared-seed
  bitwise parity of the vectorized candidate sampling.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.hardware import (legal_shape_mask, normalize_params,
                                 normalize_params_batch, sample_config_values,
                                 sample_configs_batch)
from repro.core.tuner import (DKL_SIZES, DklSuggestionModel, FilterModel,
                              PimTuner, _DKL_OPT, _FILTER_OPT, _dkl_init,
                              _dkl_predict, _dkl_step, _filter_step,
                              _init_mlp, FILTER_SIZES, _nlml, sample_configs)
from repro.engine.tuner_train import (dkl_predict, fit_dkl, fit_filter,
                                      masked_mse, masked_nlml, pad_dataset,
                                      pow2_bucket, score_candidates,
                                      score_candidates_raw)
from repro.kernels.dse_eval import lcb_rows


def _cost(cfg) -> float:
    t = cfg.as_tuple()
    return float(abs(np.log2(t[2] * t[3]) - 10)
                 + 0.2 * np.log2(t[4] + t[5]))


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    cfgs = sample_configs(40, rng)
    x = np.array([normalize_params(c) for c in cfgs[:18]], np.float32)
    y = np.array([np.log(_cost(c)) for c in cfgs[:18]])
    yn = ((y - y.mean()) / (y.std() + 1e-9)).astype(np.float32)
    xq = np.array([normalize_params(c) for c in cfgs[18:]], np.float32)
    return cfgs, x, yn, xq


# ---------------------------------------------------------------------- sampling


def test_sample_configs_batch_shared_seed_parity():
    a = sample_configs(64, np.random.default_rng(11))
    b = sample_configs_batch(64, np.random.default_rng(11))
    assert [c.as_tuple() for c in a] == [c.as_tuple() for c in b]
    # the value matrix is shape-legal by construction
    vals = sample_config_values(64, np.random.default_rng(11))
    assert legal_shape_mask(vals).all()
    assert [tuple(map(int, r)) for r in vals] == [c.as_tuple() for c in a]


def test_normalize_params_batch_matches_scalar():
    vals = sample_config_values(16, np.random.default_rng(2))
    batch = normalize_params_batch(vals)
    cfgs = sample_configs_batch(16, np.random.default_rng(2))
    scalar = np.array([normalize_params(c) for c in cfgs], np.float32)
    np.testing.assert_array_equal(batch, scalar)


def test_sample_draw_cap_raises():
    with pytest.raises(RuntimeError, match="draw cap"):
        sample_configs(4, np.random.default_rng(0), max_draws=0)
    with pytest.raises(RuntimeError, match="draw cap"):
        sample_config_values(4, np.random.default_rng(0), max_draws=0)


# ------------------------------------------------------------- scan/loop parity


def test_filter_scan_matches_loop_trajectory(dataset):
    _, x, yn, _ = dataset
    params = _init_mlp(__import__("jax").random.PRNGKey(0), FILTER_SIZES)
    opt_state = _FILTER_OPT.init(params)
    p, s = params, opt_state
    loop_losses = []
    xj, yj = jnp.asarray(x), jnp.asarray(yn)
    for _ in range(60):
        p, s, l = _filter_step(p, s, xj, yj)
        loop_losses.append(float(l))
    xp, yp, mask = pad_dataset(x, yn)
    p2, _, scan_losses = fit_filter(params, opt_state, xp, yp, mask,
                                    opt=_FILTER_OPT, steps=60)
    np.testing.assert_allclose(np.asarray(scan_losses), loop_losses,
                               rtol=1e-3, atol=1e-5)
    # the trained parameters agree too, not just the loss curve
    for la, lb in zip(p, p2):
        np.testing.assert_allclose(np.asarray(la["w"]), np.asarray(lb["w"]),
                                   atol=2e-4)


def test_dkl_scan_matches_loop_trajectory(dataset):
    _, x, yn, _ = dataset
    params = _dkl_init(0)
    opt_state = _DKL_OPT.init(params)
    p, s = params, opt_state
    loop_losses = []
    xj, yj = jnp.asarray(x), jnp.asarray(yn)
    for _ in range(60):
        p, s, l = _dkl_step(p, s, xj, yj)
        loop_losses.append(float(l))
    xp, yp, mask = pad_dataset(x, yn)
    _, _, scan_losses = fit_dkl(params, opt_state, xp, yp, mask,
                                opt=_DKL_OPT, steps=60)
    np.testing.assert_allclose(np.asarray(scan_losses), loop_losses,
                               rtol=5e-3, atol=2e-3)


# ----------------------------------------------------------- padding invariance


def _pad_to(x, y, p):
    xp = np.zeros((p, x.shape[1]), np.float32)
    yp = np.zeros((p,), np.float32)
    mask = np.zeros((p,), bool)
    n = len(y)
    xp[:n], yp[:n], mask[:n] = x, y, True
    return xp, yp, mask


def test_masked_losses_match_unpadded_exact(dataset):
    _, x, yn, _ = dataset
    params = _dkl_init(0)
    exact = float(_nlml(params, jnp.asarray(x), jnp.asarray(yn)))
    for p in (pow2_bucket(len(yn)), 64):
        xp, yp, mask = _pad_to(x, yn, p)
        got = float(masked_nlml(params, xp, yp, mask))
        assert got == pytest.approx(exact, abs=1e-4), f"pad={p}"
    from repro.core.tuner import _filter_loss
    mlp = _init_mlp(__import__("jax").random.PRNGKey(0), FILTER_SIZES)
    exact = float(_filter_loss(mlp, jnp.asarray(x), jnp.asarray(yn)))
    for p in (pow2_bucket(len(yn)), 64):
        xp, yp, mask = _pad_to(x, yn, p)
        assert float(masked_mse(mlp, xp, yp, mask)) \
            == pytest.approx(exact, rel=1e-5), f"pad={p}"


def test_masked_predictions_match_unpadded_exact(dataset):
    _, x, yn, xq = dataset
    params = _dkl_init(1)
    m_ref, v_ref = _dkl_predict(params, jnp.asarray(x), jnp.asarray(yn),
                                jnp.asarray(xq))
    for p in (pow2_bucket(len(yn)), 64):
        xp, yp, mask = _pad_to(x, yn, p)
        mean, var = dkl_predict(params, xp, yp, mask, jnp.asarray(xq))
        np.testing.assert_allclose(np.asarray(mean), np.asarray(m_ref),
                                   atol=1e-4, err_msg=f"pad={p}")
        np.testing.assert_allclose(np.asarray(var), np.asarray(v_ref),
                                   atol=5e-3, err_msg=f"pad={p}")
    # padding amount itself is invisible: 16-pad vs 64-pad agree tightly
    m16, v16 = dkl_predict(params, *_pad_to(x, yn, 32), jnp.asarray(xq))
    m64, v64 = dkl_predict(params, *_pad_to(x, yn, 64), jnp.asarray(xq))
    np.testing.assert_allclose(np.asarray(m16), np.asarray(m64), atol=5e-5)
    np.testing.assert_allclose(np.asarray(v16), np.asarray(v64), atol=5e-5)


# --------------------------------------------------------------- Pallas kernel


def test_lcb_rows_matches_numpy():
    rng = np.random.default_rng(5)
    q, n, d = 37, 24, 6
    zq = rng.normal(size=(q, d)).astype(np.float32)
    zt = rng.normal(size=(n, d)).astype(np.float32)
    alpha = rng.normal(size=(n,)).astype(np.float32)
    a = rng.normal(size=(n, n)).astype(np.float32)
    kinv = (a @ a.T / n + np.eye(n)).astype(np.float32)
    valid = np.ones(n, bool)
    valid[-5:] = False
    ls2, sf2, beta = 0.7, 1.3, 1.0

    d2 = ((zq[:, None, :] - zt[None, :, :]) ** 2).sum(-1)
    kq = sf2 * np.exp(-0.5 * d2 / ls2) * valid[None, :]
    mean = kq @ alpha
    var = sf2 - np.einsum("qi,ij,qj->q", kq, kinv, kq)
    ref = mean - beta * np.sqrt(np.clip(var, 1e-9, None))

    got = np.asarray(lcb_rows(zq, zt, alpha, kinv, valid, ls2, sf2, beta,
                              interpret=True, block_q=16))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_score_candidates_pallas_matches_jnp(dataset):
    _, x, yn, xq = dataset
    params = _dkl_init(0)
    xp, yp, mask = pad_dataset(x, yn)
    ok = np.ones(len(xq), bool)
    ok[::3] = False
    a = np.asarray(score_candidates(params, xp, yp, mask, jnp.asarray(xq),
                                    ok, 1.0, use_pallas=False))
    b = np.asarray(score_candidates(params, xp, yp, mask, jnp.asarray(xq),
                                    ok, 1.0, use_pallas=True))
    assert np.isinf(a[::3]).all() and np.isinf(b[::3]).all()
    # the jnp path computes distances via the gram trick, the fused kernel
    # via the in-VMEM broadcast difference: equal up to f32 reassociation
    np.testing.assert_allclose(a[ok], b[ok], rtol=5e-4, atol=5e-4)


# -------------------------------------------------------------- GP ablation


def test_gp_surrogate_engine_matches_numpy_reference():
    from repro.core.surrogates import GPSurrogate
    rng = np.random.default_rng(4)
    cfgs = sample_configs_batch(40, rng)
    gp_a = GPSurrogate(seed=7, n_sample=128, backend="engine")
    gp_b = GPSurrogate(seed=7, n_sample=128, backend="numpy")
    for c in cfgs[:25]:
        gp_a.observe(c, c.area_mm2(), _cost(c))
        gp_b.observe(c, c.area_mm2(), _cost(c))
    xq = np.array([normalize_params(c) for c in cfgs[25:]], np.float64)
    np.testing.assert_allclose(gp_a._rank_engine(xq), gp_b._rank(xq),
                               rtol=1e-8, atol=1e-8)
    pa = [c.as_tuple() for c in gp_a.propose(6)]
    pb = [c.as_tuple() for c in gp_b.propose(6)]
    assert pa == pb


# ----------------------------------------------------------- propose end-to-end


def _tuner_with_history(backend: str, fit_steps: int = 30,
                        seed: int = 3) -> PimTuner:
    cfgs = sample_configs(30, np.random.default_rng(9))
    t = PimTuner(seed=seed, n_sample=256, backend=backend)
    for c in cfgs:
        t.observe(c, c.area_mm2(), _cost(c))
    t.filter_model.fit(fit_steps)
    t.suggestion.fit(fit_steps)
    return t


def test_propose_deterministic_per_backend():
    for backend in ("scan", "loop"):
        a = _tuner_with_history(backend).propose(8)
        b = _tuner_with_history(backend).propose(8)
        assert [c.as_tuple() for c in a] == [c.as_tuple() for c in b], backend


def test_propose_scan_matches_loop_backend():
    # short fits keep float drift below the ranking's resolution, so the
    # fused in-array propose must pick the exact same configs as the
    # original list-based path under a shared seed
    a = _tuner_with_history("scan").propose(8)
    b = _tuner_with_history("loop").propose(8)
    assert [c.as_tuple() for c in a] == [c.as_tuple() for c in b]


def test_untrained_propose_matches_across_backends():
    a = PimTuner(seed=5, n_sample=128, backend="scan").propose(6)
    b = PimTuner(seed=5, n_sample=128, backend="loop").propose(6)
    assert [c.as_tuple() for c in a] == [c.as_tuple() for c in b]


def test_dkl_rank_refits_when_stale():
    m = DklSuggestionModel(seed=0)
    cfgs = sample_configs(12, np.random.default_rng(6))
    for c in cfgs[:6]:
        m.add(c, _cost(c))
    m.fit(30)
    mu_before = m._mu
    assert not m._dirty
    # observations added after fit() invalidate the standardization;
    # rank() must refit (not score against the stale _mu/_sigma)
    for c in cfgs[6:]:
        m.add(c, 1e6 * _cost(c))
    assert m._dirty
    xq = np.array([normalize_params(c) for c in cfgs[:4]], np.float32)
    m.rank_x(xq)
    assert not m._dirty
    assert m._mu != mu_before


def test_dse_curve_scan_vs_loop_same_seed():
    """Fig. 9-style same-seed quality curves stay within tolerance."""
    from repro.core.dse import WorkloadEvaluator, run_dse
    from repro.core.workloads import googlenet
    ev = WorkloadEvaluator([googlenet(1, scale=8)],
                           mapper_kwargs=dict(max_optim_iter=1, lm_cap=40,
                                              n_wr=3))
    curves = {}
    for backend in ("scan", "loop"):
        strat = PimTuner(seed=0, n_sample=128, backend=backend)
        res = run_dse(strat, ev, iterations=3)
        curves[backend] = res.quality_curve()
    assert len(curves["scan"]) == len(curves["loop"])
    assert curves["scan"][-1] == pytest.approx(curves["loop"][-1], rel=0.5)
