"""PIM-Mapper: LM enumeration, DP selection, end-to-end vs baseline."""

import math

import pytest
from hypothesis_compat import given, strategies as st

from repro.core.hardware import PAPER_4X4, PAPER_16X16
from repro.core.ir import DnnGraph, Layer, conv, matmul
from repro.core.mapper import PimMapper, evaluate_mapping
from repro.core.baseline import BaselineMapper, DdamMapper
from repro.core.partition import (LM, enumerate_lms, factor_splits,
                                  part_layer, wr_candidates, comm_estimate)
from repro.core.regions import gen_sm_candidates


def toy_net():
    g = DnnGraph("toy")
    g.add(conv("stem", 1, 3, 64, 64, 32, stride=2))
    g.add(conv("c1", 1, 32, 32, 32, 64), ["stem"])
    g.add(conv("b1a", 1, 64, 32, 32, 32, HK=1), ["c1"])
    g.add(conv("b1b", 1, 32, 32, 32, 64), ["b1a"])
    g.add(conv("b2a", 1, 64, 32, 32, 32, HK=1), ["c1"])
    g.add(conv("b2b", 1, 32, 32, 32, 64, HK=5), ["b2a"])
    g.add(Layer("cat", "concat", B=1, C=128, H=32, W=32, K=128),
          ["b1b", "b2b"])
    g.add(conv("c2", 1, 128, 32, 32, 128, stride=2), ["cat"])
    g.add(matmul("fc", 1, 128 * 16 * 16, 100), ["c2"])
    return g


@given(st.sampled_from([1, 2, 4, 8, 16]), st.integers(1, 5))
def test_factor_splits_product(n, k):
    for t in factor_splits(n, k):
        assert len(t) == k
        assert math.prod(t) == n


def test_enumerate_lms_cover_region():
    l = conv("c", 2, 64, 32, 32, 64)
    for lm in enumerate_lms(l, 4, 4, cap=100):
        assert lm.shape == (4, 4)
        assert lm.n_nodes == 16


def test_part_layer_dims():
    l = conv("c", 4, 64, 32, 32, 64)
    lm = LM((2, 1, 1, 2, 1), (1, 1, 1, 2, 2))
    pl_ = part_layer(l, lm)
    assert pl_.B == 2 and pl_.K == 16 and pl_.C == 32
    assert pl_.H == (pl_.P - 1) * l.stride + l.HK


def test_wr_capacity_tradeoff():
    """Lower WR stores less but communicates more."""
    l = conv("c", 1, 128, 16, 16, 128)
    lm = LM((1, 2, 1, 1, 1), (1, 1, 2, 1, 1))  # weight share group of 4
    hw = PAPER_4X4
    ests = [comm_estimate(l, lm, wr, hw) for wr in wr_candidates(l, lm)]
    sizes = [e.weight_bytes_per_node for e in ests]
    lats = [e.latency_s for e in ests]
    assert sizes == sorted(sizes, reverse=True)   # wr desc -> size desc
    assert lats == sorted(lats)                   # ... and latency asc


def test_sm_candidates_rectangles():
    g = toy_net()
    seg = [s for s in g.segments() if s.n_branches == 2][0]
    for sm in gen_sm_candidates(g, seg, 4, 4):
        covered = set()
        for r in sm.regions:
            cells = {(r.h_pos + i, r.w_pos + j)
                     for i in range(r.h_shape) for j in range(r.w_shape)}
            assert not (covered & cells), "regions overlap"
            covered |= cells
        assert max(sm.ir) == sm.n_reg - 1


@pytest.mark.parametrize("hw", [PAPER_4X4, PAPER_16X16])
def test_mapper_end_to_end(hw):
    g = toy_net()
    m = PimMapper(hw, max_optim_iter=2).map(g)
    heavy = [l.name for l in g.layers if l.is_heavy]
    assert set(m.choices) == set(heavy)
    # capacity respected
    cap = hw.node_dram_capacity
    total = sum(ch.size_bytes for ch in m.choices.values())
    assert total <= cap * 1.01
    rep = evaluate_mapping(m)
    assert rep.latency_s > 0 and rep.energy_pj > 0
    assert set(rep.energy_breakdown) == {"mac", "sram", "dram", "noc"}


def test_mapper_beats_baseline_latency():
    g = toy_net()
    hw = PAPER_16X16
    rep = evaluate_mapping(PimMapper(hw, max_optim_iter=2).map(g))
    base = evaluate_mapping(BaselineMapper(hw).map(g))
    assert rep.latency_s < base.latency_s


def test_single_branch_gets_full_array():
    g = toy_net()
    m = PimMapper(PAPER_4X4, max_optim_iter=1).map(g)
    ch = m.choices["c2"]  # its own segment
    assert (ch.region.h_shape, ch.region.w_shape) == (4, 4)


def test_ddam_throughput_vs_latency():
    g = toy_net()
    hw = PAPER_4X4
    res = DdamMapper(hw).map(g)
    rep = evaluate_mapping(PimMapper(hw, max_optim_iter=1).map(g))
    # pipeline latency >= mapper latency (paper: ~10x worse latency)
    assert res.latency_s >= rep.latency_s * 0.9
    assert res.throughput_sps > 0


def test_infeasible_capacity_raises():
    g = DnnGraph("fat")
    # one layer whose weights exceed total DRAM even at WR=1
    g.add(matmul("m", 1, 1 << 17, 1 << 17))  # 16Gi weights * 2B = 32GiB
    with pytest.raises(RuntimeError):
        PimMapper(PAPER_4X4.replace(), max_optim_iter=1).map(g)
