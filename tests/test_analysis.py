"""pimlint framework tests: fixtures vs golden, suppressions, baseline, CLI."""

import json
from pathlib import Path

import pytest

from repro.analysis import load_baseline, run_lint, save_baseline
from repro.analysis.__main__ import main as lint_main
from repro.analysis.rules import ALL_RULES, rule_by_key

FIXTURES = Path(__file__).parent / "fixtures" / "pimlint"
BAD = FIXTURES / "bad"
GOOD = FIXTURES / "good"
REPO = Path(__file__).resolve().parents[1]


def _bad_result():
    return run_lint(BAD, [BAD])


# ---------------------------------------------------------------- fixtures


def test_bad_tree_matches_golden():
    got = {(f.rule, f.path, f.line) for f in _bad_result().findings}
    want = {(e["rule"], e["path"], e["line"])
            for e in json.loads((FIXTURES / "golden.json").read_text())}
    assert got == want


@pytest.mark.parametrize("rule", ALL_RULES, ids=lambda r: r.name)
def test_each_rule_flags_its_fixture(rule):
    """Every rule must demonstrably fire on the bad tree."""
    res = run_lint(BAD, [BAD], rules=[rule])
    assert res.findings, f"{rule.id} found nothing in the bad fixture tree"
    assert all(f.rule == rule.id for f in res.findings)


def test_good_tree_is_clean_with_one_suppressed_example():
    res = run_lint(GOOD, [GOOD])
    assert res.findings == []
    assert len(res.suppressed) == 1
    assert res.suppressed[0].rule == "PIM001"


def test_findings_carry_location_and_hint():
    for f in _bad_result().findings:
        assert f.path and f.line >= 1 and f.message and f.hint
        assert f.fingerprint and len(f.fingerprint) == 16
        assert f"{f.path}:{f.line}" in f.render()


# ------------------------------------------------------------ suppressions


def test_suppression_variants(tmp_path):
    eng = tmp_path / "engine"
    eng.mkdir()
    body = (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x\n"
        "_JITTED = {'f': f}\n"
        "def run():\n"
        "    a = np.asarray(f(1))  # pimlint: disable=host-sync -- ok\n"
        "    # pimlint: disable-next-line=PIM001\n"
        "    b = np.asarray(f(2))\n"
        "    c = np.asarray(f(3))\n"
        "    return a, b, c\n")
    (eng / "mod.py").write_text(body)
    res = run_lint(tmp_path, [tmp_path])
    assert len(res.suppressed) == 2      # same-line by name, next-line by id
    assert len(res.findings) == 1        # the unsuppressed third sync
    (eng / "mod.py").write_text(
        "# pimlint: disable-file=all -- fixture\n" + body)
    res = run_lint(tmp_path, [tmp_path])
    assert res.findings == [] and len(res.suppressed) == 3


# ---------------------------------------------------------------- baseline


def test_baseline_filters_known_findings(tmp_path):
    first = _bad_result()
    path = tmp_path / "baseline.json"
    save_baseline(path, first.findings)
    res = run_lint(BAD, [BAD], baseline=load_baseline(path))
    assert res.findings == []
    assert len(res.baselined) == len(first.findings)


def test_baseline_is_line_number_stable():
    """Fingerprints hash the source text, not the line number."""
    res = _bad_result()
    f = res.findings[0]
    import dataclasses
    moved = dataclasses.replace(f, line=f.line + 10)
    assert moved.fingerprint == f.fingerprint


def test_baseline_budget_does_not_leak(tmp_path):
    """One baseline entry absolves ONE finding, not every lookalike."""
    first = _bad_result()
    path = tmp_path / "baseline.json"
    save_baseline(path, first.findings[:1])
    res = run_lint(BAD, [BAD], baseline=load_baseline(path))
    assert len(res.baselined) == 1
    assert len(res.findings) == len(first.findings) - 1


def test_bad_baseline_version_rejected(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text('{"version": 99, "findings": []}')
    with pytest.raises(ValueError):
        load_baseline(path)


# --------------------------------------------------------------------- CLI


def test_cli_exit_codes(tmp_path):
    assert lint_main(["--root", str(GOOD), str(GOOD)]) == 0
    assert lint_main(["--root", str(BAD), str(BAD)]) == 1
    assert lint_main(["--rule", "nope", str(BAD)]) == 2
    assert lint_main(["--root", str(tmp_path), str(tmp_path)]) == 2


def test_cli_json_report(tmp_path):
    out = tmp_path / "report.json"
    code = lint_main(["--root", str(BAD), str(BAD), "--json", str(out)])
    assert code == 1
    report = json.loads(out.read_text())
    assert report["schema"] == "nicepim-lint/1"
    assert report["status"] == "dirty"
    assert report["new_findings"]
    assert set(report["counts"]) <= {r.id for r in ALL_RULES}


def test_cli_write_baseline_roundtrip(tmp_path):
    base = tmp_path / "pimlint.baseline.json"
    assert lint_main(["--root", str(BAD), str(BAD), "--write-baseline",
                      "--baseline", str(base)]) == 0
    assert lint_main(["--root", str(BAD), str(BAD),
                      "--baseline", str(base)]) == 0


def test_rule_lookup():
    assert rule_by_key("PIM001").name == "host-sync"
    assert rule_by_key("cache-hygiene").id == "PIM004"
    assert rule_by_key("nope") is None


# -------------------------------------------------------------- repo gate


def test_repo_lints_clean_against_committed_baseline():
    """The acceptance gate: zero NEW findings on the real tree."""
    baseline = load_baseline(REPO / "pimlint.baseline.json")
    res = run_lint(REPO, baseline=baseline)
    assert res.files_scanned > 50
    assert res.parse_errors == []
    msgs = "\n".join(f.render() for f in res.findings)
    assert res.findings == [], f"new pimlint findings:\n{msgs}"
