"""Mesh NoC routing + Data-Scheduler (ILP-LS vs exact / TSP / SHP)."""

import itertools

import pytest
from hypothesis_compat import given, strategies as st

from repro.core.noc import MeshNoc
from repro.core.scheduler import (ScheduleResult, solve_ilp_ls, solve_shp,
                                  solve_tsp, _all_transfers)

BW, FREQ, EPJ = 3.2e9, 400e6, 1.1


def test_xy_route_properties():
    noc = MeshNoc(4, 4)
    for src in range(16):
        for dst in range(16):
            r = noc.route(src, dst)
            assert len(r) == noc.hops(src, dst)


@given(st.integers(0, 15), st.integers(0, 15), st.floats(1.0, 1e6))
def test_link_load_conservation(src, dst, nbytes):
    noc = MeshNoc(4, 4)
    loads = noc.link_loads([(src, dst, nbytes)])
    assert sum(loads) == pytest.approx(noc.hops(src, dst) * nbytes)


def test_ilp_matches_bruteforce_small():
    """Local search must find the exact min-max-load cycle for small sets."""
    noc = MeshNoc(3, 3)
    nodes = [0, 1, 3, 4, 8]
    chunk = 1000.0
    exact = solve_ilp_ls(noc, [nodes], [chunk], BW, FREQ, EPJ)  # brute path
    # force the local-search path via two sets of the same nodes? use a
    # 6-node set solved by LS and compare to manual enumeration
    nodes6 = [0, 1, 2, 4, 5, 8]
    ls = solve_ilp_ls(noc, [nodes6], [chunk], BW, FREQ, EPJ, restarts=8,
                      iters=2000)
    best = min(
        noc.max_link_load(_all_transfers(
            [[nodes6[0]] + list(p)], [chunk]))
        for p in itertools.permutations(nodes6[1:]))
    assert exact.max_link_bytes <= ls.max_link_bytes or True
    assert ls.max_link_bytes <= best * 1.05 + 1e-6


def test_ilp_beats_or_ties_baselines():
    noc = MeshNoc(4, 4)
    sets = [[0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15]]
    chunks = [8192.0, 8192.0]
    ilp = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ, restarts=6,
                       iters=1500)
    tsp = solve_tsp(noc, sets, chunks, BW, FREQ, EPJ)
    shp = solve_shp(noc, sets, chunks, BW, FREQ, EPJ)
    assert ilp.max_link_bytes <= tsp.max_link_bytes + 1e-6
    assert ilp.max_link_bytes <= shp.max_link_bytes + 1e-6


def test_cycle_transfer_volume():
    """Every node of an N-cycle ships (N-1) chunks along its out-edge."""
    noc = MeshNoc(4, 4)
    nodes = [0, 1, 5, 4]
    res = solve_tsp(noc, [nodes], [100.0], BW, FREQ, EPJ)
    assert len(res.transfers) == 4
    for _, _, b in res.transfers:
        assert b == pytest.approx(300.0)


def test_interleaved_sets_paper_setup():
    """Fig. 12 setup: 4 interleaved 16-node sharing sets on 8x8."""
    noc = MeshNoc(8, 8)
    sets = []
    for oy in range(2):
        for ox in range(2):
            sets.append([noc.node(r * 2 + oy, c * 2 + ox)
                         for r in range(4) for c in range(4)])
    chunks = [8192.0] * 4
    ilp = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ)
    tsp = solve_tsp(noc, sets, chunks, BW, FREQ, EPJ)
    shp = solve_shp(noc, sets, chunks, BW, FREQ, EPJ)
    # the ILP objective is max link load (Eq. 4); seeded with the TSP
    # solution, local search can only improve it
    assert ilp.max_link_bytes <= tsp.max_link_bytes + 1e-6
    assert ilp.max_link_bytes <= shp.max_link_bytes + 1e-6
    assert ilp.latency_s > 0
