"""Optional-hypothesis shim.

``from hypothesis_compat import given, settings, strategies as st`` behaves
exactly like importing from ``hypothesis`` when it is installed.  On a bare
interpreter the stand-ins below turn every ``@given`` test into a skip with a
clear reason while leaving plain tests in the same module runnable.
"""

import pytest

try:
    from hypothesis import assume, given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Anything:
        """Absorbs any attribute/call chain (st.composite, st.integers, ...)."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    strategies = _Anything()

    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed; property test skipped")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def assume(condition):
        return True
