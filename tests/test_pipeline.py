"""Device-resident DSE pipeline: parity, transfer hygiene, donation.

Pins the PR 7 contracts:

* ``run_dse(pipeline=True)`` produces the SAME observation stream as the
  staged path — including the PR 6 exact-shape scheduler baseline
  (``scheduler_opt._PAD_SHAPES = False``), so canonical bucket padding is
  bit-invisible end to end;
* a warmed pipeline iterates under ``jax.transfer_guard("disallow")``:
  every host->device hop is an explicit ``device_put`` and the only
  implicit sync is the proposal winner read-back;
* the jitted fit entry points really consume their donated (params,
  opt_state) buffers while matching the loop-backend reference steps;
* ``schedule_many``'s canonical (pow4 / fixed-row-chunk) bucket shapes are
  bit-identical to the exact pow2 shapes, batched or solo;
* the in-array top-k selection matches the host walk it replicates
  (stable order, stop at first invalid, duplicate suppression).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import WorkloadEvaluator, run_dse
from repro.core.noc import MeshNoc
from repro.core.tuner import PimTuner
from repro.core.workloads import googlenet
from repro.engine.pipeline import DsePipeline, _select_topk
from repro.engine.scheduler_opt import schedule_many

BW, FREQ, EPJ = 64 / 8 * 400e6, 400e6, 1.1
MAPPER_KW = dict(max_optim_iter=1, lm_cap=40, n_wr=3)


# ---------------------------------------------------------------------------
# end-to-end parity: pipeline == staged == PR 6 exact-shape baseline
# ---------------------------------------------------------------------------


def _campaign(pipeline: bool, pad_shapes: bool = True):
    import repro.engine.scheduler_opt as so
    from repro.core.mapper import _sharing_latency, clear_mapper_caches

    clear_mapper_caches()
    _sharing_latency.cache_clear()
    old = so._PAD_SHAPES
    so._PAD_SHAPES = pad_shapes
    try:
        ev = WorkloadEvaluator([googlenet(1, scale=8)],
                               mapper_kwargs=MAPPER_KW)
        res = run_dse(PimTuner(seed=5, n_sample=128, backend="scan"), ev,
                      iterations=3, propose_k=6, pipeline=pipeline)
    finally:
        so._PAD_SHAPES = old
    return [(o.iteration, o.cfg.as_tuple(), o.area_mm2, o.legal, o.cost)
            for o in res.observations]


def test_run_dse_pipeline_matches_staged_and_pr6_baseline():
    fused = _campaign(pipeline=True)
    staged = _campaign(pipeline=False)
    exact = _campaign(pipeline=False, pad_shapes=False)   # PR 6 programs
    assert fused == staged
    assert fused == exact
    assert any(cost is not None for *_, cost in fused)


# ---------------------------------------------------------------------------
# transfer hygiene: a warmed pipeline performs no implicit transfers
# ---------------------------------------------------------------------------


def _pipe_loop(pipe: DsePipeline, rounds: int = 3):
    out = []
    for r in range(rounds):
        cfgs = pipe.propose(4)
        for j, c in enumerate(cfgs):
            pipe.observe(c, 25.0 + j, 100.0 + 3 * r + j)
        pipe.fit()
        out.append([c.as_tuple() for c in cfgs])
    return out


def test_pipeline_loop_transfer_guard_clean():
    # warm run compiles every program the guarded replay dispatches (the
    # identical seed replays identical data shapes)
    warm = _pipe_loop(DsePipeline(
        PimTuner(seed=11, n_sample=128, backend="scan")))
    pipe = DsePipeline(PimTuner(seed=11, n_sample=128, backend="scan"))
    with jax.transfer_guard("disallow"):
        got = _pipe_loop(pipe)
    assert got == warm
    # the guarded loop exercised the trained filter + DKL scoring path,
    # not just the untrained zeros fallback
    assert pipe.tuner.filter_model.trained()
    assert len(pipe.tuner.suggestion._y) >= 3


def test_schedule_many_transfer_guard_clean():
    noc = MeshNoc(4, 4)
    probs = [
        (noc, [[0, 1, 2, 3, 4, 5, 6, 7]], [1024.0]),
        (noc, [[0, 2, 4, 6, 8, 10], [1, 3, 5, 7]], [512.0, 256.0]),
    ]
    kw = dict(seed=2, restarts=4, iters=100, moves_per_round=16)
    warm = schedule_many(probs, BW, FREQ, EPJ, **kw)
    with jax.transfer_guard("disallow"):
        got = schedule_many(probs, BW, FREQ, EPJ, **kw)
    for a, b in zip(warm, got):
        assert a.cycles == b.cycles
        assert a.max_link_bytes == b.max_link_bytes


# ---------------------------------------------------------------------------
# donation: the fit entry points consume their (params, opt_state) buffers
# ---------------------------------------------------------------------------


def test_fit_filter_consumes_donated_state_and_matches_loop():
    from repro.core import tuner as ct
    from repro.engine.tuner_train import fit_filter, pad_dataset

    rng = np.random.default_rng(0)
    x = rng.normal(size=(10, 7)).astype(np.float32)
    y = rng.normal(size=10).astype(np.float32)
    copy = lambda t: jax.tree_util.tree_map(jnp.array, t)

    p0 = ct._init_mlp(jax.random.PRNGKey(0), ct.FILTER_SIZES)
    o0 = ct._FILTER_OPT.init(p0)
    pl, ol = copy(p0), copy(o0)
    loss = None
    for _ in range(5):   # loop-backend reference on the unpadded data
        pl, ol, loss = ct._filter_step(pl, ol, jnp.asarray(x),
                                       jnp.asarray(y))

    xp, yp, mask = map(jax.device_put, pad_dataset(x, y))
    pf, of, losses = fit_filter(p0, o0, xp, yp, mask,
                                opt=ct._FILTER_OPT, steps=5)
    # donated: every leaf of the passed-in state was handed to XLA
    assert all(a.is_deleted()
               for a in jax.tree_util.tree_leaves((p0, o0)))
    assert float(losses[-1]) == pytest.approx(float(loss), rel=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(pf),
                    jax.tree_util.tree_leaves(pl)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=1e-7)


def test_fit_dkl_consumes_donated_state():
    from repro.core import tuner as ct
    from repro.engine.tuner_train import fit_dkl, pad_dataset

    rng = np.random.default_rng(1)
    x = rng.normal(size=(6, 7)).astype(np.float32)
    y = rng.normal(size=6).astype(np.float32)
    p0 = ct._dkl_init(0)
    o0 = ct._DKL_OPT.init(p0)
    xp, yp, mask = map(jax.device_put, pad_dataset(x, y))
    _, _, losses = fit_dkl(p0, o0, xp, yp, mask, opt=ct._DKL_OPT, steps=3)
    assert all(a.is_deleted()
               for a in jax.tree_util.tree_leaves((p0, o0)))
    assert np.isfinite(np.asarray(losses)).all()


# ---------------------------------------------------------------------------
# canonical scheduler bucket shapes are bit-invisible
# ---------------------------------------------------------------------------


def test_schedule_many_canonical_shapes_bit_parity():
    rng = np.random.default_rng(4)
    probs = []
    for dim, ns, maxn in [(4, 1, 8), (4, 2, 6), (8, 3, 10), (6, 4, 5),
                          (5, 3, 7)]:
        noc = MeshNoc(dim, dim)
        sets = [tuple(int(v) for v in
                      rng.choice(dim * dim, size=int(rng.integers(4, maxn)),
                                 replace=False))
                for _ in range(ns)]
        probs.append((noc, sets,
                      [float(rng.integers(1024, 8192)) for _ in sets]))
    # restarts=6 x 15 problems forces the fixed 32-row chunking to split
    kw = dict(seed=3, restarts=6, iters=200, moves_per_round=16)
    a = schedule_many(probs * 3, BW, FREQ, EPJ, pad_shapes=True, **kw)
    b = schedule_many(probs * 3, BW, FREQ, EPJ, pad_shapes=False, **kw)
    for x, y in zip(a, b):
        assert x.cycles == y.cycles
        assert x.max_link_bytes == y.max_link_bytes
        assert x.latency_s == y.latency_s and x.energy_pj == y.energy_pj
    # solo solve equals its batched twin through the canonical shapes
    solo = schedule_many([probs[2]], BW, FREQ, EPJ, pad_shapes=True, **kw)[0]
    assert solo.cycles == a[2].cycles


# ---------------------------------------------------------------------------
# in-array top-k selection == the host walk it replicates
# ---------------------------------------------------------------------------


def _host_topk(vals, scores, valid, k):
    order = np.argsort(scores, kind="stable")
    out, seen = [], set()
    for i in order:
        if not valid[i]:
            break                      # stop at first area-rejected row
        t = tuple(int(v) for v in vals[i])
        if t in seen:
            continue
        seen.add(t)
        out.append(int(i))
        if len(out) == k:
            break
    return out


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_select_topk_matches_host_walk(seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 3, size=(32, 7)).astype(np.int32)  # many dups
    scores = rng.normal(size=32).astype(np.float32)
    valid = rng.random(32) < 0.8
    if seed == 2:
        valid[:] = True                # full-walk variant
    sel, cnt = jax.device_get(_select_topk(
        jnp.asarray(vals), jnp.asarray(scores), jnp.asarray(valid), k=5))
    assert list(sel[:int(cnt)]) == _host_topk(vals, scores, valid, 5)
    assert all(s == -1 for s in sel[int(cnt):])
