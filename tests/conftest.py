import os

# Keep the test run on the single real CPU device; the 512-device setting is
# applied ONLY by repro.launch.dryrun (which must be a fresh process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None,
                          derandomize=True)
settings.load_profile("ci")
