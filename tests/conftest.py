import os

# Keep the test run on the single real CPU device; the 512-device setting is
# applied ONLY by repro.launch.dryrun (which must be a fresh process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is optional: property tests skip with a clear reason when it is
# absent so `pytest -x -q` still runs on a bare interpreter.
try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None,
                              derandomize=True)
    settings.load_profile("ci")
