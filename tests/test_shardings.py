"""Sharding rules: divisibility guards, spec structure, constrain no-op."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed import shardings as shd
from repro.nn import transformer as T

KEY = jax.random.PRNGKey(0)


def one_device_mesh():
    return shd.make_mesh((1, 1), ("data", "model"))


def test_param_specs_match_structure():
    cfg = get_config("qwen2_0_5b").reduced()
    params = T.init_params(cfg, KEY)
    mesh = one_device_mesh()
    specs = shd.param_specs(cfg, params, mesh)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert isinstance(s, P)
        assert len(s) <= p.ndim


def test_specs_drop_non_divisible_axes():
    cfg = get_config("qwen2_0_5b").reduced()
    params = T.init_params(cfg, KEY)
    # a fake big mesh object for divisibility checks only
    devs = jax.devices() * 1
    mesh = one_device_mesh()
    specs = shd.param_specs(cfg, params, mesh)
    # every axis with mesh size 1 must be dropped (None)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert all(a is None for a in s)


def test_constrain_is_noop_outside_mesh():
    x = jnp.ones((4, 8))
    y = shd.constrain(x, ("data",), "model")
    assert (x == y).all()


def test_constrain_inside_mesh():
    mesh = one_device_mesh()
    with shd.set_mesh(mesh):
        x = jnp.ones((4, 8))
        y = shd.constrain(x, ("data",), "model")
        assert y.shape == x.shape


def test_attn_constraints_shapes_preserved():
    mesh = one_device_mesh()
    with shd.set_mesh(mesh):
        q = jnp.ones((2, 16, 14, 64))
        k = jnp.ones((2, 16, 2, 64))
        v = jnp.ones((2, 16, 2, 64))
        q2, k2, v2 = shd.attn_constraints(q, k, v)
        assert q2.shape == q.shape and k2.shape == k.shape


def test_cache_specs_cover_all_families():
    mesh = one_device_mesh()
    for arch in ("qwen2_0_5b", "rwkv6_1_6b", "recurrentgemma_2b"):
        cfg = get_config(arch).reduced()
        cache = T.init_cache(cfg, 2, 32)
        specs = shd.cache_specs(cfg, mesh, cache)
        flat_c = jax.tree.leaves(cache)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_c) == len(flat_s)
