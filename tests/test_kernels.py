"""Per-kernel shape/dtype sweeps against the ref.py oracles (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


# ---------------------------------------------------------------- attention

ATTN_CASES = [
    # b, s, h, hkv, dh, window, bq, bk, dtype
    (2, 128, 4, 2, 64, 0, 64, 64, jnp.float32),
    (1, 256, 4, 1, 64, 64, 128, 64, jnp.float32),
    (2, 96, 2, 2, 32, 0, 64, 64, jnp.float32),    # ragged blocks
    (1, 200, 4, 2, 64, 50, 64, 64, jnp.float32),  # ragged + window
    (2, 128, 4, 4, 128, 0, 128, 128, jnp.bfloat16),
    (1, 128, 8, 2, 64, 32, 64, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,hkv,dh,win,bq,bk,dtype", ATTN_CASES)
def test_flash_attention_sweep(b, s, h, hkv, dh, win, bq, bk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h + win), 3)
    q = _rand(ks[0], (b, s, h, dh), dtype)
    k = _rand(ks[1], (b, s, hkv, dh), dtype)
    v = _rand(ks[2], (b, s, hkv, dh), dtype)
    got = ops.flash_attention(q, k, v, window=win, block_q=bq, block_k=bk,
                              interpret=True)
    want = ref.attention(q, k, v, window=win)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@given(st.integers(1, 3), st.sampled_from([64, 96, 160]),
       st.sampled_from([1, 2, 4]))
@settings(max_examples=8)
def test_flash_attention_property(b, s, hkv):
    h = hkv * 2
    ks = jax.random.split(jax.random.fold_in(KEY, b * s + hkv), 3)
    q = _rand(ks[0], (b, s, h, 32), jnp.float32)
    k = _rand(ks[1], (b, s, hkv, 32), jnp.float32)
    v = _rand(ks[2], (b, s, hkv, 32), jnp.float32)
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_attention_q_offset():
    """Decode-style: 1 query at offset attends the full prefix."""
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (1, 8, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 32), jnp.float32)
    got = ops.flash_attention(q, k, v, q_offset=56, block_q=8, block_k=32,
                              interpret=True)
    want = ref.attention(q, k, v, q_offset=56)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


# ---------------------------------------------------------------- rglru

RGLRU_CASES = [
    (2, 64, 128, 32, 64, jnp.float32),
    (1, 100, 96, 32, 64, jnp.float32),   # ragged both dims
    (3, 256, 512, 128, 256, jnp.float32),
    (2, 64, 128, 64, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,d,bs,bd,dtype", RGLRU_CASES)
def test_rglru_sweep(b, s, d, bs, bd, dtype):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, s + d))
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, d))).astype(dtype)
    x = _rand(k2, (b, s, d), dtype)
    got = ops.rglru(a, x, block_s=bs, block_d=bd, interpret=True)
    want = ref.rglru(a.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want),
                               atol=_tol(dtype) * 4, rtol=_tol(dtype) * 4)


# ---------------------------------------------------------------- rwkv6

WKV_CASES = [
    (2, 64, 2, 64, 32, jnp.float32),
    (1, 96, 4, 32, 48, jnp.float32),    # ragged chunks
    (2, 128, 2, 64, 128, jnp.float32),
    (1, 64, 2, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,dh,bs,dtype", WKV_CASES)
def test_rwkv6_sweep(b, s, h, dh, bs, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h), 5)
    r = _rand(ks[0], (b, s, h, dh), dtype)
    k = (_rand(ks[1], (b, s, h, dh), dtype) * 0.3).astype(dtype)
    v = _rand(ks[2], (b, s, h, dh), dtype)
    w = jax.nn.sigmoid(
        jax.random.normal(ks[3], (b, s, h, dh)) * 0.5 + 2).astype(dtype)
    u = (_rand(ks[4], (h, dh), dtype) * 0.1).astype(dtype)
    got = ops.rwkv6(r, k, v, w, u, block_s=bs, interpret=True)
    want = ref.wkv6(r, k, v, w, u)
    rel = np.max(np.abs(np.asarray(got, np.float32) - np.asarray(want))) \
        / (np.max(np.abs(np.asarray(want))) + 1e-9)
    assert rel < (5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_rwkv6_state_continuity():
    """Chunked kernel must carry state across chunk boundaries exactly."""
    ks = jax.random.split(KEY, 5)
    b, s, h, dh = 1, 128, 2, 32
    r = _rand(ks[0], (b, s, h, dh), jnp.float32)
    k = _rand(ks[1], (b, s, h, dh), jnp.float32) * 0.3
    v = _rand(ks[2], (b, s, h, dh), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, dh)) * 0.5 + 2)
    u = _rand(ks[4], (h, dh), jnp.float32) * 0.1
    small = ops.rwkv6(r, k, v, w, u, block_s=16, interpret=True)
    big = ops.rwkv6(r, k, v, w, u, block_s=128, interpret=True)
    np.testing.assert_allclose(np.asarray(small), np.asarray(big), atol=1e-5)
