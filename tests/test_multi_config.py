"""Multi-config batched mapping + DSE batch evaluation + campaign hygiene.

Pins the PR's contracts:

* ``PimMapper.map_many`` / ``WorkloadEvaluator.evaluate_batch`` produce
  results bitwise-identical to per-config ``map()`` / ``__call__``;
* ``batch_part_cost_paired`` cells match the ``[N, L]`` grid exactly;
* infeasible configs are contained: ``(inf, {}, {})`` — nothing from earlier
  workloads leaks into the caches;
* ``EvalCache`` persists ``inf`` as a JSON-safe sentinel (RFC-strict files);
* a changed :class:`PimConstraints` invalidates a campaign checkpoint, an
  unchanged one resumes.
"""

import json
import math

import numpy as np
import pytest

from repro.core.dse import WorkloadEvaluator, run_dse
from repro.core.hardware import (PAPER_4X4, PAPER_16X16, PAPER_BEST,
                                 PimConstraints)
from repro.core.mapper import PimMapper, clear_mapper_caches, evaluate_mapping
from repro.core.surrogates import make_strategy
from repro.core.workloads import googlenet
from repro.engine import Campaign, EvalCache, ParetoFront

MAPPER_KW = dict(max_optim_iter=1, lm_cap=20, n_wr=2)
CFGS = [PAPER_4X4, PAPER_BEST, PAPER_16X16]
TINY_CONS = PimConstraints(cap_bank_bytes=2048)   # capacity-infeasible


@pytest.fixture(scope="module")
def tiny_net():
    return googlenet(1, scale=8)


# ---------------------------------------------------------------------------
# map_many parity
# ---------------------------------------------------------------------------


def _assert_same_mapping(a, b):
    assert a.sm == b.sm
    assert set(a.choices) == set(b.choices)
    for name, ca in a.choices.items():
        cb = b.choices[name]
        assert (ca.lm, ca.wr, ca.region) == (cb.lm, cb.wr, cb.region), name
        assert (ca.dl_in, ca.dl_out) == (cb.dl_in, cb.dl_out), name
        assert ca.perf_s == cb.perf_s, name          # bitwise
        assert ca.size_bytes == cb.size_bytes, name
    assert a.est_latency_s == b.est_latency_s


@pytest.mark.parametrize("backend", ["batched", "scalar"])
def test_map_many_bitwise_matches_per_config_map(tiny_net, backend):
    kw = dict(MAPPER_KW, backend=backend)
    clear_mapper_caches()
    many = PimMapper(CFGS[0], **kw).map_many(tiny_net, CFGS)
    for cfg, got in zip(CFGS, many):
        clear_mapper_caches()
        ref = PimMapper(cfg, **kw).map(tiny_net)
        _assert_same_mapping(got, ref)


def test_map_many_evaluate_mapping_reports_identical(tiny_net):
    clear_mapper_caches()
    many = PimMapper(CFGS[0], backend="batched", **MAPPER_KW).map_many(
        tiny_net, CFGS)
    for cfg, got in zip(CFGS, many):
        clear_mapper_caches()
        ref = PimMapper(cfg, backend="batched", **MAPPER_KW).map(tiny_net)
        ra = evaluate_mapping(got, seed=1)
        import repro.core.mapper as mapper_mod
        mapper_mod._sharing_latency.cache_clear()
        rb = evaluate_mapping(ref, seed=1)
        assert ra.latency_s == rb.latency_s
        assert ra.energy_pj == rb.energy_pj


def test_map_many_multi_iteration_parity(tiny_net):
    kw = dict(MAPPER_KW, backend="batched", max_optim_iter=2)
    clear_mapper_caches()
    many = PimMapper(CFGS[0], **kw).map_many(tiny_net, CFGS[:2])
    for cfg, got in zip(CFGS[:2], many):
        clear_mapper_caches()
        _assert_same_mapping(got, PimMapper(cfg, **kw).map(tiny_net))


def test_map_many_on_infeasible(tiny_net):
    bad = PAPER_4X4.replace(cons=TINY_CONS)
    pm = PimMapper(PAPER_4X4, backend="batched", **MAPPER_KW)
    with pytest.raises(ValueError):
        pm.map_many(tiny_net, [PAPER_4X4], on_infeasible="skip")
    with pytest.raises(RuntimeError):
        PimMapper(bad, backend="batched", **MAPPER_KW).map_many(
            tiny_net, [bad])
    clear_mapper_caches()
    out = PimMapper(bad, backend="batched", **MAPPER_KW).map_many(
        tiny_net, [bad, bad], on_infeasible="none")
    assert out == [None, None]


def test_map_many_mixed_feasibility_keeps_live_configs(tiny_net):
    bad = PAPER_4X4.replace(cons=TINY_CONS)
    # mixed-cons batches fall back to per-constraints engine groups
    clear_mapper_caches()
    got = PimMapper(PAPER_4X4, backend="batched", **MAPPER_KW).map_many(
        tiny_net, [bad, PAPER_4X4], on_infeasible="none")
    assert got[0] is None and got[1] is not None
    clear_mapper_caches()
    ref = PimMapper(PAPER_4X4, backend="batched", **MAPPER_KW).map(tiny_net)
    _assert_same_mapping(got[1], ref)


# ---------------------------------------------------------------------------
# paired engine cells == grid cells
# ---------------------------------------------------------------------------


def test_batch_part_cost_paired_matches_grid(tiny_net):
    from repro.core.layout import DataLayout
    from repro.engine.batch_cost import (PartSpec, batch_part_cost,
                                         batch_part_cost_paired)
    layers = [l for l in tiny_net.layers if l.is_heavy][:9]
    specs = [PartSpec(l, DataLayout("BCHW", 4), DataLayout("BHWC"))
             for l in layers]
    cfgs = [CFGS[i % 3] for i in range(len(specs))]
    res = batch_part_cost_paired(cfgs, specs, spec_chunk=4)
    grid = batch_part_cost(CFGS, specs)
    for j in range(len(specs)):
        i = j % 3
        assert res.latency_s[0, j] == grid.latency_s[i, j]
        assert res.energy_pj[0, j] == grid.energy_pj[i, j]
        assert (res.tiling[0, j] == grid.tiling[i, j]).all()
        assert res.use_bpq_outer[0, j] == grid.use_bpq_outer[i, j]


def test_batch_part_cost_paired_rejects_mismatched_lengths():
    from repro.core.layout import DataLayout
    from repro.engine.batch_cost import PartSpec, batch_part_cost_paired
    l = googlenet(1, scale=8).layers[2]
    spec = PartSpec(l, DataLayout("BCHW", 4), DataLayout("BHWC"))
    with pytest.raises(ValueError):
        batch_part_cost_paired([PAPER_4X4, PAPER_BEST], [spec])


# ---------------------------------------------------------------------------
# evaluate_batch parity + infeasible containment
# ---------------------------------------------------------------------------


def test_evaluate_batch_matches_call(tiny_net):
    wl = [tiny_net]
    cfgs = CFGS + [PAPER_4X4]          # with a duplicate
    ev = WorkloadEvaluator(wl, mapper_kwargs=MAPPER_KW)
    clear_mapper_caches()
    batch = ev.evaluate_batch(cfgs)
    assert ev.evaluations == 3         # duplicate evaluated once
    ref = WorkloadEvaluator(wl, mapper_kwargs=MAPPER_KW)
    for cfg, got in zip(cfgs, batch):
        clear_mapper_caches()
        cost, lats, ens = ref(cfg)
        assert got[0] == cost and got[1] == lats and got[2] == ens
    # results landed in the per-instance cache: no further mapper runs
    again = ev.evaluate_batch(cfgs)
    assert ev.evaluations == 3
    assert again == batch


def test_evaluate_batch_feeds_content_cache(tiny_net):
    cache = EvalCache()
    ev = WorkloadEvaluator([tiny_net], mapper_kwargs=MAPPER_KW, cache=cache)
    clear_mapper_caches()
    ev.evaluate_batch([PAPER_4X4, PAPER_BEST])
    ev2 = WorkloadEvaluator([tiny_net], mapper_kwargs=MAPPER_KW, cache=cache)
    out = ev2.evaluate_batch([PAPER_4X4, PAPER_BEST])
    assert ev2.evaluations == 0        # both served from the shared cache
    assert all(o is not None for o in out)


def test_infeasible_returns_empty_dicts(tiny_net):
    bad = PAPER_4X4.replace(cons=TINY_CONS)
    ev = WorkloadEvaluator([tiny_net], mapper_kwargs=MAPPER_KW)
    cost, lats, ens = ev(bad)
    assert math.isinf(cost) and lats == {} and ens == {}
    ev2 = WorkloadEvaluator([tiny_net], mapper_kwargs=MAPPER_KW)
    res = ev2.evaluate_batch([bad, PAPER_4X4])
    assert math.isinf(res[0][0]) and res[0][1] == {} and res[0][2] == {}
    assert math.isfinite(res[1][0]) and res[1][1] != {}


def test_infeasible_later_workload_does_not_leak(tiny_net, monkeypatch):
    """Regression: a later infeasible workload used to leave the earlier
    workloads' latencies/energies in the cached (inf, ...) tuple."""
    g2 = googlenet(1, scale=8)
    g2.name = "second"
    calls = []
    real_map = PimMapper.map

    def fake_map(self, graph):
        calls.append(graph.name)
        if graph.name == "second":
            raise RuntimeError("no feasible mapping under DRAM capacity")
        return real_map(self, graph)

    monkeypatch.setattr(PimMapper, "map", fake_map)
    ev = WorkloadEvaluator([tiny_net, g2], mapper_kwargs=MAPPER_KW)
    cost, lats, ens = ev(PAPER_4X4)
    assert math.isinf(cost)
    assert lats == {} and ens == {}    # nothing from tiny_net leaked
    assert calls == [tiny_net.name, "second"]


# ---------------------------------------------------------------------------
# run_dse evaluate_all_legal
# ---------------------------------------------------------------------------


def test_run_dse_evaluate_all_legal_maps_whole_batch(tiny_net):
    ev = WorkloadEvaluator([tiny_net], mapper_kwargs=MAPPER_KW)
    fr = ParetoFront()
    res = run_dse(make_strategy("random", seed=0, n_sample=64), ev,
                  iterations=2, propose_k=4, pareto=fr,
                  evaluate_all_legal=True)
    costed = [o for o in res.observations if o.cost is not None]
    legal = [o for o in res.observations if o.legal]
    # every legal proposal was mapped (no first-legal-only cutoff)
    assert len(costed) == len(legal) >= 2
    assert fr.offered == len(costed)
    # default path still evaluates at most one config per iteration
    ev2 = WorkloadEvaluator([tiny_net], mapper_kwargs=MAPPER_KW)
    res2 = run_dse(make_strategy("random", seed=0, n_sample=64), ev2,
                   iterations=2, propose_k=4)
    per_iter = {}
    for o in res2.observations:
        if o.cost is not None:
            per_iter[o.iteration] = per_iter.get(o.iteration, 0) + 1
    assert all(v == 1 for v in per_iter.values())


# ---------------------------------------------------------------------------
# EvalCache: RFC-safe inf persistence
# ---------------------------------------------------------------------------


def test_eval_cache_inf_roundtrip(tmp_path):
    cache = EvalCache()
    cache.put("inf-entry", (math.inf, {}, {}))
    cache.put("finite", (1.5, {"g": 2.0}, {"g": 3.0}))
    p = tmp_path / "cache.json"
    cache.save(p)
    text = p.read_text()
    assert "Infinity" not in text            # RFC 8259-clean
    json.loads(text)                         # strict parse succeeds
    back = EvalCache.load(p)
    got = back.get("inf-entry")
    assert math.isinf(got[0]) and got[1] == {} and got[2] == {}
    assert back.get("finite")[0] == 1.5
    assert back.get("finite")[1] == {"g": 2.0}


# ---------------------------------------------------------------------------
# campaign checkpoint: constraints fold into the fingerprint
# ---------------------------------------------------------------------------


def test_campaign_checkpoint_rejected_on_constraints_change(tiny_net,
                                                            tmp_path):
    ckpt = tmp_path / "cons.json"
    kw = dict(iterations=1, propose_k=4, seed=1, n_sample=64,
              evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW),
              checkpoint=ckpt)
    Campaign([tiny_net], ("random",), **kw).run()
    assert ckpt.exists()
    # unchanged constraints: the checkpoint resumes
    same = Campaign([tiny_net], ("random",), **kw)
    assert set(same._load_checkpoint()) == {"random"}
    out = same.run()
    assert out.resumed == ["random"]
    assert out.cache_stats["misses"] == 0
    # a different area budget: stale legality judgements must not replay
    other = Campaign([tiny_net], ("random",),
                     cons=PimConstraints(area_budget_mm2=24.0), **kw)
    assert other._load_checkpoint() == {}


def test_campaign_fingerprint_keys_all_legality_inputs(tiny_net):
    kw = dict(iterations=1, propose_k=4, seed=1, n_sample=64)
    a = Campaign([tiny_net], ("random",), **kw)
    b = Campaign([tiny_net], ("random",), **kw)
    assert a._fingerprint() == b._fingerprint()
    c = Campaign([tiny_net], ("random",),
                 cons=PimConstraints(dram_energy_pj_per_bit=1.5), **kw)
    d = Campaign([tiny_net], ("random",), evaluate_all_legal=True, **kw)
    assert len({a._fingerprint(), c._fingerprint(), d._fingerprint()}) == 3


def test_campaign_evaluate_all_legal_runs(tiny_net, tmp_path):
    camp = Campaign([tiny_net], ("random",), iterations=2, propose_k=3,
                    seed=0, n_sample=64, evaluate_all_legal=True,
                    evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW),
                    checkpoint=tmp_path / "all.json")
    out = camp.run()
    res = out.results["random"]
    costed = [o for o in res.observations if o.cost is not None]
    legal = [o for o in res.observations if o.legal]
    assert len(costed) == len(legal) >= 2
    assert out.best().cost > 0
