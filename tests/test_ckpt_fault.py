"""Checkpointing (atomic/async/elastic) + fault tolerance (restart/straggler)."""

import dataclasses
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.fault import (ElasticPlan, Preempted, RestartableLoop,
                                     StragglerMonitor)
from repro.training.train_loop import TrainConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def tiny():
    cfg = dataclasses.replace(
        get_config("qwen2_0_5b").reduced(), n_layers=2, d_model=64,
        head_dim=16, d_ff=128, vocab=256, dtype="float32")
    tcfg = TrainConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    return cfg, tcfg


def batch_fn_for(cfg):
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=4, seed=3))
    return lambda step: {k: jnp.asarray(v)
                         for k, v in data.batch(step).items()}


def test_save_restore_bitexact(tmp_path):
    cfg, tcfg = tiny()
    state = init_state(cfg, tcfg, KEY)
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, state)
    restored, step = mgr.restore(state)
    assert step == 7
    same = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                        state, restored)
    assert all(jax.tree.leaves(same))


def test_atomic_commit_ignores_tmp(tmp_path):
    cfg, tcfg = tiny()
    state = init_state(cfg, tcfg, KEY)
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, state)
    # simulate a crash mid-write: a lingering .tmp dir must be invisible
    (tmp_path / "step_00000009.tmp").mkdir()
    assert mgr.latest_step() == 1


def test_retention_gc(tmp_path):
    cfg, tcfg = tiny()
    state = init_state(cfg, tcfg, KEY)
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    cfg, tcfg = tiny()
    state = init_state(cfg, tcfg, KEY)
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(5, state)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_elastic_restore_dtype_and_structure(tmp_path):
    """Restore into a differently-placed (and abstract) template."""
    cfg, tcfg = tiny()
    state = init_state(cfg, tcfg, KEY)
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, state)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, _ = mgr.restore(template)
    same = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                        state, restored)
    assert all(jax.tree.leaves(same))


def test_preempt_resume_bitexact(tmp_path):
    """Kill at step 6, resume, and match the uninterrupted run exactly."""
    cfg, tcfg = tiny()
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    batch_fn = batch_fn_for(cfg)

    # uninterrupted reference: 10 steps
    ref = init_state(cfg, tcfg, KEY)
    for s in range(10):
        ref, _ = step_fn(ref, batch_fn(s))

    loop = RestartableLoop(tmp_path / "ck", ckpt_every=3)
    state = init_state(cfg, tcfg, KEY)

    class Boom(RuntimeError):
        pass

    calls = {"n": 0}

    def crashy(st, b):
        if calls["n"] == 6:
            raise Boom()
        calls["n"] += 1
        return step_fn(st, b)

    with pytest.raises(Boom):
        loop.run(state, crashy, batch_fn, start_step=0, num_steps=10)
    # restart from the last committed checkpoint
    loop2 = RestartableLoop(tmp_path / "ck", ckpt_every=3)
    start = loop2.resume_step()
    assert start == 6
    state2, _ = loop2.mgr.restore(init_state(cfg, tcfg, KEY))
    state2, _ = loop2.run(state2, lambda st, b: step_fn(st, b), batch_fn,
                          start_step=start, num_steps=10 - start)
    same = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        ref.params, state2.params)
    assert max(jax.tree.leaves(same)) < 1e-6


def test_signal_preemption(tmp_path):
    cfg, tcfg = tiny()
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    loop = RestartableLoop(tmp_path / "ck", ckpt_every=100)
    state = init_state(cfg, tcfg, KEY)
    batch_fn = batch_fn_for(cfg)

    def step_and_preempt(st, b):
        out = step_fn(st, b)
        loop.signal_preemption()
        return out

    with pytest.raises(Preempted):
        loop.run(state, step_and_preempt, batch_fn, start_step=0,
                 num_steps=10)
    assert loop.mgr.latest_step() == 1  # emergency checkpoint at step 1


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 0.5)
    assert mon.flagged[0][0] == 10


def test_elastic_plan():
    p = ElasticPlan(global_batch=256, host_count=32)
    assert p.host_batch == 8
    q = p.rescale(16)
    assert q.host_batch == 16
    with pytest.raises(ValueError):
        p.rescale(7)
