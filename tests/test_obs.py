"""Telemetry layer: Chrome-trace tracer, metrics registry, bench gate.

Covers the trace export contract (valid Chrome trace event format: required
keys, non-negative durations, monotonic timestamps per thread row), span
nesting across concurrent threads, the zero-cost disabled path, checkpoint
discard diagnostics, and the BENCH regression-gate comparison rules.
"""

import json
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

from repro.core.workloads import googlenet
from repro.engine.campaign import Campaign
from repro.obs import metrics as obs_metrics
from repro.obs import trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

TINY_EVAL_KW = dict(mapper_kwargs=dict(max_optim_iter=1, lm_cap=20, n_wr=2))


# -- tracer ------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    assert trace.current() is None
    s1 = trace.span("map", configs=3)
    s2 = trace.span("schedule")
    assert s1 is s2  # one singleton: nothing allocated when tracing is off
    with s1 as args:
        assert args == {}
    trace.instant("nothing")  # must not raise with no tracer
    trace.set_thread_name("nobody")


def test_traced_decorator_disabled_is_passthrough():
    calls = []

    @trace.traced("work", argspec=lambda n: {"n": n})
    def work(n):
        calls.append(n)
        return n * 2

    assert work(3) == 6
    assert calls == [3]


def _required_x_keys(ev):
    return all(k in ev for k in ("name", "cat", "ph", "ts", "dur",
                                 "pid", "tid", "args"))


def test_chrome_trace_format_valid(tmp_path):
    t = Tracer()
    with trace.activate(t):
        trace.set_thread_name("main")
        with trace.span("outer", cat="dse", k=4) as sp:
            with trace.span("inner", cat="engine"):
                pass
            sp["outcome"] = "hit"
        trace.instant("marker", reason="test")
    out = t.save(tmp_path / "trace.json")
    doc = json.loads(out.read_text())  # round-trips as JSON

    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    inst = [e for e in evs if e["ph"] == "i"]
    assert {e["name"] for e in meta} >= {"process_name", "thread_name"}
    # metadata leads the file so viewers name rows before drawing spans
    assert evs[: len(meta)] == meta

    assert {e["name"] for e in spans} == {"outer", "inner"}
    for e in spans:
        assert _required_x_keys(e)
        assert e["dur"] >= 0
        assert e["ts"] >= 0
    assert len(inst) == 1 and inst[0]["args"]["reason"] == "test"

    # mutating the yielded dict lands in the recorded event args
    outer = next(e for e in spans if e["name"] == "outer")
    assert outer["args"] == {"k": 4, "outcome": "hit"}

    # monotonic ts within each tid, in file order
    by_tid = {}
    for e in spans + inst:
        by_tid.setdefault(e["tid"], []).append(e["ts"])
    for ts in by_tid.values():
        assert ts == sorted(ts)

    # nesting: inner is contained in outer's [ts, ts+dur] window
    inner = next(e for e in spans if e["name"] == "inner")
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_span_threads_get_distinct_rows():
    t = Tracer()
    barrier = threading.Barrier(2)

    def worker(label):
        trace.set_thread_name(label)
        with trace.span("outer", who=label):
            barrier.wait()  # both spans provably concurrent
            with trace.span("inner", who=label):
                time.sleep(0.001)

    with trace.activate(t):
        threads = [threading.Thread(target=worker, args=(f"w{i}",))
                   for i in range(2)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()

    spans = [e for e in t.events() if e["ph"] == "X"]
    tids = {e["args"]["who"]: e["tid"] for e in spans}
    assert tids["w0"] != tids["w1"]
    for who in ("w0", "w1"):
        mine = [e for e in spans if e["args"]["who"] == who]
        outer = next(e for e in mine if e["name"] == "outer")
        inner = next(e for e in mine if e["name"] == "inner")
        assert outer["tid"] == inner["tid"]
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    names = [e for e in t.events()
             if e["ph"] == "M" and e["name"] == "thread_name"]
    assert {e["args"]["name"] for e in names} == {"w0", "w1"}


# -- metrics -----------------------------------------------------------------

def test_metrics_registry_instruments():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2)
    reg.gauge("g").set(7)
    reg.gauge("best").min(5.0)
    reg.gauge("best").min(9.0)  # larger: ignored
    for v in (1.0, 3.0):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["c"] == 3
    assert snap["g"] == 7
    assert snap["best"] == 5.0
    assert snap["h"] == {"count": 2, "sum": 4.0, "min": 1.0, "max": 3.0,
                         "mean": 2.0}
    with pytest.raises(TypeError):
        reg.gauge("c")  # name already registered as a Counter
    reg.reset()
    assert reg.snapshot() == {}


def test_tuner_bucket_metrics():
    from repro.engine.tuner_train import _record_bucket
    obs_metrics.METRICS.reset()
    _record_bucket("filter", np.zeros(8), np.array([1.0] * 5 + [0.0] * 3))
    snap = obs_metrics.METRICS.snapshot()
    assert snap["tuner.bucket.filter"] == 8
    assert snap["tuner.bucket_fill.filter"]["mean"] == pytest.approx(5 / 8)
    assert snap["tuner.padded_rows.filter"] == 3
    obs_metrics.METRICS.reset()


# -- campaign checkpoint discard diagnostics ---------------------------------

def _tiny_campaign(tmp_path, reg, tracer=None):
    return Campaign([googlenet(1, scale=8)], ("random",), iterations=2,
                    propose_k=2, n_sample=32, evaluator_kwargs=TINY_EVAL_KW,
                    checkpoint=tmp_path / "ck.json", metrics=reg,
                    tracer=tracer)


def test_checkpoint_discard_unreadable(tmp_path):
    reg = MetricsRegistry()
    camp = _tiny_campaign(tmp_path, reg)
    (tmp_path / "ck.json").write_text('{"fingerprint": "trunca')
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert camp._load_checkpoint() == {}
    snap = reg.snapshot()
    assert snap["campaign.checkpoint_discarded"] == 1
    assert snap["campaign.checkpoint_discarded.unreadable"] == 1


def test_checkpoint_discard_fingerprint_mismatch(tmp_path):
    reg = MetricsRegistry()
    camp = _tiny_campaign(tmp_path, reg)
    (tmp_path / "ck.json").write_text(json.dumps(
        {"fingerprint": "not-this-campaign", "strategies": {}}))
    with pytest.warns(RuntimeWarning, match="fingerprint_mismatch"):
        assert camp._load_checkpoint() == {}
    snap = reg.snapshot()
    assert snap["campaign.checkpoint_discarded.fingerprint_mismatch"] == 1


def test_checkpoint_absent_is_silent(tmp_path):
    reg = MetricsRegistry()
    camp = _tiny_campaign(tmp_path, reg)
    assert camp._load_checkpoint() == {}
    assert "campaign.checkpoint_discarded" not in reg.snapshot()


# -- end-to-end: traced campaign smoke ---------------------------------------

def test_campaign_emits_spans_and_metrics(tmp_path):
    reg = MetricsRegistry()
    tracer = Tracer()
    camp = _tiny_campaign(tmp_path, reg, tracer=tracer)
    out = camp.run()

    assert set(out.wall_s) == {"random"}
    assert out.wall_s["random"] >= out.timings_s["random"] >= 0.0
    assert out.metrics["eval_cache.entries"] >= 1
    assert out.metrics["pareto.size"] == len(out.pareto)

    names = {e["name"] for e in tracer.events() if e["ph"] == "X"}
    assert {"strategy", "iteration", "propose", "evaluate", "map",
            "checkpoint"} <= names
    evaluate = [e for e in tracer.events()
                if e["ph"] == "X" and e["name"] == "evaluate"]
    assert all(e["args"].get("cache") in ("local_hit", "content_hit", "miss")
               for e in evaluate)

    # the checkpoint carries the registry snapshot for post-mortems
    state = json.loads((tmp_path / "ck.json").read_text())
    assert state["metrics"]["eval_cache.entries"] >= 1

    # saved trace loads as valid Chrome trace format
    doc = json.loads(tracer.save(tmp_path / "t.json").read_text())
    assert all(_required_x_keys(e) and e["dur"] >= 0
               for e in doc["traceEvents"] if e["ph"] == "X")


# -- bench gate --------------------------------------------------------------

def _bench(mode="smoke", **gates):
    return {"schema": "nicepim-bench/1", "bench_id": 6, "mode": mode,
            "gates": {k: {"value": v, "tolerance": 0.25,
                          "higher_is_better": True}
                      for k, v in gates.items()}}


def test_bench_gate_within_tolerance_passes():
    from benchmarks.bench_gate import compare
    fails, _ = compare(_bench(engine=4.0), _bench(engine=5.0))
    assert fails == []  # 4.0 >= 5.0 * (1 - 0.25)


def test_bench_gate_regression_fails():
    from benchmarks.bench_gate import compare
    fails, lines = compare(_bench(engine=3.0), _bench(engine=5.0))
    assert fails == ["engine"]
    assert any("REGRESSED" in ln for ln in lines)


def test_bench_gate_new_and_removed_gates_never_fail():
    from benchmarks.bench_gate import compare
    fails, lines = compare(_bench(fresh=1.0), _bench(retired=9.0))
    assert fails == []
    assert len(lines) == 2


def test_bench_gate_cli_skips(tmp_path, capsys):
    from benchmarks.bench_gate import main
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(_bench("smoke", engine=1.0)))
    # no baseline: clean skip
    assert main(["--current", str(cur)]) == 0
    assert "skipping" in capsys.readouterr().out
    # mode mismatch: clean skip
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_bench("full", engine=9.0)))
    assert main(["--current", str(cur), "--baseline", str(base)]) == 0
    assert "mode mismatch" in capsys.readouterr().out
    # comparable baseline with a regression: exit 1
    base.write_text(json.dumps(_bench("smoke", engine=9.0)))
    assert main(["--current", str(cur), "--baseline", str(base)]) == 1
