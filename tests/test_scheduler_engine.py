"""Engine Data-Scheduler: jitted multi-chain 2-opt + batched scheduling.

Pins the PR's quality contracts: exact brute-force parity on small sets,
scan <= loop across the Fig. 12 arrays, per-backend seed determinism,
batch-independence of ``schedule_many``, the vectorized NoC load model, the
``_two_opt_distance`` delta rewrite, the ``_propose_moves`` budget fix, and
numpy parity of the Pallas ``delta_maxload_rows`` kernel.
"""

import itertools
import random

import numpy as np
import pytest

from repro.core.noc import MeshNoc
from repro.core.scheduler import (SOLVERS, _all_transfers, _apply_2opt,
                                  _initial_cycles, _propose_moves,
                                  _two_opt_distance, solve_ilp_ls, solve_shp,
                                  solve_tsp)
from repro.engine.scheduler_opt import schedule_many

BW, FREQ, EPJ = 3.2e9, 400e6, 1.1
SOLVE_KW = dict(seed=0, restarts=4, iters=200, moves_per_round=16)


def fig12_sets(dim: int, stride: int):
    noc = MeshNoc(dim, dim)
    sets = [[noc.node(r * stride + oy, c * stride + ox)
             for r in range(4) for c in range(4)]
            for oy in range(stride) for ox in range(stride)]
    return noc, sets


# ---------------------------------------------------------------------------
# vectorized NoC load model
# ---------------------------------------------------------------------------


def _ref_link_loads(noc, transfers):
    loads = [0.0] * noc.n_links()
    for src, dst, nbytes in transfers:
        if src == dst or nbytes <= 0:
            continue
        for l in noc.route(src, dst):
            loads[l] += nbytes
    return loads


def test_link_loads_vectorized_parity():
    rng = random.Random(0)
    for rows, cols in ((1, 4), (3, 3), (4, 4), (8, 8)):
        noc = MeshNoc(rows, cols)
        nn = noc.n_nodes
        for _ in range(10):
            tr = [(rng.randrange(nn), rng.randrange(nn),
                   rng.choice([0.0, -5.0, rng.uniform(1, 1e6)]))
                  for _ in range(rng.randrange(0, 10))]
            ref = _ref_link_loads(noc, tr)
            np.testing.assert_allclose(noc.link_loads_np(tr), ref)
            assert noc.link_loads(tr) == ref  # list API preserved
            ref_e = sum(b * 8 * noc.hops(s, d) * EPJ for s, d, b in tr)
            assert noc.transfer_energy_pj(tr, EPJ) == pytest.approx(ref_e)


def test_route_table_matches_routes():
    noc = MeshNoc(3, 4)
    pad, hops = noc.route_table()
    for a in range(noc.n_nodes):
        for b in range(noc.n_nodes):
            r = noc.route(a, b)
            assert hops[a, b] == len(r) == noc.hops(a, b)
            assert tuple(pad[a, b, :len(r)]) == r
            assert (pad[a, b, len(r):] == noc.n_links()).all()


# ---------------------------------------------------------------------------
# TSP baseline: O(1) delta scoring must keep the full-recompute result
# ---------------------------------------------------------------------------


def _two_opt_distance_ref(noc, cyc):
    def total(c):
        return sum(noc.hops(c[i], c[(i + 1) % len(c)]) for i in range(len(c)))
    best = list(cyc)
    best_d = total(best)
    improved = True
    while improved:
        improved = False
        for i in range(1, len(best) - 1):
            for j in range(i + 1, len(best)):
                cand = _apply_2opt(best, i, j)
                d = total(cand)
                if d < best_d:
                    best, best_d = cand, d
                    improved = True
    return best


def test_two_opt_distance_delta_matches_full_recompute():
    rng = random.Random(1)
    noc = MeshNoc(5, 5)
    for _ in range(25):
        n = rng.randint(4, 10)
        cyc = rng.sample(range(noc.n_nodes), n)
        assert _two_opt_distance(noc, cyc) == _two_opt_distance_ref(noc, cyc)


# ---------------------------------------------------------------------------
# _propose_moves: full budget, no degenerate full reversals
# ---------------------------------------------------------------------------


def test_propose_moves_honors_budget():
    rng = random.Random(2)
    # size-4 cycles draw the excluded (0, n-1) pair with probability 1/5
    # per move — the old skip-not-redraw under-filled these heavily
    cycles = [[0, 1, 2, 3], [4, 5, 6, 7]]
    for _ in range(50):
        moves = _propose_moves(cycles, rng, 16)
        assert len(moves) == 16
        for si, i, j in moves:
            assert 0 <= i < j <= 3
            assert (i, j) != (0, 3)
    assert _propose_moves([[0, 1, 2]], rng, 8) == []  # nothing eligible


# ---------------------------------------------------------------------------
# property: reported objective == recompute, across every solver/backend
# ---------------------------------------------------------------------------


def _solver_calls():
    for name in SOLVERS:
        if name == "ilp":
            for backend in ("scan", "loop"):
                yield f"ilp/{backend}", dict(backend=backend)
        else:
            yield name, {}


@pytest.mark.parametrize("seed", [0, 3])
def test_reported_max_link_bytes_is_exact(seed):
    noc, sets = fig12_sets(4, 1)
    sets = [sets[0][:8], [n + 8 for n in sets[0][:8]]]
    chunks = [1000.0, 2500.0]
    for label, extra in _solver_calls():
        solver = SOLVERS[label.split("/")[0]]
        res = solver(noc, sets, chunks, BW, FREQ, EPJ, seed=seed,
                     **({"restarts": 3, "iters": 100} if "ilp" in label
                        else {}), **extra)
        assert res.max_link_bytes == pytest.approx(
            noc.max_link_load(res.transfers)), label
        if res.cycles:  # cycle solvers: transfers must derive from cycles
            rebuilt = _all_transfers(res.cycles, chunks)
            assert sorted(rebuilt) == sorted(res.transfers), label


@pytest.mark.parametrize("label_extra", list(_solver_calls()))
def test_seed_determinism_every_solver(label_extra):
    label, extra = label_extra
    noc, sets = fig12_sets(4, 1)
    sets = [sets[0][:8], [n + 8 for n in sets[0][:8]]]
    chunks = [4096.0, 4096.0]
    solver = SOLVERS[label.split("/")[0]]
    kw = dict(seed=7, **({"restarts": 3, "iters": 100}
                         if "ilp" in label else {}), **extra)
    a = solver(noc, sets, chunks, BW, FREQ, EPJ, **kw)
    b = solver(noc, sets, chunks, BW, FREQ, EPJ, **kw)
    assert a.cycles == b.cycles
    assert a.transfers == b.transfers
    assert a.max_link_bytes == b.max_link_bytes


def test_scan_rng_equals_seed():
    noc, sets = fig12_sets(4, 1)
    sets = [sets[0][:8], [n + 8 for n in sets[0][:8]]]
    chunks = [1024.0, 2048.0]
    a = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ, **SOLVE_KW)
    c = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ,
                     rng=random.Random(SOLVE_KW["seed"]),
                     **{k: v for k, v in SOLVE_KW.items() if k != "seed"})
    assert a.cycles == c.cycles


def test_unknown_backend_raises():
    noc = MeshNoc(2, 2)
    with pytest.raises(ValueError, match="backend"):
        solve_ilp_ls(noc, [[0, 1, 2, 3]], [1.0], BW, FREQ, EPJ,
                     backend="vector")


# ---------------------------------------------------------------------------
# quality: brute force on small sets, scan <= loop on the Fig. 12 arrays
# ---------------------------------------------------------------------------


def test_scan_small_single_set_is_exact():
    """The small path brute-forces — identical through either backend."""
    noc = MeshNoc(3, 3)
    nodes = [0, 1, 3, 4, 8]
    chunk = 1000.0
    best = min(noc.max_link_load(_all_transfers([[nodes[0]] + list(p)],
                                                [chunk]))
               for p in itertools.permutations(nodes[1:]))
    for backend in ("scan", "loop"):
        res = solve_ilp_ls(noc, [nodes], [chunk], BW, FREQ, EPJ,
                           backend=backend)
        assert res.max_link_bytes == pytest.approx(best)


def test_scan_two_small_sets_match_joint_bruteforce():
    """The jitted search itself (not the exact path) finds the optimum."""
    noc = MeshNoc(2, 4)
    sets = [[0, 1, 4, 5], [2, 3, 6, 7]]
    chunks = [1000.0, 1500.0]
    best = min(
        noc.max_link_load(_all_transfers(
            [[sets[0][0]] + list(p), [sets[1][0]] + list(q)], chunks))
        for p in itertools.permutations(sets[0][1:])
        for q in itertools.permutations(sets[1][1:]))
    res = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ, seed=0,
                       restarts=4, iters=400, backend="scan")
    assert res.max_link_bytes == pytest.approx(best)


@pytest.mark.parametrize("dim,stride", [(4, 1), (8, 2)])
def test_scan_not_worse_than_loop_fig12(dim, stride):
    noc, sets = fig12_sets(dim, stride)
    chunks = [8192.0] * len(sets)
    kw = dict(seed=0, restarts=4, iters=400)
    scan = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ, backend="scan",
                        **kw)
    loop = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ, backend="loop",
                        **kw)
    assert scan.max_link_bytes <= loop.max_link_bytes + 1e-9
    # both monotone searches start from the TSP seed: never worse than it
    tsp = solve_tsp(noc, sets, chunks, BW, FREQ, EPJ)
    assert scan.max_link_bytes <= tsp.max_link_bytes + 1e-9
    assert loop.max_link_bytes <= tsp.max_link_bytes + 1e-9


def test_scan_loads_match_cycles_exactly():
    """The scan's in-array delta accumulation must not drift from the
    objective recomputed from its returned cycles."""
    noc, sets = fig12_sets(4, 1)
    res = solve_ilp_ls(noc, sets, [8192.0], BW, FREQ, EPJ, **SOLVE_KW)
    assert sorted(res.cycles[0]) == sorted(sets[0])   # still a permutation
    assert res.max_link_bytes == pytest.approx(
        noc.max_link_load(_all_transfers(res.cycles, [8192.0])))


# ---------------------------------------------------------------------------
# schedule_many: lockstep multi-problem solving, batch independence
# ---------------------------------------------------------------------------


def test_schedule_many_matches_single_solves():
    noc4 = MeshNoc(4, 4)
    noc24 = MeshNoc(2, 4)
    problems = [
        # small single set: exact path
        (noc24, [[0, 1, 5]], [512.0]),
        # no 2-opt-eligible set: best-init path
        (noc4, [[0, 1, 2], [4, 5, 6]], [256.0, 256.0]),
        # scan problems, two different meshes and set counts
        (noc4, [[0, 1, 2, 3, 4, 5, 6, 7]], [1024.0]),
        (noc4, [[0, 1, 2, 3, 4, 5, 6, 7],
                [8, 9, 10, 11, 12, 13, 14, 15]], [1024.0, 2048.0]),
        (noc24, [[0, 1, 2, 3, 4, 5, 6, 7]], [4096.0]),
        # duplicate of an earlier problem: must resolve identically
        (noc4, [[0, 1, 2, 3, 4, 5, 6, 7]], [1024.0]),
    ]
    kw = dict(seed=3, restarts=4, iters=200, moves_per_round=16)
    batched = schedule_many(problems, BW, FREQ, EPJ, **kw)
    for k, (noc, sets, chunks) in enumerate(problems):
        single = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ,
                              backend="scan", **kw)
        assert single.cycles == batched[k].cycles, k
        assert single.max_link_bytes == batched[k].max_link_bytes, k
        assert single.latency_s == batched[k].latency_s, k
    assert batched[2].cycles == batched[5].cycles  # duplicates agree


def test_schedule_many_independent_of_batch_composition():
    noc = MeshNoc(4, 4)
    prob = (noc, [[0, 1, 2, 3, 4, 5, 6, 7]], [4096.0])
    other = (noc, [[8, 9, 10, 11, 12, 13, 14, 15]], [512.0])
    kw = dict(seed=1, restarts=4, iters=200, moves_per_round=16)
    alone = schedule_many([prob], BW, FREQ, EPJ, **kw)[0]
    together = schedule_many([other, prob, other], BW, FREQ, EPJ, **kw)[1]
    assert alone.cycles == together.cycles
    assert alone.max_link_bytes == together.max_link_bytes


def test_no_eligible_sets_matches_loop():
    """With no 2-opt-eligible cycle both backends reduce to best-init."""
    noc = MeshNoc(4, 4)
    sets = [[0, 1, 5], [2, 3, 7]]
    chunks = [4096.0, 4096.0]
    scan = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ, backend="scan")
    loop = solve_ilp_ls(noc, sets, chunks, BW, FREQ, EPJ, backend="loop")
    assert scan.max_link_bytes == loop.max_link_bytes
    assert scan.cycles == loop.cycles


# ---------------------------------------------------------------------------
# Pallas delta_maxload_rows kernel
# ---------------------------------------------------------------------------


def test_delta_maxload_rows_numpy_parity():
    from repro.kernels import dse_eval
    rng = np.random.default_rng(0)
    for r, m, e in ((1, 1, 4), (3, 5, 48), (8, 32, 224), (4, 130, 60)):
        base = rng.normal(size=(r, e)) * 1e4
        deltas = rng.normal(size=(r, m, e)) * 1e3
        got = np.asarray(dse_eval.delta_maxload_rows(base, deltas,
                                                     interpret=True))
        ref = (base[:, None, :] + deltas).max(axis=-1)
        np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_delta_maxload_rows_weighted_int16_parity():
    """The scheduler's streamed form: int16 flip counts scaled in-kernel.

    ``_scan_solve`` passes small-int flip counts (int16) as ``deltas`` and
    the per-set byte weight as ``weights`` so the f32 [R, M, E] slab is
    never materialized; the link axis streams in ``block_e`` tiles with a
    running max.  Pin all of that against the unfused numpy reference.
    """
    from repro.kernels import dse_eval
    rng = np.random.default_rng(1)
    for r, m, e in ((2, 3, 24), (4, 17, 960), (1, 128, 60)):
        base = (rng.normal(size=(r, e)) * 1e4).astype(np.float32)
        cnt = rng.integers(-2, 3, size=(r, m, e)).astype(np.int16)
        w = rng.uniform(0.5, 8192.0, size=(r, m)).astype(np.float32)
        ref = (base[:, None, :]
               + cnt.astype(np.float32) * w[:, :, None]).max(axis=-1)
        for block_e in (512, 64, 7):   # 7 forces ragged -inf link padding
            got = np.asarray(dse_eval.delta_maxload_rows(
                base, cnt, w, block_e=block_e, interpret=True))
            # in-kernel scale-and-add may fuse to an FMA: 1-ulp tolerance
            np.testing.assert_allclose(got, ref, rtol=1e-6)


# ---------------------------------------------------------------------------
# evaluate_mapping threading: batched prefill == per-layer path
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_mapping():
    from repro.core.hardware import DEFAULT_CONSTRAINTS, HwConfig
    from repro.core.mapper import PimMapper
    from repro.core.workloads import googlenet
    hw = HwConfig.from_tuple((4, 4, 64, 64, 128, 8, 16),
                             cons=DEFAULT_CONSTRAINTS)
    return PimMapper(hw, max_optim_iter=1, lm_cap=20, n_wr=2).map(
        googlenet(1, scale=8))


def test_evaluate_mapping_scan_prefill_matches_serial(tiny_mapping):
    import repro.core.mapper as mapper_mod
    from repro.core.mapper import (_layer_sharing_args, _sched_key,
                                   _sharing_latency, evaluate_mapping)
    hw = tiny_mapping.hw
    _sharing_latency.cache_clear()
    rep = evaluate_mapping(tiny_mapping, seed=2)     # scan + batched prefill
    batch_vals = {}
    for lname in tiny_mapping.choices:
        args = _layer_sharing_args(tiny_mapping, lname)
        key = _sched_key(hw, *args, "ilp", 2, "scan")
        batch_vals[lname] = mapper_mod._SCHED_MEMO.get(key)
        assert batch_vals[lname] is not None
    _sharing_latency.cache_clear()
    for lname in tiny_mapping.choices:   # serial per-layer scan path
        args = _layer_sharing_args(tiny_mapping, lname)
        assert _sharing_latency(hw, *args, "ilp", 2,
                                backend="scan") == batch_vals[lname], lname
    _sharing_latency.cache_clear()
    rep2 = evaluate_mapping(tiny_mapping, seed=2)
    assert rep.latency_s == rep2.latency_s
    assert rep.energy_pj == rep2.energy_pj


def test_evaluate_mapping_backends_both_finite(tiny_mapping):
    from repro.core.mapper import _sharing_latency, evaluate_mapping
    _sharing_latency.cache_clear()
    scan = evaluate_mapping(tiny_mapping, seed=0, scheduler_backend="scan")
    loop = evaluate_mapping(tiny_mapping, seed=0, scheduler_backend="loop")
    for rep in (scan, loop):
        assert np.isfinite(rep.latency_s) and rep.latency_s > 0
        assert np.isfinite(rep.energy_pj) and rep.energy_pj > 0
    # different RNG streams: close, not necessarily equal
    assert scan.latency_s == pytest.approx(loop.latency_s, rel=0.2)


def test_workload_evaluator_scheduler_backend_keys_cache():
    from repro.core.dse import WorkloadEvaluator
    from repro.core.hardware import DEFAULT_CONSTRAINTS, HwConfig
    from repro.core.workloads import googlenet
    hw = HwConfig.from_tuple((4, 4, 64, 64, 128, 8, 16),
                             cons=DEFAULT_CONSTRAINTS)
    wl = [googlenet(1, scale=8)]
    kw = dict(max_optim_iter=1, lm_cap=20, n_wr=2)
    a = WorkloadEvaluator(wl, mapper_kwargs=kw, scheduler_backend="scan")
    b = WorkloadEvaluator(wl, mapper_kwargs=kw, scheduler_backend="loop")
    assert a._content_key(hw) != b._content_key(hw)


def test_initial_cycles_shared_by_backends():
    noc = MeshNoc(4, 4)
    sets = [[0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15]]
    for r in range(3):   # the deterministic restarts
        a = _initial_cycles(noc, sets, r, random.Random(0))
        b = _initial_cycles(noc, sets, r, random.Random(0))
        assert a == b
        for init, s in zip(a, sets):
            assert sorted(init) == sorted(s)
