"""Training substrate: optimization, grad accumulation, compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.nn import transformer as T
from repro.training.optim import Adam, cosine_schedule, global_norm
from repro.training.train_loop import TrainConfig, init_state, make_train_step
from repro.training.compression import compress_decompress

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    return dataclasses.replace(
        get_config("qwen2_0_5b").reduced(), n_layers=2, d_model=64,
        head_dim=16, d_ff=128, vocab=256, dtype="float32")


def make_batches(cfg, n, batch=4, seq=32):
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=1))
    return [{k: jnp.asarray(v) for k, v in data.batch(i).items()}
            for i in range(n)]


def test_adam_minimizes_quadratic():
    opt = Adam(lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.apply(g, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, 10, 100)
    assert float(lr(jnp.array(0))) < 1e-4
    assert float(lr(jnp.array(10))) == pytest.approx(1e-3, rel=0.05)
    assert float(lr(jnp.array(100))) == pytest.approx(1e-4, rel=0.05)


def test_loss_decreases():
    cfg = tiny_cfg()
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=40,
                       microbatches=1)
    state = init_state(cfg, tcfg, KEY)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    batches = make_batches(cfg, 40)
    losses = []
    for b in batches:
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_grad_accum_matches_single_batch():
    """A=4 microbatches must give the same update as one big batch."""
    cfg = tiny_cfg()
    b = make_batches(cfg, 1, batch=8)[0]
    outs = {}
    for a in (1, 4):
        tcfg = TrainConfig(lr=1e-3, microbatches=a, warmup_steps=0,
                           clip_norm=None)
        state = init_state(cfg, tcfg, KEY)
        step = make_train_step(cfg, tcfg)
        new_state, m = step(state, b)
        outs[a] = (new_state.params, float(m["loss"]))
    d = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(x - y))),
                     outs[1][0], outs[4][0])
    # f32 accumulation-order noise is amplified by Adam's rsqrt(v) division
    assert max(jax.tree.leaves(d)) < 5e-4
    assert outs[1][1] == pytest.approx(outs[4][1], abs=1e-5)


def test_int8_compression_roundtrip():
    g = {"a": jnp.array([0.1, -3.0, 2.5]), "b": jnp.ones((8, 8)) * 0.01}
    e = jax.tree.map(jnp.zeros_like, g)
    deq, err = compress_decompress(g, e)
    rel = jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(x - y)) /
                           (jnp.max(jnp.abs(x)) + 1e-9)), g, deq)
    assert max(jax.tree.leaves(rel)) < 0.02
    # error feedback: residual equals the quantization error
    back = jax.tree.map(lambda d, r, orig: d + r - orig, deq, err, g)
    assert max(float(jnp.max(jnp.abs(x))) for x in jax.tree.leaves(back)) \
        < 1e-6


def test_int8_training_still_converges():
    cfg = tiny_cfg()
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=30,
                       grad_compression="int8")
    state = init_state(cfg, tcfg, KEY)
    assert state.err is not None
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    losses = []
    for b in make_batches(cfg, 30):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
