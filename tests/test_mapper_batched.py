"""Batched mapper backend: parity with the scalar path, knapsack kernel,
spec-chunked engine invariance, scheduler delta updates, cache hooks."""

import random

import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from test_mapper import toy_net

from repro.core import mapper as mapper_mod
from repro.core.hardware import PAPER_4X4, PAPER_16X16, PAPER_BEST
from repro.core.layout import DataLayout
from repro.core.mapper import (PimMapper, RegionTable, clear_mapper_caches,
                               evaluate_mapping)
from repro.core.noc import MeshNoc
from repro.core.partition import (comm_estimate, comm_estimate_batch,
                                  enumerate_lms, wr_candidates)
from repro.core.scheduler import (_all_transfers, _apply_2opt, _move_edges,
                                  _propose_moves, solve_ilp_ls)
from repro.core.workloads import googlenet

RTOL = 1e-6


def _mapping_pair(graph, hw, **kw):
    clear_mapper_caches()
    ms = PimMapper(hw, backend="scalar", **kw).map(graph)
    clear_mapper_caches()
    mb = PimMapper(hw, backend="batched", **kw).map(graph)
    return ms, mb


@pytest.mark.parametrize("graph,hw", [
    (toy_net(), PAPER_4X4),            # branchy graph
    (toy_net(), PAPER_16X16),
    (googlenet(1, scale=8), PAPER_BEST),
])
def test_backend_parity_identical_mapping(graph, hw):
    ms, mb = _mapping_pair(graph, hw, max_optim_iter=2)
    assert ms.sm == mb.sm
    assert set(ms.choices) == set(mb.choices)
    for name, cs in ms.choices.items():
        cb = mb.choices[name]
        assert (cs.lm, cs.wr, cs.region) == (cb.lm, cb.wr, cb.region), name
        assert (cs.dl_in, cs.dl_out) == (cb.dl_in, cb.dl_out), name
        assert cs.perf_s == pytest.approx(cb.perf_s, rel=RTOL)
        assert cs.size_bytes == pytest.approx(cb.size_bytes, rel=RTOL)
    assert ms.est_latency_s == pytest.approx(mb.est_latency_s, rel=RTOL)


def test_backend_parity_evaluate_mapping():
    g = toy_net()
    ms, mb = _mapping_pair(g, PAPER_4X4, max_optim_iter=2)
    rs = evaluate_mapping(ms, seed=1)
    mapper_mod._sharing_latency.cache_clear()
    rb = evaluate_mapping(mb, seed=1)
    assert rs.latency_s == pytest.approx(rb.latency_s, rel=RTOL)
    assert rs.energy_pj == pytest.approx(rb.energy_pj, rel=RTOL)
    for a, b in zip(rs.layers, rb.layers):
        assert a.name == b.name
        assert a.latency_s == pytest.approx(b.latency_s, rel=RTOL)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        PimMapper(PAPER_4X4, backend="gpu")


def test_candidate_tables_match_scalar():
    """The batched prefetch reproduces _layer_candidates tuples exactly."""
    hw = PAPER_4X4
    pm = PimMapper(hw, backend="batched", lm_cap=40, n_wr=3)
    layers = [l for l in googlenet(1, scale=8).layers if l.is_heavy][:6]
    clear_mapper_caches()
    for l in layers:
        din, dout = pm._default_dl(l.C), pm._default_dl(l.K)
        got = pm._candidates(l, 4, 4, din, dout)
        ref = mapper_mod._layer_candidates(hw, l, 4, 4, din, dout, 3, 40)
        assert len(got) == len(ref)
        for (wg, pg, sg, lg), (wr, pr, sr, lr) in zip(got, ref):
            assert (wg, lg) == (wr, lr)
            assert pg == pytest.approx(pr, rel=RTOL)
            assert sg == pytest.approx(sr, rel=RTOL)


# ---------------------------------------------------------------------------
# vectorized comm estimate
# ---------------------------------------------------------------------------


def test_comm_estimate_batch_bitwise():
    l = googlenet(1, scale=8).layers[2]
    hw = PAPER_16X16
    pair_lms, pair_wrs = [], []
    for lm in enumerate_lms(l, 4, 8, cap=50):
        for wr in wr_candidates(l, lm, 4):
            pair_lms.append(lm)
            pair_wrs.append(wr)
    lat, en, stored = comm_estimate_batch(l, hw, pair_lms, pair_wrs)
    for p, (lm, wr) in enumerate(zip(pair_lms, pair_wrs)):
        ce = comm_estimate(l, lm, wr, hw)
        assert lat[p] == ce.latency_s
        assert en[p] == ce.energy_pj
        assert stored[p] == ce.weight_bytes_per_node


def test_comm_estimate_batch_aux_layer_zero():
    g = toy_net()
    aux = g.layer("cat")
    lms = list(enumerate_lms(aux, 2, 2, cap=4))
    lat, en, stored = comm_estimate_batch(aux, PAPER_4X4, lms, [1] * len(lms))
    assert not lat.any() and not en.any() and not stored.any()


# ---------------------------------------------------------------------------
# array-form knapsack: numpy vs Pallas reduction
# ---------------------------------------------------------------------------


@st.composite
def knapsack_instance(draw):
    n_layers = draw(st.integers(1, 4))
    layers = []
    for i in range(n_layers):
        cands = [(c, draw(st.floats(0.1, 10.0)),
                  draw(st.integers(0, 6)) * 1000.0, None)
                 for c in range(draw(st.integers(1, 3)))]
        cands.sort(key=lambda t: -t[2])
        layers.append((f"l{i}", tuple(cands)))
    return layers, draw(st.integers(4, 12))


@given(knapsack_instance())
@settings(max_examples=25)
def test_knapsack_pallas_matches_numpy(inst):
    layers, units = inst
    a = RegionTable(layers, units, 1000.0, reduce="numpy")
    b = RegionTable(layers, units, 1000.0, reduce="pallas")
    np.testing.assert_array_equal(a.perf, b.perf)
    np.testing.assert_array_equal(a.choice, b.choice)
    np.testing.assert_array_equal(a.eff, b.eff)
    assert a.backtrack(units) == b.backtrack(units)


def test_knapsack_pallas_matches_numpy_seeded():
    """Deterministic twin of the property test (runs without hypothesis)."""
    rng = random.Random(11)
    for _ in range(30):
        layers = []
        for i in range(rng.randint(1, 5)):
            cands = [(c, rng.uniform(0.1, 10.0), rng.randint(0, 8) * 1000.0,
                      None) for c in range(rng.randint(1, 4))]
            cands.sort(key=lambda t: -t[2])
            layers.append((f"l{i}", tuple(cands)))
        units = rng.randint(4, 16)
        a = RegionTable(layers, units, 1000.0, reduce="numpy")
        b = RegionTable(layers, units, 1000.0, reduce="pallas")
        np.testing.assert_array_equal(a.perf, b.perf)
        np.testing.assert_array_equal(a.choice, b.choice)
        assert a.backtrack(units) == b.backtrack(units)


# ---------------------------------------------------------------------------
# segment min-plus convolution: array form vs the old sequential loop
# ---------------------------------------------------------------------------


INF = float("inf")


def _minplus_ref(tab, best):
    """The removed O(units^2) per-prefix Python loop, verbatim."""
    units = len(tab) - 1
    ntab = np.full(units + 1, INF)
    arg_i = np.full(units + 1, -1, np.int32)
    for i in range(units + 1):
        if not np.isfinite(tab[i]):
            continue
        cand = tab[i] + best[:units + 1 - i]
        seg = ntab[i:]
        better = cand < seg
        ntab[i:] = np.where(better, cand, seg)
        arg_i[i:][better] = i
    return ntab, arg_i


def _monotone_fill_ref(tab, arg_i):
    """The removed sequential monotone fill, verbatim."""
    tab = tab.copy()
    arg_i = arg_i.copy()
    for cap in range(1, len(tab)):
        if tab[cap - 1] < tab[cap]:
            tab[cap] = tab[cap - 1]
            arg_i[cap] = arg_i[cap - 1]
    return tab, arg_i


def _rand_minplus_case(rng, u):
    tab = rng.uniform(0.1, 5.0, u + 1)
    best = rng.uniform(0.1, 5.0, u + 1)
    tab[rng.random(u + 1) < 0.3] = INF
    best[rng.random(u + 1) < 0.3] = INF
    # quantize so ties actually occur and exercise the first-argmin rule
    tab = np.where(np.isfinite(tab), np.round(tab, 1), tab)
    best = np.where(np.isfinite(best), np.round(best, 1), best)
    return tab, best


@pytest.mark.parametrize("reduce", ["numpy", "pallas"])
def test_minplus_convolve_matches_sequential_loop(reduce):
    rng = np.random.default_rng(5)
    for _ in range(40 if reduce == "numpy" else 10):
        u = int(rng.integers(1, 48))
        tab, best = _rand_minplus_case(rng, u)
        ref_tab, ref_arg = _minplus_ref(tab, best)
        got_tab, got_arg = mapper_mod.minplus_convolve(tab, best,
                                                       reduce=reduce)
        np.testing.assert_array_equal(ref_tab, got_tab)
        np.testing.assert_array_equal(ref_arg, got_arg)


def test_minplus_monotone_fill_matches_sequential():
    """The vectorized fill in _solve_sm_lm_wr == the old in-place loop."""
    rng = np.random.default_rng(7)
    for _ in range(40):
        u = int(rng.integers(1, 48))
        tab, best = _rand_minplus_case(rng, u)
        ntab, arg_i = mapper_mod.minplus_convolve(tab, best, reduce="numpy")
        ref_tab, ref_arg = _monotone_fill_ref(ntab, arg_i)
        run = np.minimum.accumulate(ntab)
        src = np.maximum.accumulate(
            np.where(ntab <= run, np.arange(u + 1), 0))
        np.testing.assert_array_equal(ref_tab, run)
        np.testing.assert_array_equal(ref_arg, arg_i[src])


def test_minplus_rows_kernel_matches_numpy():
    from jax.experimental import enable_x64
    from repro.kernels import dse_eval
    rng = np.random.default_rng(9)
    a = rng.uniform(0.0, 4.0, 33)
    a[rng.random(33) < 0.25] = INF
    b = rng.uniform(0.0, 4.0, (17, 33))
    b[rng.random((17, 33)) < 0.25] = INF
    with enable_x64():  # the DP runs the kernel in f64, like the engine
        mn, idx = dse_eval.minplus_rows(a, b, block_r=4)
    scores = a[None, :] + b
    np.testing.assert_array_equal(np.asarray(mn), scores.min(axis=1))
    np.testing.assert_array_equal(np.asarray(idx), scores.argmin(axis=1))


def test_minplus_bad_reduce_rejected():
    with pytest.raises(ValueError):
        mapper_mod.minplus_convolve(np.zeros(4), np.zeros(4), reduce="cuda")


def test_backtrack_zero_candidate_layer_contained():
    # regression: a layer with an empty candidate tuple used to raise
    # ValueError (min() of empty sequence) in backtrack and IndexError in
    # the caller — now it is simply left unpicked
    layers = [("ok", ((0, 1.0, 1000.0, None), (1, 2.0, 0.0, None))),
              ("none", ())]
    tab = RegionTable(layers, 8, 1000.0)
    picks = tab.backtrack(8)
    assert "none" not in picks
    assert picks["ok"] in (0, 1)
    # an all-empty table stays contained too
    tab2 = RegionTable([("none", ())], 8, 1000.0)
    assert tab2.backtrack(8) == {}


def test_knapsack_empty_candidate_list_is_infeasible():
    # a layer with no legal LM contributes an all-INF row (old per-candidate
    # loop semantics), not a crash in the array-form reduction
    layers = [("ok", ((0, 1.0, 1000.0, None),)), ("none", ())]
    tab = RegionTable(layers, 8, 1000.0)
    assert not np.isfinite(tab.perf).any()
    assert (tab.choice[1] == -1).all()


def test_knapsack_bad_reduce_rejected():
    with pytest.raises(ValueError):
        RegionTable([("l0", ((0, 1.0, 0.0, None),))], 4, 1.0, reduce="cuda")


# ---------------------------------------------------------------------------
# spec-chunked engine path
# ---------------------------------------------------------------------------


def test_batch_part_cost_spec_chunk_invariant():
    from repro.engine.batch_cost import PartSpec, batch_part_cost
    layers = [l for l in googlenet(1, scale=4).layers if l.is_heavy][:9]
    specs = [PartSpec(l, DataLayout("BCHW", 4), DataLayout("BHWC"))
             for l in layers]
    a = batch_part_cost([PAPER_4X4, PAPER_BEST], specs)
    b = batch_part_cost([PAPER_4X4, PAPER_BEST], specs, spec_chunk=4)
    np.testing.assert_allclose(a.latency_s, b.latency_s, rtol=0)
    np.testing.assert_allclose(a.energy_pj, b.energy_pj, rtol=0)
    np.testing.assert_array_equal(a.tiling, b.tiling)


# ---------------------------------------------------------------------------
# batched 2-opt scheduler: delta updates + determinism
# ---------------------------------------------------------------------------


def test_move_deltas_match_rebuild():
    rng = random.Random(3)
    noc = MeshNoc(4, 4)
    for _ in range(40):
        n = rng.randint(4, 10)
        nodes = rng.sample(range(16), n)
        chunk = 64.0
        w = (n - 1) * chunk
        cyc = list(nodes)
        inc = noc.route_incidence(tuple(sorted(nodes)))
        loads = noc.link_loads_np(_all_transfers([cyc], [chunk]))
        moves = _propose_moves([cyc], rng, 3)
        for (si, i, j) in moves:
            rem, add = _move_edges(cyc, i, j)
            delta = np.zeros(loads.size)
            for sign, edges in ((1.0, add), (-1.0, rem)):
                ids = [inc[e] for e in edges if e[0] != e[1]]
                if ids:
                    np.add.at(delta, np.concatenate(ids), sign)
            cyc = _apply_2opt(cyc, i, j)
            loads = loads + w * delta
            ref = noc.link_loads_np(_all_transfers([cyc], [chunk]))
            np.testing.assert_allclose(loads, ref)


def test_batched_ls_still_deterministic_and_competitive():
    noc = MeshNoc(4, 4)
    sets = [[0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15]]
    chunks = [4096.0, 4096.0]
    a = solve_ilp_ls(noc, sets, chunks, 3.2e9, 400e6, 1.1, seed=9)
    b = solve_ilp_ls(noc, sets, chunks, 3.2e9, 400e6, 1.1, seed=9)
    assert a.cycles == b.cycles and a.max_link_bytes == b.max_link_bytes
    # a snake seed alone achieves this bound; LS must not end up worse
    from repro.core.scheduler import solve_tsp
    tsp = solve_tsp(noc, sets, chunks, 3.2e9, 400e6, 1.1)
    assert a.max_link_bytes <= tsp.max_link_bytes + 1e-6


# ---------------------------------------------------------------------------
# bounded caches + the campaign clear hook
# ---------------------------------------------------------------------------


def test_bounded_cache_evicts():
    c = mapper_mod._BoundedCache(maxsize=3)
    for i in range(5):
        c.put(i, i)
    assert len(c._d) == 3
    assert 0 not in c and 4 in c


def test_clear_mapper_caches_drops_everything():
    g = toy_net()
    PimMapper(PAPER_4X4, max_optim_iter=1, backend="batched").map(g)
    assert len(mapper_mod._BATCH_CANDS._d) > 0
    assert len(mapper_mod._NODE_LAT._d) > 0
    clear_mapper_caches()
    assert len(mapper_mod._BATCH_CANDS._d) == 0
    assert len(mapper_mod._NODE_LAT._d) == 0
    assert len(mapper_mod._CAND_STRUCT._d) == 0
    assert mapper_mod._layer_candidates.cache_info().currsize == 0


def test_evaluator_clears_between_configs():
    from repro.core.dse import WorkloadEvaluator
    ev = WorkloadEvaluator([googlenet(1, scale=8)],
                           mapper_kwargs=dict(max_optim_iter=1, lm_cap=20,
                                              n_wr=2),
                           clear_caches_between_configs=True)
    cost, _, _ = ev(PAPER_4X4)
    assert cost > 0
    assert len(mapper_mod._BATCH_CANDS._d) == 0
    assert mapper_mod._sharing_latency.cache_info().currsize == 0


def test_evaluator_backend_keys_content_cache():
    from repro.core.dse import WorkloadEvaluator
    wl = [googlenet(1, scale=8)]
    kw = dict(max_optim_iter=1, lm_cap=20, n_wr=2)
    a = WorkloadEvaluator(wl, mapper_kwargs=kw, mapper_backend="batched")
    b = WorkloadEvaluator(wl, mapper_kwargs=kw, mapper_backend="scalar")
    assert a.mapper_kwargs["backend"] == "batched"
    assert a._content_key(PAPER_4X4) != b._content_key(PAPER_4X4)
