"""Sharded mega-campaign runner + persistent EvalCache tests.

Covers the PR 9 contracts: corrupt-cache loads stay loud, the sqlite store
survives concurrent writers with coherent stats, checkpoint throttling
keeps the final state complete, and a sharded campaign's observation
stream is bit-identical to its single-stream ``run_dse`` twin — including
after a simulated mid-campaign kill, where the persistent cache must serve
every already-evaluated point (zero re-mapping).
"""

import json
import math
import threading

import pytest

from repro.core.dse import WorkloadEvaluator, run_dse
from repro.core.surrogates import make_strategy
from repro.core.workloads import googlenet
from repro.engine import (Campaign, CampaignResult, EvalCache,
                          PersistentEvalCache, ShardedCampaign, TenantSpec,
                          campaign_mesh, shard_config_rows)
from repro.engine.pareto import ParetoFront
from repro.obs import metrics as obs_metrics

MAPPER_KW = dict(max_optim_iter=1, lm_cap=20, n_wr=2)


@pytest.fixture(scope="module")
def tiny_workloads():
    return [googlenet(1, scale=8)]


# ---------------------------------------------------------------------------
# EvalCache.load robustness (satellite: corrupt checkpoint must be loud)
# ---------------------------------------------------------------------------


def test_evalcache_load_corrupt_json_starts_empty(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text('{"k": [1.0, {}, {}')          # truncated mid-write
    before = obs_metrics.METRICS.counter("cache.discarded").snapshot()
    with pytest.warns(RuntimeWarning, match="unreadable"):
        cache = EvalCache.load(p)
    assert len(cache) == 0
    after = obs_metrics.METRICS.counter("cache.discarded").snapshot()
    assert after == before + 1


def test_evalcache_load_missing_file_is_silent(tmp_path):
    before = obs_metrics.METRICS.counter("cache.discarded").snapshot()
    cache = EvalCache.load(tmp_path / "nope.json")
    assert len(cache) == 0
    assert obs_metrics.METRICS.counter("cache.discarded").snapshot() == before


def test_evalcache_save_load_roundtrip(tmp_path):
    p = tmp_path / "cache.json"
    c = EvalCache()
    c.put("a", (math.inf, {}, {}))
    c.put("b", (1.5, {"g": 2.0}, {"g": 3.0}))
    c.save(p)
    c2 = EvalCache.load(p)
    assert c2.get("a") == [math.inf, {}, {}]
    assert c2.get("b") == [1.5, {"g": 2.0}, {"g": 3.0}]


# ---------------------------------------------------------------------------
# PersistentEvalCache
# ---------------------------------------------------------------------------


def test_persistent_cache_cross_instance(tmp_path):
    db = tmp_path / "evals.sqlite"
    c1 = PersistentEvalCache(db)
    c1.put("inf", (math.inf, {}, {}))
    c1.put("fin", (2.5, {"g": 1.0}, {"g": 4.0}))
    # a second instance (another process in real life) sees both entries
    c2 = PersistentEvalCache(db)
    assert len(c2) == 2
    assert c2.get("inf") == [math.inf, {}, {}]     # json round-trip: lists
    assert c2.get("fin") == [2.5, {"g": 1.0}, {"g": 4.0}]
    assert c2.stats["persistent_hits"] == 2
    assert c2.stats["preexisting"] == 2
    # overwriting a key that predates the open is a re-evaluation — the
    # kill-and-resume contract counts (and forbids) these
    c2.put("fin", (2.5, {"g": 1.0}, {"g": 4.0}))
    assert c2.stats["reeval_preexisting"] == 1
    assert c1.stats["reeval_preexisting"] == 0


def test_persistent_cache_corrupt_file_starts_fresh(tmp_path):
    db = tmp_path / "evals.sqlite"
    db.write_bytes(b"this is not a sqlite database at all")
    with pytest.warns(RuntimeWarning, match="unreadable eval cache"):
        c = PersistentEvalCache(db)
    # the corrupt payload is sidelined, not destroyed, and the fresh
    # store is fully functional
    assert (tmp_path / "evals.sqlite.corrupt").read_bytes().startswith(
        b"this is not")
    c.put("k", (1.0, {}, {}))
    assert PersistentEvalCache(db).get("k") == [1.0, {}, {}]
    assert c.stats["preexisting"] == 0


def test_persistent_cache_concurrent_writers(tmp_path):
    db = tmp_path / "evals.sqlite"
    n_threads, n_keys = 6, 40
    errors: list = []

    def hammer(tid: int):
        try:
            store = PersistentEvalCache(db)
            for j in range(n_keys):
                store.put(f"w{tid}.{j}", (float(j), {}, {"e": float(tid)}))
                got = store.get(f"w{tid}.{j}")
                assert got == (float(j), {}, {"e": float(tid)})
        except Exception as e:        # surface into the main thread
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    # no lost entries, no corruption: every key readable from a fresh view
    fresh = PersistentEvalCache(db)
    assert len(fresh) == n_threads * n_keys
    for tid in range(n_threads):
        for j in range(n_keys):
            assert fresh.get(f"w{tid}.{j}") == [float(j), {},
                                                {"e": float(tid)}]
    stats = fresh.stats
    assert stats["hits"] == n_threads * n_keys
    assert stats["persistent_hits"] == n_threads * n_keys
    assert stats["misses"] == 0


def test_single_flight_concurrent_evaluators(tiny_workloads, tmp_path):
    """Two evaluators racing on the SAME config map it exactly once.

    This is the sharded campaign's duplicated-submission contract: tenant
    waves evaluating concurrently lease each content key, so the loser
    blocks on the winner's commit instead of re-running the mapper.
    """
    cache = PersistentEvalCache(tmp_path / "evals.sqlite")
    evs = [WorkloadEvaluator(tiny_workloads, cache=cache,
                             mapper_kwargs=MAPPER_KW)
           for _ in range(2)]
    from repro.core.hardware import DEFAULT_CONSTRAINTS, sample_configs_batch
    import numpy as np
    cfg = sample_configs_batch(1, np.random.default_rng(0),
                               DEFAULT_CONSTRAINTS)[0]
    results, errors = [], []
    barrier = threading.Barrier(2)

    def go(ev):
        try:
            barrier.wait()
            results.append(ev.evaluate_batch([cfg])[0])
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=go, args=(ev,)) for ev in evs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert results[0] == results[1]
    assert sum(ev.evaluations for ev in evs) == 1     # single flight
    assert cache.stats["flight_waits"] >= 1


def test_persistent_cache_works_as_campaign_cache(tiny_workloads, tmp_path):
    db = tmp_path / "evals.sqlite"
    kw = dict(iterations=1, propose_k=2, seed=5, n_sample=32,
              evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW))
    out1 = Campaign(tiny_workloads, ("random",),
                    cache=PersistentEvalCache(db), **kw).run()
    # a SECOND campaign process over the same search: every evaluation is
    # served from disk, the mapper never runs
    c2 = PersistentEvalCache(db)
    out2 = Campaign(tiny_workloads, ("random",), cache=c2, **kw).run()
    assert out2.cache_stats["misses"] == 0
    assert c2.stats["reeval_preexisting"] == 0
    a = [o.cfg.as_tuple() for o in out1.results["random"].observations]
    b = [o.cfg.as_tuple() for o in out2.results["random"].observations]
    assert a == b


# ---------------------------------------------------------------------------
# campaign satellites: checkpoint throttle, best() on empty
# ---------------------------------------------------------------------------


def test_checkpoint_every_n_throttles_but_completes(tiny_workloads,
                                                    tmp_path):
    writes = []
    kw = dict(iterations=3, propose_k=2, seed=2, n_sample=32,
              evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW))
    ck = tmp_path / "ck.json"
    camp = Campaign(tiny_workloads, ("random",), checkpoint=ck,
                    checkpoint_every_n=2, **kw)
    orig = camp._write_checkpoint

    def counting_write():
        writes.append(1)
        orig()
    camp._write_checkpoint = counting_write
    out = camp.run()
    # 3 iterations / every-2 -> 1 throttled write, +1 final = 2 (vs 4
    # with the default); the final state is still complete
    assert len(writes) == 2
    state = json.loads(ck.read_text())
    iters = {o["iteration"] for o in state["strategies"]["random"]}
    assert iters == {0, 1, 2}
    assert len(out.results["random"].observations) >= 3


def test_checkpoint_every_n_validation(tiny_workloads):
    with pytest.raises(ValueError, match="checkpoint_every_n"):
        Campaign(tiny_workloads, ("random",), checkpoint_every_n=0)


def test_campaign_result_best_empty_raises():
    from repro.core.dse import DseResult
    res = CampaignResult(results={"s": DseResult([])},
                         pareto=ParetoFront(), cache_stats={})
    with pytest.raises(ValueError, match="no legal observations"):
        res.best()


# ---------------------------------------------------------------------------
# sharded runner: mesh helpers + bit parity + kill-and-resume
# ---------------------------------------------------------------------------


def test_shard_config_rows_divisibility(tmp_path):
    import numpy as np
    mesh = campaign_mesh()          # 1 device under plain pytest
    x = shard_config_rows(mesh, np.arange(12.0).reshape(6, 2))
    assert x.shape == (6, 2)
    import numpy.testing as npt
    npt.assert_array_equal(np.asarray(x),
                           np.arange(12.0).reshape(6, 2))


def _tenant(tiny_workloads, seed, iterations=2):
    return TenantSpec(name=f"t{seed}", workloads=tiny_workloads, seed=seed,
                      iterations=iterations, propose_k=4, n_sample=64,
                      evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW))


def _stream(res):
    return [(o.iteration, o.cfg.as_tuple(), o.legal, o.cost)
            for o in res.observations]


def test_sharded_campaign_bit_parity_with_run_dse(tiny_workloads, tmp_path):
    spec = _tenant(tiny_workloads, seed=7)
    strat = make_strategy("nicepim", cons=spec.cons, seed=7, n_sample=64)
    ev = WorkloadEvaluator(tiny_workloads, mapper_kwargs=MAPPER_KW,
                           clear_caches_between_configs=True)
    ref = run_dse(strat, ev, iterations=2, propose_k=4, pipeline=True)

    db = tmp_path / "evals.sqlite"
    ck = tmp_path / "ck.json"
    cache = PersistentEvalCache(db)
    out = ShardedCampaign([spec], cache=cache, checkpoint=ck).run()
    assert _stream(out.results["t7"]) == _stream(ref)
    assert len(out.pareto) >= 1
    assert out.best().cost > 0

    # kill-and-resume: truncate the checkpoint to iteration 0 (as if the
    # process died mid-campaign) — the resumed run replays by re-proposal,
    # with the persistent cache serving every already-evaluated point
    state = json.loads(ck.read_text())
    state["tenants"]["t7"] = [o for o in state["tenants"]["t7"]
                              if o["iteration"] == 0]
    ck.write_text(json.dumps(state))
    cache2 = PersistentEvalCache(db)
    camp2 = ShardedCampaign([_tenant(tiny_workloads, seed=7)], cache=cache2,
                            checkpoint=ck)
    out2 = camp2.run()
    assert out2.resumed == ["t7"]
    # replay-by-re-proposal makes the continued stream BITWISE identical
    # to the uninterrupted reference, not just statistically equivalent
    assert _stream(out2.results["t7"]) == _stream(ref)
    # zero re-mapping of known configs: the mapper never ran and no
    # pre-kill cache entry was overwritten
    assert sum(s.evaluator.evaluations for s in camp2._states) == 0
    assert cache2.stats["reeval_preexisting"] == 0


def test_sharded_campaign_overlaps_multiple_tenants(tiny_workloads,
                                                    tmp_path):
    specs = [_tenant(tiny_workloads, seed=s, iterations=1) for s in (8, 9)]
    out = ShardedCampaign(specs, queue_depth=2, eval_workers=2).run()
    assert set(out.results) == {"t8", "t9"}
    for name in ("t8", "t9"):
        assert len(out.results[name].observations) >= 1
    assert out.wall_s["t8"] > 0 and out.timings_s["t8"] > 0


def test_sharded_campaign_rejects_duplicate_tenants(tiny_workloads):
    spec = _tenant(tiny_workloads, seed=1)
    with pytest.raises(ValueError, match="unique"):
        ShardedCampaign([spec, spec])
