"""Int8 gradient compression with error feedback.

Quantizes each gradient tensor to int8 with a per-tensor scale before it
crosses the network, adding the quantization error back on the next step
(error feedback keeps SGD/Adam convergence; Karimireddy et al. 2019).  Under
pjit the quantize→dequantize pair brackets the gradient all-reduce that GSPMD
inserts, cutting inter-pod gradient bytes 4x (bf16→int8 would be 2x; we
accumulate grads in f32 so the win is 4x) — one of the §Perf hillclimb
candidates for collective-bound cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err):
    """Error-feedback int8 round trip applied leaf-wise.

    Returns (decompressed grads, new error residuals).  The residual carries
    the information lost to quantization into the next step.
    """
    def one(g, e):
        g = g + e
        q, scale = _quantize(g)
        deq = _dequantize(q, scale)
        return deq, g - deq

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_e = jax.tree.unflatten(tree, [o[1] for o in outs])
    return new_g, new_e
