"""Distributed training step: grad accumulation, mixed precision, metrics.

``make_train_step`` builds the pjit-able function lowered by the dry-run and
driven by ``launch/train.py``:

* microbatched gradient accumulation via ``jax.lax.scan`` (keeps activation
  memory at 1/A of the naive step; grads accumulate in f32);
* bf16 parameters / f32 optimizer state (Adam from training.optim);
* global-norm clipping, cosine LR, token-weighted loss metrics.

The returned step is a pure ``(state, batch) -> (state, metrics)`` function;
all sharding comes from the pjit in/out specs (distributed/shardings.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..nn import transformer as tfm
from .optim import Adam, AdamState, cosine_schedule, global_norm


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10000
    microbatches: int = 1
    clip_norm: float = 1.0
    weight_decay: float = 0.01
    fsdp: bool = True
    grad_compression: str = "none"   # none | int8
    # constrain grads to the param sharding (reduce-scatter instead of a
    # full all-reduce). Off by default: the paper-faithful baseline keeps
    # GSPMD's native choice; the §Perf hillclimb flips it on.
    grad_sharding: bool = False


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt: AdamState
    # int8-compression error-feedback residual (zeros when compression off)
    err: Any = None


def make_optimizer(tcfg: TrainConfig) -> Adam:
    return Adam(lr=cosine_schedule(tcfg.lr, tcfg.warmup_steps,
                                   tcfg.total_steps),
                clip_norm=tcfg.clip_norm, weight_decay=tcfg.weight_decay)


def init_state(cfg, tcfg: TrainConfig, key) -> TrainState:
    params = tfm.init_params(cfg, key)
    opt = make_optimizer(tcfg).init(params)
    err = None
    if tcfg.grad_compression == "int8":
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(jnp.zeros((), jnp.int32), params, opt, err)


def _split_micro(batch: Any, a: int) -> Any:
    return jax.tree.map(
        lambda x: x.reshape(a, x.shape[0] // a, *x.shape[1:]), batch)


def make_train_step(cfg, tcfg: TrainConfig, param_specs: Any = None):
    """``param_specs`` (a PartitionSpec pytree matching params) constrains
    gradients to the parameter sharding.  Without it GSPMD may materialize
    replicated f32 gradients and reduce them with a full-size all-reduce
    (measured: 381 GiB/chip on moonshot-16B) instead of the reduce-scatter
    the sharded optimizer update needs."""
    optimizer = make_optimizer(tcfg)

    def _constrain_grads(grads):
        if param_specs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, param_specs)

    def train_step(state: TrainState, batch: Any):
        a = tcfg.microbatches

        def gfn(params, mb):
            return jax.value_and_grad(
                lambda p: tfm.loss_fn(cfg, p, mb), has_aux=True)(params)

        if a > 1:
            micro = _split_micro(batch, a)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, mb):
                gsum, lsum = carry
                (loss, _), g = gfn(state.params, mb)
                gsum = jax.tree.map(
                    lambda s, gi: s + gi.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / a, gsum)
            loss = lsum / a
        else:
            (loss, _), grads = gfn(state.params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        grads = _constrain_grads(grads)
        err = state.err
        if tcfg.grad_compression == "int8":
            from .compression import compress_decompress
            grads, err = compress_decompress(grads, err)

        params, opt = optimizer.apply(grads, state.opt, state.params)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "step": state.step + 1}
        return TrainState(state.step + 1, params, opt, err), metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = tfm.loss_fn(cfg, params, batch)
        return {"loss": loss, **metrics}
    return eval_step
