"""Pure-JAX optimizers over pytrees (no optax offline).

Provides Adam/AdamW with optional global-norm clipping and LR schedules.
Used both by the PIM-Tuner's models (core/tuner.py) and the LM training loop
(training/train_loop.py).  State is a plain pytree so it checkpoints and
shards like parameters.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray   # scalar int32
    mu: PyTree
    nu: PyTree


@dataclass(frozen=True)
class Adam:
    """Adam/AdamW: functional init/update mirroring the optax interface."""

    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = None

    def init(self, params: PyTree) -> AdamState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        return AdamState(jnp.zeros((), jnp.int32), zeros,
                         jax.tree.map(lambda p: jnp.zeros_like(p), params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads: PyTree, state: AdamState,
               params: PyTree) -> tuple[PyTree, AdamState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, AdamState(step, mu, nu)

    def apply(self, grads: PyTree, state: AdamState,
              params: PyTree) -> tuple[PyTree, AdamState]:
        updates, state = self.update(grads, state, params)
        return jax.tree.map(lambda p, u: p + u, params, updates), state


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    floor: float = 0.1) -> Callable:
    """Linear warmup then cosine decay to ``floor * peak_lr``."""

    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        t = jnp.clip((step - warmup_steps) /
                     max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 \
            * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr
