"""Process-wide metrics registry: counters, gauges, histograms.

The DSE stack records *what happened* here — cache hits, recompiles, pow2
bucket occupancy, Pareto front growth, per-iteration search progress — while
:mod:`.trace` records *when*.  Instruments are cheap (one small lock per
instrument, touched at dispatch-site rates, never per candidate) and always
on; campaigns snapshot the registry into :class:`CampaignResult` and the
campaign checkpoint, and ``benchmarks/report.py`` folds the snapshot into
EXPERIMENTS.md.

Naming convention: dotted lowercase paths (``eval_cache.hits``,
``tuner.bucket_fill.filter``, ``dse.random.best_cost``).
"""

from __future__ import annotations

import math
import threading


class Counter:
    """Monotonic event count."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value (sizes, best-so-far, program counts)."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = None

    def set(self, v) -> None:
        with self._lock:
            self.value = v

    def min(self, v) -> None:
        """Keep the running minimum (best-cost style gauges)."""
        with self._lock:
            if self.value is None or v < self.value:
                self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming summary (count / sum / min / max — mean derived).

    Full bucketed histograms are overkill for the campaign metrics; the
    summary is enough to read occupancy and padding waste off a run.
    """

    __slots__ = ("_lock", "count", "total", "vmin", "vmax")

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)

    def snapshot(self):
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "sum": self.total,
                "min": self.vmin, "max": self.vmax,
                "mean": self.total / self.count}


class MetricsRegistry:
    """Thread-safe name -> instrument store with typed get-or-create."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    def _get(self, name: str, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} is {type(inst).__name__}, "
                    f"not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        """Flat ``{name: value}`` dict (histograms become summary dicts)."""
        with self._lock:
            items = sorted(self._instruments.items())
        return {name: inst.snapshot() for name, inst in items}

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


#: The process-wide default registry — instrumented code writes here unless
#: handed an explicit registry (campaigns accept one for test isolation).
METRICS = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return METRICS


def collect_engine_metrics(registry: MetricsRegistry | None = None, *,
                           cache=None, pareto=None) -> dict:
    """Pull point-in-time engine state into gauges and return a snapshot.

    Collects: :class:`EvalCache` hits/misses/entries, every mapper memo's
    current size, per-entry-point XLA compiled-program counts
    (``engine.compiled_program_count``), and the Pareto front size.  Lazy
    imports keep :mod:`repro.obs` free of repro dependencies at import time.
    """
    reg = registry if registry is not None else METRICS
    if cache is not None:
        for k, v in cache.stats.items():
            reg.gauge(f"eval_cache.{k}").set(v)
    if pareto is not None:
        reg.gauge("pareto.size").set(len(pareto))
    try:
        from ..engine.tuner_train import compiled_program_count
        for name, n in compiled_program_count().items():
            reg.gauge(f"xla.programs.{name}").set(n)
    except Exception:
        pass
    try:
        from ..core.mapper import mapper_cache_stats
        for name, size in mapper_cache_stats().items():
            reg.gauge(f"mapper.memo.{name}").set(size)
    except Exception:
        pass
    return reg.snapshot()
