"""Chrome-trace-format campaign tracing.

A :class:`Tracer` collects *complete* (``ph="X"``) trace events — one per
host-side span — into an in-memory list and serializes them as Chrome trace
event format JSON (load the file in ``chrome://tracing`` or Perfetto).  The
DSE stack is instrumented at two levels:

* **phase spans** (``cat="dse"``): ``propose`` / ``map`` / ``schedule`` /
  ``fit`` / ``evaluate`` / ``checkpoint`` emitted by ``run_dse`` /
  ``WorkloadEvaluator`` / ``Campaign``, one timeline row (tid) per strategy
  thread;
* **engine dispatch spans** (``cat="engine"``): ``batch_cost``,
  ``map_many``, ``schedule_many``, ``fit_filter`` / ``fit_dkl``,
  ``score_candidates`` — each also wrapped in a
  :class:`jax.profiler.TraceAnnotation` so the host spans line up with XLA
  device traces when ``jax.profiler.trace`` is active.

Tracing is process-global and opt-in: :func:`install` (or the
:func:`activate` context manager) sets the active tracer; the module-level
:func:`span` helper is the single hot-path entry point and collapses to a
shared no-op context manager when no tracer is installed, so the disabled
path costs one global read + one singleton ``with`` (measured <1% on
``benchmarks/engine_throughput``).

Span ``args`` carry the batch size / pow2 bucket key / cache outcome of the
dispatch; the context manager yields a mutable dict, so outcomes discovered
mid-span can be recorded::

    with span("evaluate", configs=4) as sp:
        sp["cache"] = "hit" if hit else "miss"
"""

from __future__ import annotations

import functools
import json
import threading
import time
from contextlib import contextmanager
from pathlib import Path

try:                                     # host/XLA span alignment is
    from jax.profiler import TraceAnnotation   # best-effort: tracing must
except Exception:                        # work on a jax-less interpreter
    TraceAnnotation = None

_PID = 1          # one "campaign" process row per trace


class _NullSpan:
    """Shared no-op span: the whole disabled-tracing hot path."""

    __slots__ = ()

    def __enter__(self):
        return {}

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe collector of Chrome trace events.

    Timestamps are microseconds from tracer creation (``perf_counter_ns``
    deltas — monotonic across threads).  Every emitting thread gets a
    stable small integer ``tid`` on first use; :meth:`set_thread_name`
    attaches the Chrome ``thread_name`` metadata record (the campaign names
    each strategy thread after its strategy).
    """

    def __init__(self):
        self._t0_ns = time.perf_counter_ns()
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._tids: dict[int, int] = {}
        self._local = threading.local()
        self._meta("process_name", {"name": "campaign"})

    # -- event plumbing ------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def _tid(self) -> int:
        tid = getattr(self._local, "tid", None)
        if tid is None:
            ident = threading.get_ident()
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids) + 1)
            self._local.tid = tid
        return tid

    def _meta(self, name: str, args: dict, tid: int | None = None) -> None:
        ev = {"name": name, "ph": "M", "pid": _PID, "args": args}
        if tid is not None:
            ev["tid"] = tid
        with self._lock:
            self._events.append(ev)

    def set_thread_name(self, name: str) -> None:
        """Label the calling thread's timeline row (e.g. ``strategy:gp``)."""
        self._meta("thread_name", {"name": name}, tid=self._tid())

    @contextmanager
    def span(self, name: str, cat: str = "dse", **args):
        """Record one complete (``X``) event around the body.

        Yields the ``args`` dict — mutate it to attach outcomes (cache
        hit/miss, bucket keys) discovered while the span is open.
        """
        t0 = self._now_us()
        ann = TraceAnnotation(name) if TraceAnnotation is not None else None
        if ann is not None:
            ann.__enter__()
        try:
            yield args
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            t1 = self._now_us()
            ev = {"name": name, "cat": cat, "ph": "X", "ts": t0,
                  "dur": t1 - t0, "pid": _PID, "tid": self._tid(),
                  "args": args}
            with self._lock:
                self._events.append(ev)

    def instant(self, name: str, cat: str = "dse", **args) -> None:
        """Record an instant (``i``) event — warnings, one-shot markers."""
        ev = {"name": name, "cat": cat, "ph": "i", "ts": self._now_us(),
              "pid": _PID, "tid": self._tid(), "s": "t", "args": args}
        with self._lock:
            self._events.append(ev)

    # -- export --------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        """Chrome trace event format object (metadata first, spans by ts)."""
        evs = self.events()
        meta = [e for e in evs if e["ph"] == "M"]
        rest = sorted((e for e in evs if e["ph"] != "M"),
                      key=lambda e: e["ts"])
        return {"traceEvents": meta + rest, "displayTimeUnit": "ms"}

    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome()))
        return path


# ---------------------------------------------------------------------------
# Process-global active tracer
# ---------------------------------------------------------------------------

_ACTIVE: Tracer | None = None


def install(tracer: Tracer | None) -> None:
    """Set (or with ``None`` clear) the process-global active tracer."""
    global _ACTIVE
    _ACTIVE = tracer


def current() -> Tracer | None:
    return _ACTIVE


@contextmanager
def activate(tracer: Tracer):
    """Install ``tracer`` for the block, restoring the previous one after."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    try:
        yield tracer
    finally:
        _ACTIVE = prev


def span(name: str, cat: str = "dse", **args):
    """Span on the active tracer; the shared no-op when tracing is off."""
    t = _ACTIVE
    if t is None:
        return _NULL_SPAN
    return t.span(name, cat=cat, **args)


def instant(name: str, cat: str = "dse", **args) -> None:
    t = _ACTIVE
    if t is not None:
        t.instant(name, cat=cat, **args)


def set_thread_name(name: str) -> None:
    t = _ACTIVE
    if t is not None:
        t.set_thread_name(name)


def traced(name: str, cat: str = "engine", argspec=None):
    """Decorator form of :func:`span` for engine dispatch sites.

    ``argspec(*a, **kw)`` (optional) builds the span args from the call's
    arguments.  The disabled path is one global check + the undecorated
    call — nothing is built or allocated.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            t = _ACTIVE
            if t is None:
                return fn(*a, **kw)
            args = argspec(*a, **kw) if argspec is not None else {}
            with t.span(name, cat=cat, **args):
                return fn(*a, **kw)
        return wrapper
    return deco
