"""Campaign telemetry: Chrome-trace spans + a process-wide metrics registry.

* :mod:`.trace` — thread-safe tracer emitting Chrome trace event format
  JSON (``X`` complete events; pid=campaign, tid=strategy thread), with
  ``span("map"|"schedule"|"fit"|"propose"|"evaluate"|"checkpoint")``
  context managers and :class:`jax.profiler.TraceAnnotation` wrapping on
  the engine dispatch sites so host spans line up with XLA profiles.
* :mod:`.metrics` — counters/gauges/histograms (cache hits, mapper memo
  sizes, compiled-program counts, pow2-bucket occupancy, Pareto size,
  per-iteration best cost), snapshotted into ``CampaignResult`` and the
  campaign checkpoint.

Both are opt-in and near-free when idle: tracing is off until a tracer is
installed; metric writes happen at dispatch-site rates only.
"""

from .metrics import (METRICS, Counter, Gauge, Histogram, MetricsRegistry,
                      collect_engine_metrics, get_registry)
from .trace import (Tracer, activate, current, install, instant, set_thread_name,
                    span, traced)

__all__ = [
    "METRICS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "collect_engine_metrics", "get_registry", "Tracer", "activate",
    "current", "install", "instant", "set_thread_name", "span", "traced",
]
