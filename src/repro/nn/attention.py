"""Attention: GQA / MHA, causal + sliding-window, prefill + decode paths.

Two implementations behind ``cfg.attention_impl``:

* ``"xla"`` — pure jnp einsum/softmax.  Used by the dry-run/roofline so the
  compiled HLO reflects what XLA:TPU would schedule.
* ``"pallas"`` — the flash-attention kernel in ``repro.kernels`` (TPU target,
  validated with interpret=True on CPU).  Numerically equivalent; swapped in
  for real-hardware runs and exercised by the kernel tests.

Shapes: q ``(B, S, H, dh)``; k/v ``(B, T, Hkv, dh)`` with ``H % Hkv == 0``.
Softmax in f32.  ``window = 0`` means full causal.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _causal_mask(s: int, t: int, q_offset, window: int) -> jnp.ndarray:
    """(S, T) boolean mask; query i attends key j iff j <= i (+window)."""
    qi = jnp.arange(s)[:, None] + q_offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window:
        m = m & (kj > qi - window)
    return m


def attend_xla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
               window: int = 0, q_offset=0,
               kv_positions: jnp.ndarray | None = None,
               q_positions: jnp.ndarray | None = None) -> jnp.ndarray:
    """Grouped-query attention, causal (+ optional sliding window).

    ``kv_positions``/``q_positions`` override the iota mask for ring-buffer
    decode caches (entries with position < 0 are invalid).
    """
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dh)
    if kv_positions is not None:
        qp = q_positions[:, :, None] if q_positions is not None else None
        kp = kv_positions[:, None, :]
        m = (kp >= 0) & (kp <= qp)
        if window:
            m = m & (kp > qp - window)
        mask = m[:, None, None, :, :]               # (b,1,1,s,t)
    else:
        mask = _causal_mask(s, t, q_offset, window)[None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    return out.reshape(b, s, h, dh)


def attend_xla_chunked(q, k, v, *, window: int = 0, q_offset=0,
                       chunk: int = 2048) -> jnp.ndarray:
    """Online-softmax attention over K/V chunks — the flash pattern at the
    XLA level (never materializes the full (S, T) scores buffer).

    The chunk loop is a Python unroll, so the dry-run's cost analysis sees
    every block; peak scores memory drops T/chunk-fold.  This is the
    beyond-paper §Perf candidate for memory-bound prefill cells; on TPU the
    Pallas kernel (kernels/flash_attention.py) is the native equivalent.
    """
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    qi = jnp.arange(s)[:, None] + q_offset
    m = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l = jnp.zeros((b, hkv, g, s), jnp.float32)
    acc = jnp.zeros((b, s, hkv, g, dh), jnp.float32)
    for start in range(0, t, chunk):
        kc = k[:, start:start + chunk]
        vc = v[:, start:start + chunk]
        cc = kc.shape[1]
        scores = jnp.einsum("bsngd,btnd->bngst", qg, kc,
                            preferred_element_type=jnp.float32)
        scores = scores / math.sqrt(dh)
        kj = start + jnp.arange(cc)[None, :]
        mask = kj <= qi
        if window:
            mask = mask & (kj > qi - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * jnp.moveaxis(alpha, -1, 1)[..., None] \
            + jnp.einsum("bngst,btnd->bsngd", p, vc.astype(jnp.float32))
        m = m_new
    denom = jnp.moveaxis(jnp.maximum(l, 1e-20), -1, 1)[..., None]
    return (acc / denom).astype(q.dtype).reshape(b, s, h, dh)


def attend(q, k, v, *, impl: str = "xla", window: int = 0, q_offset=0,
           kv_positions=None, q_positions=None):
    if impl == "pallas" and kv_positions is None:
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, window=window,
                                    q_offset=q_offset)
    if impl == "xla_chunked" and kv_positions is None and q.shape[1] > 2048:
        return attend_xla_chunked(q, k, v, window=window, q_offset=q_offset)
    return attend_xla(q, k, v, window=window, q_offset=q_offset,
                      kv_positions=kv_positions, q_positions=q_positions)


# -- parameter init -------------------------------------------------------------


def init_attention(key, cfg, n_layers: int) -> dict:
    from .layers import dense_init
    d, dh = cfg.d_model, cfg.d_head
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (n_layers, d, h * dh), dtype),
        "wk": dense_init(ks[1], d, (n_layers, d, hkv * dh), dtype),
        "wv": dense_init(ks[2], d, (n_layers, d, hkv * dh), dtype),
        "wo": dense_init(ks[3], h * dh, (n_layers, h * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, h * dh), dtype)
        p["bk"] = jnp.zeros((n_layers, hkv * dh), dtype)
        p["bv"] = jnp.zeros((n_layers, hkv * dh), dtype)
    return p


def qkv_project(x: jnp.ndarray, lp: dict, cfg) -> tuple:
    """x: (B, S, D) -> q (B,S,H,dh), k/v (B,S,Hkv,dh) for ONE layer's params."""
    b, s, _ = x.shape
    dh = cfg.d_head
    q = jnp.einsum("bsd,de->bse", x, lp["wq"])
    k = jnp.einsum("bsd,de->bse", x, lp["wk"])
    v = jnp.einsum("bsd,de->bse", x, lp["wv"])
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    from ..distributed.shardings import attn_constraints
    q = q.reshape(b, s, cfg.n_heads, dh)
    k = k.reshape(b, s, cfg.n_kv_heads, dh)
    v = v.reshape(b, s, cfg.n_kv_heads, dh)
    # Without an explicit layout GSPMD may shard the head_dim contraction,
    # turning QK^T into a partial-sum + all-reduce of the full scores tensor
    # (~TB/chip at 4k seq); see distributed.shardings.attn_constraints.
    return attn_constraints(q, k, v)
