"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

The recurrence, per channel:

    r_t = sigmoid(W_r u_t)                      (recurrence gate)
    i_t = sigmoid(W_i u_t)                      (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)      (data-dependent decay)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Train/prefill uses ``jax.lax.associative_scan`` over the sequence (the
recurrence is linear in ``h``); decode carries ``h`` as explicit state.  The
full recurrent block is: linear in, short temporal conv (width 4), RG-LRU,
gated linear out — all per RecurrentGemma.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

C_CONST = 8.0


def _decay(lp: dict, r: jnp.ndarray) -> jnp.ndarray:
    """log a_t = -c * softplus(lambda) * r_t  (f32)."""
    lam = jax.nn.softplus(lp["lambda"].astype(jnp.float32))
    return -C_CONST * lam * r


def rglru_scan(u: jnp.ndarray, lp: dict) -> jnp.ndarray:
    """Associative linear scan over (B, S, D) inputs -> (B, S, D)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsd,d->bsd", uf, lp["wr_diag"].astype(jnp.float32))
                       + lp["br"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,d->bsd", uf, lp["wi_diag"].astype(jnp.float32))
                       + lp["bi"].astype(jnp.float32))
    log_a = _decay(lp, r)
    a = jnp.exp(log_a)
    x = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h.astype(u.dtype)


def rglru_step(u: jnp.ndarray, h_prev: jnp.ndarray, lp: dict) -> tuple:
    """One decode step: u (B, D), h_prev (B, D) f32 -> (out, h)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * lp["wr_diag"].astype(jnp.float32)
                       + lp["br"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * lp["wi_diag"].astype(jnp.float32)
                       + lp["bi"].astype(jnp.float32))
    log_a = _decay(lp, r)
    a = jnp.exp(log_a)
    h = a * h_prev + jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * uf)
    return h.astype(u.dtype), h


# -- temporal conv (width w, causal) -------------------------------------------


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B,S,D), w (W,D) -> (B,S,D)."""
    width = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pads[:, i:i + x.shape[1], :] * w[i]
    return out


def conv1d_step(x: jnp.ndarray, state: jnp.ndarray, w: jnp.ndarray) -> tuple:
    """x (B,D); state (B, W-1, D) holds previous inputs."""
    width = w.shape[0]
    hist = jnp.concatenate([state, x[:, None, :]], axis=1)   # (B, W, D)
    out = jnp.einsum("bwd,wd->bd", hist, w)
    return out, hist[:, 1:, :]


# -- the full recurrent block ----------------------------------------------------


def init_rglru_block(key, cfg, n_layers: int) -> dict:
    from .layers import dense_init
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], d, (n_layers, d, d), dtype),
        "wy": dense_init(ks[1], d, (n_layers, d, d), dtype),
        "wo": dense_init(ks[2], d, (n_layers, d, d), dtype),
        "conv_w": dense_init(ks[3], cfg.conv1d_width,
                             (n_layers, cfg.conv1d_width, d), dtype),
        "wr_diag": jnp.ones((n_layers, d), jnp.float32),
        "wi_diag": jnp.ones((n_layers, d), jnp.float32),
        "br": jnp.zeros((n_layers, d), jnp.float32),
        "bi": jnp.zeros((n_layers, d), jnp.float32),
        # Lambda init so decay a in [0.9, 0.999] at r=1 (paper appendix)
        "lambda": jnp.linspace(0.3, 1.4, d, dtype=jnp.float32)[None, :]
        * jnp.ones((n_layers, 1), jnp.float32),
    }


def rglru_block(x: jnp.ndarray, lp: dict, cfg, *,
                return_state: bool = False):
    """Full recurrent block for train/prefill: (B,S,D) -> (B,S,D)."""
    y = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, lp["wy"]))
    u_raw = jnp.einsum("bsd,de->bse", x, lp["wx"])
    u = causal_conv1d(u_raw, lp["conv_w"])
    h = rglru_scan(u, lp)
    out = jnp.einsum("bsd,de->bse", h * y, lp["wo"])
    if return_state:
        width = lp["conv_w"].shape[0]
        keep = width - 1
        if x.shape[1] < keep:  # short prefill: left-pad the history
            u_raw = jnp.pad(u_raw, ((0, 0), (keep - x.shape[1], 0), (0, 0)))
        conv_state = u_raw[:, -keep:, :]
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}
    return out


def rglru_block_step(x: jnp.ndarray, state: dict, lp: dict, cfg) -> tuple:
    """Decode step: x (B,D), state {'h': (B,D) f32, 'conv': (B,W-1,D)}."""
    y = jax.nn.gelu(x @ lp["wy"])
    u = x @ lp["wx"]
    u, conv_state = conv1d_step(u, state["conv"], lp["conv_w"])
    out, h = rglru_step(u, state["h"], lp)
    return (out * y) @ lp["wo"], {"h": h, "conv": conv_state}
