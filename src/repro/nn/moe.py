"""Mixture-of-Experts FFN with top-k routing and capacity-factor dispatch.

Mesh-TensorFlow-style dense dispatch: tokens are grouped (groups shard over
the ``data`` mesh axis), each group routes its tokens to ``top_k`` experts
with a per-expert capacity ``C = ceil(N * top_k * cf / E)``; dispatch/combine
are one-hot einsums so the whole layer is static-shaped and GSPMD-shardable
(experts shard over the ``model`` axis, which turns the dispatch einsums into
all-to-alls on a real mesh).

Over-capacity tokens are dropped (standard capacity-factor behaviour);
auxiliary load-balancing loss follows Shazeer et al.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    c = math.ceil(n_tokens * top_k * capacity_factor / n_experts)
    return max(4, min(n_tokens, c))


def init_moe(key, cfg, n_layers: int) -> dict:
    from .layers import dense_init
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, (n_layers, d, e), jnp.float32),
        "we1": dense_init(ks[1], d, (n_layers, e, d, f), dtype),
        "we3": dense_init(ks[2], d, (n_layers, e, d, f), dtype),
        "we2": dense_init(ks[3], f, (n_layers, e, f, d), dtype),
    }


def _route(x, lp, cfg):
    """Shared router: returns (probs, gate_vals, idx, pos, keep, cap)."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    cap = moe_capacity(s, e, k, cfg.moe_capacity_factor)
    logits = jnp.einsum("gnd,de->gne", x.astype(jnp.float32), lp["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # (g,n,e)
    gate_vals, idx = jax.lax.top_k(probs, k)                  # (g,n,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    sel = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # (g,n,k,e)
    pos = jnp.cumsum(sel.reshape(b, s * k, e), axis=1).reshape(b, s, k, e) - 1
    pos = jnp.sum(pos * sel, axis=-1)                         # (g,n,k)
    keep = pos < cap
    gate_vals = gate_vals * keep
    return probs, gate_vals, idx, pos, keep, cap


def _aux_loss(probs, idx, e):
    frac_tokens = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32),
                           axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return e * jnp.sum(frac_tokens * frac_probs)


def _expert_ffn(xin, lp):
    h = jnp.einsum("gecd,edf->gecf", xin, lp["we1"])
    gte = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, lp["we3"]))
    return jnp.einsum("gecf,efd->gecd", h * gte, lp["we2"])


def moe_ffn_scatter(x: jnp.ndarray, lp: dict, cfg):
    """Scatter/gather dispatch: no (g,n,e,c) one-hot intermediates.

    The einsum formulation materializes dispatch/combine tensors of
    ``tokens x experts x capacity`` per layer — for 64-128 experts those
    dominate the whole step's memory traffic (observed 10x the FFN bytes in
    the dry-run).  Here tokens scatter-add into the (e*c, d) expert buffer
    and gather back, touching each token exactly twice.
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    probs, gate_vals, idx, pos, keep, cap = _route(x, lp, cfg)
    flat = idx * cap + jnp.where(keep, pos, 0)                # (g,n,k)
    gidx = jnp.arange(b)[:, None, None]
    upd = x[:, :, None, :] * keep[..., None].astype(x.dtype)  # (g,n,k,d)
    xin = jnp.zeros((b, e * cap, d), x.dtype)
    xin = xin.at[gidx, flat].add(upd, mode="drop")
    out_e = _expert_ffn(xin.reshape(b, e, cap, d), lp)
    y = out_e.reshape(b, e * cap, d)[gidx, flat]              # (g,n,k,d)
    out = jnp.einsum("gnkd,gnk->gnd", y, gate_vals.astype(x.dtype))
    return out.reshape(b, s, d), _aux_loss(probs, idx, e)


def moe_ffn(x: jnp.ndarray, lp: dict, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).  One layer's params in ``lp``."""
    if getattr(cfg, "moe_impl", "einsum") == "scatter":
        return moe_ffn_scatter(x, lp, cfg)
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    probs, gate_vals, idx, pos, keep, cap = _route(x, lp, cfg)

    # dispatch: (g,n,e,c) one-hot of the k choices (Mesh-TF formulation)
    disp = jnp.einsum("gnke,gnkc->gnec",
                      jax.nn.one_hot(idx, e, dtype=x.dtype),
                      jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                                     dtype=x.dtype))
    # combine weights: same structure scaled by the gate value of the choice
    comb = jnp.einsum("gnec,gnke,gnk->gnec", disp,
                      jax.nn.one_hot(idx, e, dtype=x.dtype),
                      gate_vals.astype(x.dtype))

    xin = jnp.einsum("gnd,gnec->gecd", x, disp)               # (g,e,c,d)
    out_e = _expert_ffn(xin, lp)
    out = jnp.einsum("gecd,gnec->gnd", out_e, comb)
    return out.reshape(b, s, d), _aux_loss(probs, idx, e)
