"""RWKV6 "Finch" token mixer (arXiv:2404.05892): data-dependent decay WKV.

Per head (dimension ``dh``), with per-channel data-dependent decay ``w_t``:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: dh x dh)
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)    (u: per-channel bonus)

Token shift mixes each projection's input between x_t and x_{t-1} with
learned per-channel coefficients; the decay w_t comes from a small LoRA on
the shifted input (the "data-dependent" part that distinguishes v6 from v5).

Train/prefill runs the recurrence with ``jax.lax.scan`` over time (the
Pallas kernel in repro.kernels provides the chunked TPU version); decode
carries ``S`` explicitly.  The channel mixer is RWKV's squared-ReLU FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DECAY_LORA = 64


def init_rwkv6_block(key, cfg, n_layers: int) -> dict:
    from .layers import dense_init
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    p = {
        "wr": dense_init(ks[0], d, (n_layers, d, d), dtype),
        "wk": dense_init(ks[1], d, (n_layers, d, d), dtype),
        "wv": dense_init(ks[2], d, (n_layers, d, d), dtype),
        "wg": dense_init(ks[3], d, (n_layers, d, d), dtype),
        "wo": dense_init(ks[4], d, (n_layers, d, d), dtype),
        # data-dependent decay LoRA: d -> 64 -> d
        "wd1": dense_init(ks[5], d, (n_layers, d, DECAY_LORA), dtype),
        "wd2": dense_init(ks[6], DECAY_LORA, (n_layers, DECAY_LORA, d), dtype),
        "w0": jnp.full((n_layers, d), -6.0, jnp.float32),  # base decay
        "u": jnp.zeros((n_layers, cfg.n_heads, cfg.d_head), jnp.float32),
        # token-shift mixing coefficients per projection
        "mu_r": jnp.full((n_layers, d), 0.5, dtype),
        "mu_k": jnp.full((n_layers, d), 0.5, dtype),
        "mu_v": jnp.full((n_layers, d), 0.5, dtype),
        "mu_g": jnp.full((n_layers, d), 0.5, dtype),
        "mu_w": jnp.full((n_layers, d), 0.5, dtype),
    }
    return p


def _token_shift(x: jnp.ndarray, x_prev: jnp.ndarray | None = None):
    """(B,S,D) -> previous-token tensor (first position sees zeros/x_prev)."""
    if x_prev is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = x_prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _projections(x: jnp.ndarray, xs: jnp.ndarray, lp: dict, cfg):
    b = x.shape[0]
    s = x.shape[1]
    h, dh = cfg.n_heads, cfg.d_head
    r = jnp.einsum("bsd,de->bse", _mix(x, xs, lp["mu_r"]), lp["wr"])
    k = jnp.einsum("bsd,de->bse", _mix(x, xs, lp["mu_k"]), lp["wk"])
    v = jnp.einsum("bsd,de->bse", _mix(x, xs, lp["mu_v"]), lp["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", _mix(x, xs, lp["mu_g"]),
                               lp["wg"]))
    xw = _mix(x, xs, lp["mu_w"])
    dd = jnp.einsum("bsk,ke->bse", jnp.tanh(
        jnp.einsum("bsd,dk->bsk", xw, lp["wd1"])), lp["wd2"])
    logw = -jnp.exp(jnp.clip(lp["w0"].astype(jnp.float32)
                             + dd.astype(jnp.float32), -20.0, 10.0))
    from ..distributed.shardings import constrain, BATCH_AXES
    shape = (b, s, h, dh)

    def _c(t):
        return constrain(t.reshape(shape), BATCH_AXES, None, "model", None)

    return (_c(r), _c(k), _c(v), g.reshape(b, s, h * dh),
            _c(jnp.exp(logw)))


def wkv6_scan(r, k, v, w, u, *, return_state: bool = False):
    """Reference recurrence over time: all inputs (B,S,H,dh); u (H,dh)."""
    b, s, h, dh = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp                       # (B,H,dh)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, out

    state0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    final, outs = jax.lax.scan(step, state0, xs)
    outs = jnp.moveaxis(outs, 0, 1)                # (B,S,H,dh)
    if return_state:
        return outs, final
    return outs


def rwkv6_time_mix(x: jnp.ndarray, lp: dict, cfg, *, impl: str = "xla",
                   return_state: bool = False):
    """Full time-mix block for train/prefill: (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    xs = _token_shift(x)
    r, k, v, g, w = _projections(x, xs, lp, cfg)
    final = None
    if impl == "pallas" and not return_state:
        from ..kernels import ops as kops
        out = kops.rwkv6(r, k, v, w, lp["u"])
    else:
        out = wkv6_scan(r, k, v, w, lp["u"], return_state=True)
        out, final = out
    out = out.reshape(b, s, d).astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", out, lp["wo"])
    if return_state:
        return out, {"S": final, "shift": x[:, -1]}
    return out


def rwkv6_time_mix_step(x: jnp.ndarray, state: dict, lp: dict, cfg) -> tuple:
    """Decode step: x (B,D); state {'S': (B,H,dh,dh) f32, 'shift': (B,D)}."""
    b, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    x3 = x[:, None, :]
    xs3 = state["shift"][:, None, :]
    r, k, v, g, w = _projections(x3, xs3, lp, cfg)
    rt, kt, vt, wt = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
    uf = lp["u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    out = jnp.einsum("bhk,bhkv->bhv", rt, state["S"] + uf[None, :, :, None] * kv)
    new_s = wt[..., None] * state["S"] + kv
    out = out.reshape(b, d).astype(x.dtype) * g[:, 0]
    return out @ lp["wo"], {"S": new_s, "shift": x}


# -- channel mixer ----------------------------------------------------------------


def init_rwkv6_channel(key, cfg, n_layers: int) -> dict:
    from .layers import dense_init
    d, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ck": dense_init(k1, d, (n_layers, d, f), dtype),
        "cv": dense_init(k2, f, (n_layers, f, d), dtype),
        "mu_c": jnp.full((n_layers, d), 0.5, dtype),
    }


def rwkv6_channel_mix(x: jnp.ndarray, lp: dict) -> jnp.ndarray:
    xs = _token_shift(x)
    xk = _mix(x, xs, lp["mu_c"])
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, lp["ck"])))
    return jnp.einsum("bsf,fd->bsd", k, lp["cv"])


def rwkv6_channel_mix_step(x: jnp.ndarray, shift: jnp.ndarray,
                           lp: dict) -> tuple:
    xk = _mix(x, shift, lp["mu_c"])
    k = jnp.square(jax.nn.relu(xk @ lp["ck"]))
    return k @ lp["cv"], x
