"""Model assembly: every assigned architecture behind one API.

Entry points (all pure functions of ``(cfg, params, ...)``):

* ``init_params(cfg, key)``            — parameter pytree (per-layer stacked)
* ``forward(cfg, params, batch)``      — logits for train/prefill
* ``loss_fn(cfg, params, batch)``      — scalar LM loss (+ metrics)
* ``prefill(cfg, params, batch)``      — (last_logits, cache)
* ``init_cache(cfg, batch_size, max_len)`` — empty decode cache
* ``decode_step(cfg, params, tokens, pos, cache)`` — one-token serve step

Families: ``dense`` / ``audio`` / ``vlm`` (GQA attention + SwiGLU — frontends
are stub embeddings), ``moe`` (top-k expert FFN), ``ssm`` (RWKV6), ``hybrid``
(RecurrentGemma: 2 RG-LRU blocks per local-attention block, scanned in
supergroups).  Blocks run under ``jax.lax.scan`` over stacked parameters;
``cfg.remat="block"`` wraps the block body in ``jax.checkpoint``.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .attention import attend, init_attention, qkv_project
from .layers import (apply_rope, dense_init, embed_tokens,
                     logits_from_embedding, init_mlp, rms_norm,
                     softmax_cross_entropy, swiglu)
from .moe import init_moe, moe_ffn
from .rglru import (causal_conv1d, init_rglru_block, rglru_block,
                    rglru_block_step)
from .rwkv6 import (init_rwkv6_block, init_rwkv6_channel, rwkv6_channel_mix,
                    rwkv6_channel_mix_step, rwkv6_time_mix,
                    rwkv6_time_mix_step)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _hybrid_counts(cfg) -> tuple[int, int, int]:
    """(n_groups, n_rec, n_attn) for the rglru 2:1 layer pattern."""
    period = cfg.rglru_pattern + 1
    n_groups = cfg.n_layers // period
    n_attn = n_groups
    n_rec = cfg.n_layers - n_attn
    return n_groups, n_rec, n_attn


def init_params(cfg, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {
        "embed": dense_init(keys[0], d, (cfg.vocab, d), dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(keys[1], d, (d, cfg.vocab), dtype)
    L = cfg.n_layers
    if cfg.family == "ssm":
        p["blocks"] = {
            "ln1": jnp.zeros((L, d), jnp.float32),
            "ln2": jnp.zeros((L, d), jnp.float32),
            "time": init_rwkv6_block(keys[2], cfg, L),
            "chan": init_rwkv6_channel(keys[3], cfg, L),
        }
    elif cfg.rglru_pattern > 0:
        ng, n_rec, n_attn = _hybrid_counts(cfg)
        p["rec_blocks"] = {
            "ln1": jnp.zeros((n_rec, d), jnp.float32),
            "ln2": jnp.zeros((n_rec, d), jnp.float32),
            "mix": init_rglru_block(keys[2], cfg, n_rec),
            "mlp": init_mlp(keys[3], d, cfg.d_ff, dtype, n_rec),
        }
        p["attn_blocks"] = {
            "ln1": jnp.zeros((n_attn, d), jnp.float32),
            "ln2": jnp.zeros((n_attn, d), jnp.float32),
            "attn": init_attention(keys[4], cfg, n_attn),
            "mlp": init_mlp(keys[5], d, cfg.d_ff, dtype, n_attn),
        }
    else:
        blocks = {
            "ln1": jnp.zeros((L, d), jnp.float32),
            "ln2": jnp.zeros((L, d), jnp.float32),
            "attn": init_attention(keys[2], cfg, L),
        }
        if cfg.moe_experts > 1:
            blocks["moe"] = init_moe(keys[3], cfg, L)
        else:
            blocks["mlp"] = init_mlp(keys[3], d, cfg.d_ff, dtype, L)
        p["blocks"] = blocks
    return p


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# block bodies (single layer; lp = this layer's slice of the stacked params)
# ---------------------------------------------------------------------------


def _attn_block(cfg, lp, x, positions, window):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(h, lp["attn"], cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attend(q, k, v, impl=cfg.attention_impl, window=window)
    b, s, _, _ = o.shape
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1), lp["attn"]["wo"])
    return x, (k, v)


def _ffn_block(cfg, lp, x):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        out, aux = moe_ffn(h, lp["moe"], cfg)
        return x + out, aux
    return x + swiglu(h, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"]), 0.0


def _dense_layer(cfg, lp, x, positions, window=0):
    x, kv = _attn_block(cfg, lp, x, positions, window)
    x, aux = _ffn_block(cfg, lp, x)
    return x, kv, aux


def _rec_layer(cfg, lp, x):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + rglru_block(h, lp["mix"], cfg)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + swiglu(h, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])


def _rwkv_layer(cfg, lp, x):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + rwkv6_time_mix(h, lp["time"], cfg, impl=cfg.attention_impl)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + rwkv6_channel_mix(h, lp["chan"])


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat == "block" else fn


def _scan(cfg, body, carry, xs):
    """lax.scan over stacked layer params, or a Python unroll when
    ``cfg.scan_layers=False`` (used by the roofline pass: XLA's
    cost_analysis does not multiply while-loop bodies by trip count)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    outs = []
    for i in range(length):
        sl = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        outs.append(y)
    if outs and outs[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *outs)
    else:
        stacked = None
    return carry, stacked


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_input(cfg, params, batch) -> jnp.ndarray:
    """Token and/or frontend-stub embeddings -> (B, S, D)."""
    if cfg.frontend == "audio":
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision":
        tok = embed_tokens(params["embed"], batch["tokens"])
        return jnp.concatenate(
            [batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
    return embed_tokens(params["embed"], batch["tokens"])


def _lm_head(cfg, params, x) -> jnp.ndarray:
    from ..distributed.shardings import constrain, BATCH_AXES
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = logits_from_embedding(x, params["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["head"],
                            preferred_element_type=jnp.float32)
    # keep the vocab dim sharded over `model`: an unsharded (B,S,V) f32
    # logits buffer dominates step memory for 100k+ vocabularies
    if logits.ndim == 3:
        return constrain(logits, BATCH_AXES, None, "model")
    return constrain(logits, BATCH_AXES, "model")


def forward(cfg, params, batch, *, return_cache: bool = False,
            last_only: bool = False):
    """Logits (B, S, V) [f32]; optionally also the prefill KV cache.

    ``last_only`` computes the LM head for the final position only —
    prefill never needs the full (B, S, V) logits buffer, which otherwise
    dominates memory traffic for 100k+ vocabularies at 32k context.
    """
    x = _embed_input(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    aux_total = 0.0
    cache = None

    if cfg.family == "ssm":
        def body(xc, lp):
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            if return_cache:
                out, st = rwkv6_time_mix(h, lp["time"], cfg,
                                         impl=cfg.attention_impl,
                                         return_state=True)
            else:
                out = rwkv6_time_mix(h, lp["time"], cfg,
                                     impl=cfg.attention_impl)
                st = None
            xc = xc + out
            h2 = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            xc = xc + rwkv6_channel_mix(h2, lp["chan"])
            out_state = (st["S"], st["shift"], h2[:, -1]) if return_cache \
                else None
            return xc, out_state

        body = _maybe_remat(cfg, body) if not return_cache else body
        x, states = _scan(cfg, body, x, params["blocks"])
        if return_cache:
            cache = {"S": states[0], "shift_t": states[1],
                     "shift_c": states[2]}
    elif cfg.rglru_pattern > 0:
        x, cache = _hybrid_forward(cfg, params, x, positions, return_cache)
    else:
        win = cfg.local_window

        def body(carry, lp):
            xc, aux = carry
            fn = _maybe_remat(
                cfg, lambda l, x_, p_: _dense_layer(cfg, l, x_, p_, win))
            xo, kv, a = fn(lp, xc, positions)
            out = kv if return_cache else None
            return (xo, aux + a), out

        (x, aux_total), kvs = _scan(cfg, body, (x, 0.0), params["blocks"])
        if return_cache:
            cache = {"k": kvs[0], "v": kvs[1]}   # (L, B, S, Hkv, dh)
    if last_only:
        x = x[:, -1:]
    logits = _lm_head(cfg, params, x)
    if return_cache:
        return logits, cache, aux_total
    return logits, aux_total


def _ring_from_prefill(k, v, seq: int, window: int):
    """Pack the last ``window`` prefill K/V into the decode ring layout
    (entry for position p lives at slot ``p % window``).  The ring is sized
    by the attention window, NOT the prefill length — a shorter ring would
    evict keys that are still visible."""
    w = window if window else seq
    t = min(seq, w)
    p0 = seq - t
    idx = (jnp.arange(t) + p0) % w
    b, _, hkv, dh = k.shape
    ring_k = jnp.zeros((b, w, hkv, dh), k.dtype).at[:, idx].set(k[:, p0:])
    ring_v = jnp.zeros((b, w, hkv, dh), v.dtype).at[:, idx].set(v[:, p0:])
    kpos = jnp.full((b, w), -1, jnp.int32).at[:, idx].set(
        jnp.arange(p0, seq, dtype=jnp.int32)[None, :])
    return ring_k, ring_v, kpos


def _rec_layer_state(cfg, lp, x):
    """_rec_layer variant that also returns the RG-LRU/conv decode state."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    out, st = rglru_block(h, lp["mix"], cfg, return_state=True)
    x = x + out
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + swiglu(h, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
    return x, st


def _hybrid_forward(cfg, params, x, positions, return_cache):
    """RecurrentGemma stack: scan over (rec, rec, attn) supergroups."""
    ng, n_rec, n_attn = _hybrid_counts(cfg)
    per = cfg.rglru_pattern
    rec = params["rec_blocks"]
    att = params["attn_blocks"]
    seq = x.shape[1]
    # supergroup slices: rec layers [g*per:(g+1)*per], attn layer g
    rec_main = jax.tree.map(lambda a: a[:ng * per].reshape(
        ng, per, *a.shape[1:]), rec)
    rec_tail = jax.tree.map(lambda a: a[ng * per:], rec)
    win = cfg.local_window

    def group(xc, lps):
        rlp, alp = lps
        rec_states = []
        for i in range(per):
            lpi = jax.tree.map(lambda a: a[i], rlp)
            if return_cache:
                xc, st = _rec_layer_state(cfg, lpi, xc)
                rec_states.append(st)
            else:
                xc = _maybe_remat(cfg, partial(_rec_layer, cfg))(lpi, xc)
        fn = _maybe_remat(
            cfg, lambda l, x_, p_: _dense_layer(cfg, l, x_, p_, win))
        xc, (k, v), _ = fn(alp, xc, positions)
        if not return_cache:
            return xc, None
        ring_k, ring_v, kpos = _ring_from_prefill(k, v, seq, win)
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *rec_states)
        return xc, (stacked, ring_k, ring_v, kpos)

    x, outs = _scan(cfg, group, x, (rec_main, att))
    n_tail = n_rec - ng * per
    tail_states = []
    for i in range(n_tail):
        lpi = jax.tree.map(lambda a: a[i], rec_tail)
        if return_cache:
            x, st = _rec_layer_state(cfg, lpi, x)
            tail_states.append(st)
        else:
            x = _rec_layer(cfg, lpi, x)
    cache = None
    if return_cache:
        rec_states, ring_k, ring_v, kpos = outs
        # (ng, per, ...) -> (n_rec_main, ...)
        h_all = rec_states["h"].reshape(-1, *rec_states["h"].shape[2:])
        c_all = rec_states["conv"].reshape(-1, *rec_states["conv"].shape[2:])
        if tail_states:
            h_all = jnp.concatenate(
                [h_all, jnp.stack([s["h"] for s in tail_states])], 0)
            c_all = jnp.concatenate(
                [c_all, jnp.stack([s["conv"] for s in tail_states])], 0)
        cache = {"h": h_all, "conv": c_all, "k": ring_k, "v": ring_v,
                 "kpos": kpos}
    return x, cache


def loss_fn(cfg, params, batch):
    """Next-token CE over the batch; returns (loss, metrics)."""
    logits, aux = forward(cfg, params, batch)
    targets = batch["targets"]
    if cfg.frontend == "vision":   # image prefix carries no LM loss
        logits = logits[:, -targets.shape[1]:]
    mask = batch.get("mask")
    ce = softmax_cross_entropy(logits[:, :-1], targets[:, 1:],
                               None if mask is None else mask[:, 1:])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    """Empty decode state sized for ``max_len`` context."""
    dtype = jnp.dtype(cfg.dtype)
    d, dh, hkv = cfg.d_model, cfg.d_head, cfg.n_kv_heads
    if cfg.family == "ssm":
        L = cfg.n_layers
        return {
            "S": jnp.zeros((L, batch_size, cfg.n_heads, dh, dh), jnp.float32),
            "shift_t": jnp.zeros((L, batch_size, d), dtype),
            "shift_c": jnp.zeros((L, batch_size, d), dtype),
        }
    if cfg.rglru_pattern > 0:
        ng, n_rec, n_attn = _hybrid_counts(cfg)
        w = min(cfg.local_window or max_len, max_len)
        return {
            "h": jnp.zeros((n_rec, batch_size, d), jnp.float32),
            "conv": jnp.zeros((n_rec, batch_size, cfg.conv1d_width - 1, d),
                              dtype),
            "k": jnp.zeros((n_attn, batch_size, w, hkv, dh), dtype),
            "v": jnp.zeros((n_attn, batch_size, w, hkv, dh), dtype),
            "kpos": jnp.full((n_attn, batch_size, w), -1, jnp.int32),
        }
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch_size, max_len, hkv, dh), dtype),
        "v": jnp.zeros((L, batch_size, max_len, hkv, dh), dtype),
    }


def _decode_attn(cfg, lp, x, pos, kc, vc, kpos=None, window=0):
    """One-token attention against the cache; returns (x, new slices)."""
    b = x.shape[0]
    h = rms_norm(x[:, None, :], lp["ln1"], cfg.norm_eps)
    q, k, v = qkv_project(h, lp["attn"], cfg)
    posb = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    if kpos is None:
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        t = kc.shape[1]
        kv_positions = jnp.where(jnp.arange(t)[None, :] <= pos,
                                 jnp.arange(t)[None, :], -1)
        kv_positions = jnp.broadcast_to(kv_positions, (b, t))
        new_kpos = None
    else:
        slot = pos % kc.shape[1]
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        kpos = jax.lax.dynamic_update_slice(kpos, posb, (0, slot))
        kv_positions = kpos
        new_kpos = kpos
    o = attend(q, kc, vc, impl="xla", window=window,
               kv_positions=kv_positions, q_positions=posb)
    x = x + jnp.einsum("be,ed->bd", o.reshape(b, -1), lp["attn"]["wo"])
    return x, kc, vc, new_kpos


def decode_step(cfg, params, tokens, pos, cache):
    """One serve step: tokens (B,) int32 at position ``pos`` -> (logits, cache)."""
    if cfg.frontend == "audio":
        # audio decode consumes a precomputed frame embedding instead
        x = tokens if tokens.ndim == 2 else \
            embed_tokens(params["embed"], tokens)
    else:
        x = embed_tokens(params["embed"], tokens)

    if cfg.family == "ssm":
        def body(xc, lps):
            lp, st, sh_t, sh_c = lps
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            out, nst = rwkv6_time_mix_step(h, {"S": st, "shift": sh_t}, lp["time"], cfg)
            xc = xc + out
            h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            out, nshc = rwkv6_channel_mix_step(h, sh_c, lp["chan"])
            return xc + out, (nst["S"], nst["shift"], nshc)

        x, (S, sh_t, sh_c) = _scan(
            cfg, body, x, (params["blocks"], cache["S"], cache["shift_t"],
                           cache["shift_c"]))
        cache = {"S": S, "shift_t": sh_t, "shift_c": sh_c}
    elif cfg.rglru_pattern > 0:
        x, cache = _hybrid_decode(cfg, params, x, pos, cache)
    else:
        def body(xc, lps):
            lp, kc, vc = lps
            xc, kc, vc, _ = _decode_attn(cfg, lp, xc, pos, kc, vc,
                                         window=cfg.local_window)
            h = rms_norm(xc[:, None], lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                out, _ = moe_ffn(h, lp["moe"], cfg)
            else:
                out = swiglu(h, lp["mlp"]["w1"], lp["mlp"]["w3"],
                             lp["mlp"]["w2"])
            return xc + out[:, 0], (kc, vc)

        x, (k, v) = _scan(cfg, body, x, (params["blocks"], cache["k"],
                                          cache["v"]))
        cache = {"k": k, "v": v}
    logits = _lm_head(cfg, params, x)
    return logits, cache


def _hybrid_decode(cfg, params, x, pos, cache):
    ng, n_rec, n_attn = _hybrid_counts(cfg)
    per = cfg.rglru_pattern
    rec = params["rec_blocks"]
    att = params["attn_blocks"]

    def rec_one(xc, lp, h, conv):
        hh = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        out, st = rglru_block_step(hh, {"h": h, "conv": conv}, lp["mix"], cfg)
        xc = xc + out
        hh = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + swiglu(hh[:, None], lp["mlp"]["w1"], lp["mlp"]["w3"],
                         lp["mlp"]["w2"])[:, 0]
        return xc, st["h"], st["conv"]

    rec_main = jax.tree.map(lambda a: a[:ng * per].reshape(
        ng, per, *a.shape[1:]), rec)
    h_main = cache["h"][:ng * per].reshape(ng, per, *cache["h"].shape[1:])
    c_main = cache["conv"][:ng * per].reshape(ng, per, *cache["conv"].shape[1:])

    def group(xc, lps):
        rlp, hg, cg, alp, kc, vc, kp = lps
        nh, nc = [], []
        for i in range(per):
            lpi = jax.tree.map(lambda a: a[i], rlp)
            xc, hi, ci = rec_one(xc, lpi, hg[i], cg[i])
            nh.append(hi)
            nc.append(ci)
        xc, kc, vc, kp = _decode_attn(cfg, alp, xc, pos, kc, vc, kp,
                                      window=cfg.local_window)
        hh = rms_norm(xc[:, None], alp["ln2"], cfg.norm_eps)
        xc = xc + swiglu(hh, alp["mlp"]["w1"], alp["mlp"]["w3"],
                         alp["mlp"]["w2"])[:, 0]
        return xc, (jnp.stack(nh), jnp.stack(nc), kc, vc, kp)

    x, (h_new, c_new, k, v, kp) = _scan(
        cfg, group, x, (rec_main, h_main, c_main, att, cache["k"], cache["v"],
                        cache["kpos"]))
    h_all = h_new.reshape(-1, *h_new.shape[2:])
    c_all = c_new.reshape(-1, *c_new.shape[2:])
    # tail recurrent layers (un-scanned remainder)
    n_tail = n_rec - ng * per
    h_tail, c_tail = [], []
    for i in range(n_tail):
        li = ng * per + i
        lpi = jax.tree.map(lambda a: a[li], rec)
        x, hi, ci = rec_one(x, lpi, cache["h"][li], cache["conv"][li])
        h_tail.append(hi)
        c_tail.append(ci)
    if n_tail:
        h_all = jnp.concatenate([h_all, jnp.stack(h_tail)], 0)
        c_all = jnp.concatenate([c_all, jnp.stack(c_tail)], 0)
    return x, {"h": h_all, "conv": c_all, "k": k, "v": v, "kpos": kp}


def prefill(cfg, params, batch):
    """Prefill: full forward returning (last-token logits, cache)."""
    logits, cache, _ = forward(cfg, params, batch, return_cache=True,
                               last_only=True)
    return logits[:, 0], cache
