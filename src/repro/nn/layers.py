"""Shared NN building blocks: norms, RoPE, embeddings, MLPs, init helpers.

Conventions (followed by every model in the zoo):

* parameters are plain dict pytrees; per-layer tensors are **stacked** along a
  leading ``L`` axis so the block stack runs under ``jax.lax.scan`` (keeps the
  dry-run HLO small enough to compile 64 cells on one CPU core);
* compute dtype is the config dtype (bf16 by default) with f32 for softmax,
  norms, and loss;
* every function is pure; sharding comes from pjit in/out specs plus GSPMD
  propagation (see distributed/shardings.py for the logical rules).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def truncated_normal(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, fan_in: int, shape, dtype) -> jnp.ndarray:
    return truncated_normal(key, shape, 1.0 / math.sqrt(fan_in), dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


# -- rotary position embeddings ----------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# -- embeddings ----------------------------------------------------------------


def embed_tokens(embedding: jnp.ndarray, tokens: jnp.ndarray,
                 scale_by_dim: bool = False) -> jnp.ndarray:
    out = jnp.take(embedding, tokens, axis=0)
    if scale_by_dim:
        out = out * math.sqrt(embedding.shape[-1])
    return out


def logits_from_embedding(x: jnp.ndarray, embedding: jnp.ndarray) -> jnp.ndarray:
    """Tied head: (..., D) x (V, D)^T — accumulate in f32."""
    return jnp.einsum("...d,vd->...v", x, embedding,
                      preferred_element_type=jnp.float32)


# -- MLPs ----------------------------------------------------------------------


def swiglu(x: jnp.ndarray, w1, w3, w2) -> jnp.ndarray:
    """SwiGLU FFN: (x@w1 * silu(x@w3)) @ w2 with bf16 compute."""
    h = jnp.einsum("...d,df->...f", x, w1)
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w3))
    return jnp.einsum("...f,fd->...d", h * g, w2)


def init_mlp(key, d_model: int, d_ff: int, dtype, n_layers: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, d_model, (n_layers, d_model, d_ff), dtype),
        "w3": dense_init(k2, d_model, (n_layers, d_model, d_ff), dtype),
        "w2": dense_init(k3, d_ff, (n_layers, d_ff, d_model), dtype),
    }


# -- losses ----------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                          mask: jnp.ndarray | None = None,
                          z_loss: float = 1e-4) -> jnp.ndarray:
    """Token-mean CE (+ z-loss), sharding-friendly over the vocab dim.

    logits: (..., V) f32-accumulated; targets: (...,) int32.  The target
    logit is selected with a fused iota-compare masked sum instead of
    ``take_along_axis`` — a gather across a model-sharded vocab axis would
    force an all-gather of the full logits buffer.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    tgt = jnp.sum(jnp.where(vocab_iota == targets[..., None], logits, 0.0),
                  axis=-1)
    ce = lse - tgt
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(ce)
    mask = mask.astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
