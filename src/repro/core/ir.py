"""DNN intermediate representation for the NicePIM design-space explorer.

The paper (Sec. II-B) represents every heavy layer with the 7-deep conv loop
nest ``B, K, C, P, Q, HK, WK``; matrix multiplications are convs with a 1x1
filter window and 1x1 ofmap.  Auxiliary layers (add / concat / pooling /
normalization) carry (almost) no MACs and are treated as glue that rides along
with a branch.

A :class:`DnnGraph` is a DAG of :class:`Layer` nodes.  Sec. III-B requires the
graph to be cut into the *smallest serial pieces possible* (**segments**); a
multi-branch segment exposes **branches** that may be mapped onto disjoint
rectangular regions of the PIM-node array.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Iterable

# Layer kinds that perform MAC-heavy work and therefore get partitioned/mapped.
HEAVY_KINDS = ("conv", "matmul", "dwconv")
# Glue kinds: negligible compute, attached to the branch of their predecessor.
AUX_KINDS = ("add", "concat", "pool", "norm", "act", "input", "softmax")


@dataclass(frozen=True, eq=True)
class Layer:
    """One DNN layer in the paper's conv representation.

    ``B, C, H, W`` describe the input tensor, ``K, HK, WK, stride, pad`` the
    filter.  For ``matmul`` layers ``H = W = HK = WK = 1`` so that the ofmap is
    ``1 x 1`` and ``MACs = B * C * K`` (Sec. II-B).
    """

    name: str
    kind: str
    B: int = 1
    C: int = 1
    H: int = 1
    W: int = 1
    K: int = 1
    HK: int = 1
    WK: int = 1
    stride: int = 1
    pad: int = 0

    def __hash__(self) -> int:
        # layers key every mapper/cost-model memo, so the 11-field tuple
        # hash is hot — compute it once per instance
        h = self.__dict__.get("_hash")
        if h is None:
            h = hash((self.name, self.kind, self.B, self.C, self.H, self.W,
                      self.K, self.HK, self.WK, self.stride, self.pad))
            object.__setattr__(self, "_hash", h)
        return h

    # -- derived quantities ------------------------------------------------
    @property
    def P(self) -> int:
        """Output height."""
        if self.kind not in HEAVY_KINDS:
            return self.H
        return max(1, (self.H + 2 * self.pad - self.HK) // self.stride + 1)

    @property
    def Q(self) -> int:
        """Output width."""
        if self.kind not in HEAVY_KINDS:
            return self.W
        return max(1, (self.W + 2 * self.pad - self.WK) // self.stride + 1)

    @property
    def is_heavy(self) -> bool:
        return self.kind in HEAVY_KINDS

    @property
    def macs(self) -> int:
        if not self.is_heavy:
            return 0
        if self.kind == "dwconv":  # depthwise: one filter per channel
            return self.B * self.K * self.P * self.Q * self.HK * self.WK
        return self.B * self.K * self.C * self.P * self.Q * self.HK * self.WK

    @property
    def weight_count(self) -> int:
        if not self.is_heavy:
            return 0
        if self.kind == "dwconv":
            return self.K * self.HK * self.WK
        return self.K * self.C * self.HK * self.WK

    @property
    def ifmap_count(self) -> int:
        return self.B * self.C * self.H * self.W

    @property
    def ofmap_count(self) -> int:
        return self.B * self.K * self.P * self.Q

    def scaled_batch(self, batch: int) -> "Layer":
        return replace(self, B=self.B * batch)


def conv(name: str, B: int, C: int, H: int, W: int, K: int, HK: int = 3,
         WK: int | None = None, stride: int = 1, pad: int | None = None) -> Layer:
    if WK is None:
        WK = HK
    if pad is None:
        pad = HK // 2
    return Layer(name, "conv", B=B, C=C, H=H, W=W, K=K, HK=HK, WK=WK,
                 stride=stride, pad=pad)


def matmul(name: str, B: int, C: int, K: int) -> Layer:
    """``(B, C) @ (C, K)`` in the conv representation (Sec. II-B)."""
    return Layer(name, "matmul", B=B, C=C, H=1, W=1, K=K, HK=1, WK=1,
                 stride=1, pad=0)


@dataclass
class Branch:
    """A serial chain of layers inside one segment (Sec. III-B)."""

    layers: list[str]

    def macs(self, g: "DnnGraph") -> int:
        return sum(g.layer(n).macs for n in self.layers)

    def heavy_layers(self, g: "DnnGraph") -> list[str]:
        return [n for n in self.layers if g.layer(n).is_heavy]


@dataclass
class Segment:
    """The smallest serial piece of the DNN; holds >= 1 parallel branches."""

    index: int
    branches: list[Branch]

    @property
    def n_branches(self) -> int:
        return len(self.branches)

    def macs(self, g: "DnnGraph") -> int:
        return sum(b.macs(g) for b in self.branches)


class DnnGraph:
    """A DAG of layers with segment/branch extraction (Sec. III-B)."""

    def __init__(self, name: str):
        self.name = name
        self._layers: dict[str, Layer] = {}
        self._preds: dict[str, list[str]] = {}
        self._succs: dict[str, list[str]] = {}
        self._order: list[str] = []

    # -- construction ------------------------------------------------------
    def add(self, layer: Layer, preds: Iterable[str] = ()) -> Layer:
        if layer.name in self._layers:
            raise ValueError(f"duplicate layer {layer.name!r}")
        self._layers[layer.name] = layer
        self._preds[layer.name] = list(preds)
        self._succs[layer.name] = []
        for p in preds:
            if p not in self._layers:
                raise ValueError(f"unknown predecessor {p!r} for {layer.name!r}")
            self._succs[p].append(layer.name)
        self._order.append(layer.name)
        return layer

    # -- queries -------------------------------------------------------------
    def layer(self, name: str) -> Layer:
        return self._layers[name]

    @property
    def layers(self) -> list[Layer]:
        return [self._layers[n] for n in self._order]

    def preds(self, name: str) -> list[str]:
        return self._preds[name]

    def succs(self, name: str) -> list[str]:
        return self._succs[name]

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_weights(self) -> int:
        return sum(l.weight_count for l in self.layers)

    def topo_order(self) -> list[str]:
        indeg = {n: len(self._preds[n]) for n in self._order}
        # Kahn, preferring original insertion order for determinism.
        ready = [n for n in self._order if indeg[n] == 0]
        out: list[str] = []
        while ready:
            n = ready.pop(0)
            out.append(n)
            for s in self._succs[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(out) != len(self._order):
            raise ValueError(f"graph {self.name!r} has a cycle")
        return out

    def with_batch(self, batch: int) -> "DnnGraph":
        g = DnnGraph(f"{self.name}_b{batch}")
        for n in self._order:
            g.add(self._layers[n].scaled_batch(batch), self._preds[n])
        return g

    # -- segmentation (Sec. III-B) -------------------------------------------
    def cut_points(self) -> list[str]:
        """Nodes through which every source->sink path passes.

        Scanning the topological order, a node ``v`` is a cut point iff after
        emitting ``v`` no edge crosses from the emitted prefix (other than
        edges out of ``v`` itself) into the remainder.
        """
        topo = self.topo_order()
        open_edges = 0
        cuts = []
        for v in topo:
            open_edges -= len(self._preds[v])
            if open_edges == 0:
                cuts.append(v)
            open_edges += len(self._succs[v])
        return cuts

    def segments(self) -> list[Segment]:
        """Cut the DAG into the smallest serial pieces (paper Fig. 4).

        Each segment spans ``(prev_cut, cut]`` in topological order.  Interior
        nodes are grouped into branches by weak connectivity; a merge node
        (the cut itself, when it has several predecessors and is an auxiliary
        layer) is appended to its first predecessor's branch.
        """
        topo = self.topo_order()
        pos = {n: i for i, n in enumerate(topo)}
        cuts = set(self.cut_points())
        segments: list[Segment] = []
        cur: list[str] = []
        for v in topo:
            cur.append(v)
            if v in cuts:
                branches = self._extract_branches(cur, pos)
                # Pure-input segments (no heavy work at all) are still emitted;
                # the mapper will skip costing them.
                segments.append(Segment(index=len(segments), branches=branches))
                cur = []
        if cur:  # trailing non-cut nodes (multi-output nets)
            segments.append(Segment(index=len(segments),
                                    branches=self._extract_branches(cur, pos)))
        return segments

    def _extract_branches(self, nodes: list[str], pos: dict[str, int]) -> list[Branch]:
        node_set = set(nodes)
        # Union-find over intra-segment edges, but do NOT union across a merge
        # node that joins several branches: a node whose in-segment predecessors
        # number > 1 is a merge point and is attached afterwards.
        parent = {n: n for n in nodes}

        def find(x: str) -> str:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        def union(a: str, b: str) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[ra] = rb

        merge_nodes = [n for n in nodes
                       if len([p for p in self._preds[n] if p in node_set]) > 1]
        merge_set = set(merge_nodes)
        for n in nodes:
            if n in merge_set:
                continue
            for p in self._preds[n]:
                if p in node_set and p not in merge_set:
                    union(n, p)
        groups: dict[str, list[str]] = {}
        for n in nodes:
            if n in merge_set:
                continue
            groups.setdefault(find(n), []).append(n)
        # Attach each merge node to the branch of its first in-segment pred.
        for m in merge_nodes:
            preds_in = [p for p in self._preds[m] if p in node_set and p not in merge_set]
            if preds_in:
                groups.setdefault(find(preds_in[0]), []).append(m)
            else:  # merge of merges: own (auxiliary) branch
                groups[m] = [m]
        branches = [Branch(sorted(g, key=lambda n: pos[n])) for g in groups.values()]
        branches.sort(key=lambda b: pos[b.layers[0]])
        return branches

    # -- data-dependency pairs for the DL consistency pass (Sec. VI-C) --------
    def dependent_pairs(self) -> list[tuple[str, str]]:
        out = []
        for n in self._order:
            for s in self._succs[n]:
                out.append((n, s))
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"DnnGraph({self.name!r}, layers={len(self._layers)})"


def chain(g: DnnGraph, layers: list[Layer]) -> str:
    """Convenience: add ``layers`` as a serial chain, returning the last name."""
    prev: list[str] = []
    for l in layers:
        g.add(l, prev)
        prev = [l.name]
    return prev[0]
