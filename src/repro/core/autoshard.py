"""Beyond-paper: NicePIM's DSE loop re-targeted at TPU sharding plans.

The mapping dictionary (DESIGN.md §3): a *ShardPlan* plays the role of the
paper's per-layer LM/WR choice — parallelism axes, replication degree
(FSDP on/off = WR full vs 1), microbatching (the PIM-node buffer-tiling
analogue), remat policy, and gradient compression (a collective-schedule
knob like the Data-Scheduler's).  The cost oracle is the dry-run roofline:
``max(compute, memory, collective)`` per step from the compiled artifact,
with bytes-per-device as the capacity constraint (the paper's CAP).

``enumerate_plans`` produces the candidate set; ``evaluate_plan`` lowers the
cell with the plan applied; ``hillclimb`` runs the paper's iterate-on-the-
dominant-term loop and emits EXPERIMENTS.md §Perf entries.
"""

from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, field
from pathlib import Path


@dataclass(frozen=True)
class ShardPlan:
    fsdp: bool = True
    tp: bool = True                     # model-axis tensor parallelism
    microbatches: int | None = None     # None = dryrun default
    remat: str = "block"                # none | block
    scan_layers: bool = True
    grad_compression: str = "none"      # none | int8
    moe_capacity_factor: float | None = None
    moe_impl: str | None = None         # None = config default (einsum)
    attention_impl: str | None = None   # None | xla | xla_chunked
    grad_sharding: bool = False         # reduce-scatter gradient constraint
    note: str = ""

    def tag(self) -> str:
        mb = self.microbatches if self.microbatches is not None else "auto"
        return (f"fsdp={int(self.fsdp)},tp={int(self.tp)},"
                f"mb={mb},remat={self.remat},"
                f"comp={self.grad_compression}"
                + (f",cf={self.moe_capacity_factor}"
                   if self.moe_capacity_factor else "")
                + (f",moe={self.moe_impl}" if self.moe_impl else "")
                + (f",attn={self.attention_impl}"
                   if self.attention_impl else "")
                + (",gradRS" if self.grad_sharding else ""))


BASELINE_PLAN = ShardPlan(note="paper-faithful baseline (FSDP + remat + "
                               "default microbatching)")


def enumerate_plans(kind: str, is_moe: bool) -> list[ShardPlan]:
    """Candidate moves, ordered by napkin-math predicted win size
    (the §Perf methodology: biggest predicted delta on the dominant term
    first).  Microbatch count affects the per-device memory *footprint*,
    not the roofline traffic terms, so one mb variant is kept as a control."""
    plans = [BASELINE_PLAN]
    if kind == "train":
        if is_moe:
            plans += [
                ShardPlan(moe_impl="scatter",
                          note="scatter/gather MoE dispatch (no one-hot "
                               "tokens x experts x capacity intermediates)"),
                ShardPlan(moe_impl="scatter", moe_capacity_factor=1.0,
                          note="scatter dispatch + capacity 1.0"),
            ]
        plans += [
            ShardPlan(remat="none", note="no remat (memory for flops)"),
            ShardPlan(grad_compression="int8",
                      note="int8 error-feedback gradient all-reduce"),
            ShardPlan(fsdp=False, note="replicated params (WR=full)"),
            ShardPlan(microbatches=1,
                      note="control: mb changes footprint, not traffic"),
        ]
    elif kind == "prefill":
        plans += [
            ShardPlan(attention_impl="xla_chunked",
                      note="chunked online-softmax attention: never "
                           "materializes the (S,T) scores buffer"),
            ShardPlan(fsdp=False, tp=False,
                      note="fully replicated params: no TP collectives "
                           "(uses 1/model_size of the pod)"),
        ]
    else:
        plans += [
            ShardPlan(fsdp=False, tp=False,
                      note="fully replicated params: no per-token TP "
                           "collectives (uses 1/model_size of the pod)"),
        ]
    return plans


def apply_plan(cfg, plan: ShardPlan):
    over = {"remat": plan.remat}
    if plan.moe_capacity_factor is not None:
        over["moe_capacity_factor"] = plan.moe_capacity_factor
    if plan.moe_impl is not None:
        over["moe_impl"] = plan.moe_impl
    if plan.attention_impl is not None:
        over["attention_impl"] = plan.attention_impl
    if not plan.scan_layers:
        over["scan_layers"] = False
    return dataclasses.replace(cfg, **over) if over else cfg


def evaluate_plan(arch: str, shape_name: str, plan: ShardPlan, *,
                  multi_pod: bool = False, cost_pass: bool = True) -> dict:
    """Lower+compile the cell under the plan; returns the result dict.

    Must run inside a process with 512 host devices (repro.launch.dryrun
    sets XLA_FLAGS before importing jax; see benchmarks/hillclimb.py).
    """
    from repro.configs.base import SHAPES, get_config
    from repro.launch.dryrun import lower_cell
    from repro.training.train_loop import TrainConfig

    shape = SHAPES[shape_name]
    cfg = apply_plan(get_config(arch), plan)
    tcfg = None
    if shape.kind == "train":
        from repro.launch.dryrun import _microbatches
        mb = plan.microbatches or _microbatches(cfg, shape)
        tcfg = TrainConfig(microbatches=mb, fsdp=plan.fsdp,
                           grad_compression=plan.grad_compression,
                           grad_sharding=plan.grad_sharding)
    result, _ = lower_cell(arch, shape_name, multi_pod=multi_pod,
                           fsdp=plan.fsdp, tp=plan.tp, cfg=cfg, tcfg=tcfg,
                           extra_note=plan.tag(), cost_pass=cost_pass)
    return result


def hillclimb(arch: str, shape_name: str, *, multi_pod: bool = False,
              out_dir: str | Path = "experiments/perf",
              plans: list[ShardPlan] | None = None,
              stop_after_no_gain: int = 5) -> list[dict]:
    """Paper-methodology perf loop: baseline, then iterate candidates on the
    dominant roofline term; log hypothesis -> change -> before/after."""
    from repro.configs.base import get_config
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    is_moe = get_config(arch).moe_experts > 1
    from repro.configs.base import SHAPES
    kind = SHAPES[shape_name].kind
    plans = plans or enumerate_plans(kind, is_moe)

    log: list[dict] = []
    best = None
    no_gain = 0
    for plan in plans:
        t0 = time.time()
        try:
            res = evaluate_plan(arch, shape_name, plan, multi_pod=multi_pod)
            r = res["roofline"]
            mem = res.get("memory", {})
            dev_gb = (mem.get("argument_size_in_bytes", 0)
                      + mem.get("temp_size_in_bytes", 0)
                      + mem.get("output_size_in_bytes", 0)
                      - mem.get("alias_size_in_bytes", 0)) / 2**30
            entry = {
                "plan": plan.tag(), "note": plan.note,
                "step_s": r["step_s"], "bottleneck": r["bottleneck"],
                "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "frac": r["roofline_fraction"],
                "mem_gb": round(dev_gb, 2),
                "fits_hbm": dev_gb <= 16.0,
                "solve_s": round(time.time() - t0, 1),
            }
        except Exception as e:
            entry = {"plan": plan.tag(), "note": plan.note,
                     "error": f"{type(e).__name__}: {e}"}
        log.append(entry)
        if "step_s" in entry:
            if best is None or entry["step_s"] < best["step_s"] * 0.95:
                best = entry
                no_gain = 0
            else:
                no_gain += 1
        if no_gain >= stop_after_no_gain and len(log) > 1:
            break
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    (out_dir / f"{tag}.json").write_text(json.dumps(log, indent=1))
    return log
