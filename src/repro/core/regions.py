"""Inter-branch parallelism (SM, Sec. III-B): slicing-tree region generation.

For a segment with ``N_br`` branches we emit SM candidates with
``N_reg = 1 .. N_br`` rectangular regions.  Branch→region assignment balances
MAC load (LPT greedy); region rectangles come from recursively slicing the
node array proportionally to the assigned load (the paper's slicing-tree
representation [37]).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .ir import DnnGraph, Segment


@dataclass(frozen=True)
class Region:
    h_pos: int
    w_pos: int
    h_shape: int
    w_shape: int

    @property
    def n_nodes(self) -> int:
        return self.h_shape * self.w_shape

    def nodes(self, na_col: int) -> list[int]:
        return [(self.h_pos + r) * na_col + (self.w_pos + c)
                for r in range(self.h_shape) for c in range(self.w_shape)]


@dataclass(frozen=True)
class SM:
    """Segment mapping: regions + branch→region assignment (paper's SM)."""

    n_reg: int
    regions: tuple[Region, ...]
    ir: tuple[int, ...]  # ir[branch] = region index

    def branches_of(self, region: int) -> list[int]:
        return [b for b, r in enumerate(self.ir) if r == region]


def _lpt_assign(loads: list[float], n_bins: int) -> list[int]:
    """Longest-processing-time greedy: balanced branch→region assignment."""
    order = sorted(range(len(loads)), key=lambda i: -loads[i])
    bins = [0.0] * n_bins
    out = [0] * len(loads)
    for i in order:
        b = min(range(n_bins), key=lambda j: bins[j])
        out[i] = b
        bins[b] += loads[i]
    return out


def _slice(rect: tuple[int, int, int, int], loads: list[float],
           idxs: list[int], out: dict[int, Region]) -> None:
    """Recursively split ``rect`` among region indices ``idxs`` by load."""
    h0, w0, hs, ws = rect
    if len(idxs) == 1:
        out[idxs[0]] = Region(h0, w0, hs, ws)
        return
    half = len(idxs) // 2
    a, b = idxs[:half], idxs[half:]
    la = sum(loads[i] for i in a)
    lb = sum(loads[i] for i in b)
    frac = la / max(1e-12, la + lb)
    if hs >= ws:  # split along height
        cut = min(hs - 1, max(1, round(hs * frac)))
        _slice((h0, w0, cut, ws), loads, a, out)
        _slice((h0 + cut, w0, hs - cut, ws), loads, b, out)
    else:
        cut = min(ws - 1, max(1, round(ws * frac)))
        _slice((h0, w0, hs, cut), loads, a, out)
        _slice((h0, w0 + cut, hs, ws - cut), loads, b, out)


def gen_sm_candidates(g: DnnGraph, seg: Segment, na_row: int, na_col: int,
                      max_regions: int | None = None) -> list[SM]:
    """SM candidates with different inter-branch parallelism (Sec. VI-A)."""
    n_br = seg.n_branches
    loads = [max(1.0, float(b.macs(g))) for b in seg.branches]
    cap = min(n_br, na_row * na_col, max_regions or n_br)
    # geometric sweep keeps many-branch segments (BERT heads, MoE experts)
    # tractable while still covering serial..fully-parallel extremes
    n_regs = []
    v = 1
    while v < cap:
        n_regs.append(v)
        v *= 2
    n_regs.append(cap)
    outs: list[SM] = []
    seen: set[tuple] = set()
    for n_reg in n_regs:
        ir = _lpt_assign(loads, n_reg)
        used = sorted(set(ir))
        remap = {r: i for i, r in enumerate(used)}  # drop empty regions
        ir = [remap[r] for r in ir]
        n_used = len(used)
        reg_loads = [0.0] * n_used
        for b, r in enumerate(ir):
            reg_loads[r] += loads[b]
        regions: dict[int, Region] = {}
        _slice((0, 0, na_row, na_col), reg_loads, list(range(n_used)), regions)
        sm = SM(n_used, tuple(regions[i] for i in range(n_used)), tuple(ir))
        key = (sm.regions, sm.ir)
        if key not in seen:
            seen.add(key)
            outs.append(sm)
    return outs
