"""Data-Scheduler (Sec. VII): Hamilton-cycle data-sharing on the mesh NoC.

Each *sharing-set* holds one piece of data distributed 1/N per node; a
Hamilton cycle rotates chunks so after N-1 steps every node has everything —
all nodes send and receive equal amounts (the paper's load-balance argument).
The latency of the whole process is set by the hottest NoC link (Eq. 4),
where each selected cycle edge (a→b) carries ``(N-1) * chunk`` bytes over its
XY route.

The paper solves the joint cycle-selection ILP (MTZ subtour elimination,
Eq. 2–3) with Gurobi.  Gurobi is unavailable offline, so ``solve_ilp_ls``
searches the *same feasible set* (one Hamilton cycle per sharing-set) for the
*same objective* (min max-link-load) with exhaustive enumeration for small
sets and multi-restart 2-opt local search jointly across sets otherwise;
tests verify it matches brute force where brute force is tractable.  The
local search has two backends: ``"scan"`` (default) runs restarts as
parallel chains inside one jitted ``lax.scan`` on the engine layer
(``repro.engine.scheduler_opt``, which also batch-solves many problems at
once via ``schedule_many``); ``"loop"`` is the host-Python reference this
file implements.

Baselines from Sec. VIII-E: ``solve_tsp`` (per-set min-total-hop cycle, the
[47] approach) and ``solve_shp`` (shortest-path unicast of every chunk).
"""

from __future__ import annotations

import itertools
import math
import random
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from .noc import MeshNoc


@dataclass
class ScheduleResult:
    cycles: list[list[int]]          # node order per sharing-set (SHP: [])
    transfers: list[tuple[int, int, float]]
    max_link_bytes: float
    latency_s: float
    energy_pj: float


def _cycle_transfers(cycle: list[int], chunk_bytes: float) -> list[tuple[int, int, float]]:
    n = len(cycle)
    # edge a->next carries (n-1) chunks over the full process
    return [(cycle[i], cycle[(i + 1) % n], (n - 1) * chunk_bytes)
            for i in range(n)]


def _all_transfers(cycles: list[list[int]], chunks: list[float]):
    out: list[tuple[int, int, float]] = []
    for cyc, ch in zip(cycles, chunks):
        if len(cyc) > 1:
            out.extend(_cycle_transfers(cyc, ch))
    return out


def _finish(noc: MeshNoc, cycles, chunks, link_bw: float, freq: float,
            pj_per_bit_hop: float) -> ScheduleResult:
    tr = _all_transfers(cycles, chunks)
    mx = noc.max_link_load(tr)
    lat = noc.transfer_latency_s(tr, link_bw, freq)
    en = noc.transfer_energy_pj(tr, pj_per_bit_hop)
    return ScheduleResult(cycles, tr, mx, lat, en)


# -- 2-opt move algebra (shared by the joint LS and the TSP baseline) ----------

def _apply_2opt(cyc: list[int], i: int, j: int) -> list[int]:
    """The cycle with the segment ``cyc[i:j+1]`` reversed."""
    return cyc[:i] + cyc[i:j + 1][::-1] + cyc[j + 1:]


def _move_edges(cyc: list[int], i: int, j: int):
    """(removed, added) directed cycle edges for reversing ``cyc[i:j+1]``.

    Requires ``0 <= i < j <= len(cyc) - 1`` and not the full-cycle reversal
    ``(0, len - 1)`` (whose edge delta is a direction flip, not a 2-opt).
    Self-loop entries (when the reversal touches the wrap-around) carry no
    load and are filtered by the caller.
    """
    n = len(cyc)
    prv, nxt = cyc[(i - 1) % n], cyc[(j + 1) % n]
    removed = ([(prv, cyc[i])]
               + [(cyc[k], cyc[k + 1]) for k in range(i, j)]
               + [(cyc[j], nxt)])
    added = ([(prv, cyc[j])]
             + [(cyc[k + 1], cyc[k]) for k in range(i, j)]
             + [(cyc[i], nxt)])
    return removed, added


def _propose_moves(cycles: list[list[int]], rng: random.Random,
                   n_moves: int) -> list[tuple[int, int, int]]:
    """Sample ``(set, i, j)`` 2-opt proposals across all eligible cycles.

    The full-cycle reversal ``(0, n - 1)`` is not a 2-opt edge exchange; it
    is *redrawn* rather than skipped so every call returns exactly
    ``n_moves`` proposals (a skipped draw used to silently shrink the
    batch below ``moves_per_round``).
    """
    eligible = [si for si, c in enumerate(cycles) if len(c) >= 4]
    moves = []
    if not eligible:
        return moves
    for _ in range(n_moves):
        si = eligible[rng.randrange(len(eligible))]
        n = len(cycles[si])
        i, j = sorted(rng.sample(range(n), 2))
        while (i, j) == (0, n - 1):
            i, j = sorted(rng.sample(range(n), 2))
        moves.append((si, i, j))
    return moves


def _batch_max_link_load(loads: np.ndarray) -> np.ndarray:
    # deferred: engine.batch_cost transitively imports core.mapper, which
    # imports this module — by call time both are fully initialized
    from ..engine.batch_cost import batch_max_link_load
    return batch_max_link_load(loads)


# -- the ILP-equivalent joint optimizer ---------------------------------------

BACKENDS = ("scan", "loop")


@lru_cache(maxsize=4096)
def _tsp_cycle(noc: MeshNoc, nodes: tuple[int, ...]) -> tuple[int, ...]:
    """Memoized per-set min-total-hop cycle (NN construction + 2-opt).

    Deterministic in ``nodes``, so one memo serves ``solve_tsp``, every
    restart-1 seed of both LS backends, and repeated solves over the same
    sharing sets (a mapper batch revisits the same region shapes often).
    """
    return tuple(_two_opt_distance(noc, _nearest_neighbor_cycle(noc,
                                                                list(nodes))))


def _initial_cycles(noc: MeshNoc, sharing_sets, r: int,
                    rng: random.Random) -> list[list[int]]:
    """Restart ``r``'s starting cycles — shared by both LS backends."""
    cycles = []
    for si, s in enumerate(sharing_sets):
        c = list(s)
        if r == 0:
            # alternate row-/column-snakes across sets: translated sets
            # then load disjoint link classes instead of piling onto the
            # same row links (the coordination the joint ILP encodes)
            c.sort(key=lambda n: _snake_key(noc, n, flip=si % 2 == 1))
        elif r == 1:  # seed with the TSP solution: LS can only improve it
            c = list(_tsp_cycle(noc, tuple(c)))
        elif r == 2:
            c.sort(key=lambda n: _snake_key(noc, n))
        else:
            rng.shuffle(c)
        cycles.append(c)
    return cycles


def solve_ilp_ls(noc: MeshNoc, sharing_sets: list[list[int]],
                 chunk_bytes: list[float], link_bw: float, freq: float,
                 pj_per_bit_hop: float, *, seed: int = 0,
                 restarts: int = 4, iters: int = 400,
                 moves_per_round: int = 32,
                 rng: random.Random | None = None,
                 backend: str = "scan") -> ScheduleResult:
    """Joint min-max-link-load Hamilton cycle selection (paper Eq. 2–4).

    ``backend="scan"`` (default) runs the whole multi-restart 2-opt local
    search as ONE jitted ``lax.scan`` on the engine layer
    (:func:`repro.engine.scheduler_opt.schedule_many` with this single
    problem): restarts become parallel chains, each round scores a batch of
    jax-PRNG move proposals as link-load deltas via gathers + segment-sum
    against the dense :meth:`MeshNoc.route_table` and applies the best
    non-worsening move per sharing-set in-array.  ``backend="loop"`` keeps
    the host-Python reference search (the parity/quality baseline).

    Both backends share the restart initializations (snake / TSP-seeded /
    shuffles), the exhaustive small-set path, and the per-round move budget
    (``iters`` move evaluations in rounds of ``moves_per_round``); they
    draw from different RNG streams, so cycles may differ — quality is
    pinned by the scan<=loop and brute-force tests.  Every random choice
    derives from ``seed`` (or the explicit ``rng``): ``rng=Random(s)`` and
    ``seed=s`` produce the same schedule on either backend, and the global
    ``random`` state is never touched.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown scheduler backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    rng = rng if rng is not None else random.Random(seed)
    small = all(len(s) <= 7 for s in sharing_sets) and len(sharing_sets) == 1
    if small:
        return _solve_exact(noc, sharing_sets, chunk_bytes, link_bw, freq,
                            pj_per_bit_hop)
    if backend == "scan":
        # deferred: engine.scheduler_opt imports this module for the shared
        # move algebra — by call time both are fully initialized
        from ..engine.scheduler_opt import _solve_one_scan
        return _solve_one_scan(noc, sharing_sets, chunk_bytes, link_bw, freq,
                               pj_per_bit_hop, rng=rng, restarts=restarts,
                               iters=iters, moves_per_round=moves_per_round)

    # per-set weight of one cycle edge (Eq. 4: each edge carries N-1 chunks)
    weights = [(len(s) - 1) * ch for s, ch in zip(sharing_sets, chunk_bytes)]
    inc_of = {}
    for s in sharing_sets:
        key = tuple(sorted(s))
        if len(s) >= 4 and key not in inc_of:
            inc_of[key] = noc.route_incidence(key)

    best_cycles = None
    best_obj = math.inf
    rounds = max(1, -(-iters // moves_per_round))
    stall_limit = max(2, 60 // moves_per_round)
    for r in range(max(3, restarts)):
        cycles = _initial_cycles(noc, sharing_sets, r, rng)
        loads = noc.link_loads_np(_all_transfers(cycles, chunk_bytes))
        obj = float(loads.max()) if loads.size else 0.0
        stall = 0
        for _ in range(rounds):
            if stall > stall_limit:
                break
            moves = _propose_moves(cycles, rng, moves_per_round)
            if not moves:
                break
            deltas = np.zeros((len(moves), loads.size))
            for m, (si, i, j) in enumerate(moves):
                inc = inc_of[tuple(sorted(sharing_sets[si]))]
                removed, added = _move_edges(cycles[si], i, j)
                for sign, edges in ((1.0, added), (-1.0, removed)):
                    ids = [inc[e] for e in edges if e[0] != e[1]]
                    if ids:  # routes overlap, so accumulate (not assign)
                        np.add.at(deltas[m], np.concatenate(ids), sign)
                deltas[m] *= weights[si]
            objs = _batch_max_link_load(loads[None, :] + deltas)
            # apply best-first, at most one move per set (later deltas on a
            # reversed cycle would be stale); each application re-checks the
            # true objective against the accumulated loads
            improved = False
            touched: set[int] = set()
            for m in np.argsort(objs, kind="stable"):
                si, i, j = moves[m]
                if si in touched:
                    continue
                cand = loads + deltas[m]
                new_obj = float(cand.max())
                if new_obj <= obj:
                    improved = improved or new_obj < obj
                    touched.add(si)
                    cycles[si] = _apply_2opt(cycles[si], i, j)
                    loads = cand
                    obj = new_obj
            if improved:
                stall = 0
            else:
                stall += 1
        # re-derive the objective from the transfers themselves so restart
        # comparison is free of any accumulated delta round-off
        obj = noc.max_link_load(_all_transfers(cycles, chunk_bytes))
        if obj < best_obj:
            best_obj = obj
            best_cycles = [list(c) for c in cycles]
    return _finish(noc, best_cycles, chunk_bytes, link_bw, freq, pj_per_bit_hop)


def _snake_key(noc: MeshNoc, n: int, flip: bool = False) -> tuple[int, int]:
    r, c = noc.coord(n)
    if flip:  # column-major snake
        return (c, r if c % 2 == 0 else noc.rows - 1 - r)
    return (r, c if r % 2 == 0 else noc.cols - 1 - c)


def _solve_exact(noc: MeshNoc, sharing_sets, chunk_bytes, link_bw, freq,
                 pj_per_bit_hop) -> ScheduleResult:
    """Brute-force the single small sharing-set (reference for tests)."""
    s = sharing_sets[0]
    first, rest = s[0], s[1:]
    best = None
    best_obj = math.inf
    for perm in itertools.permutations(rest):
        cyc = [first] + list(perm)
        obj = noc.max_link_load(_all_transfers([cyc], chunk_bytes))
        if obj < best_obj:
            best_obj = obj
            best = cyc
    return _finish(noc, [best], chunk_bytes, link_bw, freq, pj_per_bit_hop)


# -- baselines (Sec. VIII-E) ---------------------------------------------------

def solve_tsp(noc: MeshNoc, sharing_sets: list[list[int]],
              chunk_bytes: list[float], link_bw: float, freq: float,
              pj_per_bit_hop: float, *, seed: int = 0,
              rng: random.Random | None = None,
              backend: str = "scan") -> ScheduleResult:
    """Per-set min-total-hop Hamilton cycle (the TSP method of [47]).

    Deterministic; ``seed``/``rng``/``backend`` accepted for SOLVERS
    signature parity.
    """
    cycles = [list(_tsp_cycle(noc, tuple(s))) for s in sharing_sets]
    return _finish(noc, cycles, chunk_bytes, link_bw, freq, pj_per_bit_hop)


def _nearest_neighbor_cycle(noc: MeshNoc, nodes: list[int]) -> list[int]:
    rem = list(nodes[1:])
    cyc = [nodes[0]]
    while rem:
        cur = cyc[-1]
        nxt = min(rem, key=lambda n: noc.hops(cur, n))
        rem.remove(nxt)
        cyc.append(nxt)
    return cyc


def _two_opt_distance(noc: MeshNoc, cyc: list[int]) -> list[int]:
    """First-improvement 2-opt on total cycle hop count.

    A reversal of ``cyc[i:j+1]`` only swaps the two boundary edges (interior
    edges reverse direction, and hop distance is symmetric), so each
    candidate is scored by its 2-edge delta in O(1) instead of recomputing
    the whole cycle length — same accept order and integer-exact deltas as
    the old full-recompute sweep, one n lighter in complexity.
    """
    best = list(cyc)
    n = len(best)
    improved = True
    while improved:
        improved = False
        for i in range(1, n - 1):
            for j in range(i + 1, n):
                a, b = best[i - 1], best[i]
                c, d = best[j], best[(j + 1) % n]
                delta = (noc.hops(a, c) + noc.hops(b, d)
                         - noc.hops(a, b) - noc.hops(c, d))
                if delta < 0:
                    best[i:j + 1] = best[i:j + 1][::-1]
                    improved = True
    return best


def solve_shp(noc: MeshNoc, sharing_sets: list[list[int]],
              chunk_bytes: list[float], link_bw: float, freq: float,
              pj_per_bit_hop: float, *, seed: int = 0,
              rng: random.Random | None = None,
              backend: str = "scan") -> ScheduleResult:
    """Shortest-path unicast: every chunk goes owner→consumer directly.

    Deterministic; ``seed``/``rng``/``backend`` accepted for SOLVERS
    signature parity.
    """
    tr: list[tuple[int, int, float]] = []
    for s, ch in zip(sharing_sets, chunk_bytes):
        for src in s:
            for dst in s:
                if src != dst:
                    tr.append((src, dst, ch))
    mx = noc.max_link_load(tr)
    lat = noc.transfer_latency_s(tr, link_bw, freq)
    en = noc.transfer_energy_pj(tr, pj_per_bit_hop)
    return ScheduleResult([], tr, mx, lat, en)


SOLVERS = {"ilp": solve_ilp_ls, "tsp": solve_tsp, "shp": solve_shp}
