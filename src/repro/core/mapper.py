"""PIM-Mapper (Sec. VI): joint SM / LM / WR / DL optimization for one DNN.

Implements the paper's Algorithm 1: candidate generation per segment (SM via
slicing trees; per layer, WR values from full replication down to 1 with the
best LM searched for each), Algorithm 2's dynamic program to pick one
candidate per segment/layer under the per-node DRAM capacity, and the
alternated DL optimization pass (MAX_OPTIM_ITER iterations).

The DP's ``Perf`` values use fast analytic ring estimates for the
data-sharing traffic (``partition.comm_estimate``); the final chosen mapping
is re-costed with the Data-Scheduler's optimized Hamilton cycles
(:func:`evaluate_mapping`), mirroring the paper's mapper→scheduler split.

:meth:`PimMapper.map_many` maps one DNN under a whole batch of hardware
configs in lockstep, costing every phase's candidate sweep in one
multi-config engine call (``engine.batch_part_cost_paired``) — the DSE
loop's ``evaluate_all_legal`` path maps entire proposal batches through it.
"""

from __future__ import annotations

import math
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Sequence

from .costmodel import part_layer_cost
from .hardware import HwConfig
from .ir import DnnGraph, Layer, Segment
from .layout import (DataLayout, enumerate_layouts, sequential_access_cost,
                     tile_access_cost)
from .noc import MeshNoc
from .partition import (LM, comm_batch_geometry, comm_estimate,
                        comm_estimate_batch, comm_eval_geometry,
                        enumerate_lms, group_coords, loop_strides, part_layer,
                        wr_candidates, LOOPS)
from .regions import SM, Region, gen_sm_candidates
from .scheduler import solve_ilp_ls, SOLVERS
from ..obs import trace

INF = float("inf")

BACKENDS = ("batched", "scalar")


@dataclass
class LayerChoice:
    lm: LM
    wr: int
    dl_in: DataLayout
    dl_out: DataLayout
    region: Region
    perf_s: float          # analytic latency estimate used by the DP
    size_bytes: float      # per-node DRAM weight storage


@dataclass
class Mapping:
    graph: DnnGraph
    hw: HwConfig
    segments: list[Segment]
    sm: dict[int, SM]                      # segment index -> SM
    choices: dict[str, LayerChoice]        # heavy layer name -> choice
    est_latency_s: float = 0.0             # DP objective value


@dataclass
class LayerReport:
    name: str
    latency_s: float
    comm_s: float
    energy_pj: float
    e_noc_pj: float
    breakdown: dict[str, float] = field(default_factory=dict)


@dataclass
class EvalReport:
    latency_s: float
    energy_pj: float
    energy_breakdown: dict[str, float]
    layers: list[LayerReport]

    @property
    def edp(self) -> float:
        return self.latency_s * self.energy_pj


# -- candidate generation ------------------------------------------------------
#
# The same (layer shape, region shape, layouts) keys recur constantly across
# deep nets, SM candidates, and DL iterations, so candidate tables are
# memoized — but *bounded*: a long multi-config campaign cycles through many
# HwConfigs and an unbounded cache would grow with every one of them.
# ``clear_mapper_caches`` drops everything between hardware configs.

_CACHE_CANDIDATES = 2048      # candidate tables (one per layer/region/DL key)
_CACHE_NODE_LAT = 65536       # per-(part-layer, DL) node latencies (floats)
_CACHE_SCHEDULES = 4096       # Data-Scheduler solves (see _sharing_latency)


@lru_cache(maxsize=_CACHE_CANDIDATES)
def _layer_candidates(hw: HwConfig, layer: Layer, h_shape: int, w_shape: int,
                      dl_in: DataLayout, dl_out: DataLayout,
                      n_wr: int, lm_cap: int
                      ) -> tuple[tuple[int, float, float, LM], ...]:
    """Per-WR best LM for a layer on an ``h x w`` region (scalar backend).

    Returns ``(wr, perf_s, size_bytes, lm)`` tuples sorted by size desc.
    """
    lms = enumerate_lms(layer, h_shape, w_shape, cap=lm_cap)
    best: dict[int, tuple[float, float, LM]] = {}
    for lm in lms:
        pl = part_layer(layer, lm)
        node = part_layer_cost(hw, pl, dl_in, dl_out)
        for wr in wr_candidates(layer, lm, n_wr):
            ce = comm_estimate(layer, lm, wr, hw)
            perf = node.latency_s + ce.latency_s
            size = ce.weight_bytes_per_node
            cur = best.get(wr)
            if cur is None or perf < cur[0]:
                best[wr] = (perf, size, lm)
    out = [(wr, p, s, lm) for wr, (p, s, lm) in best.items()]
    out.sort(key=lambda t: -t[2])
    return tuple(out)


class _BoundedCache:
    """Tiny bounded memo dict with FIFO eviction.

    Reads are plain (GIL-atomic) dict lookups so the hot path takes no lock;
    writes lock only for the insert-and-trim.  FIFO (not strict LRU) is fine
    here: entries are hw-config-scoped and campaigns clear between configs —
    the bound only guards against pathological single-config growth.
    """

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: OrderedDict = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key, default=None):
        return self._d.get(key, default)

    def __contains__(self, key) -> bool:
        return key in self._d

    def put(self, key, value) -> None:
        with self._lock:
            self._d[key] = value
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def put_many(self, items) -> None:
        """Insert ``(key, value)`` pairs under ONE lock acquisition.

        The multi-config fill writes tens of thousands of node latencies per
        batch; per-entry locking would dominate the fill itself.
        """
        with self._lock:
            d = self._d
            for key, value in items:
                d[key] = value
            while len(d) > self.maxsize:
                d.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()


_BATCH_CANDS = _BoundedCache(_CACHE_CANDIDATES)
_NODE_LAT = _BoundedCache(_CACHE_NODE_LAT)
_CAND_STRUCT = _BoundedCache(_CACHE_CANDIDATES)
_CAND_BASE = _BoundedCache(_CACHE_CANDIDATES)
_COMM_GEOM = _BoundedCache(_CACHE_CANDIDATES)


def clear_mapper_caches() -> None:
    """Drop every mapper-level memo (candidates, node costs, schedules).

    Campaigns call this between configs to keep long multi-config runs at a
    flat memory footprint.  Most entries are keyed by :class:`HwConfig` and
    carry nothing across configurations anyway; the hw-independent shape
    memos (``_CAND_BASE``, ``_COMM_GEOM``) ARE reusable across configs but
    are dropped too, keeping the memory guarantee simple — ``map_many``
    amortizes them across a whole batch before the next clear.
    """
    _layer_candidates.cache_clear()
    _BATCH_CANDS.clear()
    _NODE_LAT.clear()
    _CAND_STRUCT.clear()
    _CAND_BASE.clear()
    _COMM_GEOM.clear()
    _sharing_latency.cache_clear()
    part_layer_cost.cache_clear()
    tile_access_cost.cache_clear()
    sequential_access_cost.cache_clear()


def mapper_cache_stats() -> dict[str, int]:
    """Current size of every mapper-level memo (observability snapshot).

    Keys mirror the module-level cache names; campaigns fold these into
    their metrics snapshot so memo growth is visible without a debugger.
    """
    return {
        "layer_candidates": _layer_candidates.cache_info().currsize,
        "batch_candidates": len(_BATCH_CANDS._d),
        "node_latencies": len(_NODE_LAT._d),
        "candidate_structs": len(_CAND_STRUCT._d),
        "candidate_bases": len(_CAND_BASE._d),
        "comm_geometries": len(_COMM_GEOM._d),
        "schedules": len(_SCHED_MEMO._d),
        "part_layer_costs": part_layer_cost.cache_info().currsize,
        "tile_access_costs": tile_access_cost.cache_info().currsize,
        "sequential_access_costs":
            sequential_access_cost.cache_info().currsize,
    }


def _batched_node_latencies(hw: HwConfig,
                            specs: list[tuple[Layer, DataLayout, DataLayout]]
                            ) -> np.ndarray:
    """Node latency for every ``(part-layer, dl_in, dl_out)`` spec, memoized.

    Misses are costed in ONE chunked :func:`engine.batch_cost.batch_part_cost`
    call — this is the mapper's whole-segment candidate costing hot path.
    """
    keys = [(hw,) + s for s in specs]
    # single cache read per key: a concurrent clear_mapper_caches() (another
    # campaign thread finishing its config) must never be able to swap a
    # value source mid-call — fresh results are kept locally
    vals = [_NODE_LAT.get(key) for key in keys]
    missing: dict[tuple, int] = {}
    for key, v in zip(keys, vals):
        if v is None and key not in missing:
            missing[key] = len(missing)
    if missing:
        from ..engine.batch_cost import batch_part_cost
        lat = batch_part_cost([hw], [k[1:] for k in missing],
                              spec_chunk=1024).latency_s[0]
        fresh = {key: float(lat[j]) for key, j in missing.items()}
        _NODE_LAT.put_many(fresh.items())
        vals = [fresh[key] if v is None else v
                for key, v in zip(keys, vals)]
    return np.array(vals)


def _fill_node_latencies_multi(requests) -> dict:
    """Warm ``_NODE_LAT`` for several configs' spec lists in one engine call.

    ``requests`` is ``[(hw, [spec, ...]), ...]`` with ``spec = (part-layer,
    dl_in, dl_out)``.  Missing cells are costed through ONE multi-config
    ``batch_part_cost_paired`` call per shared :class:`PimConstraints` group
    — each (config, spec) pair rides the engine's spec axis with its config
    fields broadcast alongside, so compute scales with the number of missing
    pairs (configs' candidate sets are mostly disjoint; a full ``[N configs]
    x [union specs]`` grid would waste ~N x the work) while the dispatch
    count drops from one per config to one per batch.

    Returns the freshly costed ``{(hw,) + spec: latency}`` dict.  Callers
    consume it directly (falling back to :func:`_batched_node_latencies` for
    anything not in it): the fills are larger than any single cache bound
    should have to accommodate, so round-tripping a huge batch through the
    FIFO-bounded ``_NODE_LAT`` could evict its own warm entries before they
    are read.  The memo write-back is advisory warming for later sweeps, and
    a concurrent ``clear_mapper_caches`` between fill and read only costs a
    single-config re-derivation.
    """
    return _dispatch_node_fill(requests).resolve()


class _PendingFill:
    """An in-flight multi-config node-latency fill.

    Holds one :class:`~repro.engine.overlap.PendingPairedCost` per
    constraints group; :meth:`resolve` blocks on the device rows (once),
    builds the ``{(hw,) + spec: latency}`` dict, and warms ``_NODE_LAT``
    — the exact tail of the serial ``_fill_node_latencies_multi``.
    """

    __slots__ = ("_groups", "_fresh")

    def __init__(self, groups):
        self._groups = groups
        self._fresh: dict | None = None

    @property
    def ready(self) -> bool:
        return (self._fresh is not None
                or all(p.ready for _, p in self._groups))

    def resolve(self) -> dict:
        if self._fresh is None:
            fresh: dict[tuple, float] = {}
            for pairs, pending in self._groups:
                lat = pending.latency_row()
                for (hw, s), v in zip(pairs, lat):
                    fresh[(hw,) + s] = float(v)
            if fresh:
                _NODE_LAT.put_many(fresh.items())
            self._fresh = fresh
            self._groups = None
        return self._fresh


def _dispatch_node_fill(requests) -> _PendingFill:
    """Dispatch phase of :func:`_fill_node_latencies_multi`.

    Enqueues the paired sweeps for every missing cell and returns a
    :class:`_PendingFill` without blocking on the device results, so
    callers can run host work while the costs are in flight.
    """
    missing: dict[HwConfig, dict[tuple, None]] = {}
    for hw, specs in requests:
        d = missing.setdefault(hw, {})
        for s in specs:
            if (hw,) + s not in _NODE_LAT:
                d[s] = None
    missing = {hw: d for hw, d in missing.items() if d}
    if not missing:
        return _PendingFill(())
    from ..engine.overlap import dispatch_paired_latency
    groups: dict[object, list[HwConfig]] = {}
    for hw in missing:  # one engine batch must share one PimConstraints
        groups.setdefault(hw.cons, []).append(hw)
    out = []
    for hws in groups.values():
        pairs = [(hw, s) for hw in hws for s in missing[hw]]
        pending = dispatch_paired_latency([hw for hw, _ in pairs],
                                          [s for _, s in pairs])
        out.append((pairs, pending))
    return _PendingFill(out)


def _prefetch_candidates_multi(key_lists) -> dict[tuple, tuple]:
    """Cost every missing candidate table of several key sets in one call.

    ``key_lists`` holds one ``_cand_key`` list per hardware config (the hw is
    the first key element); the node latencies of every missing table are
    costed through one multi-config :func:`_fill_node_latencies_multi` pass.
    Returns a table per requested key, like
    :meth:`PimMapper._prefetch_candidates` (which delegates here) — callers
    consume the returned dict rather than re-reading ``_BATCH_CANDS``, so a
    concurrent ``clear_mapper_caches()`` can only ever cost re-derivation,
    never correctness.
    """
    return _dispatch_candidates_multi(key_lists).resolve()


class _PendingTables:
    """In-flight candidate tables: node fills dispatched, tables not built.

    :meth:`resolve` blocks on the underlying :class:`_PendingFill` and
    runs the table-construction tail of ``_prefetch_candidates_multi``.
    """

    __slots__ = ("_out", "_work", "_fill")

    def __init__(self, out, work, fill):
        self._out = out
        self._work = work
        self._fill = fill

    @property
    def ready(self) -> bool:
        return not self._work or self._fill.ready

    def resolve(self) -> dict[tuple, tuple]:
        if self._work:
            fresh = self._fill.resolve()
            for hw, key, struct, specs in self._work:
                node_lat = _node_lat_from(fresh, hw, specs)
                table = _layer_candidates_batched(struct, node_lat)
                self._out[key] = table
                _BATCH_CANDS.put(key, table)
            self._work = ()
        return self._out


def _dispatch_candidates_multi(key_lists) -> _PendingTables:
    """Dispatch phase of :func:`_prefetch_candidates_multi`."""
    out: dict[tuple, tuple] = {}
    work = []
    for keys in key_lists:
        for key in keys:
            if key in out:
                continue
            got = _BATCH_CANDS.get(key)
            if got is None:
                out[key] = ()  # placeholder: dedupes repeated missing keys
                hw, layer, h, w, din, dout, n_wr, lm_cap = key
                struct = _cand_struct(hw, layer, h, w, n_wr, lm_cap)
                work.append((hw, key, struct,
                             [(pl, din, dout) for pl in struct.uniq_pls]))
            else:
                out[key] = got
    if not work:
        return _PendingTables(out, (), None)
    fill = _dispatch_node_fill([(hw, specs) for hw, _, _, specs in work])
    return _PendingTables(out, work, fill)


def _node_lat_from(fresh: dict, hw: HwConfig, specs) -> np.ndarray:
    """Node latencies from a fill's returned dict, memo-backed.

    Prefers the freshly costed values (immune to FIFO self-eviction on huge
    fills), falls back per key to the memo, and re-derives through
    :func:`_batched_node_latencies` only if a concurrent clear dropped both.
    """
    vals = [fresh.get((hw,) + s) for s in specs]
    if any(v is None for v in vals):
        vals = [_NODE_LAT.get((hw,) + s) if v is None else v
                for v, s in zip(vals, specs)]
    if any(v is None for v in vals):
        return _batched_node_latencies(hw, specs)
    return np.array(vals)


@dataclass
class _CandStruct:
    """The DL-independent half of a candidate sweep for (layer, region).

    Built once per (hw, layer, region-shape) and reused across every DL
    iteration and segment that revisits the same shapes — only the node
    latencies (which depend on the data layouts) are re-gathered per key.
    Part-layers are deduped (different P_orders and collapsed ceil-divisions
    share one node cost); ``pair_pl`` maps each (LM x WR) pair to its row in
    ``uniq_pls``.
    """

    uniq_pls: list[Layer]               # deduped part_layer rows
    pair_pl: np.ndarray                 # (LM x WR) pair -> uniq_pls index
    pair_lm_of: list[LM]                # (LM x WR) pair -> LM
    comm_lat: np.ndarray                # vectorized comm_estimate per pair
    stored: np.ndarray                  # weight bytes/node per pair
    by_wr: list[tuple[int, np.ndarray]]  # WR -> pair indices, first-seen order


@dataclass
class _CandBase:
    """The hardware-independent half of :class:`_CandStruct`.

    LM enumeration, part-layer dedup, and the (LM x WR) pair structure
    depend only on (layer, region shape, mapper knobs) — never on the
    :class:`HwConfig` — so one base serves every config that visits the
    shape.  Cached separately from the per-hw comm arrays: a multi-config
    batch builds each base once instead of once per config.
    """

    uniq_pls: list[Layer]
    pair_pl: np.ndarray
    pair_lm_of: list[LM]
    pair_wrs: list[int]
    by_wr: list[tuple[int, np.ndarray]]


def _cand_base(layer: Layer, h_shape: int, w_shape: int,
               n_wr: int, lm_cap: int) -> _CandBase:
    key = (layer, h_shape, w_shape, n_wr, lm_cap)
    got = _CAND_BASE.get(key)
    if got is not None:
        return got
    lms = enumerate_lms(layer, h_shape, w_shape, cap=lm_cap)
    uniq_pls: list[Layer] = []
    pl_index: dict[Layer, int] = {}
    pair_lms: list[LM] = []
    pair_wrs: list[int] = []
    pair_pl: list[int] = []
    for lm in lms:
        pl = part_layer(layer, lm)
        pi = pl_index.get(pl)
        if pi is None:
            pi = pl_index[pl] = len(uniq_pls)
            uniq_pls.append(pl)
        for wr in wr_candidates(layer, lm, n_wr):
            pair_lms.append(lm)
            pair_wrs.append(wr)
            pair_pl.append(pi)
    by_wr: dict[int, list[int]] = {}
    for p, wr in enumerate(pair_wrs):       # first-seen WR order, like the
        by_wr.setdefault(wr, []).append(p)  # scalar best-dict insertion
    base = _CandBase(
        uniq_pls=uniq_pls, pair_pl=np.array(pair_pl, dtype=np.intp),
        pair_lm_of=pair_lms, pair_wrs=pair_wrs,
        by_wr=[(wr, np.array(idxs, dtype=np.intp))
               for wr, idxs in by_wr.items()])
    _CAND_BASE.put(key, base)
    return base


def _cand_struct(hw: HwConfig, layer: Layer, h_shape: int, w_shape: int,
                 n_wr: int, lm_cap: int) -> _CandStruct:
    key = (hw, layer, h_shape, w_shape, n_wr, lm_cap)
    got = _CAND_STRUCT.get(key)
    if got is not None:
        return got
    base = _cand_base(layer, h_shape, w_shape, n_wr, lm_cap)
    m = len(base.pair_lm_of)
    dbytes = hw.cons.data_bits // 8
    psbytes = hw.cons.psum_bits // 8
    if m == 0 or not layer.is_heavy:
        z = np.zeros(m)
        comm_lat, stored = z, z.copy()
    else:
        # the ring/sharing geometry is hw-independent: compute it once per
        # (shape, data-width) key and re-apply only the per-hw scalars —
        # multi-config batches revisit the same shapes under many configs
        gkey = (layer, h_shape, w_shape, n_wr, lm_cap, dbytes, psbytes)
        geom = _COMM_GEOM.get(gkey)
        if geom is None:
            geom = comm_batch_geometry(layer, base.pair_lm_of, base.pair_wrs,
                                       dbytes, psbytes)
            _COMM_GEOM.put(gkey, geom)
        comm_lat, _, stored = comm_eval_geometry(geom, hw)
    struct = _CandStruct(
        uniq_pls=base.uniq_pls, pair_pl=base.pair_pl,
        pair_lm_of=base.pair_lm_of, comm_lat=comm_lat, stored=stored,
        by_wr=base.by_wr)
    _CAND_STRUCT.put(key, struct)
    return struct


def _layer_candidates_batched(struct: _CandStruct, node_lat: np.ndarray
                              ) -> tuple[tuple[int, float, float, LM], ...]:
    """Assemble one candidate table from pre-batched node latencies.

    ``node_lat[i]`` is the node cost of ``struct.uniq_pls[i]``; the (LM x WR)
    communication axis comes pre-scored from the vectorized
    :func:`partition.comm_estimate_batch` and is reduced per WR with the
    same first-strict-< winner rule as the scalar loop (first-argmin).
    """
    perf = node_lat[struct.pair_pl] + struct.comm_lat
    out = []
    for wr, idxs in struct.by_wr:
        p = idxs[int(np.argmin(perf[idxs]))]
        out.append((wr, float(perf[p]), float(struct.stored[p]),
                    struct.pair_lm_of[p]))
    out.sort(key=lambda t: -t[2])
    return tuple(out)


# -- Algorithm 2: DP over capacity --------------------------------------------


import numpy as np

_ON_TPU: bool | None = None


def _on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        try:
            import jax
            _ON_TPU = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover - jax always present here
            _ON_TPU = False
    return _ON_TPU


def _resolve_reduce(reduce: str) -> str:
    if reduce == "auto":
        return "pallas" if _on_tpu() else "numpy"
    if reduce not in ("numpy", "pallas"):
        raise ValueError(f"unknown DP reduce {reduce!r}; "
                         f"expected 'auto', 'numpy' or 'pallas'")
    return reduce


def minplus_convolve(tab: np.ndarray, best: np.ndarray, *,
                     reduce: str = "auto") -> tuple[np.ndarray, np.ndarray]:
    """Min-plus convolution ``out[c] = min_i(tab[i] + best[c - i])`` + argmin.

    Array form of the segment-combination step of Algorithm 2: every
    ``(cap, prefix-budget)`` split of the shared per-node DRAM budget is
    scored at once and reduced with a min + *first*-argmin over the prefix
    budget ``i`` — the exact first-strict-< winner of the old sequential
    i-ascending update loop.  ``reduce`` picks vectorized NumPy or the Pallas
    ``kernels.dse_eval.minplus_rows`` kernel (``interpret=True`` off-TPU).

    Returns ``(out, arg)`` with ``arg[c] = -1`` where no feasible split
    exists (``out[c]`` stays ``inf``), matching the old loop's untouched
    ``arg_i`` cells.
    """
    u = len(tab) - 1
    ext = np.concatenate([np.full(u, INF), best])
    # rows[c, i] = best[c - i] for i <= c, inf otherwise (Toeplitz of best)
    rows = np.lib.stride_tricks.sliding_window_view(ext, u + 1)[:, ::-1]
    if _resolve_reduce(reduce) == "pallas":
        from jax.experimental import enable_x64
        from ..kernels import dse_eval
        with enable_x64():
            mn, idx = dse_eval.minplus_rows(tab, np.ascontiguousarray(rows))
        mn = np.asarray(mn)
        idx = np.asarray(idx)
    else:
        scores = tab[None, :] + rows
        idx = scores.argmin(axis=1)
        mn = scores[np.arange(scores.shape[0]), idx]  # one reduction pass
    arg = np.where(np.isfinite(mn), idx, -1).astype(np.int32)
    return mn, arg


class RegionTable:
    """Knapsack result for one region: monotone perf-vs-capacity + backtrack.

    The per-layer DP step is array-form over the full candidate axis: every
    ``(candidate, cap)`` cell is scored at once (``perf[cap - size] + perf_c``
    where feasible) and the min + first-argmin over candidates — the exact
    first-strict-< winner of the old per-candidate Python loop — runs either
    in NumPy or in the Pallas ``kernels.dse_eval.argmin_rows`` reduction
    (``reduce="pallas"``, the on-TPU default alongside ``tile_select``).

    Backtracking is array-based (O(layers x units) int16), replayed in
    reverse: at budget ``cap`` layer ``l`` chose candidate ``choice[l, eff]``
    where ``eff = eff_cap[l, cap]`` is the cell the monotone fill borrowed
    from; the remaining budget is ``eff - size(choice)``.
    """

    def __init__(self, layer_cands, units: int, unit_bytes: float,
                 *, reduce: str = "auto"):
        reduce = _resolve_reduce(reduce)
        self.layer_cands = layer_cands
        self.units = units
        perf = np.zeros(units + 1)
        self.choice = np.full((len(layer_cands), units + 1), -1, np.int16)
        self.eff = np.zeros((len(layer_cands), units + 1), np.int32)
        self.sizes = []
        caps = np.arange(units + 1)
        for li, (lname, cands) in enumerate(layer_cands):
            sizes = np.minimum(units + 1,
                               np.ceil(np.array([c[2] for c in cands])
                                       / unit_bytes)).astype(np.int64)
            self.sizes.append(sizes)
            perfs = np.array([c[1] for c in cands])
            if len(cands) == 0:  # layer with no legal LM: stays infeasible
                nperf = np.full(units + 1, INF)
            else:
                # [C, units+1]: candidate ci at cap spends sizes[ci], leaving
                # the prefix budget cap - sizes[ci]; infeasible cells get INF
                left = caps[None, :] - sizes[:, None]
                feas = left >= 0
                scores = np.where(
                    feas, perf[np.clip(left, 0, units)] + perfs[:, None], INF)
                if reduce == "pallas":
                    from jax.experimental import enable_x64
                    from ..kernels import dse_eval
                    with enable_x64():
                        mn, idx = dse_eval.argmin_rows(scores.T)
                    nperf = np.asarray(mn)
                    ci = np.asarray(idx)
                else:
                    nperf = scores.min(axis=0)
                    ci = scores.argmin(axis=0)
                self.choice[li] = np.where(np.isfinite(nperf), ci, -1)
            # monotone fill, tracking effective cap
            eff = np.arange(units + 1, dtype=np.int32)
            run = np.minimum.accumulate(nperf)
            borrowed = nperf > run
            # effective cap = last index where run decreased
            last = np.where(~borrowed, eff, 0)
            eff = np.maximum.accumulate(last)
            self.eff[li] = eff
            perf = run
        self.perf = perf

    def backtrack(self, cap: int) -> dict[str, int]:
        picks: dict[str, int] = {}
        cap = int(min(cap, self.units))
        for li in range(len(self.layer_cands) - 1, -1, -1):
            lname, cands = self.layer_cands[li]
            eff = int(self.eff[li, cap])
            ci = int(self.choice[li, eff])
            if ci < 0:  # infeasible cell: fall back to fastest candidate
                if not cands:
                    # a layer with zero legal candidates has nothing to fall
                    # back on — leave it unpicked so infeasibility stays
                    # contained to this layer instead of raising here
                    continue
                ci = min(range(len(cands)), key=lambda i: cands[i][1])
                picks[lname] = ci
                continue
            picks[lname] = ci
            cap = eff - int(self.sizes[li][ci])
        return picks


# -- the mapper ---------------------------------------------------------------


class PimMapper:
    """Sec. VI mapper.

    ``backend="batched"`` (default) costs every (LM x WR x layer x region)
    candidate of a network through the vectorized engine
    (``engine.batch_cost.batch_part_cost`` + ``partition.comm_estimate_batch``)
    in one chunked call per mapping pass; ``backend="scalar"`` keeps the
    original one-candidate-at-a-time reference path.  Both produce identical
    mappings (the parity tests pin choices/SM exactly and latencies to 1e-6).
    """

    def __init__(self, hw: HwConfig, *, max_optim_iter: int = 3,
                 cap_units: int = 1024, lm_cap: int = 200, n_wr: int = 5,
                 sm_max_regions: int | None = None,
                 dl_max_group: int = 32, backend: str = "batched",
                 dp_reduce: str = "auto"):
        if backend not in BACKENDS:
            raise ValueError(f"unknown mapper backend {backend!r}; "
                             f"expected one of {BACKENDS}")
        self.hw = hw
        self.max_optim_iter = max_optim_iter
        self.cap_units = cap_units
        self.lm_cap = lm_cap
        self.n_wr = n_wr
        self.sm_max_regions = sm_max_regions
        self.dl_max_group = dl_max_group
        self.backend = backend
        self.dp_reduce = dp_reduce

    # ---- candidate costing (scalar or batched) -------------------------------
    def _cand_key(self, layer: Layer, region_h: int, region_w: int,
                  din: DataLayout, dout: DataLayout) -> tuple:
        return (self.hw, layer, region_h, region_w, din, dout,
                self.n_wr, self.lm_cap)

    def _candidates(self, layer: Layer, region_h: int, region_w: int,
                    din: DataLayout, dout: DataLayout):
        key = self._cand_key(layer, region_h, region_w, din, dout)
        if self.backend == "scalar":
            return _layer_candidates(*key)
        got = _BATCH_CANDS.get(key)
        if got is None:  # cache miss (evicted or cleared): fill just this
            got = self._prefetch_candidates([key])[key]
        return got

    def _prefetch_candidates(self, keys: list[tuple]) -> dict[tuple, tuple]:
        """Cost every missing candidate table in one batched engine call.

        Returns a table per requested key.  Callers consume the returned
        dict rather than re-reading ``_BATCH_CANDS`` — a concurrent
        ``clear_mapper_caches()`` (another campaign thread finishing its
        config) may empty or evict the shared cache at any point, and must
        only ever cost re-derivation, never correctness.
        """
        return _prefetch_candidates_multi([keys])

    # ---- DL bookkeeping ------------------------------------------------------
    def _default_dl(self, channels: int) -> DataLayout:
        g = 1
        while g * 2 <= min(channels, 16):
            g *= 2
        return DataLayout("BCHW", g)

    def _init_dls(self, g: DnnGraph) -> dict[str, tuple[DataLayout, DataLayout]]:
        dls = {}
        for layer in g.layers:
            dls[layer.name] = (self._default_dl(layer.C),
                               self._default_dl(layer.K))
        return dls

    # ---- Algorithm 1 ----------------------------------------------------------
    def map(self, graph: DnnGraph) -> Mapping:
        with trace.span("map", graph=graph.name, configs=1):
            return self._map(graph)

    def _map(self, graph: DnnGraph) -> Mapping:
        segments = graph.segments()
        dls = self._init_dls(graph)
        mapping: Mapping | None = None
        for it in range(self.max_optim_iter):
            mapping = self._solve_sm_lm_wr(graph, segments, dls)
            dls = self._optimize_dl(graph, mapping, dls)
            for name, ch in mapping.choices.items():
                ch.dl_in, ch.dl_out = dls[name]
        return mapping

    def _with_hw(self, hw: HwConfig) -> "PimMapper":
        if hw == self.hw:
            return self
        return PimMapper(hw, max_optim_iter=self.max_optim_iter,
                         cap_units=self.cap_units, lm_cap=self.lm_cap,
                         n_wr=self.n_wr, sm_max_regions=self.sm_max_regions,
                         dl_max_group=self.dl_max_group, backend=self.backend,
                         dp_reduce=self.dp_reduce)

    @trace.traced("map_many", argspec=lambda self, graph, cfgs, **kw:
                  {"graph": graph.name, "configs": len(cfgs)})
    def map_many(self, graph: DnnGraph, cfgs: Sequence[HwConfig],
                 *, on_infeasible: str = "raise") -> list[Mapping | None]:
        """Map ``graph`` under several hardware configs, batched across them.

        Every config's Algorithm-1 iteration runs in lockstep so each phase's
        candidate sweep — the (SM x LM x WR x layer x region) costing and the
        DL layout sweep — is costed in ONE multi-config
        ``engine.batch_part_cost`` call (the engine's ``[N configs]`` axis)
        instead of one engine round-trip per config.  Batching only pre-warms
        the shared memos; the per-config DP/backtracking path is the exact
        :meth:`map` code, so results are identical to per-config ``map()``
        calls (pinned by the parity tests).

        ``on_infeasible`` controls configs with no capacity-feasible mapping:
        ``"raise"`` propagates the :class:`RuntimeError` like :meth:`map`
        (the default); ``"none"`` leaves ``None`` in that config's slot and
        continues the rest of the batch.
        """
        gen = self.map_many_phases(graph, cfgs, on_infeasible=on_infeasible)
        while True:
            try:
                next(gen)
            except StopIteration as stop:
                return stop.value

    def map_many_phases(self, graph: DnnGraph, cfgs: Sequence[HwConfig],
                        *, on_infeasible: str = "raise"):
        """Phase generator behind :meth:`map_many`.

        Yields once per in-flight engine dispatch (the candidate-table
        sweep and the DL sweep of each Algorithm-1 iteration) and returns
        the mapping list via ``StopIteration.value``.  At each yield the
        just-dispatched device work has NOT been synced — an
        :class:`~repro.engine.overlap.OverlapExecutor` driving this
        generator runs deferred host work (the previous wave's scheduling
        and accounting) in that window.  Driving the generator straight to
        exhaustion is exactly :meth:`map_many`; both paths execute this
        one code body, so overlapped and serial results are identical by
        construction.
        """
        if on_infeasible not in ("raise", "none"):
            raise ValueError(f"unknown on_infeasible {on_infeasible!r}; "
                             f"expected 'raise' or 'none'")
        subs = [self._with_hw(cfg) for cfg in cfgs]
        return self._map_many_gen(graph, subs, on_infeasible)

    def _map_many_gen(self, graph: DnnGraph, subs: list["PimMapper"],
                      on_infeasible: str):
        if self.backend == "scalar":  # reference path: plain per-config loop
            out: list[Mapping | None] = []
            for sub in subs:
                try:
                    out.append(sub.map(graph))
                except RuntimeError:
                    if on_infeasible == "raise":
                        raise
                    out.append(None)
            return out
        segments = graph.segments()
        dls = [sub._init_dls(graph) for sub in subs]
        mappings: list[Mapping | None] = [None] * len(subs)
        alive = list(range(len(subs)))
        seg_sms = {i: subs[i]._seg_sms(graph, segments)
                   for i in range(len(subs))}
        for _ in range(self.max_optim_iter):
            pending_tables = _dispatch_candidates_multi(
                [subs[i]._solve_keys(graph, segments, seg_sms[i], dls[i])
                 for i in alive])
            yield pending_tables  # candidate costs in flight
            # the resolved tables are handed straight to each sub's solve —
            # a batch whose key union exceeds the _BATCH_CANDS bound must
            # not self-evict into per-config engine fills
            tables = pending_tables.resolve()
            for i in list(alive):
                try:
                    mappings[i] = subs[i]._solve_sm_lm_wr(
                        graph, segments, dls[i], seg_sms=seg_sms[i],
                        cand_tables=tables)
                except RuntimeError:
                    if on_infeasible == "raise":
                        raise
                    mappings[i] = None
                    alive.remove(i)
            sweeps = {i: subs[i]._dl_sweep_specs(graph, mappings[i])
                      for i in alive}
            pending_fill = _dispatch_node_fill(
                [(subs[i].hw, sweeps[i][1]) for i in alive])
            yield pending_fill  # DL-sweep costs in flight
            fresh = pending_fill.resolve()
            for i in alive:
                entries, specs = sweeps[i]
                lat = _node_lat_from(fresh, subs[i].hw, specs)
                table = {e: float(l) for e, l in zip(entries, lat)}
                dls[i] = subs[i]._optimize_dl(graph, mappings[i], dls[i],
                                              table=table)
                for name, ch in mappings[i].choices.items():
                    ch.dl_in, ch.dl_out = dls[i][name]
        return mappings

    def _seg_sms(self, graph: DnnGraph, segments: list[Segment]):
        return [gen_sm_candidates(graph, seg, self.hw.na_row, self.hw.na_col,
                                  self.sm_max_regions) for seg in segments]

    def _solve_keys(self, graph: DnnGraph, segments: list[Segment],
                    seg_sms, dls) -> list[tuple]:
        """Every candidate-table key one ``_solve_sm_lm_wr`` pass touches."""
        keys = []
        for seg, sms in zip(segments, seg_sms):
            for sm in sms:
                for ri, region in enumerate(sm.regions):
                    for bi in sm.branches_of(ri):
                        for lname in seg.branches[bi].heavy_layers(graph):
                            din, dout = dls[lname]
                            keys.append(self._cand_key(
                                graph.layer(lname), region.h_shape,
                                region.w_shape, din, dout))
        return keys

    def _solve_sm_lm_wr(self, graph: DnnGraph, segments: list[Segment],
                        dls, seg_sms=None, cand_tables=None) -> Mapping:
        hw = self.hw
        units = self.cap_units
        unit_bytes = hw.node_dram_capacity / units
        if seg_sms is None:
            seg_sms = self._seg_sms(graph, segments)
        if cand_tables is None:
            cand_tables = {}
            if self.backend == "batched":
                # every (LM x WR x layer x region-shape) candidate of the
                # whole network is costed up front in one chunked engine
                # call; the costing loop below reads the returned dict, so
                # cache eviction or a concurrent clear can never force
                # per-key dispatches (map_many passes its own multi-config
                # prefetch result in for the same reason)
                cand_tables = self._prefetch_candidates(
                    self._solve_keys(graph, segments, seg_sms, dls))
        # Per segment: list of (sm, seg_perf, reg_tabs) where seg_perf[cap] is
        # max over its regions' knapsack tables at per-node budget cap.
        seg_tables = []
        for seg, sms in zip(segments, seg_sms):
            per_sm = []
            for sm in sms:
                reg_tabs = []
                seg_perf = np.zeros(units + 1)
                for ri, region in enumerate(sm.regions):
                    layer_cands = []
                    for bi in sm.branches_of(ri):
                        for lname in seg.branches[bi].heavy_layers(graph):
                            layer = graph.layer(lname)
                            din, dout = dls[lname]
                            key = self._cand_key(layer, region.h_shape,
                                                 region.w_shape, din, dout)
                            cands = cand_tables.get(key)
                            if cands is None:
                                cands = self._candidates(
                                    layer, region.h_shape, region.w_shape,
                                    din, dout)
                            layer_cands.append((lname, cands))
                    if not layer_cands:
                        continue
                    tab = RegionTable(layer_cands, units, unit_bytes,
                                      reduce=self.dp_reduce)
                    seg_perf = np.maximum(seg_perf, tab.perf)
                    reg_tabs.append((region, tab))
                if np.isinf(seg_perf[units]) and reg_tabs:
                    continue  # SM infeasible even at full capacity
                per_sm.append((sm, seg_perf, reg_tabs))
            has_heavy = any(b.heavy_layers(graph) for b in seg.branches)
            if has_heavy and not per_sm:
                raise RuntimeError(
                    f"no feasible mapping under DRAM capacity for segment "
                    f"{seg.index} of {graph.name}")
            seg_tables.append(per_sm)

        # combine SMs: best per (segment, cap); then min-plus convolve
        tab = np.zeros(units + 1)
        seg_choice: list[tuple[np.ndarray, np.ndarray, np.ndarray] | None] = []
        for per_sm in seg_tables:
            if not per_sm:
                seg_choice.append(None)
                continue
            best = np.full(units + 1, INF)
            best_sm = np.full(units + 1, -1, np.int32)
            for smi, (_, seg_perf, _) in enumerate(per_sm):
                better = seg_perf < best
                best = np.where(better, seg_perf, best)
                best_sm[better] = smi
            # arg_i[c] = prefix budget used; min-plus convolution, kernelized
            ntab, arg_i = minplus_convolve(tab, best, reduce=self.dp_reduce)
            seg_choice.append((best_sm, arg_i, None))
            # monotone fill (keep arg of the borrowed cell): a cell is
            # borrowed iff a strictly smaller value exists at a lower cap,
            # and takes the arg of the last non-borrowed cell below it
            tab = np.minimum.accumulate(ntab)
            src = np.maximum.accumulate(
                np.where(ntab <= tab, np.arange(units + 1), 0))
            arg_i[:] = arg_i[src]

        if not np.isfinite(tab[units]):
            raise RuntimeError("no feasible mapping under DRAM capacity")

        # backtrack: recover per-segment (sm index, cap_seg)
        plan: list[tuple[int, int, int]] = []  # (seg_idx, smi, cap_seg)
        cap = units
        for si in range(len(seg_tables) - 1, -1, -1):
            ch = seg_choice[si]
            if ch is None:
                continue
            best_sm, arg_i, _ = ch
            i = int(arg_i[cap])
            if i < 0:
                i = 0
            cap_seg = cap - i
            # the seg table is monotone: find the smallest budget achieving it
            smi = int(best_sm[min(cap_seg, units)])
            plan.append((si, smi, cap_seg))
            cap = i

        choices: dict[str, LayerChoice] = {}
        sm_chosen: dict[int, SM] = {}
        for si, smi, cap_seg in reversed(plan):
            per_sm = seg_tables[si]
            if smi < 0 or not per_sm:
                smi = 0
            sm, seg_perf, reg_tabs = per_sm[smi]
            sm_chosen[si] = sm
            for region, rtab in reg_tabs:
                pick = rtab.backtrack(cap_seg)
                for lname, cands in rtab.layer_cands:
                    if not cands:  # zero-candidate layer: nothing to choose
                        continue
                    ci = pick.get(lname, 0)
                    wr, p, size, lm = cands[ci]
                    din, dout = dls[lname]
                    choices[lname] = LayerChoice(lm, wr, din, dout, region,
                                                 p, size)
        return Mapping(graph, hw, segments, sm_chosen, choices,
                       est_latency_s=float(tab[units]))

    # ---- DL alternated pass (Sec. VI-C) ---------------------------------------
    def _din_universe(self) -> list[DataLayout]:
        """Every DLi a layer can inherit: any predecessor's swept DLo or a
        default layout — BHWC plus power-of-two channel groups (the cost
        model clamps groups beyond the fmap's channel count)."""
        outs = [DataLayout("BHWC")]
        g = 1
        while g <= max(self.dl_max_group, 16):
            outs.append(DataLayout("BCHW", g))
            g *= 2
        return outs

    def _dl_sweep_specs(self, graph: DnnGraph, mapping: Mapping
                        ) -> tuple[list[tuple], list[tuple]]:
        """(entries, part-layer specs) of the full per-layer layout sweep."""
        entries: list[tuple] = []
        specs: list[tuple] = []
        for name, ch in mapping.choices.items():
            layer = graph.layer(name)
            pl = part_layer(layer, ch.lm)
            for din in self._din_universe():
                for dout in enumerate_layouts(layer.K, self.dl_max_group):
                    entries.append((name, din, dout))
                    specs.append((pl, din, dout))
        return entries, specs

    def _dl_sweep_table(self, graph: DnnGraph, mapping: Mapping
                        ) -> dict[tuple, float]:
        """Latency of every (layer, DLi, DLo) sweep point, batched.

        One chunked engine call covers the full layout sweep of every heavy
        chosen layer — the sequential DLo(pred)=DLi(succ) propagation then
        just reads the table instead of costing per candidate.
        """
        entries, specs = self._dl_sweep_specs(graph, mapping)
        lat = _batched_node_latencies(self.hw, specs)
        return {e: float(l) for e, l in zip(entries, lat)}

    def _optimize_dl(self, graph: DnnGraph, mapping: Mapping, dls,
                     table: dict | None = None):
        hw = self.hw
        if table is None:
            table = (self._dl_sweep_table(graph, mapping)
                     if self.backend == "batched" else None)
        new: dict[str, tuple[DataLayout, DataLayout]] = {}
        out_dl: dict[str, DataLayout] = {}
        for name in graph.topo_order():
            layer = graph.layer(name)
            preds = graph.preds(name)
            if preds:
                din = out_dl[preds[0]]
                for p in preds[1:]:  # dependency constraint: DLo(pred)=DLi(succ)
                    out_dl[p] = din
            else:
                din = self._default_dl(layer.C)
            if layer.is_heavy and name in mapping.choices:
                ch = mapping.choices[name]
                pl = part_layer(layer, ch.lm)
                best, best_lat = None, INF
                for cand in enumerate_layouts(layer.K, self.dl_max_group):
                    if table is not None:
                        lat = table.get((name, din, cand))
                        if lat is None:  # DLi outside the swept universe
                            lat = part_layer_cost(hw, pl, din, cand).latency_s
                    else:
                        lat = part_layer_cost(hw, pl, din, cand).latency_s
                    if lat < best_lat:
                        best, best_lat = cand, lat
                out_dl[name] = best
            else:
                out_dl[name] = din  # aux layers pass data through
            new[name] = (din, out_dl[name])
        # refresh DLi from (possibly rewritten) predecessor DLo
        final: dict[str, tuple[DataLayout, DataLayout]] = {}
        for name in graph.topo_order():
            preds = graph.preds(name)
            din = out_dl[preds[0]] if preds else new[name][0]
            final[name] = (din, out_dl[name])
        return final


# -- final evaluation with the Data-Scheduler ----------------------------------


def _node_of(lm: LM, region: Region, na_col: int,
             idx: dict[str, tuple[int, int]]) -> int:
    st = loop_strides(lm)
    h = region.h_pos
    w = region.w_pos
    for l in LOOPS:
        ih, iw = idx.get(l, (0, 0))
        sh, sw = st[l]
        h += ih * sh
        w += iw * sw
    return h * na_col + w


def _enumerate_indices(lm: LM, loops: tuple[str, ...]):
    """All index dicts over the given loops (others zero)."""
    outs = [dict()]
    for l in loops:
        i = LOOPS.index(l)
        new = []
        for a in range(lm.ph[i]):
            for b in range(lm.pw[i]):
                for d in outs:
                    dd = dict(d)
                    dd[l] = (a, b)
                    new.append(dd)
        outs = new
    return outs


def _sharing_problem_list(lm: LM, region_shape: tuple[int, int], wr: int,
                          w_bytes: float, i_bytes: float, p_bytes: float
                          ) -> list[tuple[tuple[tuple[int, ...], ...], float]]:
    """A layer's three sharing processes as ``(sets, chunk)`` problems.

    Each entry is one joint min-max-link-load solve on the region's mesh
    (sets of size <= 1 and zero-byte chunks already dropped) — the shared
    construction behind both the per-layer :func:`_sharing_latency` path
    and the whole-mapping batched ``engine.scheduler_opt.schedule_many``
    prefill.
    """
    na_col = region_shape[1]
    region = Region(0, 0, region_shape[0], region_shape[1])
    problems: list[tuple[tuple[tuple[int, ...], ...], float]] = []

    def add(sets: list[list[int]], chunk: float):
        kept = tuple(tuple(s) for s in sets if len(s) > 1)
        if kept and chunk > 0:
            problems.append((kept, chunk))

    # weight sharing: per (k, c) group split into wr replica subsets
    n_ws = lm.weight_share
    group = math.ceil(n_ws / max(1, min(wr, n_ws)))
    if group > 1 and w_bytes > 0:
        share_loops = tuple(l for l in ("B", "P", "Q") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, tuple(
                l for l in ("K", "C") if lm.parts(l) > 1)):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, share_loops)]
            for s in range(0, len(nodes), group):
                sets.append(nodes[s:s + group])
        add(sets, w_bytes / group)
    # input sharing across K
    if lm.input_share > 1 and i_bytes > 0:
        other = tuple(l for l in ("B", "P", "Q", "C") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, other):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, ("K",))]
            sets.append(nodes)
        add(sets, i_bytes / lm.input_share)
    # psum reduction across C (~2 ring passes)
    if lm.psum_share > 1 and p_bytes > 0:
        other = tuple(l for l in ("B", "P", "Q", "K") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, other):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, ("C",))]
            sets.append(nodes)
        add(sets, 2 * p_bytes / lm.psum_share)
    return problems


_SCHED_MEMO = _BoundedCache(_CACHE_SCHEDULES)


def _sched_key(hw: HwConfig, lm: LM, region_shape: tuple[int, int], wr: int,
               w_bytes: float, i_bytes: float, p_bytes: float, solver: str,
               seed: int, backend: str) -> tuple:
    # tsp/shp ignore the LS backend: normalize so they share one entry
    return (hw, lm, region_shape, wr, w_bytes, i_bytes, p_bytes, solver,
            seed, backend if solver == "ilp" else "-")


def _sharing_latency(hw: HwConfig, lm: LM, region_shape: tuple[int, int],
                     wr: int, w_bytes: float, i_bytes: float, p_bytes: float,
                     solver: str, seed: int,
                     backend: str = "scan") -> tuple[float, float]:
    """Scheduled (latency_s, energy_pj) for a layer's three sharing processes.

    Translation-invariant (XY routes stay inside the set's bounding box), so
    memoized on the region *shape*, not its position.  The memo is a plain
    :class:`_BoundedCache` (rather than an ``lru_cache``) so the batched
    ``evaluate_mapping`` path can prefill whole mappings through
    ``engine.scheduler_opt.schedule_many`` — per-problem PRNG streams make
    the prefilled values bit-identical to this per-layer path.
    """
    key = _sched_key(hw, lm, region_shape, wr, w_bytes, i_bytes, p_bytes,
                     solver, seed, backend)
    got = _SCHED_MEMO.get(key)
    if got is not None:
        return got
    noc = MeshNoc(region_shape[0], region_shape[1])
    solve = SOLVERS[solver]
    lat = 0.0
    en = 0.0
    for sets, chunk in _sharing_problem_list(lm, region_shape, wr, w_bytes,
                                             i_bytes, p_bytes):
        # every solver draws from an explicit Random(seed): repeated DSE
        # runs over the same mapping are bit-reproducible
        res = solve(noc, [list(s) for s in sets], [chunk] * len(sets),
                    hw.link_bw_bytes, hw.cons.freq_hz,
                    hw.cons.noc_energy_pj_per_bit_hop, seed=seed,
                    backend=backend)
        lat += res.latency_s
        en += res.energy_pj
    out = (lat, en)
    _SCHED_MEMO.put(key, out)
    return out


def _sched_cache_info():
    from types import SimpleNamespace
    return SimpleNamespace(currsize=len(_SCHED_MEMO._d),
                           maxsize=_SCHED_MEMO.maxsize)


# lru_cache-compatible handles (tests and clear_mapper_caches use them)
_sharing_latency.cache_clear = _SCHED_MEMO.clear
_sharing_latency.cache_info = _sched_cache_info


def _layer_sharing_args(mapping: Mapping, lname: str):
    """(lm, region_shape, wr, w/i/p bytes) of one mapped heavy layer."""
    hw = mapping.hw
    ch = mapping.choices[lname]
    pl = part_layer(mapping.graph.layer(lname), ch.lm)
    dbytes = hw.cons.data_bits // 8
    return (ch.lm, (ch.region.h_shape, ch.region.w_shape), ch.wr,
            pl.weight_count * dbytes, pl.ifmap_count * dbytes,
            pl.ofmap_count * (hw.cons.psum_bits // 8))


def _prefill_schedules(mapping: Mapping, solver: str, seed: int,
                       backend: str) -> None:
    """Solve a whole mapping's missing sharing problems in one engine batch.

    The single-mapping entry point of :func:`prefill_schedules_many`
    (``evaluate_mapping`` calls it per mapping on the scan backend).
    """
    prefill_schedules_many([mapping], solver=solver, seed=seed,
                           backend=backend)


def prefill_schedules_many(mappings: Sequence[Mapping], *,
                           solver: str = "ilp", seed: int = 0,
                           backend: str = "scan") -> None:
    """Prefill the sharing-schedule memo for SEVERAL mappings in one batch.

    The cross-config generalization behind the device-resident DSE
    pipeline: collects every uncached ``_sharing_latency`` key across all
    mappings (typically one mapping per still-feasible config of a proposal
    round), dedups the underlying ``(mesh, sets, chunk)`` problems, and
    runs ONE :func:`engine.scheduler_opt.schedule_many` call per distinct
    ``(link_bw, freq, pj/bit/hop)`` NoC-scalar group — configs that differ
    only in parameters the NoC scalars don't depend on share a single
    pow2-bucketed dispatch.  Every memo value is bit-identical to the
    serial per-layer path (``schedule_many``'s per-problem PRNG streams
    are batch-independent), so prefilled and lazily-computed entries can
    never disagree.  No-op for non-scan backends / non-ilp solvers.
    """
    if solver != "ilp" or backend != "scan":
        return
    # sched key -> (shape, problems, hw); the key embeds hw, so identical
    # sharing problems under DIFFERENT configs stay distinct memo entries
    want: dict[tuple, tuple] = {}
    for mapping in mappings:
        hw = mapping.hw
        for lname in mapping.choices:
            args = _layer_sharing_args(mapping, lname)
            key = _sched_key(hw, *args, solver, seed, backend)
            if key in _SCHED_MEMO or key in want:
                continue
            want[key] = (args[1], _sharing_problem_list(*args), hw)
    if not want:
        return
    from ..engine.scheduler_opt import schedule_many

    def _scalars(hw: HwConfig) -> tuple:
        return (hw.link_bw_bytes, hw.cons.freq_hz,
                hw.cons.noc_energy_pj_per_bit_hop)

    # NoC-scalar triple -> (problem identity -> flat index, flat problems)
    groups: dict[tuple, tuple[dict, list]] = {}
    for shape, problems, hw in want.values():
        uniq, flat = groups.setdefault(_scalars(hw), ({}, []))
        for sets, chunk in problems:
            pk = (shape, sets, chunk)
            if pk not in uniq:
                uniq[pk] = len(flat)
                flat.append((MeshNoc(shape[0], shape[1]), sets,
                             [chunk] * len(sets)))
    with trace.span("prefill_schedules", cat="engine",
                    mappings=len(mappings), missing=len(want),
                    problems=sum(len(f) for _, f in groups.values()),
                    groups=len(groups)):
        solved = {tri: schedule_many(flat, *tri, seed=seed)
                  for tri, (_, flat) in groups.items()}
    fills = []
    for key, (shape, problems, hw) in want.items():
        uniq, _ = groups[_scalars(hw)]
        results = solved[_scalars(hw)]
        lat = 0.0
        en = 0.0
        for sets, chunk in problems:
            res = results[uniq[(shape, sets, chunk)]]
            lat += res.latency_s
            en += res.energy_pj
        fills.append((key, (lat, en)))
    _SCHED_MEMO.put_many(fills)


def evaluate_mapping(mapping: Mapping, *, solver: str = "ilp",
                     seed: int = 0,
                     scheduler_backend: str = "scan") -> EvalReport:
    """Final latency/energy with Data-Scheduler-optimized data sharing.

    ``scheduler_backend`` picks the joint-LS implementation behind the
    ``"ilp"`` solver: ``"scan"`` (default) batches every uncached layer's
    sharing problems through the jitted engine scheduler in one
    ``schedule_many`` call before the per-layer accounting walk;
    ``"loop"`` keeps the host-Python reference search.
    """
    g = mapping.graph
    hw = mapping.hw
    dbytes = hw.cons.data_bits // 8
    if scheduler_backend == "scan" and solver == "ilp":
        _prefill_schedules(mapping, solver, seed, scheduler_backend)
    layers: list[LayerReport] = []
    total_lat = 0.0
    total_energy = 0.0
    bd = {"mac": 0.0, "sram": 0.0, "dram": 0.0, "noc": 0.0}
    for seg_i, seg in enumerate(mapping.segments):
        sm = mapping.sm.get(seg_i)
        region_lat: dict[int, float] = {}
        for bi, branch in enumerate(seg.branches):
            for lname in branch.heavy_layers(g):
                ch = mapping.choices.get(lname)
                if ch is None:
                    continue
                layer = g.layer(lname)
                pl = part_layer(layer, ch.lm)
                node = part_layer_cost(hw, pl, ch.dl_in, ch.dl_out)
                w_kc = pl.weight_count * dbytes
                i_b = pl.ifmap_count * dbytes
                p_b = pl.ofmap_count * (hw.cons.psum_bits // 8)
                comm_lat, comm_en = _sharing_latency(
                    hw, ch.lm, (ch.region.h_shape, ch.region.w_shape),
                    ch.wr, w_kc, i_b, p_b, solver, seed,
                    backend=scheduler_backend)
                n_nodes = ch.region.n_nodes
                lat = node.latency_s + comm_lat
                energy = node.energy_pj * n_nodes + comm_en
                ri = sm.ir[bi] if sm else 0
                region_lat[ri] = region_lat.get(ri, 0.0) + lat
                bd["mac"] += node.e_mac_pj * n_nodes
                bd["sram"] += node.e_sram_pj * n_nodes
                bd["dram"] += node.e_dram_pj * n_nodes
                bd["noc"] += comm_en
                total_energy += energy
                layers.append(LayerReport(lname, lat, comm_lat, energy,
                                          comm_en, dict(node.breakdown)))
        total_lat += max(region_lat.values()) if region_lat else 0.0
    return EvalReport(total_lat, total_energy, bd, layers)
