"""PIM-Mapper (Sec. VI): joint SM / LM / WR / DL optimization for one DNN.

Implements the paper's Algorithm 1: candidate generation per segment (SM via
slicing trees; per layer, WR values from full replication down to 1 with the
best LM searched for each), Algorithm 2's dynamic program to pick one
candidate per segment/layer under the per-node DRAM capacity, and the
alternated DL optimization pass (MAX_OPTIM_ITER iterations).

The DP's ``Perf`` values use fast analytic ring estimates for the
data-sharing traffic (``partition.comm_estimate``); the final chosen mapping
is re-costed with the Data-Scheduler's optimized Hamilton cycles
(:func:`evaluate_mapping`), mirroring the paper's mapper→scheduler split.
"""

from __future__ import annotations

import math
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import lru_cache

from .costmodel import part_layer_cost
from .hardware import HwConfig
from .ir import DnnGraph, Layer, Segment
from .layout import DataLayout, enumerate_layouts
from .noc import MeshNoc
from .partition import (LM, comm_estimate, comm_estimate_batch, enumerate_lms,
                        group_coords, loop_strides, part_layer, wr_candidates,
                        LOOPS)
from .regions import SM, Region, gen_sm_candidates
from .scheduler import solve_ilp_ls, SOLVERS

INF = float("inf")

BACKENDS = ("batched", "scalar")


@dataclass
class LayerChoice:
    lm: LM
    wr: int
    dl_in: DataLayout
    dl_out: DataLayout
    region: Region
    perf_s: float          # analytic latency estimate used by the DP
    size_bytes: float      # per-node DRAM weight storage


@dataclass
class Mapping:
    graph: DnnGraph
    hw: HwConfig
    segments: list[Segment]
    sm: dict[int, SM]                      # segment index -> SM
    choices: dict[str, LayerChoice]        # heavy layer name -> choice
    est_latency_s: float = 0.0             # DP objective value


@dataclass
class LayerReport:
    name: str
    latency_s: float
    comm_s: float
    energy_pj: float
    e_noc_pj: float
    breakdown: dict[str, float] = field(default_factory=dict)


@dataclass
class EvalReport:
    latency_s: float
    energy_pj: float
    energy_breakdown: dict[str, float]
    layers: list[LayerReport]

    @property
    def edp(self) -> float:
        return self.latency_s * self.energy_pj


# -- candidate generation ------------------------------------------------------
#
# The same (layer shape, region shape, layouts) keys recur constantly across
# deep nets, SM candidates, and DL iterations, so candidate tables are
# memoized — but *bounded*: a long multi-config campaign cycles through many
# HwConfigs and an unbounded cache would grow with every one of them.
# ``clear_mapper_caches`` drops everything between hardware configs.

_CACHE_CANDIDATES = 2048      # candidate tables (one per layer/region/DL key)
_CACHE_NODE_LAT = 65536       # per-(part-layer, DL) node latencies (floats)
_CACHE_SCHEDULES = 4096       # Data-Scheduler solves (see _sharing_latency)


@lru_cache(maxsize=_CACHE_CANDIDATES)
def _layer_candidates(hw: HwConfig, layer: Layer, h_shape: int, w_shape: int,
                      dl_in: DataLayout, dl_out: DataLayout,
                      n_wr: int, lm_cap: int
                      ) -> tuple[tuple[int, float, float, LM], ...]:
    """Per-WR best LM for a layer on an ``h x w`` region (scalar backend).

    Returns ``(wr, perf_s, size_bytes, lm)`` tuples sorted by size desc.
    """
    lms = enumerate_lms(layer, h_shape, w_shape, cap=lm_cap)
    best: dict[int, tuple[float, float, LM]] = {}
    for lm in lms:
        pl = part_layer(layer, lm)
        node = part_layer_cost(hw, pl, dl_in, dl_out)
        for wr in wr_candidates(layer, lm, n_wr):
            ce = comm_estimate(layer, lm, wr, hw)
            perf = node.latency_s + ce.latency_s
            size = ce.weight_bytes_per_node
            cur = best.get(wr)
            if cur is None or perf < cur[0]:
                best[wr] = (perf, size, lm)
    out = [(wr, p, s, lm) for wr, (p, s, lm) in best.items()]
    out.sort(key=lambda t: -t[2])
    return tuple(out)


class _BoundedCache:
    """Tiny bounded memo dict with FIFO eviction.

    Reads are plain (GIL-atomic) dict lookups so the hot path takes no lock;
    writes lock only for the insert-and-trim.  FIFO (not strict LRU) is fine
    here: entries are hw-config-scoped and campaigns clear between configs —
    the bound only guards against pathological single-config growth.
    """

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: OrderedDict = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key, default=None):
        return self._d.get(key, default)

    def __contains__(self, key) -> bool:
        return key in self._d

    def put(self, key, value) -> None:
        with self._lock:
            self._d[key] = value
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()


_BATCH_CANDS = _BoundedCache(_CACHE_CANDIDATES)
_NODE_LAT = _BoundedCache(_CACHE_NODE_LAT)
_CAND_STRUCT = _BoundedCache(_CACHE_CANDIDATES)


def clear_mapper_caches() -> None:
    """Drop every mapper-level memo (candidates, node costs, schedules).

    Entries are keyed by :class:`HwConfig`, so nothing carries over between
    hardware configurations anyway — campaigns call this between configs to
    keep long multi-config runs at a flat memory footprint.
    """
    _layer_candidates.cache_clear()
    _BATCH_CANDS.clear()
    _NODE_LAT.clear()
    _CAND_STRUCT.clear()
    _sharing_latency.cache_clear()
    part_layer_cost.cache_clear()


def _batched_node_latencies(hw: HwConfig,
                            specs: list[tuple[Layer, DataLayout, DataLayout]]
                            ) -> np.ndarray:
    """Node latency for every ``(part-layer, dl_in, dl_out)`` spec, memoized.

    Misses are costed in ONE chunked :func:`engine.batch_cost.batch_part_cost`
    call — this is the mapper's whole-segment candidate costing hot path.
    """
    keys = [(hw,) + s for s in specs]
    # single cache read per key: a concurrent clear_mapper_caches() (another
    # campaign thread finishing its config) must never be able to swap a
    # value source mid-call — fresh results are kept locally
    vals = [_NODE_LAT.get(key) for key in keys]
    missing: dict[tuple, int] = {}
    for key, v in zip(keys, vals):
        if v is None and key not in missing:
            missing[key] = len(missing)
    if missing:
        from ..engine.batch_cost import batch_part_cost
        lat = batch_part_cost([hw], [k[1:] for k in missing],
                              spec_chunk=1024).latency_s[0]
        fresh = {key: float(lat[j]) for key, j in missing.items()}
        for key, v in fresh.items():
            _NODE_LAT.put(key, v)
        vals = [fresh[key] if v is None else v
                for key, v in zip(keys, vals)]
    return np.array(vals)


@dataclass
class _CandStruct:
    """The DL-independent half of a candidate sweep for (layer, region).

    Built once per (hw, layer, region-shape) and reused across every DL
    iteration and segment that revisits the same shapes — only the node
    latencies (which depend on the data layouts) are re-gathered per key.
    Part-layers are deduped (different P_orders and collapsed ceil-divisions
    share one node cost); ``pair_pl`` maps each (LM x WR) pair to its row in
    ``uniq_pls``.
    """

    uniq_pls: list[Layer]               # deduped part_layer rows
    pair_pl: np.ndarray                 # (LM x WR) pair -> uniq_pls index
    pair_lm_of: list[LM]                # (LM x WR) pair -> LM
    comm_lat: np.ndarray                # vectorized comm_estimate per pair
    stored: np.ndarray                  # weight bytes/node per pair
    by_wr: list[tuple[int, np.ndarray]]  # WR -> pair indices, first-seen order


def _cand_struct(hw: HwConfig, layer: Layer, h_shape: int, w_shape: int,
                 n_wr: int, lm_cap: int) -> _CandStruct:
    key = (hw, layer, h_shape, w_shape, n_wr, lm_cap)
    got = _CAND_STRUCT.get(key)
    if got is not None:
        return got
    lms = enumerate_lms(layer, h_shape, w_shape, cap=lm_cap)
    uniq_pls: list[Layer] = []
    pl_index: dict[Layer, int] = {}
    pair_lms: list[LM] = []
    pair_wrs: list[int] = []
    pair_pl: list[int] = []
    for lm in lms:
        pl = part_layer(layer, lm)
        pi = pl_index.get(pl)
        if pi is None:
            pi = pl_index[pl] = len(uniq_pls)
            uniq_pls.append(pl)
        for wr in wr_candidates(layer, lm, n_wr):
            pair_lms.append(lm)
            pair_wrs.append(wr)
            pair_pl.append(pi)
    comm_lat, _, stored = comm_estimate_batch(layer, hw, pair_lms, pair_wrs)
    by_wr: dict[int, list[int]] = {}
    for p, wr in enumerate(pair_wrs):       # first-seen WR order, like the
        by_wr.setdefault(wr, []).append(p)  # scalar best-dict insertion
    struct = _CandStruct(
        uniq_pls=uniq_pls, pair_pl=np.array(pair_pl, dtype=np.intp),
        pair_lm_of=pair_lms, comm_lat=comm_lat, stored=stored,
        by_wr=[(wr, np.array(idxs, dtype=np.intp))
               for wr, idxs in by_wr.items()])
    _CAND_STRUCT.put(key, struct)
    return struct


def _layer_candidates_batched(struct: _CandStruct, node_lat: np.ndarray
                              ) -> tuple[tuple[int, float, float, LM], ...]:
    """Assemble one candidate table from pre-batched node latencies.

    ``node_lat[i]`` is the node cost of ``struct.uniq_pls[i]``; the (LM x WR)
    communication axis comes pre-scored from the vectorized
    :func:`partition.comm_estimate_batch` and is reduced per WR with the
    same first-strict-< winner rule as the scalar loop (first-argmin).
    """
    perf = node_lat[struct.pair_pl] + struct.comm_lat
    out = []
    for wr, idxs in struct.by_wr:
        p = idxs[int(np.argmin(perf[idxs]))]
        out.append((wr, float(perf[p]), float(struct.stored[p]),
                    struct.pair_lm_of[p]))
    out.sort(key=lambda t: -t[2])
    return tuple(out)


# -- Algorithm 2: DP over capacity --------------------------------------------


import numpy as np

_ON_TPU: bool | None = None


def _on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        try:
            import jax
            _ON_TPU = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover - jax always present here
            _ON_TPU = False
    return _ON_TPU


class RegionTable:
    """Knapsack result for one region: monotone perf-vs-capacity + backtrack.

    The per-layer DP step is array-form over the full candidate axis: every
    ``(candidate, cap)`` cell is scored at once (``perf[cap - size] + perf_c``
    where feasible) and the min + first-argmin over candidates — the exact
    first-strict-< winner of the old per-candidate Python loop — runs either
    in NumPy or in the Pallas ``kernels.dse_eval.argmin_rows`` reduction
    (``reduce="pallas"``, the on-TPU default alongside ``tile_select``).

    Backtracking is array-based (O(layers x units) int16), replayed in
    reverse: at budget ``cap`` layer ``l`` chose candidate ``choice[l, eff]``
    where ``eff = eff_cap[l, cap]`` is the cell the monotone fill borrowed
    from; the remaining budget is ``eff - size(choice)``.
    """

    def __init__(self, layer_cands, units: int, unit_bytes: float,
                 *, reduce: str = "auto"):
        if reduce == "auto":
            reduce = "pallas" if _on_tpu() else "numpy"
        if reduce not in ("numpy", "pallas"):
            raise ValueError(f"unknown RegionTable reduce {reduce!r}")
        self.layer_cands = layer_cands
        self.units = units
        perf = np.zeros(units + 1)
        self.choice = np.full((len(layer_cands), units + 1), -1, np.int16)
        self.eff = np.zeros((len(layer_cands), units + 1), np.int32)
        self.sizes = []
        caps = np.arange(units + 1)
        for li, (lname, cands) in enumerate(layer_cands):
            sizes = np.minimum(units + 1,
                               np.ceil(np.array([c[2] for c in cands])
                                       / unit_bytes)).astype(np.int64)
            self.sizes.append(sizes)
            perfs = np.array([c[1] for c in cands])
            if len(cands) == 0:  # layer with no legal LM: stays infeasible
                nperf = np.full(units + 1, INF)
            else:
                # [C, units+1]: candidate ci at cap spends sizes[ci], leaving
                # the prefix budget cap - sizes[ci]; infeasible cells get INF
                left = caps[None, :] - sizes[:, None]
                feas = left >= 0
                scores = np.where(
                    feas, perf[np.clip(left, 0, units)] + perfs[:, None], INF)
                if reduce == "pallas":
                    from jax.experimental import enable_x64
                    from ..kernels import dse_eval
                    with enable_x64():
                        mn, idx = dse_eval.argmin_rows(scores.T)
                    nperf = np.asarray(mn)
                    ci = np.asarray(idx)
                else:
                    nperf = scores.min(axis=0)
                    ci = scores.argmin(axis=0)
                self.choice[li] = np.where(np.isfinite(nperf), ci, -1)
            # monotone fill, tracking effective cap
            eff = np.arange(units + 1, dtype=np.int32)
            run = np.minimum.accumulate(nperf)
            borrowed = nperf > run
            # effective cap = last index where run decreased
            last = np.where(~borrowed, eff, 0)
            eff = np.maximum.accumulate(last)
            self.eff[li] = eff
            perf = run
        self.perf = perf

    def backtrack(self, cap: int) -> dict[str, int]:
        picks: dict[str, int] = {}
        cap = int(min(cap, self.units))
        for li in range(len(self.layer_cands) - 1, -1, -1):
            lname, cands = self.layer_cands[li]
            eff = int(self.eff[li, cap])
            ci = int(self.choice[li, eff])
            if ci < 0:  # infeasible cell: fall back to fastest candidate
                ci = min(range(len(cands)), key=lambda i: cands[i][1])
                picks[lname] = ci
                continue
            picks[lname] = ci
            cap = eff - int(self.sizes[li][ci])
        return picks


# -- the mapper ---------------------------------------------------------------


class PimMapper:
    """Sec. VI mapper.

    ``backend="batched"`` (default) costs every (LM x WR x layer x region)
    candidate of a network through the vectorized engine
    (``engine.batch_cost.batch_part_cost`` + ``partition.comm_estimate_batch``)
    in one chunked call per mapping pass; ``backend="scalar"`` keeps the
    original one-candidate-at-a-time reference path.  Both produce identical
    mappings (the parity tests pin choices/SM exactly and latencies to 1e-6).
    """

    def __init__(self, hw: HwConfig, *, max_optim_iter: int = 3,
                 cap_units: int = 1024, lm_cap: int = 200, n_wr: int = 5,
                 sm_max_regions: int | None = None,
                 dl_max_group: int = 32, backend: str = "batched",
                 dp_reduce: str = "auto"):
        if backend not in BACKENDS:
            raise ValueError(f"unknown mapper backend {backend!r}; "
                             f"expected one of {BACKENDS}")
        self.hw = hw
        self.max_optim_iter = max_optim_iter
        self.cap_units = cap_units
        self.lm_cap = lm_cap
        self.n_wr = n_wr
        self.sm_max_regions = sm_max_regions
        self.dl_max_group = dl_max_group
        self.backend = backend
        self.dp_reduce = dp_reduce

    # ---- candidate costing (scalar or batched) -------------------------------
    def _cand_key(self, layer: Layer, region_h: int, region_w: int,
                  din: DataLayout, dout: DataLayout) -> tuple:
        return (self.hw, layer, region_h, region_w, din, dout,
                self.n_wr, self.lm_cap)

    def _candidates(self, layer: Layer, region_h: int, region_w: int,
                    din: DataLayout, dout: DataLayout):
        key = self._cand_key(layer, region_h, region_w, din, dout)
        if self.backend == "scalar":
            return _layer_candidates(*key)
        got = _BATCH_CANDS.get(key)
        if got is None:  # cache miss (evicted or cleared): fill just this
            got = self._prefetch_candidates([key])[key]
        return got

    def _prefetch_candidates(self, keys: list[tuple]) -> dict[tuple, tuple]:
        """Cost every missing candidate table in one batched engine call.

        Returns a table per requested key.  Callers consume the returned
        dict rather than re-reading ``_BATCH_CANDS`` — a concurrent
        ``clear_mapper_caches()`` (another campaign thread finishing its
        config) may empty or evict the shared cache at any point, and must
        only ever cost re-derivation, never correctness.
        """
        out: dict[tuple, tuple] = {}
        missing = []
        for key in keys:
            if key in out:
                continue
            got = _BATCH_CANDS.get(key)
            if got is None:
                out[key] = ()  # placeholder: dedupes repeated missing keys
                missing.append(key)
            else:
                out[key] = got
        if not missing:
            return out
        # every (key, lm) pair contributes one part-layer spec; identical
        # part-layers (different P_order, collapsed ceil-divisions, repeated
        # layer shapes) dedupe inside _batched_node_latencies' memo
        work = []
        for key in missing:
            _, layer, h, w, din, dout, n_wr, lm_cap = key
            struct = _cand_struct(self.hw, layer, h, w, n_wr, lm_cap)
            work.append((key, struct,
                         [(pl, din, dout) for pl in struct.uniq_pls]))
        flat = [s for _, _, specs in work for s in specs]
        node_lat = _batched_node_latencies(self.hw, flat)
        at = 0
        for key, struct, specs in work:
            table = _layer_candidates_batched(
                struct, node_lat[at:at + len(specs)])
            out[key] = table
            _BATCH_CANDS.put(key, table)
            at += len(specs)
        return out

    # ---- DL bookkeeping ------------------------------------------------------
    def _default_dl(self, channels: int) -> DataLayout:
        g = 1
        while g * 2 <= min(channels, 16):
            g *= 2
        return DataLayout("BCHW", g)

    def _init_dls(self, g: DnnGraph) -> dict[str, tuple[DataLayout, DataLayout]]:
        dls = {}
        for layer in g.layers:
            dls[layer.name] = (self._default_dl(layer.C),
                               self._default_dl(layer.K))
        return dls

    # ---- Algorithm 1 ----------------------------------------------------------
    def map(self, graph: DnnGraph) -> Mapping:
        hw = self.hw
        segments = graph.segments()
        dls = self._init_dls(graph)
        mapping: Mapping | None = None
        for it in range(self.max_optim_iter):
            mapping = self._solve_sm_lm_wr(graph, segments, dls)
            dls = self._optimize_dl(graph, mapping, dls)
            for name, ch in mapping.choices.items():
                ch.dl_in, ch.dl_out = dls[name]
        return mapping

    def _solve_sm_lm_wr(self, graph: DnnGraph, segments: list[Segment],
                        dls) -> Mapping:
        hw = self.hw
        units = self.cap_units
        unit_bytes = hw.node_dram_capacity / units
        seg_sms = [gen_sm_candidates(graph, seg, hw.na_row, hw.na_col,
                                     self.sm_max_regions) for seg in segments]
        cand_tables: dict[tuple, tuple] = {}
        if self.backend == "batched":
            # every (LM x WR x layer x region-shape) candidate of the whole
            # network is costed up front in one chunked engine call; the
            # costing loop below reads the returned dict, so cache eviction
            # or a concurrent clear can never force per-key dispatches
            keys = []
            for seg, sms in zip(segments, seg_sms):
                for sm in sms:
                    for ri, region in enumerate(sm.regions):
                        for bi in sm.branches_of(ri):
                            for lname in seg.branches[bi].heavy_layers(graph):
                                din, dout = dls[lname]
                                keys.append(self._cand_key(
                                    graph.layer(lname), region.h_shape,
                                    region.w_shape, din, dout))
            cand_tables = self._prefetch_candidates(keys)
        # Per segment: list of (sm, seg_perf, reg_tabs) where seg_perf[cap] is
        # max over its regions' knapsack tables at per-node budget cap.
        seg_tables = []
        for seg, sms in zip(segments, seg_sms):
            per_sm = []
            for sm in sms:
                reg_tabs = []
                seg_perf = np.zeros(units + 1)
                for ri, region in enumerate(sm.regions):
                    layer_cands = []
                    for bi in sm.branches_of(ri):
                        for lname in seg.branches[bi].heavy_layers(graph):
                            layer = graph.layer(lname)
                            din, dout = dls[lname]
                            key = self._cand_key(layer, region.h_shape,
                                                 region.w_shape, din, dout)
                            cands = cand_tables.get(key)
                            if cands is None:
                                cands = self._candidates(
                                    layer, region.h_shape, region.w_shape,
                                    din, dout)
                            layer_cands.append((lname, cands))
                    if not layer_cands:
                        continue
                    tab = RegionTable(layer_cands, units, unit_bytes,
                                      reduce=self.dp_reduce)
                    seg_perf = np.maximum(seg_perf, tab.perf)
                    reg_tabs.append((region, tab))
                if np.isinf(seg_perf[units]) and reg_tabs:
                    continue  # SM infeasible even at full capacity
                per_sm.append((sm, seg_perf, reg_tabs))
            has_heavy = any(b.heavy_layers(graph) for b in seg.branches)
            if has_heavy and not per_sm:
                raise RuntimeError(
                    f"no feasible mapping under DRAM capacity for segment "
                    f"{seg.index} of {graph.name}")
            seg_tables.append(per_sm)

        # combine SMs: best per (segment, cap); then min-plus convolve
        tab = np.zeros(units + 1)
        seg_choice: list[tuple[np.ndarray, np.ndarray, np.ndarray] | None] = []
        for per_sm in seg_tables:
            if not per_sm:
                seg_choice.append(None)
                continue
            best = np.full(units + 1, INF)
            best_sm = np.full(units + 1, -1, np.int32)
            for smi, (_, seg_perf, _) in enumerate(per_sm):
                better = seg_perf < best
                best = np.where(better, seg_perf, best)
                best_sm[better] = smi
            ntab = np.full(units + 1, INF)
            arg_i = np.full(units + 1, -1, np.int32)  # prefix budget used
            for i in range(units + 1):
                if not np.isfinite(tab[i]):
                    continue
                cand = tab[i] + best[:units + 1 - i]
                seg = ntab[i:]
                better = cand < seg
                ntab[i:] = np.where(better, cand, seg)
                arg_i[i:][better] = i
            seg_choice.append((best_sm, arg_i, None))
            tab = ntab
            # monotone fill (keep arg of the borrowed cell)
            for cap in range(1, units + 1):
                if tab[cap - 1] < tab[cap]:
                    tab[cap] = tab[cap - 1]
                    arg_i[cap] = arg_i[cap - 1]

        if not np.isfinite(tab[units]):
            raise RuntimeError("no feasible mapping under DRAM capacity")

        # backtrack: recover per-segment (sm index, cap_seg)
        plan: list[tuple[int, int, int]] = []  # (seg_idx, smi, cap_seg)
        cap = units
        for si in range(len(seg_tables) - 1, -1, -1):
            ch = seg_choice[si]
            if ch is None:
                continue
            best_sm, arg_i, _ = ch
            i = int(arg_i[cap])
            if i < 0:
                i = 0
            cap_seg = cap - i
            # the seg table is monotone: find the smallest budget achieving it
            smi = int(best_sm[min(cap_seg, units)])
            plan.append((si, smi, cap_seg))
            cap = i

        choices: dict[str, LayerChoice] = {}
        sm_chosen: dict[int, SM] = {}
        for si, smi, cap_seg in reversed(plan):
            per_sm = seg_tables[si]
            if smi < 0 or not per_sm:
                smi = 0
            sm, seg_perf, reg_tabs = per_sm[smi]
            sm_chosen[si] = sm
            for region, rtab in reg_tabs:
                pick = rtab.backtrack(cap_seg)
                for lname, cands in rtab.layer_cands:
                    ci = pick.get(lname, 0)
                    wr, p, size, lm = cands[ci]
                    din, dout = dls[lname]
                    choices[lname] = LayerChoice(lm, wr, din, dout, region,
                                                 p, size)
        return Mapping(graph, hw, segments, sm_chosen, choices,
                       est_latency_s=float(tab[units]))

    # ---- DL alternated pass (Sec. VI-C) ---------------------------------------
    def _din_universe(self) -> list[DataLayout]:
        """Every DLi a layer can inherit: any predecessor's swept DLo or a
        default layout — BHWC plus power-of-two channel groups (the cost
        model clamps groups beyond the fmap's channel count)."""
        outs = [DataLayout("BHWC")]
        g = 1
        while g <= max(self.dl_max_group, 16):
            outs.append(DataLayout("BCHW", g))
            g *= 2
        return outs

    def _dl_sweep_table(self, graph: DnnGraph, mapping: Mapping
                        ) -> dict[tuple, float]:
        """Latency of every (layer, DLi, DLo) sweep point, batched.

        One chunked engine call covers the full layout sweep of every heavy
        chosen layer — the sequential DLo(pred)=DLi(succ) propagation then
        just reads the table instead of costing per candidate.
        """
        entries: list[tuple] = []
        specs: list[tuple] = []
        for name, ch in mapping.choices.items():
            layer = graph.layer(name)
            pl = part_layer(layer, ch.lm)
            for din in self._din_universe():
                for dout in enumerate_layouts(layer.K, self.dl_max_group):
                    entries.append((name, din, dout))
                    specs.append((pl, din, dout))
        lat = _batched_node_latencies(self.hw, specs)
        return {e: float(l) for e, l in zip(entries, lat)}

    def _optimize_dl(self, graph: DnnGraph, mapping: Mapping, dls):
        hw = self.hw
        table = (self._dl_sweep_table(graph, mapping)
                 if self.backend == "batched" else None)
        new: dict[str, tuple[DataLayout, DataLayout]] = {}
        out_dl: dict[str, DataLayout] = {}
        for name in graph.topo_order():
            layer = graph.layer(name)
            preds = graph.preds(name)
            if preds:
                din = out_dl[preds[0]]
                for p in preds[1:]:  # dependency constraint: DLo(pred)=DLi(succ)
                    out_dl[p] = din
            else:
                din = self._default_dl(layer.C)
            if layer.is_heavy and name in mapping.choices:
                ch = mapping.choices[name]
                pl = part_layer(layer, ch.lm)
                best, best_lat = None, INF
                for cand in enumerate_layouts(layer.K, self.dl_max_group):
                    if table is not None:
                        lat = table.get((name, din, cand))
                        if lat is None:  # DLi outside the swept universe
                            lat = part_layer_cost(hw, pl, din, cand).latency_s
                    else:
                        lat = part_layer_cost(hw, pl, din, cand).latency_s
                    if lat < best_lat:
                        best, best_lat = cand, lat
                out_dl[name] = best
            else:
                out_dl[name] = din  # aux layers pass data through
            new[name] = (din, out_dl[name])
        # refresh DLi from (possibly rewritten) predecessor DLo
        final: dict[str, tuple[DataLayout, DataLayout]] = {}
        for name in graph.topo_order():
            preds = graph.preds(name)
            din = out_dl[preds[0]] if preds else new[name][0]
            final[name] = (din, out_dl[name])
        return final


# -- final evaluation with the Data-Scheduler ----------------------------------


def _node_of(lm: LM, region: Region, na_col: int,
             idx: dict[str, tuple[int, int]]) -> int:
    st = loop_strides(lm)
    h = region.h_pos
    w = region.w_pos
    for l in LOOPS:
        ih, iw = idx.get(l, (0, 0))
        sh, sw = st[l]
        h += ih * sh
        w += iw * sw
    return h * na_col + w


def _enumerate_indices(lm: LM, loops: tuple[str, ...]):
    """All index dicts over the given loops (others zero)."""
    outs = [dict()]
    for l in loops:
        i = LOOPS.index(l)
        new = []
        for a in range(lm.ph[i]):
            for b in range(lm.pw[i]):
                for d in outs:
                    dd = dict(d)
                    dd[l] = (a, b)
                    new.append(dd)
        outs = new
    return outs


@lru_cache(maxsize=_CACHE_SCHEDULES)
def _sharing_latency(hw: HwConfig, lm: LM, region_shape: tuple[int, int],
                     wr: int, w_bytes: float, i_bytes: float, p_bytes: float,
                     solver: str, seed: int) -> tuple[float, float]:
    """Scheduled (latency_s, energy_pj) for a layer's three sharing processes.

    Translation-invariant (XY routes stay inside the set's bounding box), so
    cached on the region *shape*, not its position.
    """
    na_col = region_shape[1]
    noc = MeshNoc(region_shape[0], region_shape[1])
    region = Region(0, 0, region_shape[0], region_shape[1])
    solve = SOLVERS[solver]
    lat = 0.0
    en = 0.0

    def run(sets: list[list[int]], chunk: float):
        nonlocal lat, en
        sets = [s for s in sets if len(s) > 1]
        if not sets or chunk <= 0:
            return
        # every solver draws from an explicit Random(seed): repeated DSE
        # runs over the same mapping are bit-reproducible
        res = solve(noc, sets, [chunk] * len(sets), hw.link_bw_bytes,
                    hw.cons.freq_hz, hw.cons.noc_energy_pj_per_bit_hop,
                    seed=seed)
        lat += res.latency_s
        en += res.energy_pj

    # weight sharing: per (k, c) group split into wr replica subsets
    n_ws = lm.weight_share
    group = math.ceil(n_ws / max(1, min(wr, n_ws)))
    if group > 1 and w_bytes > 0:
        share_loops = tuple(l for l in ("B", "P", "Q") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, tuple(
                l for l in ("K", "C") if lm.parts(l) > 1)):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, share_loops)]
            for s in range(0, len(nodes), group):
                sets.append(nodes[s:s + group])
        run(sets, w_bytes / group)
    # input sharing across K
    if lm.input_share > 1 and i_bytes > 0:
        other = tuple(l for l in ("B", "P", "Q", "C") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, other):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, ("K",))]
            sets.append(nodes)
        run(sets, i_bytes / lm.input_share)
    # psum reduction across C (~2 ring passes)
    if lm.psum_share > 1 and p_bytes > 0:
        other = tuple(l for l in ("B", "P", "Q", "K") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, other):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, ("C",))]
            sets.append(nodes)
        run(sets, 2 * p_bytes / lm.psum_share)
    return lat, en


def evaluate_mapping(mapping: Mapping, *, solver: str = "ilp",
                     seed: int = 0) -> EvalReport:
    """Final latency/energy with Data-Scheduler-optimized data sharing."""
    g = mapping.graph
    hw = mapping.hw
    dbytes = hw.cons.data_bits // 8
    layers: list[LayerReport] = []
    total_lat = 0.0
    total_energy = 0.0
    bd = {"mac": 0.0, "sram": 0.0, "dram": 0.0, "noc": 0.0}
    for seg_i, seg in enumerate(mapping.segments):
        sm = mapping.sm.get(seg_i)
        region_lat: dict[int, float] = {}
        for bi, branch in enumerate(seg.branches):
            for lname in branch.heavy_layers(g):
                ch = mapping.choices.get(lname)
                if ch is None:
                    continue
                layer = g.layer(lname)
                pl = part_layer(layer, ch.lm)
                node = part_layer_cost(hw, pl, ch.dl_in, ch.dl_out)
                w_kc = pl.weight_count * dbytes
                i_b = pl.ifmap_count * dbytes
                p_b = pl.ofmap_count * (hw.cons.psum_bits // 8)
                comm_lat, comm_en = _sharing_latency(
                    hw, ch.lm, (ch.region.h_shape, ch.region.w_shape),
                    ch.wr, w_kc, i_b, p_b, solver, seed)
                n_nodes = ch.region.n_nodes
                lat = node.latency_s + comm_lat
                energy = node.energy_pj * n_nodes + comm_en
                ri = sm.ir[bi] if sm else 0
                region_lat[ri] = region_lat.get(ri, 0.0) + lat
                bd["mac"] += node.e_mac_pj * n_nodes
                bd["sram"] += node.e_sram_pj * n_nodes
                bd["dram"] += node.e_dram_pj * n_nodes
                bd["noc"] += comm_en
                total_energy += energy
                layers.append(LayerReport(lname, lat, comm_lat, energy,
                                          comm_en, dict(node.breakdown)))
        total_lat += max(region_lat.values()) if region_lat else 0.0
    return EvalReport(total_lat, total_energy, bd, layers)
