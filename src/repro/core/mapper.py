"""PIM-Mapper (Sec. VI): joint SM / LM / WR / DL optimization for one DNN.

Implements the paper's Algorithm 1: candidate generation per segment (SM via
slicing trees; per layer, WR values from full replication down to 1 with the
best LM searched for each), Algorithm 2's dynamic program to pick one
candidate per segment/layer under the per-node DRAM capacity, and the
alternated DL optimization pass (MAX_OPTIM_ITER iterations).

The DP's ``Perf`` values use fast analytic ring estimates for the
data-sharing traffic (``partition.comm_estimate``); the final chosen mapping
is re-costed with the Data-Scheduler's optimized Hamilton cycles
(:func:`evaluate_mapping`), mirroring the paper's mapper→scheduler split.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache

from .costmodel import part_layer_cost
from .hardware import HwConfig
from .ir import DnnGraph, Layer, Segment
from .layout import DataLayout, enumerate_layouts
from .noc import MeshNoc
from .partition import (LM, comm_estimate, enumerate_lms, group_coords,
                        loop_strides, part_layer, wr_candidates, LOOPS)
from .regions import SM, Region, gen_sm_candidates
from .scheduler import solve_ilp_ls, SOLVERS

INF = float("inf")


@dataclass
class LayerChoice:
    lm: LM
    wr: int
    dl_in: DataLayout
    dl_out: DataLayout
    region: Region
    perf_s: float          # analytic latency estimate used by the DP
    size_bytes: float      # per-node DRAM weight storage


@dataclass
class Mapping:
    graph: DnnGraph
    hw: HwConfig
    segments: list[Segment]
    sm: dict[int, SM]                      # segment index -> SM
    choices: dict[str, LayerChoice]        # heavy layer name -> choice
    est_latency_s: float = 0.0             # DP objective value


@dataclass
class LayerReport:
    name: str
    latency_s: float
    comm_s: float
    energy_pj: float
    e_noc_pj: float
    breakdown: dict[str, float] = field(default_factory=dict)


@dataclass
class EvalReport:
    latency_s: float
    energy_pj: float
    energy_breakdown: dict[str, float]
    layers: list[LayerReport]

    @property
    def edp(self) -> float:
        return self.latency_s * self.energy_pj


# -- candidate generation ------------------------------------------------------


@lru_cache(maxsize=None)
def _layer_candidates(hw: HwConfig, layer: Layer, h_shape: int, w_shape: int,
                      dl_in: DataLayout, dl_out: DataLayout,
                      n_wr: int, lm_cap: int
                      ) -> tuple[tuple[int, float, float, LM], ...]:
    """Per-WR best LM for a layer on an ``h x w`` region.

    Returns ``(wr, perf_s, size_bytes, lm)`` tuples sorted by size desc —
    heavily cached: identical layer shapes recur across deep nets.
    """
    lms = enumerate_lms(layer, h_shape, w_shape, cap=lm_cap)
    best: dict[int, tuple[float, float, LM]] = {}
    for lm in lms:
        pl = part_layer(layer, lm)
        node = part_layer_cost(hw, pl, dl_in, dl_out)
        for wr in wr_candidates(layer, lm, n_wr):
            ce = comm_estimate(layer, lm, wr, hw)
            perf = node.latency_s + ce.latency_s
            size = ce.weight_bytes_per_node
            cur = best.get(wr)
            if cur is None or perf < cur[0]:
                best[wr] = (perf, size, lm)
    out = [(wr, p, s, lm) for wr, (p, s, lm) in best.items()]
    out.sort(key=lambda t: -t[2])
    return tuple(out)


# -- Algorithm 2: DP over capacity --------------------------------------------


import numpy as np


class RegionTable:
    """Knapsack result for one region: monotone perf-vs-capacity + backtrack.

    Backtracking is array-based (O(layers x units) int16), replayed in
    reverse: at budget ``cap`` layer ``l`` chose candidate ``choice[l, eff]``
    where ``eff = eff_cap[l, cap]`` is the cell the monotone fill borrowed
    from; the remaining budget is ``eff - size(choice)``.
    """

    def __init__(self, layer_cands, units: int, unit_bytes: float):
        self.layer_cands = layer_cands
        self.units = units
        perf = np.zeros(units + 1)
        self.choice = np.full((len(layer_cands), units + 1), -1, np.int16)
        self.eff = np.zeros((len(layer_cands), units + 1), np.int32)
        self.sizes = []
        for li, (lname, cands) in enumerate(layer_cands):
            sizes = np.minimum(units + 1,
                               np.ceil(np.array([c[2] for c in cands])
                                       / unit_bytes)).astype(np.int64)
            self.sizes.append(sizes)
            perfs = np.array([c[1] for c in cands])
            nperf = np.full(units + 1, INF)
            for ci in range(len(cands)):
                s = int(sizes[ci])
                if s > units:
                    continue
                cand = perf[:units + 1 - s] + perfs[ci]
                seg = nperf[s:]
                better = cand < seg
                nperf[s:] = np.where(better, cand, seg)
                self.choice[li, s:][better] = ci
            # monotone fill, tracking effective cap
            eff = np.arange(units + 1, dtype=np.int32)
            run = np.minimum.accumulate(nperf)
            borrowed = nperf > run
            # effective cap = last index where run decreased
            last = np.where(~borrowed, eff, 0)
            eff = np.maximum.accumulate(last)
            self.eff[li] = eff
            perf = run
        self.perf = perf

    def backtrack(self, cap: int) -> dict[str, int]:
        picks: dict[str, int] = {}
        cap = int(min(cap, self.units))
        for li in range(len(self.layer_cands) - 1, -1, -1):
            lname, cands = self.layer_cands[li]
            eff = int(self.eff[li, cap])
            ci = int(self.choice[li, eff])
            if ci < 0:  # infeasible cell: fall back to fastest candidate
                ci = min(range(len(cands)), key=lambda i: cands[i][1])
                picks[lname] = ci
                continue
            picks[lname] = ci
            cap = eff - int(self.sizes[li][ci])
        return picks


# -- the mapper ---------------------------------------------------------------


class PimMapper:
    def __init__(self, hw: HwConfig, *, max_optim_iter: int = 3,
                 cap_units: int = 1024, lm_cap: int = 200, n_wr: int = 5,
                 sm_max_regions: int | None = None,
                 dl_max_group: int = 32):
        self.hw = hw
        self.max_optim_iter = max_optim_iter
        self.cap_units = cap_units
        self.lm_cap = lm_cap
        self.n_wr = n_wr
        self.sm_max_regions = sm_max_regions
        self.dl_max_group = dl_max_group

    # ---- DL bookkeeping ------------------------------------------------------
    def _default_dl(self, channels: int) -> DataLayout:
        g = 1
        while g * 2 <= min(channels, 16):
            g *= 2
        return DataLayout("BCHW", g)

    def _init_dls(self, g: DnnGraph) -> dict[str, tuple[DataLayout, DataLayout]]:
        dls = {}
        for layer in g.layers:
            dls[layer.name] = (self._default_dl(layer.C),
                               self._default_dl(layer.K))
        return dls

    # ---- Algorithm 1 ----------------------------------------------------------
    def map(self, graph: DnnGraph) -> Mapping:
        hw = self.hw
        segments = graph.segments()
        dls = self._init_dls(graph)
        mapping: Mapping | None = None
        for it in range(self.max_optim_iter):
            mapping = self._solve_sm_lm_wr(graph, segments, dls)
            dls = self._optimize_dl(graph, mapping, dls)
            for name, ch in mapping.choices.items():
                ch.dl_in, ch.dl_out = dls[name]
        return mapping

    def _solve_sm_lm_wr(self, graph: DnnGraph, segments: list[Segment],
                        dls) -> Mapping:
        hw = self.hw
        units = self.cap_units
        unit_bytes = hw.node_dram_capacity / units
        # Per segment: list of (sm, seg_perf, reg_tabs) where seg_perf[cap] is
        # max over its regions' knapsack tables at per-node budget cap.
        seg_tables = []
        for seg in segments:
            sms = gen_sm_candidates(graph, seg, hw.na_row, hw.na_col,
                                    self.sm_max_regions)
            per_sm = []
            for sm in sms:
                reg_tabs = []
                seg_perf = np.zeros(units + 1)
                for ri, region in enumerate(sm.regions):
                    layer_cands = []
                    for bi in sm.branches_of(ri):
                        for lname in seg.branches[bi].heavy_layers(graph):
                            layer = graph.layer(lname)
                            din, dout = dls[lname]
                            cands = _layer_candidates(
                                hw, layer, region.h_shape, region.w_shape,
                                din, dout, self.n_wr, self.lm_cap)
                            layer_cands.append((lname, cands))
                    if not layer_cands:
                        continue
                    tab = RegionTable(layer_cands, units, unit_bytes)
                    seg_perf = np.maximum(seg_perf, tab.perf)
                    reg_tabs.append((region, tab))
                if np.isinf(seg_perf[units]) and reg_tabs:
                    continue  # SM infeasible even at full capacity
                per_sm.append((sm, seg_perf, reg_tabs))
            has_heavy = any(b.heavy_layers(graph) for b in seg.branches)
            if has_heavy and not per_sm:
                raise RuntimeError(
                    f"no feasible mapping under DRAM capacity for segment "
                    f"{seg.index} of {graph.name}")
            seg_tables.append(per_sm)

        # combine SMs: best per (segment, cap); then min-plus convolve
        tab = np.zeros(units + 1)
        seg_choice: list[tuple[np.ndarray, np.ndarray, np.ndarray] | None] = []
        for per_sm in seg_tables:
            if not per_sm:
                seg_choice.append(None)
                continue
            best = np.full(units + 1, INF)
            best_sm = np.full(units + 1, -1, np.int32)
            for smi, (_, seg_perf, _) in enumerate(per_sm):
                better = seg_perf < best
                best = np.where(better, seg_perf, best)
                best_sm[better] = smi
            ntab = np.full(units + 1, INF)
            arg_i = np.full(units + 1, -1, np.int32)  # prefix budget used
            for i in range(units + 1):
                if not np.isfinite(tab[i]):
                    continue
                cand = tab[i] + best[:units + 1 - i]
                seg = ntab[i:]
                better = cand < seg
                ntab[i:] = np.where(better, cand, seg)
                arg_i[i:][better] = i
            seg_choice.append((best_sm, arg_i, None))
            tab = ntab
            # monotone fill (keep arg of the borrowed cell)
            for cap in range(1, units + 1):
                if tab[cap - 1] < tab[cap]:
                    tab[cap] = tab[cap - 1]
                    arg_i[cap] = arg_i[cap - 1]

        if not np.isfinite(tab[units]):
            raise RuntimeError("no feasible mapping under DRAM capacity")

        # backtrack: recover per-segment (sm index, cap_seg)
        plan: list[tuple[int, int, int]] = []  # (seg_idx, smi, cap_seg)
        cap = units
        for si in range(len(seg_tables) - 1, -1, -1):
            ch = seg_choice[si]
            if ch is None:
                continue
            best_sm, arg_i, _ = ch
            i = int(arg_i[cap])
            if i < 0:
                i = 0
            cap_seg = cap - i
            # the seg table is monotone: find the smallest budget achieving it
            smi = int(best_sm[min(cap_seg, units)])
            plan.append((si, smi, cap_seg))
            cap = i

        choices: dict[str, LayerChoice] = {}
        sm_chosen: dict[int, SM] = {}
        for si, smi, cap_seg in reversed(plan):
            per_sm = seg_tables[si]
            if smi < 0 or not per_sm:
                smi = 0
            sm, seg_perf, reg_tabs = per_sm[smi]
            sm_chosen[si] = sm
            for region, rtab in reg_tabs:
                pick = rtab.backtrack(cap_seg)
                for lname, cands in rtab.layer_cands:
                    ci = pick.get(lname, 0)
                    wr, p, size, lm = cands[ci]
                    din, dout = dls[lname]
                    choices[lname] = LayerChoice(lm, wr, din, dout, region,
                                                 p, size)
        return Mapping(graph, hw, segments, sm_chosen, choices,
                       est_latency_s=float(tab[units]))

    # ---- DL alternated pass (Sec. VI-C) ---------------------------------------
    def _optimize_dl(self, graph: DnnGraph, mapping: Mapping, dls):
        hw = self.hw
        new: dict[str, tuple[DataLayout, DataLayout]] = {}
        out_dl: dict[str, DataLayout] = {}
        for name in graph.topo_order():
            layer = graph.layer(name)
            preds = graph.preds(name)
            if preds:
                din = out_dl[preds[0]]
                for p in preds[1:]:  # dependency constraint: DLo(pred)=DLi(succ)
                    out_dl[p] = din
            else:
                din = self._default_dl(layer.C)
            if layer.is_heavy and name in mapping.choices:
                ch = mapping.choices[name]
                pl = part_layer(layer, ch.lm)
                best, best_lat = None, INF
                for cand in enumerate_layouts(layer.K, self.dl_max_group):
                    lat = part_layer_cost(hw, pl, din, cand).latency_s
                    if lat < best_lat:
                        best, best_lat = cand, lat
                out_dl[name] = best
            else:
                out_dl[name] = din  # aux layers pass data through
            new[name] = (din, out_dl[name])
        # refresh DLi from (possibly rewritten) predecessor DLo
        final: dict[str, tuple[DataLayout, DataLayout]] = {}
        for name in graph.topo_order():
            preds = graph.preds(name)
            din = out_dl[preds[0]] if preds else new[name][0]
            final[name] = (din, out_dl[name])
        return final


# -- final evaluation with the Data-Scheduler ----------------------------------


def _node_of(lm: LM, region: Region, na_col: int,
             idx: dict[str, tuple[int, int]]) -> int:
    st = loop_strides(lm)
    h = region.h_pos
    w = region.w_pos
    for l in LOOPS:
        ih, iw = idx.get(l, (0, 0))
        sh, sw = st[l]
        h += ih * sh
        w += iw * sw
    return h * na_col + w


def _enumerate_indices(lm: LM, loops: tuple[str, ...]):
    """All index dicts over the given loops (others zero)."""
    outs = [dict()]
    for l in loops:
        i = LOOPS.index(l)
        new = []
        for a in range(lm.ph[i]):
            for b in range(lm.pw[i]):
                for d in outs:
                    dd = dict(d)
                    dd[l] = (a, b)
                    new.append(dd)
        outs = new
    return outs


@lru_cache(maxsize=None)
def _sharing_latency(hw: HwConfig, lm: LM, region_shape: tuple[int, int],
                     wr: int, w_bytes: float, i_bytes: float, p_bytes: float,
                     solver: str, seed: int) -> tuple[float, float]:
    """Scheduled (latency_s, energy_pj) for a layer's three sharing processes.

    Translation-invariant (XY routes stay inside the set's bounding box), so
    cached on the region *shape*, not its position.
    """
    na_col = region_shape[1]
    noc = MeshNoc(region_shape[0], region_shape[1])
    region = Region(0, 0, region_shape[0], region_shape[1])
    solve = SOLVERS[solver]
    lat = 0.0
    en = 0.0

    def run(sets: list[list[int]], chunk: float):
        nonlocal lat, en
        sets = [s for s in sets if len(s) > 1]
        if not sets or chunk <= 0:
            return
        # every solver draws from an explicit Random(seed): repeated DSE
        # runs over the same mapping are bit-reproducible
        res = solve(noc, sets, [chunk] * len(sets), hw.link_bw_bytes,
                    hw.cons.freq_hz, hw.cons.noc_energy_pj_per_bit_hop,
                    seed=seed)
        lat += res.latency_s
        en += res.energy_pj

    # weight sharing: per (k, c) group split into wr replica subsets
    n_ws = lm.weight_share
    group = math.ceil(n_ws / max(1, min(wr, n_ws)))
    if group > 1 and w_bytes > 0:
        share_loops = tuple(l for l in ("B", "P", "Q") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, tuple(
                l for l in ("K", "C") if lm.parts(l) > 1)):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, share_loops)]
            for s in range(0, len(nodes), group):
                sets.append(nodes[s:s + group])
        run(sets, w_bytes / group)
    # input sharing across K
    if lm.input_share > 1 and i_bytes > 0:
        other = tuple(l for l in ("B", "P", "Q", "C") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, other):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, ("K",))]
            sets.append(nodes)
        run(sets, i_bytes / lm.input_share)
    # psum reduction across C (~2 ring passes)
    if lm.psum_share > 1 and p_bytes > 0:
        other = tuple(l for l in ("B", "P", "Q", "K") if lm.parts(l) > 1)
        sets = []
        for idx in _enumerate_indices(lm, other):
            nodes = [_node_of(lm, region, na_col, {**idx, **sub})
                     for sub in _enumerate_indices(lm, ("C",))]
            sets.append(nodes)
        run(sets, 2 * p_bytes / lm.psum_share)
    return lat, en


def evaluate_mapping(mapping: Mapping, *, solver: str = "ilp",
                     seed: int = 0) -> EvalReport:
    """Final latency/energy with Data-Scheduler-optimized data sharing."""
    g = mapping.graph
    hw = mapping.hw
    dbytes = hw.cons.data_bits // 8
    layers: list[LayerReport] = []
    total_lat = 0.0
    total_energy = 0.0
    bd = {"mac": 0.0, "sram": 0.0, "dram": 0.0, "noc": 0.0}
    for seg_i, seg in enumerate(mapping.segments):
        sm = mapping.sm.get(seg_i)
        region_lat: dict[int, float] = {}
        for bi, branch in enumerate(seg.branches):
            for lname in branch.heavy_layers(g):
                ch = mapping.choices.get(lname)
                if ch is None:
                    continue
                layer = g.layer(lname)
                pl = part_layer(layer, ch.lm)
                node = part_layer_cost(hw, pl, ch.dl_in, ch.dl_out)
                w_kc = pl.weight_count * dbytes
                i_b = pl.ifmap_count * dbytes
                p_b = pl.ofmap_count * (hw.cons.psum_bits // 8)
                comm_lat, comm_en = _sharing_latency(
                    hw, ch.lm, (ch.region.h_shape, ch.region.w_shape),
                    ch.wr, w_kc, i_b, p_b, solver, seed)
                n_nodes = ch.region.n_nodes
                lat = node.latency_s + comm_lat
                energy = node.energy_pj * n_nodes + comm_en
                ri = sm.ir[bi] if sm else 0
                region_lat[ri] = region_lat.get(ri, 0.0) + lat
                bd["mac"] += node.e_mac_pj * n_nodes
                bd["sram"] += node.e_sram_pj * n_nodes
                bd["dram"] += node.e_dram_pj * n_nodes
                bd["noc"] += comm_en
                total_energy += energy
                layers.append(LayerReport(lname, lat, comm_lat, energy,
                                          comm_en, dict(node.breakdown)))
        total_lat += max(region_lat.values()) if region_lat else 0.0
    return EvalReport(total_lat, total_energy, bd, layers)
