"""Hardware configuration + area/bandwidth models for the DRAM-PIM accelerator.

Constants follow the paper's Table II (UniIC hybrid-bonding stacked DRAM
substrate [10], 28 nm logic @ 400 MHz, 16x16 banks x 8 MiB, 128-bit bank
ports, 48 mm^2 logic-die budget, 0.88 pJ/bit DRAM access, 1.1 pJ/bit/hop NoC).

The *area model* stands in for Timeloop+Accelergy: MAC-array area plus SRAM
macro area at 28 nm with published-order-of-magnitude constants, calibrated so
the paper's reported best configuration (4x8 nodes, 128x8 PEs, 16/144/32 KiB
buffers) lands comfortably inside the 48 mm^2 budget while maximal
configurations (16x16 nodes x 256x256 PEs) are far outside it.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

KIB = 1024
MIB = 1024 * KIB


@dataclass(frozen=True)
class PimConstraints:
    """Fixed substrate attributes (Table II, 'Constant' rows)."""

    tech_nm: int = 28
    ba_row: int = 16                  # DRAM bank array rows
    ba_col: int = 16                  # DRAM bank array cols
    width_bank_bits: int = 128        # port width per bank
    cap_bank_bytes: int = 8 * MIB     # capacity per bank
    area_budget_mm2: float = 48.0     # logic-die area for NN engines
    freq_hz: float = 400e6            # logic + bank-port clock
    data_bits: int = 16               # activations / weights
    psum_bits: int = 32               # partial sums

    # DRAM electricals (UniIC IEDM'20 [10] + stacked-DRAM-order timing)
    dram_energy_pj_per_bit: float = 0.88
    dram_row_bytes: int = 2048        # row-buffer size per bank
    dram_row_act_energy_pj: float = 800.0   # per activation (row miss)
    dram_row_miss_cycles: int = 16    # tRC-equivalent at 400 MHz

    # NoC (mesh, XY routing; Sec. VIII-B)
    noc_energy_pj_per_bit_hop: float = 1.1
    router_latency_cycles: int = 2

    # Area model constants (28 nm)
    mac_area_um2: float = 900.0       # 16-bit MAC incl. operand regs
    sram_area_mm2_per_mib: float = 1.2   # SRAM macro density
    node_fixed_area_mm2: float = 0.05    # router + bank controller + misc

    @property
    def n_banks(self) -> int:
        return self.ba_row * self.ba_col

    @property
    def bank_bw_bytes(self) -> float:
        """Peak bytes/s of one bank port (128 bit per cycle @ freq)."""
        return self.width_bank_bits / 8 * self.freq_hz


DEFAULT_CONSTRAINTS = PimConstraints()


@dataclass(frozen=True)
class HwConfig:
    """Variable hardware design parameters (Table I / Table II 'Variable')."""

    na_row: int
    na_col: int
    pea_row: int
    pea_col: int
    ibuf_kib: int
    wbuf_kib: int
    obuf_kib: int
    cons: PimConstraints = DEFAULT_CONSTRAINTS

    # -- legality ----------------------------------------------------------
    def divides_bank_array(self) -> bool:
        c = self.cons
        return c.ba_row % self.na_row == 0 and c.ba_col % self.na_col == 0

    def in_range(self) -> bool:
        c = self.cons
        return (2 <= self.na_row <= c.ba_row and 2 <= self.na_col <= c.ba_col
                and 1 <= self.pea_row <= 256 and 1 <= self.pea_col <= 256
                and 1 <= self.ibuf_kib <= 2048 and 1 <= self.wbuf_kib <= 2048
                and 1 <= self.obuf_kib <= 2048)

    def legal_shape(self) -> bool:
        return self.in_range() and self.divides_bank_array()

    # -- derived per-node resources ----------------------------------------
    @property
    def n_nodes(self) -> int:
        return self.na_row * self.na_col

    @property
    def banks_per_node(self) -> int:
        return self.cons.n_banks // self.n_nodes

    @property
    def node_dram_capacity(self) -> int:
        return self.banks_per_node * self.cons.cap_bank_bytes

    @property
    def node_dram_bw(self) -> float:
        """Bytes/s: bound bank ports behave as one wide port (Sec. III-A)."""
        return self.banks_per_node * self.cons.bank_bw_bytes

    @property
    def node_dram_width_bits(self) -> int:
        return self.banks_per_node * self.cons.width_bank_bits

    @property
    def noc_flit_bits(self) -> int:
        """Flit width = half the total DRAM port width of a node (Sec. VIII-B)."""
        return max(32, self.node_dram_width_bits // 2)

    @property
    def link_bw_bytes(self) -> float:
        return self.noc_flit_bits / 8 * self.cons.freq_hz

    @property
    def macs_per_node(self) -> int:
        return self.pea_row * self.pea_col

    @property
    def peak_macs_per_s(self) -> float:
        return self.n_nodes * self.macs_per_node * self.cons.freq_hz

    # -- area model (ground truth the filter model learns) ------------------
    def node_area_mm2(self) -> float:
        c = self.cons
        pe = self.pea_row * self.pea_col * c.mac_area_um2 * 1e-6
        buf_mib = (self.ibuf_kib + self.wbuf_kib + self.obuf_kib) / 1024
        return pe + buf_mib * c.sram_area_mm2_per_mib + c.node_fixed_area_mm2

    def area_mm2(self) -> float:
        return self.n_nodes * self.node_area_mm2()

    def area_legal(self) -> bool:
        return self.legal_shape() and self.area_mm2() <= self.cons.area_budget_mm2

    # -- (de)serialization for the tuner ------------------------------------
    def as_tuple(self) -> tuple[int, ...]:
        return (self.na_row, self.na_col, self.pea_row, self.pea_col,
                self.ibuf_kib, self.wbuf_kib, self.obuf_kib)

    @staticmethod
    def from_tuple(t, cons: PimConstraints = DEFAULT_CONSTRAINTS) -> "HwConfig":
        return HwConfig(*map(int, t), cons=cons)

    def replace(self, **kw) -> "HwConfig":
        return dataclasses.replace(self, **kw)


# Paper Sec. VIII-C: architecture found by NicePIM for the EDP goal.
PAPER_BEST = HwConfig(na_row=4, na_col=8, pea_row=128, pea_col=8,
                      ibuf_kib=16, wbuf_kib=144, obuf_kib=32)
# Sec. VIII-D fixed evaluation systems.
PAPER_4X4 = HwConfig(na_row=4, na_col=4, pea_row=32, pea_col=32,
                     ibuf_kib=128, wbuf_kib=128, obuf_kib=128)
PAPER_16X16 = HwConfig(na_row=16, na_col=16, pea_row=8, pea_col=8,
                       ibuf_kib=8, wbuf_kib=8, obuf_kib=8)


def divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def sample_space(cons: PimConstraints = DEFAULT_CONSTRAINTS):
    """The raw design space bounds (Table II 'Variable' rows).

    Returns a dict of parameter -> candidate values; the tuner samples from
    the cartesian product (~1e10 points before legality filtering).
    """
    pe_vals = [v for v in (1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256)]
    buf_vals = [v for v in (1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 192, 256,
                            384, 512, 768, 1024, 1536, 2048)]
    return {
        "na_row": [d for d in divisors(cons.ba_row) if d >= 2],
        "na_col": [d for d in divisors(cons.ba_col) if d >= 2],
        "pea_row": pe_vals,
        "pea_col": pe_vals,
        "ibuf_kib": buf_vals,
        "wbuf_kib": buf_vals,
        "obuf_kib": buf_vals,
    }


def normalize_params(cfg: HwConfig) -> list[float]:
    """Map a config to [0,1]^7 (log-scaled) for the tuner's models."""
    t = cfg.as_tuple()
    los = [2, 2, 1, 1, 1, 1, 1]
    his = [16, 16, 256, 256, 2048, 2048, 2048]
    return [(math.log2(v) - math.log2(lo)) / (math.log2(hi) - math.log2(lo))
            for v, lo, hi in zip(t, los, his)]
