"""Hardware configuration + area/bandwidth models for the DRAM-PIM accelerator.

Constants follow the paper's Table II (UniIC hybrid-bonding stacked DRAM
substrate [10], 28 nm logic @ 400 MHz, 16x16 banks x 8 MiB, 128-bit bank
ports, 48 mm^2 logic-die budget, 0.88 pJ/bit DRAM access, 1.1 pJ/bit/hop NoC).

The *area model* stands in for Timeloop+Accelergy: MAC-array area plus SRAM
macro area at 28 nm with published-order-of-magnitude constants, calibrated so
the paper's reported best configuration (4x8 nodes, 128x8 PEs, 16/144/32 KiB
buffers) lands comfortably inside the 48 mm^2 budget while maximal
configurations (16x16 nodes x 256x256 PEs) are far outside it.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

KIB = 1024
MIB = 1024 * KIB


@dataclass(frozen=True)
class PimConstraints:
    """Fixed substrate attributes (Table II, 'Constant' rows)."""

    tech_nm: int = 28
    ba_row: int = 16                  # DRAM bank array rows
    ba_col: int = 16                  # DRAM bank array cols
    width_bank_bits: int = 128        # port width per bank
    cap_bank_bytes: int = 8 * MIB     # capacity per bank
    area_budget_mm2: float = 48.0     # logic-die area for NN engines
    freq_hz: float = 400e6            # logic + bank-port clock
    data_bits: int = 16               # activations / weights
    psum_bits: int = 32               # partial sums

    # DRAM electricals (UniIC IEDM'20 [10] + stacked-DRAM-order timing)
    dram_energy_pj_per_bit: float = 0.88
    dram_row_bytes: int = 2048        # row-buffer size per bank
    dram_row_act_energy_pj: float = 800.0   # per activation (row miss)
    dram_row_miss_cycles: int = 16    # tRC-equivalent at 400 MHz

    # NoC (mesh, XY routing; Sec. VIII-B)
    noc_energy_pj_per_bit_hop: float = 1.1
    router_latency_cycles: int = 2

    # Area model constants (28 nm)
    mac_area_um2: float = 900.0       # 16-bit MAC incl. operand regs
    sram_area_mm2_per_mib: float = 1.2   # SRAM macro density
    node_fixed_area_mm2: float = 0.05    # router + bank controller + misc

    @property
    def n_banks(self) -> int:
        return self.ba_row * self.ba_col

    @property
    def bank_bw_bytes(self) -> float:
        """Peak bytes/s of one bank port (128 bit per cycle @ freq)."""
        return self.width_bank_bits / 8 * self.freq_hz


DEFAULT_CONSTRAINTS = PimConstraints()


@dataclass(frozen=True)
class HwConfig:
    """Variable hardware design parameters (Table I / Table II 'Variable')."""

    na_row: int
    na_col: int
    pea_row: int
    pea_col: int
    ibuf_kib: int
    wbuf_kib: int
    obuf_kib: int
    cons: PimConstraints = DEFAULT_CONSTRAINTS

    # -- legality ----------------------------------------------------------
    def divides_bank_array(self) -> bool:
        c = self.cons
        return c.ba_row % self.na_row == 0 and c.ba_col % self.na_col == 0

    def in_range(self) -> bool:
        c = self.cons
        return (2 <= self.na_row <= c.ba_row and 2 <= self.na_col <= c.ba_col
                and 1 <= self.pea_row <= 256 and 1 <= self.pea_col <= 256
                and 1 <= self.ibuf_kib <= 2048 and 1 <= self.wbuf_kib <= 2048
                and 1 <= self.obuf_kib <= 2048)

    def legal_shape(self) -> bool:
        return self.in_range() and self.divides_bank_array()

    # -- derived per-node resources ----------------------------------------
    @property
    def n_nodes(self) -> int:
        return self.na_row * self.na_col

    @property
    def banks_per_node(self) -> int:
        return self.cons.n_banks // self.n_nodes

    @property
    def node_dram_capacity(self) -> int:
        return self.banks_per_node * self.cons.cap_bank_bytes

    @property
    def node_dram_bw(self) -> float:
        """Bytes/s: bound bank ports behave as one wide port (Sec. III-A)."""
        return self.banks_per_node * self.cons.bank_bw_bytes

    @property
    def node_dram_width_bits(self) -> int:
        return self.banks_per_node * self.cons.width_bank_bits

    @property
    def noc_flit_bits(self) -> int:
        """Flit width = half the total DRAM port width of a node (Sec. VIII-B)."""
        return max(32, self.node_dram_width_bits // 2)

    @property
    def link_bw_bytes(self) -> float:
        return self.noc_flit_bits / 8 * self.cons.freq_hz

    @property
    def macs_per_node(self) -> int:
        return self.pea_row * self.pea_col

    @property
    def peak_macs_per_s(self) -> float:
        return self.n_nodes * self.macs_per_node * self.cons.freq_hz

    # -- area model (ground truth the filter model learns) ------------------
    def node_area_mm2(self) -> float:
        c = self.cons
        pe = self.pea_row * self.pea_col * c.mac_area_um2 * 1e-6
        buf_mib = (self.ibuf_kib + self.wbuf_kib + self.obuf_kib) / 1024
        return pe + buf_mib * c.sram_area_mm2_per_mib + c.node_fixed_area_mm2

    def area_mm2(self) -> float:
        return self.n_nodes * self.node_area_mm2()

    def area_legal(self) -> bool:
        return self.legal_shape() and self.area_mm2() <= self.cons.area_budget_mm2

    # -- (de)serialization for the tuner ------------------------------------
    def as_tuple(self) -> tuple[int, ...]:
        return (self.na_row, self.na_col, self.pea_row, self.pea_col,
                self.ibuf_kib, self.wbuf_kib, self.obuf_kib)

    @staticmethod
    def from_tuple(t, cons: PimConstraints = DEFAULT_CONSTRAINTS) -> "HwConfig":
        return HwConfig(*map(int, t), cons=cons)

    def replace(self, **kw) -> "HwConfig":
        return dataclasses.replace(self, **kw)


# Paper Sec. VIII-C: architecture found by NicePIM for the EDP goal.
PAPER_BEST = HwConfig(na_row=4, na_col=8, pea_row=128, pea_col=8,
                      ibuf_kib=16, wbuf_kib=144, obuf_kib=32)
# Sec. VIII-D fixed evaluation systems.
PAPER_4X4 = HwConfig(na_row=4, na_col=4, pea_row=32, pea_col=32,
                     ibuf_kib=128, wbuf_kib=128, obuf_kib=128)
PAPER_16X16 = HwConfig(na_row=16, na_col=16, pea_row=8, pea_col=8,
                       ibuf_kib=8, wbuf_kib=8, obuf_kib=8)


def divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def sample_space(cons: PimConstraints = DEFAULT_CONSTRAINTS):
    """The raw design space bounds (Table II 'Variable' rows).

    Returns a dict of parameter -> candidate values; the tuner samples from
    the cartesian product (~1e10 points before legality filtering).
    """
    pe_vals = [v for v in (1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256)]
    buf_vals = [v for v in (1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 192, 256,
                            384, 512, 768, 1024, 1536, 2048)]
    return {
        "na_row": [d for d in divisors(cons.ba_row) if d >= 2],
        "na_col": [d for d in divisors(cons.ba_col) if d >= 2],
        "pea_row": pe_vals,
        "pea_col": pe_vals,
        "ibuf_kib": buf_vals,
        "wbuf_kib": buf_vals,
        "obuf_kib": buf_vals,
    }


def normalize_params(cfg: HwConfig) -> list[float]:
    """Map a config to [0,1]^7 (log-scaled) for the tuner's models."""
    t = cfg.as_tuple()
    los = [2, 2, 1, 1, 1, 1, 1]
    his = [16, 16, 256, 256, 2048, 2048, 2048]
    return [(math.log2(v) - math.log2(lo)) / (math.log2(hi) - math.log2(lo))
            for v, lo, hi in zip(t, los, his)]


_NORM_LOS = np.array([2, 2, 1, 1, 1, 1, 1], dtype=np.float64)
_NORM_HIS = np.array([16, 16, 256, 256, 2048, 2048, 2048], dtype=np.float64)


def normalize_params_batch(values: np.ndarray,
                           dtype=np.float32) -> np.ndarray:
    """Vectorized :func:`normalize_params` over an ``[n, 7]`` value matrix.

    Defaults to ``float32`` (the dtype the tuner's models train in); matches
    the scalar version elementwise (both go through float64 log2 first).
    """
    values = np.asarray(values, dtype=np.float64)
    x = (np.log2(values) - np.log2(_NORM_LOS)) \
        / (np.log2(_NORM_HIS) - np.log2(_NORM_LOS))
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Vectorized design-space sampling (the tuner's candidate draw)
# ---------------------------------------------------------------------------


def space_tables(cons: PimConstraints = DEFAULT_CONSTRAINTS
                 ) -> tuple[tuple[str, ...], list[np.ndarray]]:
    """:func:`sample_space` as (keys, value arrays) for index-based draws."""
    space = sample_space(cons)
    keys = tuple(space)
    return keys, [np.asarray(space[k], dtype=np.int64) for k in keys]


def legal_shape_mask(values: np.ndarray,
                     cons: PimConstraints = DEFAULT_CONSTRAINTS) -> np.ndarray:
    """Vectorized ``HwConfig.legal_shape`` over an ``[n, 7]`` value matrix."""
    values = np.asarray(values, dtype=np.int64)
    na_row, na_col = values[:, 0], values[:, 1]
    pea = values[:, 2:4]
    bufs = values[:, 4:7]
    in_range = ((na_row >= 2) & (na_row <= cons.ba_row)
                & (na_col >= 2) & (na_col <= cons.ba_col)
                & (pea >= 1).all(axis=1) & (pea <= 256).all(axis=1)
                & (bufs >= 1).all(axis=1) & (bufs <= 2048).all(axis=1))
    divides = (cons.ba_row % np.maximum(na_row, 1) == 0) \
        & (cons.ba_col % np.maximum(na_col, 1) == 0)
    return in_range & divides


def sample_config_values(n: int, rng: np.random.Generator,
                         cons: PimConstraints = DEFAULT_CONSTRAINTS,
                         max_draws: int | None = None) -> np.ndarray:
    """Draw ``n`` shape-legal configs as an ``[n, 7]`` raw-value matrix.

    The whole candidate batch is drawn as index arrays over the Table-II
    grid (one broadcasted ``rng.integers`` call per deficit chunk) and
    filtered through the vectorized :func:`legal_shape_mask` — no per-config
    Python rejection loop.  The draw order consumes the generator stream
    exactly like the scalar :func:`repro.core.tuner.sample_configs` reference
    (numpy's broadcasted bounded-integer draw is elementwise-sequential in C
    order), so a shared seed yields identical samples; the parity tests pin
    this.  ``max_draws`` caps total *attempts* (legal or not); exceeding it
    raises instead of looping forever on a degenerate space.
    """
    if max_draws is None:
        max_draws = 64 * n + 1024
    keys, tables = space_tables(cons)
    highs = np.array([len(t) for t in tables], dtype=np.int64)
    if (highs == 0).any():
        raise RuntimeError(
            f"empty design space for {cons}: no candidate values for "
            f"{[k for k, h in zip(keys, highs) if h == 0]}")
    out: list[np.ndarray] = []
    got = 0
    drawn = 0
    while got < n:
        m = min(n - got, max(0, max_draws - drawn))
        if m <= 0:
            raise RuntimeError(
                f"sample_config_values: drew {drawn} candidates but only "
                f"{got}/{n} passed legal_shape (draw cap {max_draws}); the "
                f"constraint set likely leaves no legal configurations")
        idx = rng.integers(0, highs, size=(m, len(tables)))
        drawn += m
        vals = np.stack([t[idx[:, i]] for i, t in enumerate(tables)], axis=1)
        legal = legal_shape_mask(vals, cons)
        if legal.any():
            out.append(vals[legal])
            got += int(legal.sum())
    return np.concatenate(out, axis=0)[:n]


def sample_configs_batch(n: int, rng: np.random.Generator,
                         cons: PimConstraints = DEFAULT_CONSTRAINTS,
                         max_draws: int | None = None) -> list[HwConfig]:
    """Batched drop-in for ``tuner.sample_configs`` (same seed, same configs)."""
    vals = sample_config_values(n, rng, cons, max_draws=max_draws)
    return [HwConfig(*map(int, row), cons=cons) for row in vals]


def configs_from_rows(values: np.ndarray, cons: PimConstraints, order,
                      k: int, valid: np.ndarray | None = None
                      ) -> list[HwConfig]:
    """Materialize the top-k unique configs of a ranked ``[n, 7]`` matrix.

    ``order`` ranks rows best-first; ``valid`` optionally marks rows that may
    be returned — iteration stops at the first invalid row, so callers that
    mask candidates in-array (``+inf`` score, sorted last) never surface
    them.  The single dedup-to-k implementation behind every strategy's
    propose, so tie-breaking/dedup semantics cannot drift between backends.
    """
    seen, out = set(), []
    for i in order:
        if valid is not None and not valid[i]:
            break
        t = tuple(int(v) for v in values[i])
        if t not in seen:
            seen.add(t)
            out.append(HwConfig.from_tuple(t, cons=cons))
        if len(out) >= k:
            break
    return out
