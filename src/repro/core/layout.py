"""Data layout patterns in DRAM and the burst/row-buffer access model (Sec. III-E).

A feature map ``(B, C, H, W)`` is flattened into a node's DRAM either in
``BCHW[Cg]`` order (channel-major with ``g`` channels interleaved innermost)
or ``BHWC`` order (pixel-major, all channels interleaved).  DRAM delivers
``burst_words`` values per access (the bound bank ports of one PIM-node act as
a single wide port), so fetching a tile costs a number of **bursts** that
depends on how contiguous the tile is under the layout, plus **row
activations** whenever the access stream leaves the current DRAM row.

The burst count reproduces the paper's Fig. 6 reasoning: a run of ``L``
contiguous values whose start offsets are multiples of ``align`` (mod the
burst width) costs the mean over feasible offsets of ``ceil((off + L) /
burst)`` bursts.  E.g. with 4 words/burst a 3-value run at value alignment
costs 1.5 bursts on average (9 accesses for a two-channel 3x3 window in plain
BCHW, as in the paper), while a 6-value run at 2-value alignment costs exactly
2 (6 accesses in BCHW[C2]).

Runs that happen to be adjacent in the flattened address space are
**coalesced** (full-width rows merge across H; full planes merge across
channel groups and batch), which is what makes e.g. a streaming matmul operand
read sequential instead of one row-activation per sample.

Everything is written against ``numpy`` semantics so the same code runs on
scalars (reference path, used by the tests) and on vectors of candidate tile
shapes (the cost-model's tiling search).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

LAYOUT_ORDERS = ("BCHW", "BHWC")


@dataclass(frozen=True)
class DataLayout:
    order: str = "BCHW"
    group: int = 1  # channel grouping [Cg]; only meaningful for BCHW

    def __post_init__(self):
        if self.order not in LAYOUT_ORDERS:
            raise ValueError(f"bad layout order {self.order!r}")

    def short(self) -> str:
        if self.order == "BHWC":
            return "BHWC"
        return "BCHW" if self.group == 1 else f"BCHW[C{self.group}]"


def enumerate_layouts(C: int, max_group: int = 32) -> list[DataLayout]:
    """All candidate DLs for a fmap with ``C`` channels (Sec. VI-C)."""
    outs = [DataLayout("BHWC")]
    g = 1
    while g <= min(C, max_group):
        outs.append(DataLayout("BCHW", g))
        g *= 2
    return outs


def mean_bursts(run_len, align: int, burst: int):
    """Alignment-averaged bursts to read a contiguous run (vectorizable).

    Closed form of the mean over start offsets ``{0, g, .., burst-g}`` (with
    ``g = gcd(align, burst)``) of ``ceil((off + run) / burst)``: writing
    ``run = q*burst + r`` with ``r`` in ``(0, burst]``, an offset adds one
    extra burst exactly when ``off > burst - r``, so the mean is ``q + 1``
    plus the fraction of the ``m = burst/g`` offsets past that point.  O(1)
    per run instead of O(burst/gcd) — this is the inner loop of the batched
    DSE engine (engine/batch_cost mirrors this formula in JAX).
    """
    g = math.gcd(max(1, int(align)), int(burst))
    m = burst // g
    run = np.asarray(run_len, dtype=np.float64)
    q = np.ceil(run / burst) - 1.0
    r = run - q * burst                          # residual in (0, burst]
    over = m - 1.0 - np.floor((burst - r) / g)   # offsets costing 1 extra
    return q + 1.0 + over / m


def access_pattern(fmap, tb, tc, th, tw, order: str, group: int):
    """Describe the address pattern of one tile fetch under a layout.

    Returns ``(run, n_runs, span, n_extents)`` — all numpy-broadcastable:
    ``run`` values per contiguous run, ``n_runs`` runs, and ``n_extents``
    disjoint regions each spanning ``span`` values (for row-activation
    accounting).  Coalesces runs that are adjacent in the address space.
    """
    B, C, H, W = fmap
    tb = np.minimum(np.asarray(tb, dtype=np.float64), B)
    tc = np.minimum(np.asarray(tc, dtype=np.float64), C)
    th = np.minimum(np.asarray(th, dtype=np.float64), H)
    tw = np.minimum(np.asarray(tw, dtype=np.float64), W)
    full_w = tw >= W
    full_h = th >= H
    full_c = tc >= C

    if order == "BHWC":
        # linear index: ((b*H + h)*W + w)*C + c
        base_run = np.where(full_c, tw * C, tc)
        base_nruns = np.where(full_c, tb * th, tb * th * tw)
        # coalesce: full channel rows merge across h; full planes across b
        run = np.where(full_c & full_w, th * W * C, base_run)
        n_runs = np.where(full_c & full_w, tb, base_nruns)
        run = np.where(full_c & full_w & full_h, tb * H * W * C, run)
        n_runs = np.where(full_c & full_w & full_h, 1.0, n_runs)
        span = np.where(full_c & full_w & full_h, tb * H * W * C,
                        ((th - 1) * W + tw) * C)
        n_extents = np.where(full_c & full_w & full_h, 1.0, tb)
    else:
        g = min(max(1, group), C)
        c_groups = np.ceil(tc / g)
        # linear index: (((b*(C/g) + cg)*H + h)*W + w)*g + c_in_g
        run = tw * g * np.ones_like(tc)
        n_runs = tb * c_groups * th
        # coalesce full-width rows across h
        run = np.where(full_w, tw * g * th, run)
        n_runs = np.where(full_w, tb * c_groups, n_runs)
        # full spatial planes merge across channel groups
        plane = full_w & full_h
        run = np.where(plane, H * W * g * c_groups, run)
        n_runs = np.where(plane, tb, n_runs)
        # ... and across batch when all channels are taken
        whole = plane & full_c
        run = np.where(whole, tb * C * H * W, run)
        n_runs = np.where(whole, 1.0, n_runs)
        span = np.where(plane, run, ((th - 1) * W + tw) * g)
        n_extents = np.where(plane, n_runs, tb * c_groups)
        return run, n_runs, span, n_extents, g
    return run, n_runs, span, n_extents, C


def tile_cost_vec(fmap, tb, tc, th, tw, layout: DataLayout,
                  burst_words: int, row_words: int):
    """(bursts, row_activations) per single tile fetch — vectorized."""
    run, n_runs, span, n_extents, align = access_pattern(
        fmap, tb, tc, th, tw, layout.order, layout.group)
    bursts = n_runs * mean_bursts(run, align, burst_words)
    rows = n_extents * np.maximum(1.0, span / row_words)
    return bursts, rows


@lru_cache(maxsize=65536)
def tile_access_cost(
    fmap: tuple[int, int, int, int],
    tile: tuple[int, int, int, int],
    layout: DataLayout,
    burst_words: int,
    row_words: int,
) -> tuple[float, float]:
    """(bursts, row_activations) to fetch one ``tile`` of ``fmap`` once.

    Scalar convenience wrapper over :func:`tile_cost_vec`; ``burst_words`` /
    ``row_words`` are in *values* (DRAM port width and row size divided by the
    data width).
    """
    tb, tc, th, tw = tile
    bursts, rows = tile_cost_vec(fmap, tb, tc, th, tw, layout,
                                 burst_words, row_words)
    return float(bursts), float(rows)


@lru_cache(maxsize=65536)
def sequential_access_cost(
    n_values: int, burst_words: int, row_words: int
) -> tuple[float, float]:
    """Bursts/rows for perfectly sequential data (weights are pre-arranged)."""
    if n_values <= 0:
        return 0.0, 0.0
    return float(math.ceil(n_values / burst_words)), max(1.0, n_values / row_words)
