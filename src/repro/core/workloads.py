"""Workload DNNs (Sec. VIII-B) expressed in the NicePIM IR.

GoogLeNet, VGG16, ResNet152, DarkNet53 and BERT-Base, exactly the five
evaluation networks of the paper, plus a generic decoder-transformer /
MoE export used to run the paper's DSE over the assigned LM architectures
(each transformer block's matmuls in the conv representation of Sec. II-B;
attention heads and MoE experts become parallel *branches*).

All builders take ``batch`` (the paper evaluates batch 1) and optional
``scale`` to shrink spatial dims / layer counts for fast CI runs.
"""

from __future__ import annotations

import math

from .ir import DnnGraph, Layer, conv, matmul


def _pool(name: str, B: int, C: int, H: int, W: int, stride: int = 2) -> Layer:
    return Layer(name, "pool", B=B, C=C, H=H, W=W, K=C,
                 HK=stride, WK=stride, stride=stride)


def _aux(name: str, kind: str, B: int, C: int, H: int, W: int) -> Layer:
    return Layer(name, kind, B=B, C=C, H=H, W=W, K=C)


# ---------------------------------------------------------------------------
# VGG16
# ---------------------------------------------------------------------------

def vgg16(batch: int = 1, scale: int = 1) -> DnnGraph:
    g = DnnGraph("vgg16")
    hw_ = 224 // scale
    cfg = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]
    prev = None
    c_in, size = 3, hw_
    idx = 0
    for ci, (c_out, reps) in enumerate(cfg):
        for r in range(reps):
            name = f"conv{idx}"
            g.add(conv(name, batch, c_in, size, size, c_out),
                  [prev] if prev else [])
            prev, c_in = name, c_out
            idx += 1
        pname = f"pool{ci}"
        g.add(_pool(pname, batch, c_in, size, size), [prev])
        prev = pname
        size //= 2
    feat = c_in * size * size
    for i, k in enumerate((4096 // scale, 4096 // scale, 1000)):
        name = f"fc{i}"
        g.add(matmul(name, batch, feat, k), [prev])
        prev, feat = name, k
    return g


# ---------------------------------------------------------------------------
# GoogLeNet (Inception v1)
# ---------------------------------------------------------------------------

_INCEPTION = {
    "3a": (192, 64, 96, 128, 16, 32, 32),
    "3b": (256, 128, 128, 192, 32, 96, 64),
    "4a": (480, 192, 96, 208, 16, 48, 64),
    "4b": (512, 160, 112, 224, 24, 64, 64),
    "4c": (512, 128, 128, 256, 24, 64, 64),
    "4d": (512, 112, 144, 288, 32, 64, 64),
    "4e": (528, 256, 160, 320, 32, 128, 128),
    "5a": (832, 256, 160, 320, 32, 128, 128),
    "5b": (832, 384, 192, 384, 48, 128, 128),
}


def googlenet(batch: int = 1, scale: int = 1) -> DnnGraph:
    g = DnnGraph("googlenet")
    size = 224 // scale
    g.add(conv("stem1", batch, 3, size, size, 64, HK=7, stride=2))
    size //= 2
    g.add(_pool("pool1", batch, 64, size, size), ["stem1"])
    size //= 2
    g.add(conv("stem2", batch, 64, size, size, 64, HK=1), ["pool1"])
    g.add(conv("stem3", batch, 64, size, size, 192, HK=3), ["stem2"])
    g.add(_pool("pool2", batch, 192, size, size), ["stem3"])
    size //= 2
    prev = "pool2"
    for blk, (cin, c1, c3r, c3, c5r, c5, pp) in _INCEPTION.items():
        if blk in ("4a", "5a"):
            g.add(_pool(f"pool_{blk}", batch, cin, size, size), [prev])
            prev = f"pool_{blk}"
            size //= 2
        b1 = f"i{blk}_1x1"
        g.add(conv(b1, batch, cin, size, size, c1, HK=1), [prev])
        b2a, b2b = f"i{blk}_3r", f"i{blk}_3x3"
        g.add(conv(b2a, batch, cin, size, size, c3r, HK=1), [prev])
        g.add(conv(b2b, batch, c3r, size, size, c3, HK=3), [b2a])
        b3a, b3b = f"i{blk}_5r", f"i{blk}_5x5"
        g.add(conv(b3a, batch, cin, size, size, c5r, HK=1), [prev])
        g.add(conv(b3b, batch, c5r, size, size, c5, HK=5), [b3a])
        b4a, b4b = f"i{blk}_pool", f"i{blk}_pp"
        g.add(Layer(b4a, "pool", B=batch, C=cin, H=size, W=size, K=cin,
                    HK=3, WK=3, stride=1), [prev])
        g.add(conv(b4b, batch, cin, size, size, pp, HK=1), [b4a])
        cat = f"i{blk}_cat"
        cout = c1 + c3 + c5 + pp
        g.add(_aux(cat, "concat", batch, cout, size, size),
              [b1, b2b, b3b, b4b])
        prev = cat
    g.add(_pool("gap", batch, 1024, size, size, stride=size), [prev])
    g.add(matmul("fc", batch, 1024, 1000), ["gap"])
    return g


# ---------------------------------------------------------------------------
# ResNet-152
# ---------------------------------------------------------------------------

def resnet152(batch: int = 1, scale: int = 1,
              stage_blocks: tuple[int, ...] = (3, 8, 36, 3)) -> DnnGraph:
    return _resnet(batch, scale, stage_blocks, "resnet152")


def resnet50(batch: int = 1, scale: int = 1) -> DnnGraph:
    return _resnet(batch, scale, (3, 4, 6, 3), "resnet50")


def _resnet(batch: int, scale: int, stage_blocks, name: str) -> DnnGraph:
    g = DnnGraph(name)
    size = 224 // scale
    g.add(conv("stem", batch, 3, size, size, 64, HK=7, stride=2))
    size //= 2
    g.add(_pool("pool1", batch, 64, size, size), ["stem"])
    size //= 2
    prev, cin = "pool1", 64
    widths = (64, 128, 256, 512)
    for si, (blocks, w) in enumerate(zip(stage_blocks, widths)):
        cout = w * 4
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            pfx = f"s{si}b{bi}"
            g.add(conv(f"{pfx}_c1", batch, cin, size, size, w, HK=1,
                       stride=stride), [prev])
            nsize = size // stride
            g.add(conv(f"{pfx}_c2", batch, w, nsize, nsize, w, HK=3),
                  [f"{pfx}_c1"])
            g.add(conv(f"{pfx}_c3", batch, w, nsize, nsize, cout, HK=1),
                  [f"{pfx}_c2"])
            if cin != cout or stride > 1:
                g.add(conv(f"{pfx}_sc", batch, cin, size, size, cout, HK=1,
                           stride=stride), [prev])
                sc = f"{pfx}_sc"
            else:
                sc = prev
            g.add(_aux(f"{pfx}_add", "add", batch, cout, nsize, nsize),
                  [f"{pfx}_c3", sc])
            prev, cin, size = f"{pfx}_add", cout, nsize
    g.add(_pool("gap", batch, cin, size, size, stride=size), [prev])
    g.add(matmul("fc", batch, cin, 1000), ["gap"])
    return g


# ---------------------------------------------------------------------------
# DarkNet-53 (YOLOv3 backbone)
# ---------------------------------------------------------------------------

def darknet53(batch: int = 1, scale: int = 1) -> DnnGraph:
    g = DnnGraph("darknet53")
    size = 256 // scale
    g.add(conv("c0", batch, 3, size, size, 32, HK=3))
    prev, cin = "c0", 32
    stages = [(64, 1), (128, 2), (256, 8), (512, 8), (1024, 4)]
    for si, (cout, reps) in enumerate(stages):
        g.add(conv(f"d{si}", batch, cin, size, size, cout, HK=3, stride=2),
              [prev])
        size //= 2
        prev, cin = f"d{si}", cout
        half = cout // 2
        for r in range(reps):
            pfx = f"s{si}r{r}"
            g.add(conv(f"{pfx}_a", batch, cout, size, size, half, HK=1),
                  [prev])
            g.add(conv(f"{pfx}_b", batch, half, size, size, cout, HK=3),
                  [f"{pfx}_a"])
            g.add(_aux(f"{pfx}_add", "add", batch, cout, size, size),
                  [f"{pfx}_b", prev])
            prev = f"{pfx}_add"
    g.add(_pool("gap", batch, cin, size, size, stride=size), [prev])
    g.add(matmul("fc", batch, cin, 1000), ["gap"])
    return g


# ---------------------------------------------------------------------------
# BERT-Base (12 layers, 12 heads) — heads as parallel branches
# ---------------------------------------------------------------------------

def bert_base(batch: int = 1, seq: int = 128, n_layers: int = 12,
              d_model: int = 768, n_heads: int = 12,
              d_ff: int | None = None) -> DnnGraph:
    g = DnnGraph("bert_base" if n_layers == 12 else
                 f"bert_{n_layers}l")
    d_ff = d_ff or 4 * d_model
    d_head = d_model // n_heads
    tok = batch * seq
    g.add(_aux("embed", "input", tok, d_model, 1, 1))
    prev = "embed"
    for li in range(n_layers):
        pfx = f"l{li}"
        head_outs = []
        for h in range(n_heads):
            hp = f"{pfx}h{h}"
            g.add(matmul(f"{hp}_q", tok, d_model, d_head), [prev])
            g.add(matmul(f"{hp}_k", tok, d_model, d_head), [prev])
            g.add(matmul(f"{hp}_v", tok, d_model, d_head), [prev])
            # scores: (B*seq, d_head) x (d_head, seq) per sample
            g.add(matmul(f"{hp}_qk", tok, d_head, seq),
                  [f"{hp}_q", f"{hp}_k"])
            g.add(_aux(f"{hp}_sm", "softmax", tok, seq, 1, 1), [f"{hp}_qk"])
            g.add(matmul(f"{hp}_av", tok, seq, d_head),
                  [f"{hp}_sm", f"{hp}_v"])
            head_outs.append(f"{hp}_av")
        g.add(_aux(f"{pfx}_cat", "concat", tok, d_model, 1, 1), head_outs)
        g.add(matmul(f"{pfx}_proj", tok, d_model, d_model), [f"{pfx}_cat"])
        g.add(_aux(f"{pfx}_ln1", "norm", tok, d_model, 1, 1), [f"{pfx}_proj"])
        g.add(matmul(f"{pfx}_ff1", tok, d_model, d_ff), [f"{pfx}_ln1"])
        g.add(matmul(f"{pfx}_ff2", tok, d_ff, d_model), [f"{pfx}_ff1"])
        g.add(_aux(f"{pfx}_ln2", "norm", tok, d_model, 1, 1), [f"{pfx}_ff2"])
        prev = f"{pfx}_ln2"
    return g


# ---------------------------------------------------------------------------
# Generic decoder transformer / MoE export for the assigned architectures
# ---------------------------------------------------------------------------

def transformer_graph(name: str, *, n_layers: int, d_model: int,
                      n_heads: int, n_kv_heads: int, d_ff: int,
                      vocab: int, seq: int = 512, batch: int = 1,
                      n_experts: int = 0, top_k: int = 0,
                      attention_free: bool = False,
                      layers_limit: int | None = 2) -> DnnGraph:
    """Decoder block stack in the conv representation (Sec. II-B).

    ``layers_limit`` keeps the PIM DSE tractable: the graph holds
    ``min(n_layers, layers_limit)`` representative blocks plus the LM head;
    reported totals can be scaled by ``n_layers / layers_limit``.  MoE
    experts become parallel branches with the expected per-expert token load
    (``tokens * top_k / n_experts``), exercising the paper's multi-branch SM
    machinery the same way BERT's heads do.
    """
    g = DnnGraph(name)
    tok = batch * seq
    d_head = d_model // n_heads
    kv_dim = n_kv_heads * d_head
    g.add(_aux("embed", "input", tok, d_model, 1, 1))
    prev = "embed"
    blocks = min(n_layers, layers_limit or n_layers)
    for li in range(blocks):
        pfx = f"l{li}"
        if not attention_free:
            g.add(matmul(f"{pfx}_q", tok, d_model, d_model), [prev])
            g.add(matmul(f"{pfx}_k", tok, d_model, kv_dim), [prev])
            g.add(matmul(f"{pfx}_v", tok, d_model, kv_dim), [prev])
            g.add(matmul(f"{pfx}_qk", tok, d_head, seq * n_heads // 8),
                  [f"{pfx}_q", f"{pfx}_k"])
            g.add(_aux(f"{pfx}_sm", "softmax", tok, seq, 1, 1), [f"{pfx}_qk"])
            g.add(matmul(f"{pfx}_av", tok, seq * n_heads // 8, d_head),
                  [f"{pfx}_sm", f"{pfx}_v"])
            g.add(matmul(f"{pfx}_proj", tok, d_model, d_model), [f"{pfx}_av"])
            attn_out = f"{pfx}_proj"
        else:
            # SSM-style token mixer: projections only (scan is auxiliary)
            g.add(matmul(f"{pfx}_rg_in", tok, d_model, 2 * d_model), [prev])
            g.add(_aux(f"{pfx}_scan", "act", tok, d_model, 1, 1),
                  [f"{pfx}_rg_in"])
            g.add(matmul(f"{pfx}_rg_out", tok, d_model, d_model),
                  [f"{pfx}_scan"])
            attn_out = f"{pfx}_rg_out"
        g.add(_aux(f"{pfx}_ln", "norm", tok, d_model, 1, 1), [attn_out])
        if n_experts > 1:
            outs = []
            etok = max(1, tok * top_k // n_experts)
            for e in range(n_experts):
                g.add(matmul(f"{pfx}e{e}_up", etok, d_model, d_ff),
                      [f"{pfx}_ln"])
                g.add(matmul(f"{pfx}e{e}_dn", etok, d_ff, d_model),
                      [f"{pfx}e{e}_up"])
                outs.append(f"{pfx}e{e}_dn")
            g.add(_aux(f"{pfx}_moe_cat", "concat", tok, d_model, 1, 1), outs)
            prev = f"{pfx}_moe_cat"
        else:
            g.add(matmul(f"{pfx}_ff1", tok, d_model, d_ff), [f"{pfx}_ln"])
            g.add(matmul(f"{pfx}_ff2", tok, d_ff, d_model), [f"{pfx}_ff1"])
            g.add(_aux(f"{pfx}_ln2", "norm", tok, d_model, 1, 1),
                  [f"{pfx}_ff2"])
            prev = f"{pfx}_ln2"
    g.add(matmul("lm_head", tok, d_model, vocab), [prev])
    return g


# registry used by benchmarks / tests
PAPER_WORKLOADS = {
    "googlenet": googlenet,
    "vgg16": vgg16,
    "resnet152": resnet152,
    "darknet53": darknet53,
    "bert_base": bert_base,
}


def paper_workloads(batch: int = 1, *, fast: bool = False) -> list[DnnGraph]:
    """The five evaluation DNNs; ``fast`` shrinks them for unit tests."""
    if fast:
        return [
            googlenet(batch, scale=4),
            vgg16(batch, scale=4),
            resnet50(batch, scale=4),
            darknet53(batch, scale=4),
            bert_base(batch, seq=64, n_layers=2, n_heads=4),
        ]
    return [
        googlenet(batch),
        vgg16(batch),
        resnet152(batch),
        darknet53(batch),
        bert_base(batch),
    ]
