"""Comparison mappers from Sec. VIII-D.

* :class:`BaselineMapper` — the paper's baseline: every layer is mapped onto
  the whole PIM-node array; the LM is solved per layer with a Timeloop-like
  per-node-delay objective (no communication awareness, no inter-branch
  parallelism); WR starts at full replication and is halved on the
  largest-weight layers until the DRAM capacity constraint is met; one global
  DL is used for all layers, chosen as the best of {BCHW, BHWC, BCHW[C8]}.
  Its data-sharing is still scheduled by the Data-Scheduler (as in the paper,
  for fairness).

* :class:`DdamMapper` — DDAM-lite [47]: partitions the DNN into contiguous
  pipeline stages balanced by MACs (dynamic programming), maps each stage
  onto its own region, and optimizes *throughput*; latency is the sum of all
  stage latencies (pipeline fill), which reproduces the paper's "latency is
  10x worse" observation qualitatively.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .costmodel import part_layer_cost
from .hardware import HwConfig
from .ir import DnnGraph
from .layout import DataLayout
from .mapper import (LayerChoice, Mapping, evaluate_mapping, _layer_candidates)
from .partition import comm_estimate, enumerate_lms, part_layer, wr_candidates
from .regions import SM, Region

INF = float("inf")

BASELINE_DLS = (DataLayout("BCHW", 1), DataLayout("BHWC"), DataLayout("BCHW", 8))


class BaselineMapper:
    """Sequential whole-array mapping (the paper's baseline method)."""

    def __init__(self, hw: HwConfig, *, lm_cap: int = 200):
        self.hw = hw
        self.lm_cap = lm_cap

    def map(self, graph: DnnGraph) -> Mapping:
        best_mapping = None
        best_lat = INF
        for dl in BASELINE_DLS:
            m = self._map_with_dl(graph, dl)
            if m.est_latency_s < best_lat:
                best_lat = m.est_latency_s
                best_mapping = m
        return best_mapping

    def _map_with_dl(self, graph: DnnGraph, dl: DataLayout) -> Mapping:
        hw = self.hw
        region = Region(0, 0, hw.na_row, hw.na_col)
        segments = graph.segments()
        choices: dict[str, LayerChoice] = {}
        sm: dict[int, SM] = {}
        dbytes = hw.cons.data_bits // 8
        # LM per layer: Timeloop-style min per-node delay (ignores comm).
        for name in graph.topo_order():
            layer = graph.layer(name)
            if not layer.is_heavy:
                continue
            best_lm, best_lat = None, INF
            for lm in enumerate_lms(layer, hw.na_row, hw.na_col,
                                    cap=self.lm_cap):
                pl = part_layer(layer, lm)
                lat = part_layer_cost(hw, pl, dl, dl).latency_s
                if lat < best_lat:
                    best_lm, best_lat = lm, lat
            wr = best_lm.weight_share  # start at full replication
            choices[name] = LayerChoice(best_lm, wr, dl, dl, region,
                                        best_lat, 0.0)
        # WR: shrink from the largest-weight layers until capacity fits.
        self._fit_capacity(graph, choices)
        # fill sizes/perf estimates
        est = 0.0
        for name, ch in choices.items():
            layer = graph.layer(name)
            ce = comm_estimate(layer, ch.lm, ch.wr, hw)
            node = part_layer_cost(hw, part_layer(layer, ch.lm),
                                   ch.dl_in, ch.dl_out)
            ch.size_bytes = ce.weight_bytes_per_node
            ch.perf_s = node.latency_s + ce.latency_s
            est += ch.perf_s
        for i, seg in enumerate(segments):
            sm[i] = SM(1, (region,), tuple(0 for _ in seg.branches))
        return Mapping(graph, hw, segments, sm, choices, est_latency_s=est)

    def _fit_capacity(self, graph: DnnGraph, choices: dict[str, LayerChoice]):
        hw = self.hw
        cap = hw.node_dram_capacity

        def usage() -> float:
            tot = 0.0
            for name, ch in choices.items():
                tot += comm_estimate(graph.layer(name), ch.lm, ch.wr,
                                     hw).weight_bytes_per_node
            return tot

        guard = 0
        while usage() > cap and guard < 10000:
            guard += 1
            # largest stored-weight layer with wr still reducible
            cand = max(
                (ch for ch in choices.values() if ch.wr > 1),
                key=lambda ch: comm_estimate(
                    graph.layer(_name_of(choices, ch)), ch.lm, ch.wr,
                    hw).weight_bytes_per_node,
                default=None)
            if cand is None:
                break
            cand.wr = max(1, cand.wr // 2)


def _name_of(choices: dict[str, LayerChoice], ch: LayerChoice) -> str:
    for k, v in choices.items():
        if v is ch:
            return k
    raise KeyError


@dataclass
class PipelineResult:
    mapping: Mapping
    throughput_sps: float   # samples/s in steady state
    latency_s: float        # single-sample latency (pipeline fill)
    energy_pj: float


class DdamMapper:
    """DDAM-lite: contiguous pipeline stages balanced by MACs."""

    def __init__(self, hw: HwConfig, *, n_stages: int | None = None,
                 lm_cap: int = 120):
        self.hw = hw
        self.n_stages = n_stages
        self.lm_cap = lm_cap

    def map(self, graph: DnnGraph) -> PipelineResult:
        hw = self.hw
        order = [n for n in graph.topo_order() if graph.layer(n).is_heavy]
        macs = [graph.layer(n).macs for n in order]
        n_stages = self.n_stages or max(2, min(8, hw.n_nodes // 4,
                                               len(order) // 2 or 1))
        n_stages = max(1, min(n_stages, len(order)))
        bounds = _balanced_chunks(macs, n_stages)
        # stage regions: split array columns proportionally to stage MACs
        regions = _column_regions(hw, [sum(macs[a:b]) for a, b in bounds])
        choices: dict[str, LayerChoice] = {}
        stage_lat = []
        total_energy = 0.0
        for (a, b), region in zip(bounds, regions):
            lat = 0.0
            for name in order[a:b]:
                layer = graph.layer(name)
                dl = DataLayout("BCHW", 8)
                cands = _layer_candidates(hw, layer, region.h_shape,
                                          region.w_shape, dl, dl, 3,
                                          self.lm_cap)
                wr, perf, size, lm = min(cands, key=lambda t: t[1])
                choices[name] = LayerChoice(lm, wr, dl, dl, region, perf, size)
                lat += perf
            stage_lat.append(lat)
        segments = graph.segments()
        sm = {i: SM(1, (regions[0],), tuple(0 for _ in s.branches))
              for i, s in enumerate(segments)}
        mapping = Mapping(graph, hw, segments, sm, choices,
                          est_latency_s=sum(stage_lat))
        rep = evaluate_mapping(mapping)
        # scale: steady-state throughput set by the slowest stage
        frac = max(stage_lat) / max(1e-12, sum(stage_lat))
        bottleneck = rep.latency_s * frac
        return PipelineResult(mapping, 1.0 / max(1e-12, bottleneck),
                              rep.latency_s, rep.energy_pj)


def _balanced_chunks(vals: list[int], k: int) -> list[tuple[int, int]]:
    """Split list into k contiguous chunks minimizing the max chunk sum (DP)."""
    n = len(vals)
    pre = [0]
    for v in vals:
        pre.append(pre[-1] + v)

    best = {(0, 0): 0.0}
    back: dict[tuple[int, int], int] = {}
    for j in range(1, k + 1):
        for i in range(1, n + 1):
            b, arg = INF, -1
            for p in range(j - 1, i):
                if (p, j - 1) not in best:
                    continue
                v = max(best[(p, j - 1)], pre[i] - pre[p])
                if v < b:
                    b, arg = v, p
            if arg >= 0:
                best[(i, j)] = b
                back[(i, j)] = arg
    bounds = []
    i, j = n, k
    while j > 0:
        p = back[(i, j)]
        bounds.append((p, i))
        i, j = p, j - 1
    return list(reversed(bounds))


def _column_regions(hw: HwConfig, loads: list[float]) -> list[Region]:
    """Split the array into column strips proportional to stage loads."""
    total = sum(loads) or 1.0
    cols = []
    acc = 0.0
    prev = 0
    for i, l in enumerate(loads):
        acc += l
        c = round(acc / total * hw.na_col)
        c = max(prev + 1, min(c, hw.na_col - (len(loads) - 1 - i)))
        cols.append((prev, c))
        prev = c
    return [Region(0, a, hw.na_row, b - a) for a, b in cols]
