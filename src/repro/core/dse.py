"""The overall NicePIM design-space-exploration loop (Fig. 7).

Per iteration: the strategy (PIM-Tuner or a Fig. 9 comparison strategy)
proposes candidate hardware configurations; candidates are area-checked
one-by-one with the "simulator" (our analytic area model, standing in for
Timeloop+Accelergy) until a legal one is found; the PIM-Mapper +
Data-Scheduler produce mapping schemes for every workload DNN and the
resulting latency/energy feed the cost function

    Cost = sum_DNN Energy^alpha * Latency^beta * gamma      (Eq. 1)

which is appended to the strategy's dataset before its models are refit.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from .hardware import DEFAULT_CONSTRAINTS, HwConfig, PimConstraints
from .ir import DnnGraph
from .mapper import PimMapper, evaluate_mapping


@dataclass
class Observation:
    iteration: int
    cfg: HwConfig
    area_mm2: float
    legal: bool
    cost: float | None = None
    latency_s: dict = field(default_factory=dict)
    energy_pj: dict = field(default_factory=dict)


@dataclass
class DseResult:
    observations: list[Observation]

    def best_cost_curve(self) -> list[float]:
        best = math.inf
        out = []
        cur_iter = -1
        for o in self.observations:
            if o.cost is not None:
                best = min(best, o.cost)
            if o.iteration != cur_iter:
                cur_iter = o.iteration
                out.append(best)
            else:
                out[-1] = best
        return out

    def quality_curve(self) -> list[float]:
        """Paper Fig. 9 metric: mean reciprocal cost of the best 3 so far."""
        costs: list[float] = []
        out = []
        cur_iter = -1
        for o in self.observations:
            if o.cost is not None:
                costs.append(o.cost)
            if o.iteration != cur_iter:
                cur_iter = o.iteration
                out.append(self._top3(costs))
            else:
                out[-1] = self._top3(costs)
        return out

    @staticmethod
    def _top3(costs: list[float]) -> float:
        if not costs:
            return 0.0
        top = sorted(costs)[:3]
        return sum(1.0 / c for c in top) / len(top)

    def best(self) -> Observation:
        cands = [o for o in self.observations if o.cost is not None]
        return min(cands, key=lambda o: o.cost)


class WorkloadEvaluator:
    """Maps + schedules every workload on a config; caches by config tuple."""

    def __init__(self, workloads: list[DnnGraph], *, alpha: float = 1.0,
                 beta: float = 1.0, gamma: float = 1.0,
                 mapper_kwargs: dict | None = None):
        self.workloads = workloads
        self.alpha = alpha
        self.beta = beta
        self.gamma = gamma
        self.mapper_kwargs = mapper_kwargs or {}
        self._cache: dict[tuple, tuple[float, dict, dict]] = {}

    def __call__(self, cfg: HwConfig) -> tuple[float, dict, dict]:
        key = cfg.as_tuple()
        if key in self._cache:
            return self._cache[key]
        mapper = PimMapper(cfg, **self.mapper_kwargs)
        lats: dict[str, float] = {}
        ens: dict[str, float] = {}
        cost = 0.0
        for g in self.workloads:
            try:
                rep = evaluate_mapping(mapper.map(g))
            except RuntimeError:   # capacity-infeasible mapping
                cost = math.inf
                break
            lats[g.name] = rep.latency_s
            ens[g.name] = rep.energy_pj
            energy_j = rep.energy_pj * 1e-12
            cost += (energy_j ** self.alpha) * (rep.latency_s ** self.beta) \
                * self.gamma
        out = (cost, lats, ens)
        self._cache[key] = out
        return out


def run_dse(strategy, evaluator: WorkloadEvaluator, *, iterations: int = 20,
            propose_k: int = 8,
            cons: PimConstraints = DEFAULT_CONSTRAINTS,
            verbose: bool = False) -> DseResult:
    obs: list[Observation] = []
    for it in range(iterations):
        t0 = time.time()
        props = strategy.propose(propose_k)
        chosen = None
        # area-check one-by-one until a legal architecture appears (Fig. 7-4)
        for cfg in props:
            area = cfg.area_mm2()
            legal = area <= cons.area_budget_mm2
            if legal:
                chosen = (cfg, area)
                break
            strategy.observe(cfg, area, None)
            obs.append(Observation(it, cfg, area, False))
        if chosen is None:
            continue
        cfg, area = chosen
        cost, lats, ens = evaluator(cfg)
        if math.isinf(cost):
            strategy.observe(cfg, area, None)
            obs.append(Observation(it, cfg, area, True))
        else:
            strategy.observe(cfg, area, cost)
            obs.append(Observation(it, cfg, area, True, cost, lats, ens))
        strategy.fit()
        if verbose:
            print(f"[dse:{getattr(strategy, 'name', 'nicepim')}] it={it} "
                  f"cfg={cfg.as_tuple()} area={area:.1f} "
                  f"cost={cost if not math.isinf(cost) else 'inf'} "
                  f"({time.time() - t0:.1f}s)")
    return DseResult(obs)
