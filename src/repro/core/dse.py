"""The overall NicePIM design-space-exploration loop (Fig. 7).

Per iteration: the strategy (PIM-Tuner or a Fig. 9 comparison strategy)
proposes candidate hardware configurations; candidates are area-checked
one-by-one with the "simulator" (our analytic area model, standing in for
Timeloop+Accelergy) until a legal one is found; the PIM-Mapper +
Data-Scheduler produce mapping schemes for every workload DNN and the
resulting latency/energy feed the cost function

    Cost = sum_DNN Energy^alpha * Latency^beta * gamma      (Eq. 1)

which is appended to the strategy's dataset before its models are refit.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from .hardware import DEFAULT_CONSTRAINTS, HwConfig, PimConstraints
from .ir import DnnGraph
from .mapper import PimMapper, clear_mapper_caches, evaluate_mapping
from ..obs import metrics, trace


@dataclass
class Observation:
    iteration: int
    cfg: HwConfig
    area_mm2: float
    legal: bool
    cost: float | None = None
    latency_s: dict = field(default_factory=dict)
    energy_pj: dict = field(default_factory=dict)


@dataclass
class DseResult:
    observations: list[Observation]

    def best_cost_curve(self) -> list[float]:
        best = math.inf
        out = []
        cur_iter = -1
        for o in self.observations:
            if o.cost is not None:
                best = min(best, o.cost)
            if o.iteration != cur_iter:
                cur_iter = o.iteration
                out.append(best)
            else:
                out[-1] = best
        return out

    def quality_curve(self) -> list[float]:
        """Paper Fig. 9 metric: mean reciprocal cost of the best 3 so far."""
        costs: list[float] = []
        out = []
        cur_iter = -1
        for o in self.observations:
            if o.cost is not None:
                costs.append(o.cost)
            if o.iteration != cur_iter:
                cur_iter = o.iteration
                out.append(self._top3(costs))
            else:
                out[-1] = self._top3(costs)
        return out

    @staticmethod
    def _top3(costs: list[float]) -> float:
        if not costs:
            return 0.0
        top = sorted(costs)[:3]
        return sum(1.0 / c for c in top) / len(top)

    def best(self) -> Observation:
        cands = [o for o in self.observations if o.cost is not None]
        return min(cands, key=lambda o: o.cost)


class WorkloadEvaluator:
    """Maps + schedules every workload on a config; caches by config tuple.

    An optional :class:`repro.engine.cache.EvalCache` adds content-addressed
    memoization shared across strategies / processes / checkpoint resumes on
    top of the per-instance tuple cache.

    ``mapper_backend`` selects the PIM-Mapper costing path (``"batched"`` —
    the vectorized engine — or ``"scalar"``); it folds into
    ``mapper_kwargs`` so it also keys the content-addressed cache.
    ``scheduler_backend`` selects the Data-Scheduler's joint-LS path
    (``"scan"`` — the jitted engine search, batched per mapping — or
    ``"loop"``, the host-Python reference); it keys both caches too, since
    the two searches draw different RNG streams.
    ``clear_caches_between_configs=True`` drops the mapper-level memos
    (candidate tables, node costs, Data-Scheduler solves — mostly hw-keyed,
    plus the hw-independent shape memos) after each newly evaluated
    configuration, keeping long multi-config campaigns at a flat memory
    footprint; :meth:`evaluate_batch` clears once per batch instead so the
    shape memos amortize across the whole batch.
    ``batch_prefill=True`` makes :meth:`evaluate_batch` solve the WHOLE
    batch's uncached sharing schedules in one cross-config
    ``prefill_schedules_many`` pass before the per-mapping accounting walk
    (one ``schedule_many`` dispatch per NoC-scalar group instead of one
    per mapping); results are bit-identical either way, so the flag keys
    neither cache.  ``run_dse(..., pipeline=True)`` turns it on for the
    duration of the run.
    ``overlap=True`` (the default) runs :meth:`evaluate_batch` through the
    :class:`repro.engine.overlap.OverlapExecutor`: each workload wave's
    scheduling prefill and accounting walk are deferred into the window
    where the NEXT workload's candidate costs are in flight on device.
    Deferred waves retire strictly FIFO, so cost accumulation order — and
    every float result — matches the serial schedule exactly; the flag
    keys neither cache.  ``overlap=False`` restores sync-at-dispatch
    serial execution (the benchmark baseline).
    """

    def __init__(self, workloads: list[DnnGraph], *, alpha: float = 1.0,
                 beta: float = 1.0, gamma: float = 1.0,
                 mapper_kwargs: dict | None = None, cache=None,
                 mapper_backend: str | None = None,
                 scheduler_backend: str = "scan",
                 clear_caches_between_configs: bool = False,
                 batch_prefill: bool = False, overlap: bool = True):
        self.workloads = workloads
        self.alpha = alpha
        self.beta = beta
        self.gamma = gamma
        self.mapper_kwargs = dict(mapper_kwargs or {})
        if mapper_backend is not None:
            self.mapper_kwargs["backend"] = mapper_backend
        self.scheduler_backend = scheduler_backend
        self.clear_caches_between_configs = clear_caches_between_configs
        self.batch_prefill = batch_prefill
        self.overlap = overlap
        self._cache: dict[tuple, tuple[float, dict, dict]] = {}
        self.cache = cache
        self._wl_digest: str | None = None
        self.evaluations = 0   # mapper runs actually performed

    def _content_key(self, cfg: HwConfig) -> str:
        # hw_digest covers EVERY PimConstraints field alongside the variable
        # tuple (audited: the cons feed the cost model, capacity, and NoC
        # energies), so a config evaluated under different substrate
        # constants can never alias a cached result
        from ..engine.cache import _sha, hw_digest, workloads_digest
        if self._wl_digest is None:
            # the result depends on the cost-function exponents and every
            # mapper knob, not just (hw, workloads) — key them all
            self._wl_digest = _sha({
                "workloads": workloads_digest(self.workloads),
                "alpha": self.alpha, "beta": self.beta, "gamma": self.gamma,
                "mapper_kwargs": repr(sorted(self.mapper_kwargs.items())),
                "scheduler_backend": self.scheduler_backend,
            })
        return hw_digest(cfg) + ":" + self._wl_digest

    def __call__(self, cfg: HwConfig) -> tuple[float, dict, dict]:
        with trace.span("evaluate", configs=1) as sp:
            return self._eval_one(cfg, sp)

    def _eval_one(self, cfg: HwConfig, sp: dict) -> tuple[float, dict, dict]:
        # the constraints are part of the point's identity: two configs with
        # the same variable tuple but different substrate constants (e.g. a
        # different cap_bank_bytes) must never alias one cache entry
        key = (cfg.as_tuple(), cfg.cons)
        if key in self._cache:
            sp["cache"] = "local_hit"
            return self._cache[key]
        ckey = None
        if self.cache is not None:
            ckey = self._content_key(cfg)
            # single-flight: if another evaluator (eval worker, duplicated
            # tenant) is computing this key, block for its commit instead
            # of re-running the mapper
            hit, _ = self.cache.lease(ckey)
            if hit is not None:
                sp["cache"] = "content_hit"
                out = (hit[0], dict(hit[1]), dict(hit[2]))
                self._cache[key] = out
                return out
        sp["cache"] = "miss"
        self.evaluations += 1
        mapper = PimMapper(cfg, **self.mapper_kwargs)
        lats: dict[str, float] = {}
        ens: dict[str, float] = {}
        cost = 0.0
        try:
            for g in self.workloads:
                try:
                    rep = evaluate_mapping(
                        mapper.map(g),
                        scheduler_backend=self.scheduler_backend)
                except RuntimeError:   # capacity-infeasible mapping
                    # earlier workloads' numbers must not leak into the
                    # caches alongside the inf cost: an infeasible config
                    # has no meaningful per-workload latency/energy entries
                    cost, lats, ens = math.inf, {}, {}
                    break
                lats[g.name] = rep.latency_s
                ens[g.name] = rep.energy_pj
                energy_j = rep.energy_pj * 1e-12
                cost += (energy_j ** self.alpha) \
                    * (rep.latency_s ** self.beta) * self.gamma
            out = (cost, lats, ens)
            self._cache[key] = out
            if ckey is not None:
                self.cache.put(ckey, out)
        finally:
            if ckey is not None:
                self.cache.complete(ckey)
            if self.clear_caches_between_configs:
                # the memo entries are keyed by this cfg: nothing carries
                # over to the next configuration, so drop them
                clear_mapper_caches()
        return out

    def evaluate_batch(self, cfgs: list[HwConfig]
                       ) -> list[tuple[float, dict, dict]]:
        """Evaluate several configs, batch-mapping each workload across them.

        Every workload is mapped under all still-feasible configs in one
        :meth:`PimMapper.map_many` pass — the engine's ``[N configs]`` batch
        axis — instead of one candidate-costing sweep per config.  Results
        are identical to per-config ``__call__`` (pinned by the parity tests)
        and feed the same two caches; duplicate configs in the batch are
        evaluated once.  With ``clear_caches_between_configs`` the mapper
        memos are dropped once after the whole batch (clearing inside it
        would defeat the cross-config batching).
        """
        with trace.span("evaluate", configs=len(cfgs)) as sp:
            return self._eval_batch(cfgs, sp)

    def _eval_batch(self, cfgs: list[HwConfig], sp: dict
                    ) -> list[tuple[float, dict, dict]]:
        out: list = [None] * len(cfgs)
        todo: dict[tuple, list[int]] = {}    # cfg tuple -> batch positions
        cfg_of: dict[tuple, HwConfig] = {}
        for i, cfg in enumerate(cfgs):
            key = (cfg.as_tuple(), cfg.cons)
            if key in self._cache:
                out[i] = self._cache[key]
                continue
            if key not in todo and self.cache is not None:
                hit = self.cache.get(self._content_key(cfg))
                if hit is not None:
                    res = (hit[0], dict(hit[1]), dict(hit[2]))
                    self._cache[key] = res
                    out[i] = res
                    continue
            todo.setdefault(key, []).append(i)
            cfg_of.setdefault(key, cfg)
        # single-flight pass: lease every remaining key in sorted content-key
        # order (every concurrent evaluator acquires ascending, so waits can
        # never cycle into a deadlock).  A lease that resolves to a hit means
        # another evaluator just computed it — take the result; the keys we
        # end up owning are mapped below and completed in the finally.
        leased: list[str] = []
        ckey_of: dict[tuple, str] = {}
        if self.cache is not None and todo:
            for k in sorted(todo, key=lambda k: self._content_key(cfg_of[k])):
                ckey = self._content_key(cfg_of[k])
                hit, owner = self.cache.lease(ckey)
                if hit is not None:
                    res = (hit[0], dict(hit[1]), dict(hit[2]))
                    self._cache[k] = res
                    for i in todo[k]:
                        out[i] = res
                    del todo[k]
                    continue
                leased.append(ckey)
                ckey_of[k] = ckey
        sp["evaluated"] = len(todo)
        sp["cached"] = len(cfgs) - sum(len(v) for v in todo.values())
        if not todo:
            return out
        self.evaluations += len(todo)
        mapper = PimMapper(next(iter(cfg_of.values())), **self.mapper_kwargs)
        costs = {k: 0.0 for k in todo}
        lats: dict[tuple, dict] = {k: {} for k in todo}
        ens: dict[tuple, dict] = {k: {} for k in todo}
        live = list(todo)
        from contextlib import nullcontext
        from ..engine.overlap import OverlapExecutor, serial_dispatch
        executor = OverlapExecutor(enabled=self.overlap)
        ctx = nullcontext() if self.overlap else serial_dispatch()
        try:
            with ctx:
                for g in self.workloads:
                    if not live:
                        break
                    # drive this workload's dispatch/resolve phases; at each
                    # in-flight window the executor steps the PREVIOUS
                    # workload's deferred scheduling/accounting — the span
                    # nesting in the trace shows the overlap
                    with trace.span("map_wave", cat="engine", graph=g.name,
                                    configs=len(live)):
                        mappings = executor.drive(mapper.map_many_phases(
                            g, [cfg_of[k] for k in live],
                            on_infeasible="none"))
                    wave = live
                    live = [k for k, m in zip(wave, mappings)
                            if m is not None]
                    executor.defer(self._finish_wave(
                        g, wave, mappings, costs, lats, ens))
                executor.drain()  # observation boundary: everything lands
            for k, positions in todo.items():
                res = (costs[k], lats[k], ens[k])
                self._cache[k] = res
                if self.cache is not None:
                    self.cache.put(ckey_of.get(k) or self._content_key(
                        cfg_of[k]), res)
                for i in positions:
                    out[i] = res
        finally:
            if self.cache is not None:
                for ckey in leased:
                    self.cache.complete(ckey)
            if self.clear_caches_between_configs:
                clear_mapper_caches()
        return out

    def _finish_wave(self, g, wave, mappings, costs, lats, ens):
        """Deferred half of one workload wave: prefill + accounting.

        A generator so the :class:`~repro.engine.overlap.OverlapExecutor`
        can advance it stepwise inside the next wave's in-flight windows.
        The statements are the exact serial tail of the historical
        ``evaluate_batch`` workload loop, in the same order — only the
        scheduling boundary moved, not the arithmetic.
        """
        if self.batch_prefill and self.scheduler_backend == "scan":
            # one cross-config scheduler batch for the whole proposal
            # round, instead of one per surviving mapping
            from .mapper import prefill_schedules_many
            prefill_schedules_many([m for m in mappings if m is not None],
                                   backend=self.scheduler_backend)
            yield
        for k, m in zip(wave, mappings):
            if m is None:          # capacity-infeasible: same containment
                costs[k] = math.inf     # as __call__ — nothing leaks
                lats[k], ens[k] = {}, {}
                continue
            rep = evaluate_mapping(
                m, scheduler_backend=self.scheduler_backend)
            lats[k][g.name] = rep.latency_s
            ens[k][g.name] = rep.energy_pj
            energy_j = rep.energy_pj * 1e-12
            costs[k] += (energy_j ** self.alpha) \
                * (rep.latency_s ** self.beta) * self.gamma
            yield


def run_dse(strategy, evaluator: WorkloadEvaluator, *, iterations: int = 20,
            propose_k: int = 8,
            cons: PimConstraints = DEFAULT_CONSTRAINTS,
            verbose: bool = False, pareto=None, start_iteration: int = 0,
            on_iteration=None, evaluate_all_legal: bool = False,
            tracer=None, pipeline: bool = False) -> DseResult:
    """One strategy's DSE loop (Fig. 7).

    The whole proposal batch is area-checked in one vectorized call
    (``engine.batch_cost.batch_area_mm2``) instead of one ``area_mm2()``
    per candidate.  ``pareto`` (anything with ``.offer``) receives a
    latency/energy/area :class:`ParetoPoint` per legal finite observation;
    ``on_iteration(it, new_obs)`` fires after every iteration (campaign
    checkpointing); ``start_iteration`` supports checkpoint resume.

    ``evaluate_all_legal=False`` (default) keeps the paper's Fig. 7-4 walk:
    candidates are taken in proposal order until the first legal one, which
    alone is mapped.  ``evaluate_all_legal=True`` maps EVERY legal proposal
    of the batch through ``evaluator.evaluate_batch`` (one multi-config
    candidate-costing pass) — each iteration then feeds ``propose_k``
    observations to ``strategy.observe`` and the Pareto front instead of at
    most one mapped point, widening the suggestion model's dataset per
    refit at far less than ``propose_k`` times the mapping cost.

    ``tracer`` (a :class:`repro.obs.Tracer`) is installed as the active
    tracer for the run; when one is already active (a campaign installed
    it) every iteration's ``propose``/``evaluate``/``fit`` phases emit
    spans regardless.  Per-iteration best-cost and legal-fraction metrics
    land in the process registry under ``dse.<strategy>``.

    ``pipeline=True`` runs the device-resident iteration pipeline: the
    strategy (a scan-backend :class:`PimTuner`) is wrapped in
    :class:`repro.engine.pipeline.DsePipeline` — fused on-device propose,
    one host sync per proposal, deferred fit — and the evaluator's
    ``batch_prefill`` flag is enabled for the duration so each proposal
    round's sharing schedules solve in one cross-config batch.  The
    candidate waves are double-buffered: iteration ``k+1``'s fused propose
    chain is dispatched right after iteration ``k``'s fit (via
    ``DsePipeline.propose_dispatch``) and resolved — one small device_get
    — at the top of iteration ``k+1``, so the propose compute hides under
    the ingest tail (metrics, checkpoint I/O).  The dispatch point sees
    the exact strategy/RNG state the serial propose would, so streams stay
    identical to the staged path under a shared seed (pinned by
    ``tests/test_pipeline.py`` and ``benchmarks/pipeline_throughput.py``).
    """
    from contextlib import nullcontext
    from ..engine.batch_cost import batch_area_mm2
    prefill_restore = None
    if pipeline:
        from ..engine.pipeline import DsePipeline
        if not isinstance(strategy, DsePipeline):
            strategy = DsePipeline(strategy)
        if hasattr(evaluator, "batch_prefill"):
            prefill_restore = evaluator.batch_prefill
            evaluator.batch_prefill = True
    sname = getattr(strategy, "name", type(strategy).__name__.lower())
    best_gauge = metrics.METRICS.gauge(f"dse.{sname}.best_cost")
    legal_hist = metrics.METRICS.histogram(f"dse.{sname}.legal_fraction")
    obs: list[Observation] = []
    ctx = trace.activate(tracer) if tracer is not None else nullcontext()
    # double-buffered proposes: iteration k+1's fused chain is dispatched
    # at iteration k's ingest tail and resolved here at the loop top; an
    # overlap=False evaluator opts the whole campaign out (serial baseline)
    can_dispatch = (pipeline and hasattr(strategy, "propose_dispatch")
                    and getattr(evaluator, "overlap", True))
    nxt: dict = {"handle": None}
    try:
        with ctx:
            for it in range(start_iteration, iterations):
                handle, nxt["handle"] = nxt["handle"], None
                props = handle.resolve() if handle is not None else None
                propose_next = None
                if can_dispatch and it + 1 < iterations:
                    def propose_next():
                        nxt["handle"] = strategy.propose_dispatch(propose_k)
                obs.extend(_dse_iteration(
                    strategy, evaluator, it, propose_k, cons, verbose,
                    pareto, on_iteration, evaluate_all_legal, sname,
                    best_gauge, legal_hist, batch_area_mm2,
                    props=props, propose_next=propose_next))
    finally:
        if prefill_restore is not None:
            evaluator.batch_prefill = prefill_restore
    return DseResult(obs)


def propose_screen(strategy, it: int, propose_k: int,
                   cons: PimConstraints, sname: str,
                   evaluate_all_legal: bool, batch_area_mm2,
                   props: list | None = None
                   ) -> tuple[list, list[Observation],
                              list[tuple[HwConfig, float]], int]:
    """Iteration phase A: propose a batch and area-screen it.

    Proposals are drawn from the strategy, the whole batch is area-checked
    in one vectorized call, and every area-illegal candidate that the walk
    visits is fed back to the strategy immediately (it trains the filter
    model).  Returns ``(props, it_obs, to_eval, legal_n)`` where
    ``to_eval`` is the ``(cfg, area)`` list still needing mapper
    evaluation: all legal proposals under ``evaluate_all_legal``, at most
    the FIRST legal one otherwise (the paper's Fig. 7-4 walk — later
    illegal candidates are then not observed either).

    Shared by :func:`_dse_iteration` and the sharded campaign runner
    (``repro.engine.sharded``), which evaluates ``to_eval`` out-of-line so
    wave N+1's propose can overlap wave N's mapping.  ``props`` supplies a
    pre-resolved proposal batch (the double-buffered pipeline path) and
    skips the propose call.
    """
    it_obs: list[Observation] = []
    if props is None:
        with trace.span("propose", strategy=sname, k=propose_k):
            props = strategy.propose(propose_k)
    areas = batch_area_mm2(props)
    legal_n = sum(1 for a in areas if float(a) <= cons.area_budget_mm2)
    to_eval: list[tuple[HwConfig, float]] = []
    for cfg, area in zip(props, areas):
        area = float(area)
        if area <= cons.area_budget_mm2:
            to_eval.append((cfg, area))
            if not evaluate_all_legal:
                break
        else:
            strategy.observe(cfg, area, None)
            it_obs.append(Observation(it, cfg, area, False))
    return props, it_obs, to_eval, legal_n


def ingest_results(strategy, it: int, it_obs: list[Observation],
                   evaluated: list[tuple[HwConfig, float, tuple]],
                   pareto, sname: str, best_gauge, legal_hist,
                   legal_n: int, n_props: int, on_iteration, verbose: bool,
                   t0: float, propose_next=None) -> list[Observation]:
    """Iteration phase B: observe mapper results, refit, record metrics.

    ``evaluated`` carries ``(cfg, area, (cost, lats, ens))`` per mapped
    config; ``it_obs`` arrives holding phase A's illegal observations and
    leaves holding the full iteration's.  The fit only runs when something
    was mapped — identical to the historical inline loop.  ``propose_next``
    (pipeline double-buffering) fires right after the fit — the earliest
    point with final strategy state — so the next wave's propose chain is
    in flight while the metrics/checkpoint tail below runs on host.
    """
    for cfg, area, (cost, lats, ens) in evaluated:
        if math.isinf(cost):
            strategy.observe(cfg, area, None)
            it_obs.append(Observation(it, cfg, area, True))
        else:
            strategy.observe(cfg, area, cost)
            it_obs.append(Observation(it, cfg, area, True, cost, lats,
                                      ens))
            if pareto is not None:
                from ..engine.pareto import ParetoPoint
                pareto.offer(ParetoPoint(sum(lats.values()),
                                         sum(ens.values()), area,
                                         payload=list(cfg.as_tuple())))
    if evaluated:
        with trace.span("fit", strategy=sname):
            fit_info = strategy.fit()
    else:
        fit_info = None
    if propose_next is not None:
        propose_next()
    # per-iteration search-progress metrics (read back by campaigns
    # and the fig9/report observability sections)
    metrics.METRICS.counter(f"dse.{sname}.iterations").inc()
    metrics.METRICS.counter(f"dse.{sname}.observations").inc(len(it_obs))
    legal_hist.observe(legal_n / max(1, n_props))
    for o in it_obs:
        if o.cost is not None and not math.isinf(o.cost):
            best_gauge.min(o.cost)
    if on_iteration is not None:
        on_iteration(it, it_obs)
    if verbose and evaluated:
        cfg, area, (cost, _, _) = evaluated[0]
        # PimTuner.fit reports its model losses; other strategies None
        fit_str = "" if not isinstance(fit_info, dict) else " " + " ".join(
            f"{k}_loss={v:.3g}" for k, v in fit_info.items())
        print(f"[dse:{getattr(strategy, 'name', 'nicepim')}] it={it} "
              f"mapped={len(evaluated)} cfg={cfg.as_tuple()} "
              f"area={area:.1f} "
              f"cost={cost if not math.isinf(cost) else 'inf'} "
              f"({time.time() - t0:.1f}s){fit_str}")
    return it_obs


def _dse_iteration(strategy, evaluator, it, propose_k, cons, verbose,
                   pareto, on_iteration, evaluate_all_legal, sname,
                   best_gauge, legal_hist, batch_area_mm2,
                   props=None, propose_next=None) -> list[Observation]:
    with trace.span("iteration", strategy=sname, it=it):
        t0 = time.time()
        props, it_obs, to_eval, legal_n = propose_screen(
            strategy, it, propose_k, cons, sname, evaluate_all_legal,
            batch_area_mm2, props=props)
        evaluated: list[tuple[HwConfig, float, tuple]] = []
        if evaluate_all_legal:
            if to_eval:
                # every legal proposal is mapped, batched across configs
                results = evaluator.evaluate_batch(
                    [cfg for cfg, _ in to_eval])
                evaluated = [(cfg, area, res) for (cfg, area), res
                             in zip(to_eval, results)]
        elif to_eval:
            cfg, area = to_eval[0]
            evaluated = [(cfg, area, evaluator(cfg))]
        ingest_results(strategy, it, it_obs, evaluated, pareto, sname,
                       best_gauge, legal_hist, legal_n, len(props),
                       on_iteration, verbose, t0,
                       propose_next=propose_next)
    return it_obs
