"""The overall NicePIM design-space-exploration loop (Fig. 7).

Per iteration: the strategy (PIM-Tuner or a Fig. 9 comparison strategy)
proposes candidate hardware configurations; candidates are area-checked
one-by-one with the "simulator" (our analytic area model, standing in for
Timeloop+Accelergy) until a legal one is found; the PIM-Mapper +
Data-Scheduler produce mapping schemes for every workload DNN and the
resulting latency/energy feed the cost function

    Cost = sum_DNN Energy^alpha * Latency^beta * gamma      (Eq. 1)

which is appended to the strategy's dataset before its models are refit.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from .hardware import DEFAULT_CONSTRAINTS, HwConfig, PimConstraints
from .ir import DnnGraph
from .mapper import PimMapper, clear_mapper_caches, evaluate_mapping


@dataclass
class Observation:
    iteration: int
    cfg: HwConfig
    area_mm2: float
    legal: bool
    cost: float | None = None
    latency_s: dict = field(default_factory=dict)
    energy_pj: dict = field(default_factory=dict)


@dataclass
class DseResult:
    observations: list[Observation]

    def best_cost_curve(self) -> list[float]:
        best = math.inf
        out = []
        cur_iter = -1
        for o in self.observations:
            if o.cost is not None:
                best = min(best, o.cost)
            if o.iteration != cur_iter:
                cur_iter = o.iteration
                out.append(best)
            else:
                out[-1] = best
        return out

    def quality_curve(self) -> list[float]:
        """Paper Fig. 9 metric: mean reciprocal cost of the best 3 so far."""
        costs: list[float] = []
        out = []
        cur_iter = -1
        for o in self.observations:
            if o.cost is not None:
                costs.append(o.cost)
            if o.iteration != cur_iter:
                cur_iter = o.iteration
                out.append(self._top3(costs))
            else:
                out[-1] = self._top3(costs)
        return out

    @staticmethod
    def _top3(costs: list[float]) -> float:
        if not costs:
            return 0.0
        top = sorted(costs)[:3]
        return sum(1.0 / c for c in top) / len(top)

    def best(self) -> Observation:
        cands = [o for o in self.observations if o.cost is not None]
        return min(cands, key=lambda o: o.cost)


class WorkloadEvaluator:
    """Maps + schedules every workload on a config; caches by config tuple.

    An optional :class:`repro.engine.cache.EvalCache` adds content-addressed
    memoization shared across strategies / processes / checkpoint resumes on
    top of the per-instance tuple cache.

    ``mapper_backend`` selects the PIM-Mapper costing path (``"batched"`` —
    the vectorized engine — or ``"scalar"``); it folds into
    ``mapper_kwargs`` so it also keys the content-addressed cache.
    ``clear_caches_between_configs=True`` drops the mapper-level memos
    (candidate tables, node costs, Data-Scheduler solves — all keyed by
    HwConfig) after each newly evaluated configuration, keeping long
    multi-config campaigns at a flat memory footprint.
    """

    def __init__(self, workloads: list[DnnGraph], *, alpha: float = 1.0,
                 beta: float = 1.0, gamma: float = 1.0,
                 mapper_kwargs: dict | None = None, cache=None,
                 mapper_backend: str | None = None,
                 clear_caches_between_configs: bool = False):
        self.workloads = workloads
        self.alpha = alpha
        self.beta = beta
        self.gamma = gamma
        self.mapper_kwargs = dict(mapper_kwargs or {})
        if mapper_backend is not None:
            self.mapper_kwargs["backend"] = mapper_backend
        self.clear_caches_between_configs = clear_caches_between_configs
        self._cache: dict[tuple, tuple[float, dict, dict]] = {}
        self.cache = cache
        self._wl_digest: str | None = None
        self.evaluations = 0   # mapper runs actually performed

    def _content_key(self, cfg: HwConfig) -> str:
        from ..engine.cache import _sha, hw_digest, workloads_digest
        if self._wl_digest is None:
            # the result depends on the cost-function exponents and every
            # mapper knob, not just (hw, workloads) — key them all
            self._wl_digest = _sha({
                "workloads": workloads_digest(self.workloads),
                "alpha": self.alpha, "beta": self.beta, "gamma": self.gamma,
                "mapper_kwargs": repr(sorted(self.mapper_kwargs.items())),
            })
        return hw_digest(cfg) + ":" + self._wl_digest

    def __call__(self, cfg: HwConfig) -> tuple[float, dict, dict]:
        key = cfg.as_tuple()
        if key in self._cache:
            return self._cache[key]
        ckey = None
        if self.cache is not None:
            ckey = self._content_key(cfg)
            hit = self.cache.get(ckey)
            if hit is not None:
                out = (hit[0], dict(hit[1]), dict(hit[2]))
                self._cache[key] = out
                return out
        self.evaluations += 1
        mapper = PimMapper(cfg, **self.mapper_kwargs)
        lats: dict[str, float] = {}
        ens: dict[str, float] = {}
        cost = 0.0
        try:
            for g in self.workloads:
                try:
                    rep = evaluate_mapping(mapper.map(g))
                except RuntimeError:   # capacity-infeasible mapping
                    cost = math.inf
                    break
                lats[g.name] = rep.latency_s
                ens[g.name] = rep.energy_pj
                energy_j = rep.energy_pj * 1e-12
                cost += (energy_j ** self.alpha) \
                    * (rep.latency_s ** self.beta) * self.gamma
        finally:
            if self.clear_caches_between_configs:
                # the memo entries are keyed by this cfg: nothing carries
                # over to the next configuration, so drop them
                clear_mapper_caches()
        out = (cost, lats, ens)
        self._cache[key] = out
        if ckey is not None:
            self.cache.put(ckey, out)
        return out


def run_dse(strategy, evaluator: WorkloadEvaluator, *, iterations: int = 20,
            propose_k: int = 8,
            cons: PimConstraints = DEFAULT_CONSTRAINTS,
            verbose: bool = False, pareto=None, start_iteration: int = 0,
            on_iteration=None) -> DseResult:
    """One strategy's DSE loop (Fig. 7).

    The whole proposal batch is area-checked in one vectorized call
    (``engine.batch_cost.batch_area_mm2``) instead of one ``area_mm2()``
    per candidate.  ``pareto`` (anything with ``.offer``) receives a
    latency/energy/area :class:`ParetoPoint` per legal finite observation;
    ``on_iteration(it, new_obs)`` fires after every iteration (campaign
    checkpointing); ``start_iteration`` supports checkpoint resume.
    """
    from ..engine.batch_cost import batch_area_mm2
    obs: list[Observation] = []
    for it in range(start_iteration, iterations):
        t0 = time.time()
        it_obs: list[Observation] = []
        props = strategy.propose(propose_k)
        chosen = None
        areas = batch_area_mm2(props)
        # walk the batch in proposal order until a legal architecture
        # appears (Fig. 7-4); illegal prefixes still train the filter model
        for cfg, area in zip(props, areas):
            area = float(area)
            legal = area <= cons.area_budget_mm2
            if legal:
                chosen = (cfg, area)
                break
            strategy.observe(cfg, area, None)
            it_obs.append(Observation(it, cfg, area, False))
        if chosen is None:
            obs.extend(it_obs)
            if on_iteration is not None:
                on_iteration(it, it_obs)
            continue
        cfg, area = chosen
        cost, lats, ens = evaluator(cfg)
        if math.isinf(cost):
            strategy.observe(cfg, area, None)
            it_obs.append(Observation(it, cfg, area, True))
        else:
            strategy.observe(cfg, area, cost)
            it_obs.append(Observation(it, cfg, area, True, cost, lats, ens))
            if pareto is not None:
                from ..engine.pareto import ParetoPoint
                pareto.offer(ParetoPoint(sum(lats.values()),
                                         sum(ens.values()), area,
                                         payload=list(cfg.as_tuple())))
        strategy.fit()
        obs.extend(it_obs)
        if on_iteration is not None:
            on_iteration(it, it_obs)
        if verbose:
            print(f"[dse:{getattr(strategy, 'name', 'nicepim')}] it={it} "
                  f"cfg={cfg.as_tuple()} area={area:.1f} "
                  f"cost={cost if not math.isinf(cost) else 'inf'} "
                  f"({time.time() - t0:.1f}s)")
    return DseResult(obs)
