"""Analytic per-PIM-node cost model (the Timeloop+Accelergy/Ramulator stand-in).

Given one (part-)layer resident on a single PIM-node, the model searches
double-buffered SRAM tilings under the ibuf/wbuf/obuf capacity constraints and
returns latency + energy with a full breakdown:

* **compute** — the PE array is ``PEA_row x PEA_col`` parallel MAC units
  (NVDLA-style: input channels map to rows, output channels to columns), so a
  tile costs ``ceil(Tc/PEA_row) * ceil(Tk/PEA_col) * HK * WK`` cycles per
  output point; ragged edges lose utilization through the ceils.
* **DRAM** — traffic follows one of two loop orders (weights-outer vs.
  outputs-outer, partial sums always obuf-resident with C innermost); the
  burst/row-activation counts come from the Sec. III-E data-layout model
  (vectorized here; ``layout.tile_access_cost`` is the scalar reference the
  property tests compare against).
* **SRAM/MAC energy** — linear-in-access Accelergy-style constants at 28 nm.

Latency per layer pass = max(compute, DRAM) assuming double buffering, which
is what makes the buffer-size / PE-size trade the PIM-Tuner explores real.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from .hardware import HwConfig
from .layout import DataLayout, tile_cost_vec
from .ir import Layer

# Accelergy-style energy constants (28 nm, 16-bit datapath).
MAC_ENERGY_PJ = 0.30          # one 16-bit MAC
SRAM_BASE_PJ_PER_BIT = 0.05   # small-macro access
SRAM_LOG_PJ_PER_BIT = 0.012   # + per log2(KiB) wordline/bitline growth


def _sram_pj_per_bit(size_kib: int) -> float:
    return SRAM_BASE_PJ_PER_BIT + SRAM_LOG_PJ_PER_BIT * math.log2(max(2, size_kib))


@dataclass(frozen=True)
class PartCost:
    """Cost of processing one part-layer once on one PIM-node."""

    latency_s: float
    energy_pj: float
    compute_s: float
    dram_s: float
    dram_bytes: float
    e_mac_pj: float
    e_sram_pj: float
    e_dram_pj: float
    tiling: tuple[int, int, int, int, int]  # (Tb, Tk, Tc, Tp, Tq)
    loop_order: str                         # "K_outer" | "BPQ_outer"

    @property
    def breakdown(self) -> dict[str, float]:
        return {"mac": self.e_mac_pj, "sram": self.e_sram_pj,
                "dram": self.e_dram_pj}


def _tile_candidates(dim: int, cap: int = 7) -> list[int]:
    """Power-of-two tile sizes up to ``dim`` plus the exact dim."""
    outs = []
    t = 1
    while t < dim:
        outs.append(t)
        t *= 2
    outs.append(dim)
    if len(outs) > cap:  # keep the largest ones — small tiles rarely win
        outs = outs[-cap:]
    return outs


@lru_cache(maxsize=65536)
def part_layer_cost(hw: HwConfig, layer: Layer,
                    dl_in: DataLayout, dl_out: DataLayout) -> PartCost:
    """Latency/energy for one part-layer resident on one PIM-node."""
    if not layer.is_heavy:
        return PartCost(0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                        (1, 1, 1, 1, 1), "K_outer")
    c = hw.cons
    B, C, H, W = layer.B, layer.C, layer.H, layer.W
    K, HK, WK, s = layer.K, layer.HK, layer.WK, layer.stride
    P, Q = layer.P, layer.Q
    dbytes = c.data_bits // 8
    pbytes = c.psum_bits // 8
    burst_words = max(1, hw.node_dram_width_bits // c.data_bits)
    row_words = max(burst_words,
                    c.dram_row_bytes * hw.banks_per_node // dbytes)

    # ---- candidate tilings (vectorized grid) -------------------------------
    tks = np.array(_tile_candidates(K), dtype=np.int64)
    tcs = np.array(_tile_candidates(C), dtype=np.int64)
    tps = np.array(_tile_candidates(P), dtype=np.int64)
    tqs = np.array([Q], dtype=np.int64) if Q <= 64 else \
        np.array(_tile_candidates(Q, cap=4), dtype=np.int64)
    tbs = np.array(_tile_candidates(B, cap=4), dtype=np.int64)
    TB, TK, TC, TP, TQ = [a.reshape(-1) for a in
                          np.meshgrid(tbs, tks, tcs, tps, tqs, indexing="ij")]

    TH = (TP - 1) * s + HK
    TW = (TQ - 1) * s + WK
    # double-buffered capacity constraints
    fits = ((TB * TC * TH * TW * dbytes * 2 <= hw.ibuf_kib * 1024)
            & (TK * TC * HK * WK * dbytes * 2 <= hw.wbuf_kib * 1024)
            & (TB * TK * TP * TQ * pbytes <= hw.obuf_kib * 1024))
    if not bool(fits.any()):
        # minimal tiles don't fit: heavily serialized fallback (discourages
        # this config without crashing the search)
        fits = np.zeros_like(fits)
        fits[int(np.argmin(TB * TC * TH * TW))] = True
    TB, TK, TC, TP, TQ = TB[fits], TK[fits], TC[fits], TP[fits], TQ[fits]
    TH, TW = TH[fits], TW[fits]

    n_k = np.ceil(K / TK)
    n_c = np.ceil(C / TC)
    n_bpq = np.ceil(B / TB) * np.ceil(P / TP) * np.ceil(Q / TQ)
    n_tiles_i = np.ceil(B / TB) * n_c * np.ceil(P / TP) * np.ceil(Q / TQ)
    n_tiles_o = np.ceil(B / TB) * n_k * np.ceil(P / TP) * np.ceil(Q / TQ)

    # ---- compute cycles ----------------------------------------------------
    # per output point: ceil(Tc/rows)*HK*WK cycles for a Tk-column group
    cyc_tile = (np.ceil(TC / hw.pea_row) * np.ceil(TK / hw.pea_col)
                * HK * WK * TP * TQ * TB)
    compute_cycles = cyc_tile * n_k * n_c * n_bpq

    # ---- DRAM traffic under the two loop orders ----------------------------
    ib, ir = tile_cost_vec((B, C, H, W), TB, TC, TH, TW, dl_in,
                           burst_words, row_words)
    ob, orow = tile_cost_vec((B, K, P, Q), TB, TK, TP, TQ, dl_out,
                             burst_words, row_words)
    w_vals = float(layer.weight_count)
    w_bursts = np.ceil(w_vals / burst_words)
    w_rows = np.maximum(1.0, w_vals / row_words)

    all_w_fit = (K * C * HK * WK * dbytes * 2 <= hw.wbuf_kib * 1024)
    all_i_fit = (B * C * H * W * dbytes * 2 <= hw.ibuf_kib * 1024)
    # K_outer: weights streamed once; inputs refetched per k-tile
    i_passes_ko = np.where(all_i_fit, 1.0, n_k)
    w_passes_ko = 1.0
    # BPQ_outer: inputs streamed once; weights refetched per bpq-tile
    i_passes_bo = 1.0
    w_passes_bo = np.where(all_w_fit, 1.0, n_bpq)

    def dram_terms(i_passes, w_passes):
        bursts = (ib * n_tiles_i * i_passes + w_bursts * w_passes
                  + ob * n_tiles_o)
        rows = (ir * n_tiles_i * i_passes + w_rows * w_passes
                + orow * n_tiles_o)
        values = (B * C * H * W * i_passes + w_vals * w_passes
                  + B * K * P * Q)
        return bursts, rows, values

    b_ko, r_ko, v_ko = dram_terms(i_passes_ko, w_passes_ko)
    b_bo, r_bo, v_bo = dram_terms(i_passes_bo, w_passes_bo)
    dram_cycles_ko = b_ko + r_ko * c.dram_row_miss_cycles
    dram_cycles_bo = b_bo + r_bo * c.dram_row_miss_cycles
    use_bo = dram_cycles_bo < dram_cycles_ko
    dram_cycles = np.where(use_bo, dram_cycles_bo, dram_cycles_ko)
    bursts = np.where(use_bo, b_bo, b_ko)
    rows = np.where(use_bo, r_bo, r_ko)
    values = np.where(use_bo, v_bo, v_ko)

    total_cycles = np.maximum(compute_cycles, dram_cycles)
    best = int(np.argmin(total_cycles))

    # ---- energies at the chosen tiling --------------------------------------
    macs = float(layer.macs)
    e_mac = macs * MAC_ENERGY_PJ
    tb_, tk_, tc_, tp_, tq_ = (int(TB[best]), int(TK[best]), int(TC[best]),
                               int(TP[best]), int(TQ[best]))
    # ibuf: each input value feeds PEA_col-wide broadcast once per k-tile pass
    ibuf_reads = macs / max(1, min(tk_, hw.pea_col))
    # wbuf: weights reused over the (Tb,Tp,Tq) tile from PE-local registers
    wbuf_reads = macs / max(1, tb_ * tp_ * tq_)
    # obuf: one psum read+write per (row-group) reduction step
    obuf_acc = 2.0 * macs / max(1, min(tc_, hw.pea_row))
    e_sram = (ibuf_reads * c.data_bits * _sram_pj_per_bit(hw.ibuf_kib)
              + wbuf_reads * c.data_bits * _sram_pj_per_bit(hw.wbuf_kib)
              + obuf_acc * c.psum_bits * _sram_pj_per_bit(hw.obuf_kib))
    moved_bits = float(bursts[best]) * hw.node_dram_width_bits
    useful_bits = float(values[best]) * c.data_bits
    e_dram = (max(moved_bits, useful_bits) * c.dram_energy_pj_per_bit
              + float(rows[best]) * c.dram_row_act_energy_pj)

    compute_s = float(compute_cycles[best]) / c.freq_hz
    dram_s = float(dram_cycles[best]) / c.freq_hz
    return PartCost(
        latency_s=float(total_cycles[best]) / c.freq_hz,
        energy_pj=e_mac + e_sram + e_dram,
        compute_s=compute_s,
        dram_s=dram_s,
        dram_bytes=float(values[best]) * dbytes,
        e_mac_pj=e_mac,
        e_sram_pj=e_sram,
        e_dram_pj=e_dram,
        tiling=(tb_, tk_, tc_, tp_, tq_),
        loop_order="BPQ_outer" if bool(use_bo[best]) else "K_outer",
    )
