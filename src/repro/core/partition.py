"""Layer partitioning (LM, Sec. III-C) and the induced data-sharing structure.

An ``LM`` carries five ``(Ph, Pw)`` bi-tuples — partition counts along the
region's height/width for loops ``B, P, Q, K, C`` — plus the spatial order
``P_order`` that decides which loop varies fastest across the node grid
(paper Fig. 5: outermost loop in ``P_order`` splits the region first).

Partitioning converts temporal reuse into *data-sharing* (Sec. VII):

* nodes that differ only in their (B, P, Q) indices need the **same weights**
  → weight sharing-sets of size ``PhB*PwB*PhP*PwP*PhQ*PwQ`` (``WR`` replicas
  shrink the ring to ``ceil(N/WR)`` nodes each);
* nodes that differ only in their K index need the **same inputs** → input
  sharing-sets of size ``PhK*PwK``;
* nodes that differ only in their C index hold **partial sums** that must be
  reduced → psum groups of size ``PhC*PwC``.

The mapper's fast path uses analytic ring estimates over the *exact* node
coordinates (so ``P_order`` genuinely changes hop distances); the chosen
mapping is later re-costed with the Data-Scheduler's optimized cycles.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from functools import lru_cache
from typing import Sequence

import numpy as np

from .hardware import HwConfig
from .ir import Layer

LOOPS = ("B", "P", "Q", "K", "C")

# A small diverse set of spatial orders: which loops sit innermost (adjacent
# nodes) matters for sharing-ring hop distance; 120 permutations collapse into
# few equivalence classes for our 5-loop grids.
DEFAULT_ORDERS = (
    ("B", "P", "Q", "K", "C"),
    ("K", "C", "B", "P", "Q"),
    ("B", "K", "P", "Q", "C"),
    ("P", "Q", "B", "C", "K"),
    ("C", "K", "Q", "P", "B"),
)


@dataclass(frozen=True, eq=True)
class LM:
    ph: tuple[int, int, int, int, int]
    pw: tuple[int, int, int, int, int]
    p_order: tuple[str, ...] = ("B", "P", "Q", "K", "C")

    def __hash__(self) -> int:
        # LMs key the sharing/candidate memos — cache the tuple hash
        h = self.__dict__.get("_hash")
        if h is None:
            h = hash((self.ph, self.pw, self.p_order))
            object.__setattr__(self, "_hash", h)
        return h

    def parts(self, loop: str) -> int:
        i = LOOPS.index(loop)
        return self.ph[i] * self.pw[i]

    @property
    def n_nodes(self) -> int:
        return math.prod(self.ph) * math.prod(self.pw)

    @property
    def shape(self) -> tuple[int, int]:
        return (math.prod(self.ph), math.prod(self.pw))

    # group sizes of the three sharing structures
    @property
    def weight_share(self) -> int:
        return self.parts("B") * self.parts("P") * self.parts("Q")

    @property
    def input_share(self) -> int:
        return self.parts("K")

    @property
    def psum_share(self) -> int:
        return self.parts("C")

    def short(self) -> str:
        ps = ",".join(f"{l}{h}x{w}" for l, h, w in zip(LOOPS, self.ph, self.pw)
                      if h * w > 1)
        return f"LM({ps or 'none'};{''.join(self.p_order)})"


@lru_cache(maxsize=4096)
def factor_splits(n: int, k: int) -> tuple[tuple[int, ...], ...]:
    """All ordered k-tuples of positive ints with product n."""
    if k == 1:
        return ((n,),)
    outs = []
    for d in range(1, n + 1):
        if n % d == 0:
            for rest in factor_splits(n // d, k - 1):
                outs.append((d,) + rest)
    return tuple(outs)


@lru_cache(maxsize=65536)
def part_layer(layer: Layer, lm: LM) -> Layer:
    """Ceil-divided part-layer processed by one node (halo materialized)."""
    Bp = math.ceil(layer.B / lm.parts("B"))
    Pp = math.ceil(layer.P / lm.parts("P"))
    Qp = math.ceil(layer.Q / lm.parts("Q"))
    Kp = math.ceil(layer.K / lm.parts("K"))
    Cp = math.ceil(layer.C / lm.parts("C"))
    Hp = (Pp - 1) * layer.stride + layer.HK
    Wp = (Qp - 1) * layer.stride + layer.WK
    return replace(layer, B=Bp, C=Cp, H=Hp, W=Wp, K=Kp, pad=0)


@lru_cache(maxsize=4096)   # a multi-config batch sweeps many (layer, shape)
def enumerate_lms(layer: Layer, h_shape: int, w_shape: int,
                  orders: tuple[tuple[str, ...], ...] = DEFAULT_ORDERS,
                  cap: int = 400) -> tuple[LM, ...]:
    """All legal LMs for mapping ``layer`` onto an ``h x w`` region."""
    lens = {"B": layer.B, "P": layer.P, "Q": layer.Q,
            "K": layer.K, "C": layer.C}
    outs: list[LM] = []
    seen: set[tuple] = set()
    for ph in factor_splits(h_shape, 5):
        for pw in factor_splits(w_shape, 5):
            ok = all(ph[i] * pw[i] <= lens[l] or ph[i] * pw[i] == 1
                     for i, l in enumerate(LOOPS))
            if not ok:
                continue
            for od in orders:
                lm = LM(ph, pw, od)
                key = (ph, pw, od)
                if key in seen:
                    continue
                seen.add(key)
                outs.append(lm)
    if len(outs) > cap:
        # favour balanced partitions: fewer ragged ceil-division leftovers
        def ragged(lm: LM) -> float:
            r = 0.0
            for i, l in enumerate(LOOPS):
                p = lm.ph[i] * lm.pw[i]
                r += (math.ceil(lens[l] / p) * p / max(1, lens[l])) - 1.0
            return r
        outs.sort(key=ragged)
        outs = outs[:cap]
    return tuple(outs)


# -- node placement ----------------------------------------------------------

@lru_cache(maxsize=4096)
def _strides(radices: tuple[int, ...]) -> tuple[int, ...]:
    """Mixed-radix strides, big-endian (first radix is outermost)."""
    out = [1] * len(radices)
    for i in range(len(radices) - 2, -1, -1):
        out[i] = out[i + 1] * radices[i + 1]
    return tuple(out)


def loop_strides(lm: LM) -> dict[str, tuple[int, int]]:
    """(h_stride, w_stride) of each loop's index in the region grid."""
    order = lm.p_order
    h_rad = tuple(lm.ph[LOOPS.index(l)] for l in order)
    w_rad = tuple(lm.pw[LOOPS.index(l)] for l in order)
    hs, ws = _strides(h_rad), _strides(w_rad)
    return {l: (hs[i], ws[i]) for i, l in enumerate(order)}


@lru_cache(maxsize=65536)
def group_coords(lm: LM, loops: tuple[str, ...]) -> tuple[tuple[int, int], ...]:
    """Region-relative coords of one sharing group: nodes spanning ``loops``
    (all other loop indices held at zero), in snake order for ring building."""
    strides = loop_strides(lm)
    coords = [(0, 0)]
    for l in loops:
        i = LOOPS.index(l)
        sh, sw = strides[l]
        new = []
        for a in range(lm.ph[i]):
            for b in range(lm.pw[i]):
                for (h, w) in coords:
                    new.append((h + a * sh, w + b * sw))
        coords = new
    # snake order: sort by (h, w with alternating direction) for a short ring
    coords.sort(key=lambda hw: (hw[0], hw[1] if hw[0] % 2 == 0 else -hw[1]))
    return tuple(coords)


def ring_avg_hops(coords: tuple[tuple[int, int], ...]) -> float:
    """Mean manhattan distance between ring-consecutive nodes."""
    n = len(coords)
    if n <= 1:
        return 0.0
    d = 0
    for i in range(n):
        a, b = coords[i], coords[(i + 1) % n]
        d += abs(a[0] - b[0]) + abs(a[1] - b[1])
    return d / n


# -- analytic communication estimates (mapper fast path) ---------------------

@dataclass(frozen=True)
class CommEstimate:
    latency_s: float
    energy_pj: float
    weight_bytes_per_node: float  # DRAM capacity the layer claims per node

    def __add__(self, o: "CommEstimate") -> "CommEstimate":
        return CommEstimate(self.latency_s + o.latency_s,
                            self.energy_pj + o.energy_pj,
                            self.weight_bytes_per_node + o.weight_bytes_per_node)


ZERO_COMM = CommEstimate(0.0, 0.0, 0.0)


def _ring_cost(n: int, total_bytes: float, avg_hops: float,
               hw: HwConfig) -> tuple[float, float]:
    """(latency, energy) for a Hamilton-ring share of ``total_bytes`` spread
    over ``n`` nodes: N-1 steps, each moving chunk=total/n per node."""
    if n <= 1 or total_bytes <= 0:
        return 0.0, 0.0
    chunk = total_bytes / n
    # per step every node sends one chunk over ~avg_hops links; the limiting
    # link carries ~avg_hops chunks (XY routes of a spread ring overlap)
    lat = (n - 1) * chunk * max(1.0, avg_hops) / hw.link_bw_bytes
    energy = (n - 1) * total_bytes * 8 * max(1.0, avg_hops) \
        * hw.cons.noc_energy_pj_per_bit_hop
    return lat, energy


def comm_estimate(layer: Layer, lm: LM, wr: int, hw: HwConfig) -> CommEstimate:
    """NoC latency/energy + per-node weight storage for one execution."""
    if not layer.is_heavy:
        return ZERO_COMM
    dbytes = hw.cons.data_bits // 8
    pl = part_layer(layer, lm)
    lat = 0.0
    energy = 0.0

    # ---- weight sharing (Sec. III-D) ----------------------------------------
    n_ws = lm.weight_share
    wr = max(1, min(wr, n_ws))
    group = math.ceil(n_ws / wr)          # nodes sharing one replica
    w_kc = pl.weight_count * dbytes       # weights of one (k,c) partition
    stored = w_kc / group
    if group > 1:
        share_loops = tuple(l for l in ("B", "P", "Q") if lm.parts(l) > 1)
        hops = ring_avg_hops(group_coords(lm, share_loops)[:group])
        l1, e1 = _ring_cost(group, w_kc, hops, hw)
        # every (k,c) partition runs its ring concurrently on disjoint nodes;
        # energy sums over all replica groups in the region
        n_groups = lm.parts("K") * lm.parts("C") * wr
        lat += l1
        energy += e1 * n_groups
    # ---- input sharing (partitioned on K) -----------------------------------
    n_is = lm.input_share
    if n_is > 1:
        i_bytes = pl.ifmap_count * dbytes
        hops = ring_avg_hops(group_coords(lm, ("K",)))
        l2, e2 = _ring_cost(n_is, i_bytes, hops, hw)
        n_groups = lm.weight_share * lm.parts("C")
        lat += l2
        energy += e2 * n_groups
    # ---- psum reduction (partitioned on C) ----------------------------------
    n_ps = lm.psum_share
    if n_ps > 1:
        p_bytes = pl.ofmap_count * (hw.cons.psum_bits // 8)
        hops = ring_avg_hops(group_coords(lm, ("C",)))
        # reduce-scatter + all-gather style: ~2x one ring pass
        l3, e3 = _ring_cost(n_ps, 2 * p_bytes, hops, hw)
        n_groups = lm.weight_share * lm.parts("K")
        lat += l3
        energy += e3 * n_groups
    return CommEstimate(lat, energy, stored)


@lru_cache(maxsize=4096)
def _ring_prefix_hops(lm: LM, loops: tuple[str, ...]) -> tuple[float, ...]:
    """``ring_avg_hops(group_coords(lm, loops)[:k])`` for every prefix k.

    O(n) total instead of O(n) per prefix: consecutive-hop partial sums plus
    the wrap-around edge, dividing the integer hop total exactly as
    :func:`ring_avg_hops` does (bitwise-identical means).
    """
    coords = group_coords(lm, loops)
    out = [0.0, 0.0]  # k = 0, 1: single/no node, no ring
    seg = 0
    for k in range(2, len(coords) + 1):
        a, b = coords[k - 2], coords[k - 1]
        seg += abs(a[0] - b[0]) + abs(a[1] - b[1])
        wrap = (abs(coords[k - 1][0] - coords[0][0])
                + abs(coords[k - 1][1] - coords[0][1]))
        out.append((seg + wrap) / k)
    return tuple(out)


def _ring_cost_vec(n, total_bytes, avg_hops, hw: HwConfig):
    """Vectorized :func:`_ring_cost` (same op order, so bitwise-identical)."""
    live = (n > 1) & (total_bytes > 0)
    n_safe = np.where(live, n, 2)
    chunk = total_bytes / n_safe
    hop = np.maximum(1.0, avg_hops)
    lat = (n_safe - 1) * chunk * hop / hw.link_bw_bytes
    energy = ((n_safe - 1) * total_bytes * 8 * hop
              * hw.cons.noc_energy_pj_per_bit_hop)
    zero = np.zeros_like(lat)
    return np.where(live, lat, zero), np.where(live, energy, zero)


@lru_cache(maxsize=65536)
def _comm_lm_row(layer: Layer, lm: LM, dbytes: int, psbytes: int) -> tuple:
    """Per-(layer, LM) sharing structure: group sizes, byte counts, hops."""
    pl = part_layer(layer, lm)
    share_loops = tuple(l for l in ("B", "P", "Q") if lm.parts(l) > 1)
    return (
        lm.weight_share, lm.input_share, lm.psum_share,
        lm.parts("K"), lm.parts("C"),
        pl.weight_count * dbytes, pl.ifmap_count * dbytes,
        pl.ofmap_count * psbytes,
        _ring_prefix_hops(lm, share_loops),
        ring_avg_hops(group_coords(lm, ("K",))) if lm.input_share > 1
        else 0.0,
        ring_avg_hops(group_coords(lm, ("C",))) if lm.psum_share > 1
        else 0.0,
    )


def comm_batch_geometry(layer: Layer, lms: Sequence[LM], wrs: Sequence[int],
                        dbytes: int, psbytes: int) -> tuple:
    """The hardware-independent arrays of :func:`comm_estimate_batch`.

    Sharing-group sizes, per-node byte counts, and ring hop distances depend
    only on (layer, lms, wrs) and the data widths — never on the rest of the
    :class:`HwConfig` — so multi-config mapper sweeps cache one geometry per
    candidate base and re-apply the per-hw scalars via
    :func:`comm_eval_geometry`.
    """
    uniq: dict[LM, int] = {}
    rows: list[tuple] = []
    for lm in lms:
        if lm in uniq:
            continue
        uniq[lm] = len(rows)
        rows.append(_comm_lm_row(layer, lm, dbytes, psbytes))
    li = np.array([uniq[lm] for lm in lms])
    n_ws, n_is, n_ps, parts_k, parts_c, w_kc, i_bytes, p_bytes = (
        np.array([r[f] for r in rows], dtype=np.int64)[li] for f in range(8))
    wr = np.maximum(1, np.minimum(np.asarray(wrs, dtype=np.int64), n_ws))
    group = np.ceil(n_ws / wr).astype(np.int64)
    stored = w_kc / group
    hops_w = np.array([rows[r][8][g] for r, g in zip(li, group)])
    hops_i = np.array([rows[r][9] for r in li])
    hops_p = np.array([rows[r][10] for r in li])
    return (n_ws, n_is, n_ps, parts_k, parts_c, w_kc, i_bytes, p_bytes,
            wr, group, stored, hops_w, hops_i, hops_p)


def comm_eval_geometry(geom: tuple, hw: HwConfig
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Apply the per-hw scalars to a :func:`comm_batch_geometry` result."""
    (n_ws, n_is, n_ps, parts_k, parts_c, w_kc, i_bytes, p_bytes,
     wr, group, stored, hops_w, hops_i, hops_p) = geom
    # weight sharing: ring over the first `group` share-loop coords
    l1, e1 = _ring_cost_vec(np.where(group > 1, group, 1), w_kc, hops_w, hw)
    e1 = e1 * (parts_k * parts_c * wr)
    # input sharing across K
    l2, e2 = _ring_cost_vec(n_is, i_bytes, hops_i, hw)
    e2 = e2 * (n_ws * parts_c)
    # psum reduction across C (~2 ring passes)
    l3, e3 = _ring_cost_vec(n_ps, 2 * p_bytes, hops_p, hw)
    e3 = e3 * (n_ws * parts_k)
    return l1 + l2 + l3, e1 + e2 + e3, stored


def comm_estimate_batch(layer: Layer, hw: HwConfig, lms: Sequence[LM],
                        wrs: Sequence[int]
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized :func:`comm_estimate` over parallel ``(lm, wr)`` arrays.

    Per-LM structure (sharing-group sizes, ring hop distances) is computed
    once per distinct LM through the cached coordinate helpers; the ring
    latency/energy arithmetic then runs as float64 numpy over the whole
    candidate axis with the same operation order as the scalar reference,
    so results are bitwise-identical — the mapper's batched backend relies
    on that for its parity guarantee.  Returns ``(latency_s, energy_pj,
    weight_bytes_per_node)``.
    """
    m = len(lms)
    z = np.zeros(m)
    if m == 0 or not layer.is_heavy:
        return z, z.copy(), z.copy()
    dbytes = hw.cons.data_bits // 8
    psbytes = hw.cons.psum_bits // 8
    geom = comm_batch_geometry(layer, lms, wrs, dbytes, psbytes)
    return comm_eval_geometry(geom, hw)


@lru_cache(maxsize=1024)
def _wr_from_ws(n: int, n_cands: int) -> tuple[int, ...]:
    outs = []
    v = n
    while v >= 1 and len(outs) < n_cands:
        outs.append(v)
        if v == 1:
            break
        v = max(1, v // 2)
    if 1 not in outs:
        outs.append(1)
    return tuple(outs)


def wr_candidates(layer: Layer, lm: LM, n_cands: int = 5) -> list[int]:
    """WR values from full replication down to 1 (Sec. VI-A)."""
    return list(_wr_from_ws(lm.weight_share, n_cands))
