"""Comparison DSE strategies for Fig. 9: Random, SimAnneal, plain GP, GBT.

Each strategy implements ``observe(cfg, cost)`` + ``propose(k)`` so the DSE
driver (core/dse.py) can swap them for the NicePIM tuner.  ``GBTSurrogate``
is a from-scratch gradient-boosted-tree regressor standing in for XGBoost
(unavailable offline); ``GPSurrogate`` is an exact RBF GP on the raw
normalized parameters (no learned feature extractor — the ablation the paper
runs against deep kernel learning).

Candidate batches are drawn through the vectorized
:func:`repro.core.hardware.sample_config_values` (bitwise-identical to the
scalar ``tuner.sample_configs`` under a shared seed), and ``GPSurrogate``
scores them through the engine's shared masked-GP primitives
(:func:`repro.engine.tuner_train.score_candidates_raw`) so the Fig. 9
ablation and the deep-kernel tuner run one code path; ``backend="numpy"``
keeps the original float64 reference ranking for the parity tests.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .hardware import (DEFAULT_CONSTRAINTS, HwConfig, PimConstraints,
                       configs_from_rows, normalize_params,
                       normalize_params_batch, sample_config_values,
                       sample_configs_batch, sample_space)

# interpret-mode Pallas is slower than plain jnp off-TPU (same policy as the
# tuner and the mapper's knapsack reduce)
_USE_PALLAS = jax.default_backend() == "tpu"


class _Base:
    def __init__(self, cons: PimConstraints = DEFAULT_CONSTRAINTS,
                 seed: int = 0, n_sample: int = 2048):
        self.cons = cons
        self.rng = np.random.default_rng(seed)
        self.n_sample = n_sample
        self._x: list[list[float]] = []
        self._y: list[float] = []

    def observe(self, cfg: HwConfig, area_mm2: float, cost: float | None):
        if cost is not None:
            self._x.append(normalize_params(cfg))
            self._y.append(math.log(max(cost, 1e-30)))

    def fit(self):
        pass


class RandomSearch(_Base):
    name = "random"

    def propose(self, k: int = 8) -> list[HwConfig]:
        return sample_configs_batch(k, self.rng, self.cons)


class SimulatedAnnealing(_Base):
    """Random-walk annealing over the discrete parameter grid."""

    name = "simanneal"

    def __init__(self, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                 n_sample: int = 2048, t0: float = 1.0, decay: float = 0.92):
        super().__init__(cons, seed, n_sample)
        self.t = t0
        self.decay = decay
        self.cur: HwConfig | None = None
        self.cur_cost = math.inf

    def observe(self, cfg: HwConfig, area_mm2: float, cost: float | None):
        super().observe(cfg, area_mm2, cost)
        if cost is None:
            return
        c = math.log(max(cost, 1e-30))
        if (self.cur is None or c < self.cur_cost or
                self.rng.random() < math.exp(-(c - self.cur_cost) /
                                             max(self.t, 1e-6))):
            self.cur = cfg
            self.cur_cost = c
        self.t *= self.decay

    def _neighbor(self, cfg: HwConfig) -> HwConfig:
        space = sample_space(self.cons)
        keys = list(space)
        for _ in range(64):
            k = keys[self.rng.integers(len(keys))]
            vals = space[k]
            cur = getattr(cfg, k)
            i = min(range(len(vals)), key=lambda j: abs(vals[j] - cur))
            j = int(np.clip(i + self.rng.integers(-2, 3), 0, len(vals) - 1))
            cand = cfg.replace(**{k: vals[j]})
            if cand.legal_shape():
                return cand
        return cfg

    def propose(self, k: int = 8) -> list[HwConfig]:
        if self.cur is None:
            return sample_configs_batch(k, self.rng, self.cons)
        return [self._neighbor(self.cur) for _ in range(k)]


class GPSurrogate(_Base):
    """Exact RBF GP on raw params (median-heuristic lengthscale).

    ``backend="engine"`` (default) scores candidates through the shared
    masked-Cholesky / LCB primitives in :mod:`repro.engine.tuner_train`
    (float64, pow2-padded — one jitted dispatch per candidate batch);
    ``backend="numpy"`` is the original dense reference, kept for parity.
    """

    name = "gp"

    # the tuner's backend vocabulary maps onto the GP's engine/reference split
    _BACKEND_ALIASES = {"scan": "engine", "loop": "numpy"}

    def __init__(self, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                 n_sample: int = 2048, beta: float = 1.0,
                 backend: str = "engine"):
        super().__init__(cons, seed, n_sample)
        self.beta = beta
        self.backend = self._BACKEND_ALIASES.get(backend, backend)
        if self.backend not in ("engine", "numpy"):
            raise ValueError(f"GPSurrogate backend must be 'engine' or "
                             f"'numpy' (or the tuner aliases 'scan'/'loop'), "
                             f"got {backend!r}")

    def _rank(self, xq: np.ndarray) -> np.ndarray:
        """Float64 numpy reference (the engine path's parity target)."""
        x = np.array(self._x)
        y = np.array(self._y)
        mu, sd = y.mean(), y.std() + 1e-9
        yn = (y - mu) / sd
        d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        ls2 = np.median(d2[d2 > 0]) if (d2 > 0).any() else 1.0
        k = np.exp(-0.5 * d2 / ls2) + 1e-3 * np.eye(len(x))
        kinv_y = np.linalg.solve(k, yn)
        dq2 = ((xq[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        kq = np.exp(-0.5 * dq2 / ls2)
        mean = kq @ kinv_y
        var = np.clip(1.0 - np.einsum("qi,ij,qj->q", kq,
                                      np.linalg.inv(k), kq), 1e-9, None)
        return mean - self.beta * np.sqrt(var)

    def _rank_engine(self, xq: np.ndarray) -> np.ndarray:
        from jax.experimental import enable_x64
        from ..engine.tuner_train import pow2_bucket, score_candidates_raw
        x = np.array(self._x, np.float64)
        y = np.array(self._y, np.float64)
        n = len(y)
        p = pow2_bucket(n)
        xp = np.zeros((p, x.shape[1]))
        yp = np.zeros((p,))
        mask = np.zeros((p,), bool)
        xp[:n], yp[:n], mask[:n] = x, y, True
        with enable_x64():
            scores = score_candidates_raw(
                jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mask),
                jnp.asarray(np.asarray(xq, np.float64)),
                jnp.ones(len(xq), bool), self.beta,
                use_pallas=_USE_PALLAS)
        return np.asarray(scores)

    def propose(self, k: int = 8) -> list[HwConfig]:
        vals = sample_config_values(self.n_sample, self.rng, self.cons)
        if len(self._y) < 3:
            return [HwConfig.from_tuple(map(int, row), cons=self.cons)
                    for row in vals[:k]]
        xq = normalize_params_batch(vals, dtype=np.float64)
        scores = self._rank(xq) if self.backend == "numpy" \
            else self._rank_engine(xq)
        return configs_from_rows(vals, self.cons,
                                 np.argsort(scores, kind="stable"), k)


# -- tiny gradient-boosted trees (XGBoost stand-in) ---------------------------


@dataclass
class _Stump:
    feat: int
    thresh: float
    left: float
    right: float

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.where(x[:, self.feat] <= self.thresh, self.left, self.right)


def _fit_stump(x: np.ndarray, r: np.ndarray, rng) -> _Stump:
    n, d = x.shape
    best = None
    best_err = math.inf
    feats = rng.choice(d, size=min(d, 5), replace=False)
    for f in feats:
        vals = np.unique(x[:, f])
        if len(vals) < 2:
            continue
        for t in np.quantile(vals, [0.25, 0.5, 0.75]):
            m = x[:, f] <= t
            if m.sum() == 0 or (~m).sum() == 0:
                continue
            lv, rv = r[m].mean(), r[~m].mean()
            err = ((r - np.where(m, lv, rv)) ** 2).sum()
            if err < best_err:
                best_err = err
                best = _Stump(int(f), float(t), float(lv), float(rv))
    return best or _Stump(0, 0.5, float(r.mean()), float(r.mean()))


class GBTSurrogate(_Base):
    """Gradient-boosted stumps with squared loss (XGBoost stand-in)."""

    name = "gbt"

    def __init__(self, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                 n_sample: int = 2048, n_trees: int = 120, lr: float = 0.15):
        super().__init__(cons, seed, n_sample)
        self.n_trees = n_trees
        self.lr = lr
        self._trees: list[_Stump] = []
        self._bias = 0.0

    def fit(self):
        if len(self._y) < 4:
            return
        x = np.array(self._x)
        y = np.array(self._y)
        self._bias = float(y.mean())
        pred = np.full(len(y), self._bias)
        self._trees = []
        for _ in range(self.n_trees):
            stump = _fit_stump(x, y - pred, self.rng)
            pred = pred + self.lr * stump.predict(x)
            self._trees.append(stump)

    def _predict(self, xq: np.ndarray) -> np.ndarray:
        pred = np.full(len(xq), self._bias)
        for t in self._trees:
            pred = pred + self.lr * t.predict(xq)
        return pred

    def propose(self, k: int = 8) -> list[HwConfig]:
        vals = sample_config_values(self.n_sample, self.rng, self.cons)
        if not self._trees:
            return [HwConfig.from_tuple(map(int, row), cons=self.cons)
                    for row in vals[:k]]
        xq = normalize_params_batch(vals, dtype=np.float64)
        return configs_from_rows(
            vals, self.cons,
            np.argsort(self._predict(xq), kind="stable"), k)


def make_strategy(name: str, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                  n_sample: int = 2048, backend: str | None = None):
    """Factory covering every Fig. 9 curve (incl. the NicePIM tuner).

    ``backend`` threads into the strategies that have an engine/reference
    split: the NicePIM tuner (``"scan"``/``"loop"``) and the GP ablation
    (``"engine"``/``"numpy"``); the rest ignore it.
    """
    from .tuner import PimTuner
    name = name.lower()
    if name in ("nicepim", "dkl"):
        return PimTuner(cons=cons, seed=seed, n_sample=n_sample,
                        backend=backend or "scan")
    if name == "gp":
        return GPSurrogate(cons, seed, n_sample, backend=backend or "engine")
    cls = {"random": RandomSearch, "simanneal": SimulatedAnnealing,
           "gbt": GBTSurrogate, "xgboost": GBTSurrogate}[name]
    return cls(cons, seed, n_sample)
