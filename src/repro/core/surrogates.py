"""Comparison DSE strategies for Fig. 9: Random, SimAnneal, plain GP, GBT.

Each strategy implements ``observe(cfg, cost)`` + ``propose(k)`` so the DSE
driver (core/dse.py) can swap them for the NicePIM tuner.  ``GBTSurrogate``
is a from-scratch gradient-boosted-tree regressor standing in for XGBoost
(unavailable offline); ``GPSurrogate`` is an exact RBF GP on the raw
normalized parameters (no learned feature extractor — the ablation the paper
runs against deep kernel learning).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .hardware import (DEFAULT_CONSTRAINTS, HwConfig, PimConstraints,
                       normalize_params, sample_space)
from .tuner import sample_configs


class _Base:
    def __init__(self, cons: PimConstraints = DEFAULT_CONSTRAINTS,
                 seed: int = 0, n_sample: int = 2048):
        self.cons = cons
        self.rng = np.random.default_rng(seed)
        self.n_sample = n_sample
        self._x: list[list[float]] = []
        self._y: list[float] = []

    def observe(self, cfg: HwConfig, area_mm2: float, cost: float | None):
        if cost is not None:
            self._x.append(normalize_params(cfg))
            self._y.append(math.log(max(cost, 1e-30)))

    def fit(self):
        pass


class RandomSearch(_Base):
    name = "random"

    def propose(self, k: int = 8) -> list[HwConfig]:
        return sample_configs(k, self.rng, self.cons)


class SimulatedAnnealing(_Base):
    """Random-walk annealing over the discrete parameter grid."""

    name = "simanneal"

    def __init__(self, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                 n_sample: int = 2048, t0: float = 1.0, decay: float = 0.92):
        super().__init__(cons, seed, n_sample)
        self.t = t0
        self.decay = decay
        self.cur: HwConfig | None = None
        self.cur_cost = math.inf

    def observe(self, cfg: HwConfig, area_mm2: float, cost: float | None):
        super().observe(cfg, area_mm2, cost)
        if cost is None:
            return
        c = math.log(max(cost, 1e-30))
        if (self.cur is None or c < self.cur_cost or
                self.rng.random() < math.exp(-(c - self.cur_cost) /
                                             max(self.t, 1e-6))):
            self.cur = cfg
            self.cur_cost = c
        self.t *= self.decay

    def _neighbor(self, cfg: HwConfig) -> HwConfig:
        space = sample_space(self.cons)
        keys = list(space)
        for _ in range(64):
            k = keys[self.rng.integers(len(keys))]
            vals = space[k]
            cur = getattr(cfg, k)
            i = min(range(len(vals)), key=lambda j: abs(vals[j] - cur))
            j = int(np.clip(i + self.rng.integers(-2, 3), 0, len(vals) - 1))
            cand = cfg.replace(**{k: vals[j]})
            if cand.legal_shape():
                return cand
        return cfg

    def propose(self, k: int = 8) -> list[HwConfig]:
        if self.cur is None:
            return sample_configs(k, self.rng, self.cons)
        return [self._neighbor(self.cur) for _ in range(k)]


class GPSurrogate(_Base):
    """Exact RBF GP on raw params (median-heuristic lengthscale)."""

    name = "gp"

    def __init__(self, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                 n_sample: int = 2048, beta: float = 1.0):
        super().__init__(cons, seed, n_sample)
        self.beta = beta

    def _rank(self, xq: np.ndarray) -> np.ndarray:
        x = np.array(self._x)
        y = np.array(self._y)
        mu, sd = y.mean(), y.std() + 1e-9
        yn = (y - mu) / sd
        d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        ls2 = np.median(d2[d2 > 0]) if (d2 > 0).any() else 1.0
        k = np.exp(-0.5 * d2 / ls2) + 1e-3 * np.eye(len(x))
        kinv_y = np.linalg.solve(k, yn)
        dq2 = ((xq[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        kq = np.exp(-0.5 * dq2 / ls2)
        mean = kq @ kinv_y
        var = np.clip(1.0 - np.einsum("qi,ij,qj->q", kq,
                                      np.linalg.inv(k), kq), 1e-9, None)
        return mean - self.beta * np.sqrt(var)

    def propose(self, k: int = 8) -> list[HwConfig]:
        cands = sample_configs(self.n_sample, self.rng, self.cons)
        if len(self._y) < 3:
            return cands[:k]
        xq = np.array([normalize_params(c) for c in cands])
        order = np.argsort(self._rank(xq))
        seen, out = set(), []
        for i in order:
            t = cands[i].as_tuple()
            if t not in seen:
                seen.add(t)
                out.append(cands[i])
            if len(out) >= k:
                break
        return out


# -- tiny gradient-boosted trees (XGBoost stand-in) ---------------------------


@dataclass
class _Stump:
    feat: int
    thresh: float
    left: float
    right: float

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.where(x[:, self.feat] <= self.thresh, self.left, self.right)


def _fit_stump(x: np.ndarray, r: np.ndarray, rng) -> _Stump:
    n, d = x.shape
    best = None
    best_err = math.inf
    feats = rng.choice(d, size=min(d, 5), replace=False)
    for f in feats:
        vals = np.unique(x[:, f])
        if len(vals) < 2:
            continue
        for t in np.quantile(vals, [0.25, 0.5, 0.75]):
            m = x[:, f] <= t
            if m.sum() == 0 or (~m).sum() == 0:
                continue
            lv, rv = r[m].mean(), r[~m].mean()
            err = ((r - np.where(m, lv, rv)) ** 2).sum()
            if err < best_err:
                best_err = err
                best = _Stump(int(f), float(t), float(lv), float(rv))
    return best or _Stump(0, 0.5, float(r.mean()), float(r.mean()))


class GBTSurrogate(_Base):
    """Gradient-boosted stumps with squared loss (XGBoost stand-in)."""

    name = "gbt"

    def __init__(self, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                 n_sample: int = 2048, n_trees: int = 120, lr: float = 0.15):
        super().__init__(cons, seed, n_sample)
        self.n_trees = n_trees
        self.lr = lr
        self._trees: list[_Stump] = []
        self._bias = 0.0

    def fit(self):
        if len(self._y) < 4:
            return
        x = np.array(self._x)
        y = np.array(self._y)
        self._bias = float(y.mean())
        pred = np.full(len(y), self._bias)
        self._trees = []
        for _ in range(self.n_trees):
            stump = _fit_stump(x, y - pred, self.rng)
            pred = pred + self.lr * stump.predict(x)
            self._trees.append(stump)

    def _predict(self, xq: np.ndarray) -> np.ndarray:
        pred = np.full(len(xq), self._bias)
        for t in self._trees:
            pred = pred + self.lr * t.predict(xq)
        return pred

    def propose(self, k: int = 8) -> list[HwConfig]:
        cands = sample_configs(self.n_sample, self.rng, self.cons)
        if not self._trees:
            return cands[:k]
        xq = np.array([normalize_params(c) for c in cands])
        order = np.argsort(self._predict(xq))
        seen, out = set(), []
        for i in order:
            t = cands[i].as_tuple()
            if t not in seen:
                seen.add(t)
                out.append(cands[i])
            if len(out) >= k:
                break
        return out


def make_strategy(name: str, cons=DEFAULT_CONSTRAINTS, seed: int = 0,
                  n_sample: int = 2048):
    """Factory covering every Fig. 9 curve (incl. the NicePIM tuner)."""
    from .tuner import PimTuner
    name = name.lower()
    if name in ("nicepim", "dkl"):
        return PimTuner(cons=cons, seed=seed, n_sample=n_sample)
    cls = {"random": RandomSearch, "simanneal": SimulatedAnnealing,
           "gp": GPSurrogate, "gbt": GBTSurrogate, "xgboost": GBTSurrogate}[name]
    return cls(cons, seed, n_sample)
