"""PIM-Tuner (Sec. V): filter MLP + deep-kernel-learning suggestion model.

Both models are pure JAX, trained with the from-scratch Adam in
``repro.training.optim``:

* **Filter model** — MLP with 256/64/16/1 ReLU layers (paper Sec. VIII-B)
  regressing the logic-die area from the normalized 7-d hardware parameter
  vector; candidates whose predicted area exceeds the constraint are
  discarded before ranking.
* **Suggestion model** — deep kernel learning [27]: an MLP feature extractor
  (256/64/16) feeding an RBF Gaussian process; MLP weights and GP
  hyperparameters (lengthscale, signal, noise) are optimized *jointly* by
  maximizing the exact GP log marginal likelihood.  Ranking uses a lower
  confidence bound on the predicted (standardized log-)cost.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..training.optim import Adam
from .hardware import HwConfig, PimConstraints, DEFAULT_CONSTRAINTS, \
    normalize_params, sample_space


def _init_mlp(key, sizes: list[int]) -> list[dict]:
    layers = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (a, b), jnp.float32) * math.sqrt(2.0 / a)
        layers.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
    return layers


def _mlp_forward(layers: list[dict], x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for i, l in enumerate(layers):
        h = h @ l["w"] + l["b"]
        if i < len(layers) - 1:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Filter model
# ---------------------------------------------------------------------------

FILTER_SIZES = [7, 256, 64, 16, 1]


@jax.jit
def _filter_loss(params, x, y):
    pred = _mlp_forward(params, x)[:, 0]
    return jnp.mean((pred - y) ** 2)


@jax.jit
def _filter_step(params, opt_state, x, y):
    loss, grads = jax.value_and_grad(_filter_loss)(params, x, y)
    params, opt_state = _FILTER_OPT.apply(grads, opt_state, params)
    return params, opt_state, loss


_FILTER_OPT = Adam(lr=3e-3)


class FilterModel:
    """Predicts log(area/budget) from hw params (area spans ~4 decades)."""

    def __init__(self, cons: PimConstraints = DEFAULT_CONSTRAINTS, seed: int = 0):
        self.cons = cons
        self.params = _init_mlp(jax.random.PRNGKey(seed), FILTER_SIZES)
        self.opt_state = _FILTER_OPT.init(self.params)
        self._x: list[list[float]] = []
        self._y: list[float] = []

    def add(self, cfg: HwConfig, area_mm2: float) -> None:
        self._x.append(normalize_params(cfg))
        self._y.append(math.log(max(area_mm2, 1e-6) /
                                self.cons.area_budget_mm2))

    def fit(self, steps: int = 200) -> float:
        if len(self._y) < 8:
            return float("nan")
        x = jnp.asarray(np.array(self._x, np.float32))
        y = jnp.asarray(np.array(self._y, np.float32))
        loss = jnp.inf
        for _ in range(steps):
            self.params, self.opt_state, loss = _filter_step(
                self.params, self.opt_state, x, y)
        return float(loss)

    def predict_area(self, cfgs: list[HwConfig]) -> np.ndarray:
        x = jnp.asarray(np.array([normalize_params(c) for c in cfgs],
                                 np.float32))
        pred = _mlp_forward(self.params, x)[:, 0]
        return np.exp(np.asarray(pred)) * self.cons.area_budget_mm2

    def trained(self) -> bool:
        return len(self._y) >= 8


# ---------------------------------------------------------------------------
# Deep-kernel-learning suggestion model
# ---------------------------------------------------------------------------

DKL_SIZES = [7, 256, 64, 16]


def _dkl_init(seed: int) -> dict:
    return {
        "mlp": _init_mlp(jax.random.PRNGKey(seed), DKL_SIZES),
        "log_ls": jnp.zeros(()),       # RBF lengthscale
        "log_sf": jnp.zeros(()),       # signal stddev
        "log_sn": jnp.asarray(-2.0),   # noise stddev
    }


def _features(params, x):
    z = _mlp_forward(params["mlp"], x)
    return z / (jnp.linalg.norm(z, axis=-1, keepdims=True) + 1e-6)


def _kernel(params, za, zb):
    ls = jnp.exp(params["log_ls"])
    sf2 = jnp.exp(2 * params["log_sf"])
    d2 = jnp.sum((za[:, None, :] - zb[None, :, :]) ** 2, -1)
    return sf2 * jnp.exp(-0.5 * d2 / (ls ** 2 + 1e-8))


@jax.jit
def _nlml(params, x, y):
    """Negative log marginal likelihood of the exact GP."""
    z = _features(params, x)
    n = x.shape[0]
    k = _kernel(params, z, z) + (jnp.exp(2 * params["log_sn"]) + 1e-6) \
        * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha + jnp.sum(jnp.log(jnp.diag(chol)))
            + 0.5 * n * jnp.log(2 * jnp.pi)) / n


_DKL_OPT = Adam(lr=3e-3, clip_norm=10.0)


@jax.jit
def _dkl_step(params, opt_state, x, y):
    loss, grads = jax.value_and_grad(_nlml)(params, x, y)
    params, opt_state = _DKL_OPT.apply(grads, opt_state, params)
    return params, opt_state, loss


@jax.jit
def _dkl_predict(params, x_train, y_train, x_query):
    zt = _features(params, x_train)
    zq = _features(params, x_query)
    n = x_train.shape[0]
    k = _kernel(params, zt, zt) + (jnp.exp(2 * params["log_sn"]) + 1e-6) \
        * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_train)
    kq = _kernel(params, zq, zt)
    mean = kq @ alpha
    v = jax.scipy.linalg.solve_triangular(chol, kq.T, lower=True)
    var = jnp.exp(2 * params["log_sf"]) - jnp.sum(v * v, axis=0)
    return mean, jnp.clip(var, 1e-9)


class DklSuggestionModel:
    """Ranks hardware configs by LCB of predicted standardized log-cost."""

    name = "dkl"

    def __init__(self, seed: int = 0, beta: float = 1.0):
        self.params = _dkl_init(seed)
        self.opt_state = _DKL_OPT.init(self.params)
        self.beta = beta
        self._x: list[list[float]] = []
        self._y: list[float] = []
        self._mu = 0.0
        self._sigma = 1.0

    def add(self, cfg: HwConfig, cost: float) -> None:
        self._x.append(normalize_params(cfg))
        self._y.append(math.log(max(cost, 1e-30)))

    def fit(self, steps: int = 300) -> float:
        if len(self._y) < 3:
            return float("nan")
        y = np.array(self._y, np.float64)
        self._mu = float(y.mean())
        self._sigma = float(y.std() + 1e-9)
        x = jnp.asarray(np.array(self._x, np.float32))
        yn = jnp.asarray(((y - self._mu) / self._sigma).astype(np.float32))
        loss = jnp.inf
        for _ in range(steps):
            self.params, self.opt_state, loss = _dkl_step(
                self.params, self.opt_state, x, yn)
        return float(loss)

    def rank(self, cfgs: list[HwConfig]) -> np.ndarray:
        """Scores (lower = better); LCB on the predicted cost."""
        if len(self._y) < 3:
            return np.zeros(len(cfgs))
        xt = jnp.asarray(np.array(self._x, np.float32))
        yt = jnp.asarray(
            ((np.array(self._y) - self._mu) / self._sigma).astype(np.float32))
        xq = jnp.asarray(np.array([normalize_params(c) for c in cfgs],
                                  np.float32))
        mean, var = _dkl_predict(self.params, xt, yt, xq)
        return np.asarray(mean - self.beta * jnp.sqrt(var))


# ---------------------------------------------------------------------------
# Sampling + the tuner driver
# ---------------------------------------------------------------------------


def sample_configs(n: int, rng: np.random.Generator,
                   cons: PimConstraints = DEFAULT_CONSTRAINTS) -> list[HwConfig]:
    """Uniform raw samples from the Table-II design space (shape-legal only)."""
    space = sample_space(cons)
    keys = list(space)
    outs = []
    while len(outs) < n:
        vals = {k: space[k][rng.integers(len(space[k]))] for k in keys}
        cfg = HwConfig(cons=cons, **vals)
        if cfg.legal_shape():
            outs.append(cfg)
    return outs


@dataclass
class PimTuner:
    """One NicePIM tuner iteration: sample -> filter -> rank (Fig. 8)."""

    name = "nicepim"

    cons: PimConstraints = DEFAULT_CONSTRAINTS
    seed: int = 0
    n_sample: int = 2048
    beta: float = 1.0
    filter_model: FilterModel = None
    suggestion: DklSuggestionModel = None

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        if self.filter_model is None:
            self.filter_model = FilterModel(self.cons, self.seed)
        if self.suggestion is None:
            self.suggestion = DklSuggestionModel(self.seed, self.beta)

    def propose(self, k: int = 8) -> list[HwConfig]:
        cands = sample_configs(self.n_sample, self.rng, self.cons)
        if self.filter_model.trained():
            areas = self.filter_model.predict_area(cands)
            keep = [c for c, a in zip(cands, areas)
                    if a <= self.cons.area_budget_mm2]
            if keep:
                cands = keep
        scores = self.suggestion.rank(cands)
        order = np.argsort(scores)
        # dedup while preserving rank order
        seen, out = set(), []
        for i in order:
            t = cands[i].as_tuple()
            if t not in seen:
                seen.add(t)
                out.append(cands[i])
            if len(out) >= k:
                break
        return out

    def observe(self, cfg: HwConfig, area_mm2: float,
                cost: float | None) -> None:
        self.filter_model.add(cfg, area_mm2)
        if cost is not None:
            self.suggestion.add(cfg, cost)

    def fit(self) -> None:
        self.filter_model.fit()
        self.suggestion.fit()
