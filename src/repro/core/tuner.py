"""PIM-Tuner (Sec. V): filter MLP + deep-kernel-learning suggestion model.

Both models are pure JAX, trained with the from-scratch Adam in
``repro.training.optim``:

* **Filter model** — MLP with 256/64/16/1 ReLU layers (paper Sec. VIII-B)
  regressing the logic-die area from the normalized 7-d hardware parameter
  vector; candidates whose predicted area exceeds the constraint are
  discarded before ranking.
* **Suggestion model** — deep kernel learning [27]: an MLP feature extractor
  (256/64/16) feeding an RBF Gaussian process; MLP weights and GP
  hyperparameters (lengthscale, signal, noise) are optimized *jointly* by
  maximizing the exact GP log marginal likelihood.  Ranking uses a lower
  confidence bound on the predicted (standardized log-)cost.

Both models run on one of two backends:

* ``backend="scan"`` (default) — the engine layer
  (:mod:`repro.engine.tuner_train`): the whole Adam trajectory runs inside
  one jitted ``lax.scan`` over pow2-bucketed, validity-masked data (no
  per-step host round-trips, no recompile per growing dataset size), propose
  scoring is one fused jitted dispatch over the full candidate batch (area
  mask applied in-array), and candidates are drawn through the vectorized
  :func:`repro.core.hardware.sample_config_values`.
* ``backend="loop"`` — the original per-step host-dispatch reference path,
  kept as the parity baseline for ``tests/test_tuner_engine.py`` and the
  scalar side of ``benchmarks/tuner_throughput.py``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..engine.tuner_train import (dkl_features, fit_dkl, fit_filter,
                                  mlp_forward, mlp_init, pad_dataset,
                                  rbf_cross, score_candidates)
from ..training.optim import Adam
from .hardware import HwConfig, PimConstraints, DEFAULT_CONSTRAINTS, \
    configs_from_rows, normalize_params, normalize_params_batch, \
    sample_config_values, sample_space

# shared model primitives live in the engine layer (one code path for the
# scan backend, these references, and the Fig. 9 GP ablation)
_init_mlp = mlp_init
_mlp_forward = mlp_forward
_features = dkl_features

# the Pallas LCB kernel is the on-TPU default; off-TPU the pure-jnp scoring
# path is faster than interpret-mode Pallas (same policy as the mapper's
# knapsack reduce)
_USE_PALLAS = jax.default_backend() == "tpu"


def _check_backend(backend: str) -> str:
    if backend not in ("scan", "loop"):
        raise ValueError(f"tuner backend must be 'scan' or 'loop', "
                         f"got {backend!r}")
    return backend


# ---------------------------------------------------------------------------
# Filter model
# ---------------------------------------------------------------------------

FILTER_SIZES = [7, 256, 64, 16, 1]


@jax.jit
def _filter_loss(params, x, y):
    pred = _mlp_forward(params, x)[:, 0]
    return jnp.mean((pred - y) ** 2)


@jax.jit
def _filter_step(params, opt_state, x, y):
    loss, grads = jax.value_and_grad(_filter_loss)(params, x, y)
    params, opt_state = _FILTER_OPT.apply(grads, opt_state, params)
    return params, opt_state, loss


@jax.jit
def _filter_forward(params, x):
    return _mlp_forward(params, x)[:, 0]


_FILTER_OPT = Adam(lr=3e-3)


class FilterModel:
    """Predicts log(area/budget) from hw params (area spans ~4 decades)."""

    def __init__(self, cons: PimConstraints = DEFAULT_CONSTRAINTS,
                 seed: int = 0, backend: str = "scan"):
        self.cons = cons
        self.backend = _check_backend(backend)
        self.params = _init_mlp(jax.random.PRNGKey(seed), FILTER_SIZES)
        self.opt_state = _FILTER_OPT.init(self.params)
        self._x: list[list[float]] = []
        self._y: list[float] = []

    def add(self, cfg: HwConfig, area_mm2: float) -> None:
        self._x.append(normalize_params(cfg))
        self._y.append(math.log(max(area_mm2, 1e-6) /
                                self.cons.area_budget_mm2))

    def fit(self, steps: int = 200) -> float:
        if len(self._y) < 8:
            return float("nan")
        if self.backend == "loop":
            x = np.array(self._x, np.float32)
            y = np.array(self._y, np.float32)
            xj, yj = jnp.asarray(x), jnp.asarray(y)
            loss = jnp.inf
            for _ in range(steps):
                self.params, self.opt_state, loss = _filter_step(
                    self.params, self.opt_state, xj, yj)
            return float(loss)
        return float(self.fit_arrays(steps)[-1])

    def fit_arrays(self, steps: int = 200):
        """Scan-backend fit WITHOUT the final-loss host sync.

        Returns the device-resident loss trajectory (``None`` when there
        are too few observations) — the device-resident pipeline's hook:
        the dispatch is enqueued asynchronously and the host never blocks
        on it unless someone actually reads a loss.  Model state updates
        are identical to :meth:`fit`.
        """
        if len(self._y) < 8:
            return None
        x = np.array(self._x, np.float32)
        y = np.array(self._y, np.float32)
        # explicit put: the training-set staging is the ONE host->device
        # hop of a fit, so the pipeline's transfer guard stays clean
        xp, yp, mask = map(jax.device_put, pad_dataset(x, y))
        self.params, self.opt_state, losses = fit_filter(
            self.params, self.opt_state, xp, yp, mask,
            opt=_FILTER_OPT, steps=steps)
        return losses

    def predict_area_x(self, x: np.ndarray) -> np.ndarray:
        """Predicted areas (mm^2) for an ``[n, 7]`` normalized-param matrix."""
        pred = _filter_forward(self.params, jnp.asarray(x, jnp.float32))
        return np.exp(np.asarray(pred)) * self.cons.area_budget_mm2

    def predict_area(self, cfgs: list[HwConfig]) -> np.ndarray:
        return self.predict_area_x(
            np.array([normalize_params(c) for c in cfgs], np.float32))

    def trained(self) -> bool:
        return len(self._y) >= 8


# ---------------------------------------------------------------------------
# Deep-kernel-learning suggestion model
# ---------------------------------------------------------------------------

DKL_SIZES = [7, 256, 64, 16]


def _dkl_init(seed: int) -> dict:
    return {
        "mlp": _init_mlp(jax.random.PRNGKey(seed), DKL_SIZES),
        "log_ls": jnp.zeros(()),       # RBF lengthscale
        "log_sf": jnp.zeros(()),       # signal stddev
        # strong f32 (a weak-typed scalar here would flip type after the
        # first fit and force one spurious recompile per shape bucket)
        "log_sn": jnp.asarray(-2.0, jnp.float32),
    }


def _kernel(params, za, zb):
    # shares the engine's gram-trick RBF so both backends run identical ops
    ls = jnp.exp(params["log_ls"])
    sf2 = jnp.exp(2 * params["log_sf"])
    return rbf_cross(za, zb, ls ** 2 + 1e-8, sf2)


@jax.jit
def _nlml(params, x, y):
    """Negative log marginal likelihood of the exact GP."""
    z = _features(params, x)
    n = x.shape[0]
    k = _kernel(params, z, z) + (jnp.exp(2 * params["log_sn"]) + 1e-6) \
        * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (0.5 * y @ alpha + jnp.sum(jnp.log(jnp.diag(chol)))
            + 0.5 * n * jnp.log(2 * jnp.pi)) / n


_DKL_OPT = Adam(lr=3e-3, clip_norm=10.0)


@jax.jit
def _dkl_step(params, opt_state, x, y):
    loss, grads = jax.value_and_grad(_nlml)(params, x, y)
    params, opt_state = _DKL_OPT.apply(grads, opt_state, params)
    return params, opt_state, loss


@jax.jit
def _dkl_predict(params, x_train, y_train, x_query):
    zt = _features(params, x_train)
    zq = _features(params, x_query)
    n = x_train.shape[0]
    k = _kernel(params, zt, zt) + (jnp.exp(2 * params["log_sn"]) + 1e-6) \
        * jnp.eye(n)
    chol = jnp.linalg.cholesky(k)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y_train)
    kq = _kernel(params, zq, zt)
    mean = kq @ alpha
    v = jax.scipy.linalg.solve_triangular(chol, kq.T, lower=True)
    var = jnp.exp(2 * params["log_sf"]) - jnp.sum(v * v, axis=0)
    return mean, jnp.clip(var, 1e-9)


class DklSuggestionModel:
    """Ranks hardware configs by LCB of predicted standardized log-cost."""

    name = "dkl"

    def __init__(self, seed: int = 0, beta: float = 1.0,
                 backend: str = "scan"):
        self.params = _dkl_init(seed)
        self.opt_state = _DKL_OPT.init(self.params)
        self.beta = beta
        self.backend = _check_backend(backend)
        self._x: list[list[float]] = []
        self._y: list[float] = []
        self._mu = 0.0
        self._sigma = 1.0
        # observations added after the last fit() invalidate the GP state
        # AND the (_mu, _sigma) standardization; rank() refits when dirty
        # instead of scoring against stale statistics
        self._dirty = True
        self._train: tuple | None = None   # padded (x, y, mask) of last fit

    def add(self, cfg: HwConfig, cost: float) -> None:
        self._x.append(normalize_params(cfg))
        self._y.append(math.log(max(cost, 1e-30)))
        self._dirty = True

    def fit(self, steps: int = 300) -> float:
        if len(self._y) < 3:
            return float("nan")
        if self.backend == "loop":
            y = np.array(self._y, np.float64)
            self._mu = float(y.mean())
            self._sigma = float(y.std() + 1e-9)
            x = np.array(self._x, np.float32)
            yn = ((y - self._mu) / self._sigma).astype(np.float32)
            xj, yj = jnp.asarray(x), jnp.asarray(yn)
            loss = jnp.inf
            for _ in range(steps):
                self.params, self.opt_state, loss = _dkl_step(
                    self.params, self.opt_state, xj, yj)
            self._dirty = False
            return float(loss)
        return float(self.fit_arrays(steps)[-1])

    def fit_arrays(self, steps: int = 300):
        """Scan-backend fit WITHOUT the final-loss host sync (see
        :meth:`FilterModel.fit_arrays`); returns ``None`` below 3 points."""
        if len(self._y) < 3:
            return None
        y = np.array(self._y, np.float64)
        self._mu = float(y.mean())
        self._sigma = float(y.std() + 1e-9)
        x = np.array(self._x, np.float32)
        yn = ((y - self._mu) / self._sigma).astype(np.float32)
        # device-resident training set: one explicit put per fit, and the
        # cached ``_train`` feeds propose scoring without another transfer
        xp, yp, mask = map(jax.device_put, pad_dataset(x, yn))
        self.params, self.opt_state, losses = fit_dkl(
            self.params, self.opt_state, xp, yp, mask,
            opt=_DKL_OPT, steps=steps)
        self._train = (xp, yp, mask)
        self._dirty = False
        return losses

    def rank_x(self, xq: np.ndarray,
               area_ok: np.ndarray | None = None) -> np.ndarray:
        """Scores for an ``[n, 7]`` normalized-param matrix (lower = better).

        ``area_ok`` is the filter model's in-array mask: candidates with
        ``area_ok=False`` score ``+inf`` so they sort last.  Stale models
        (observations added since the last ``fit``) are refit first.
        """
        if len(self._y) < 3:
            scores = np.zeros(len(xq))
            return scores if area_ok is None \
                else np.where(area_ok, scores, np.inf)
        if self._dirty:
            self.fit()
        if self.backend == "loop" or self._train is None:
            xt = jnp.asarray(np.array(self._x, np.float32))
            yt = jnp.asarray(((np.array(self._y) - self._mu)
                              / self._sigma).astype(np.float32))
            mean, var = _dkl_predict(self.params, xt, yt,
                                     jnp.asarray(xq, jnp.float32))
            scores = np.asarray(mean - self.beta * jnp.sqrt(var))
            return scores if area_ok is None \
                else np.where(area_ok, scores, np.inf)
        xp, yp, mask = self._train
        ok = np.ones(len(xq), bool) if area_ok is None else area_ok
        return np.asarray(score_candidates(
            self.params, xp, yp, mask, jnp.asarray(xq, jnp.float32),
            ok, self.beta, use_pallas=_USE_PALLAS))

    def rank(self, cfgs: list[HwConfig]) -> np.ndarray:
        """Scores (lower = better); LCB on the predicted cost."""
        if len(self._y) < 3:
            return np.zeros(len(cfgs))
        return self.rank_x(np.array([normalize_params(c) for c in cfgs],
                                    np.float32))


# ---------------------------------------------------------------------------
# Sampling + the tuner driver
# ---------------------------------------------------------------------------


def sample_configs(n: int, rng: np.random.Generator,
                   cons: PimConstraints = DEFAULT_CONSTRAINTS,
                   max_draws: int | None = None) -> list[HwConfig]:
    """Uniform raw samples from the Table-II design space (shape-legal only).

    The scalar reference loop: one candidate per iteration, rejected through
    ``HwConfig.legal_shape``.  It consumes the generator stream exactly like
    the vectorized :func:`repro.core.hardware.sample_config_values`, so a
    shared seed yields identical samples (pinned by the parity tests).
    ``max_draws`` caps total attempts — a degenerate constraint set raises
    instead of spinning forever.
    """
    if max_draws is None:
        max_draws = 64 * n + 1024
    space = sample_space(cons)
    keys = list(space)
    outs = []
    draws = 0
    while len(outs) < n:
        if draws >= max_draws:
            raise RuntimeError(
                f"sample_configs: drew {draws} candidates but only "
                f"{len(outs)}/{n} passed legal_shape (draw cap {max_draws}); "
                f"the constraint set likely leaves no legal configurations")
        vals = {k: space[k][rng.integers(len(space[k]))] for k in keys}
        draws += 1
        cfg = HwConfig(cons=cons, **vals)
        if cfg.legal_shape():
            outs.append(cfg)
    return outs


@dataclass
class PimTuner:
    """One NicePIM tuner iteration: sample -> filter -> rank (Fig. 8)."""

    name = "nicepim"

    cons: PimConstraints = DEFAULT_CONSTRAINTS
    seed: int = 0
    n_sample: int = 2048
    beta: float = 1.0
    backend: str = "scan"
    filter_model: FilterModel = None
    suggestion: DklSuggestionModel = None

    def __post_init__(self):
        _check_backend(self.backend)
        self.rng = np.random.default_rng(self.seed)
        if self.filter_model is None:
            self.filter_model = FilterModel(self.cons, self.seed,
                                            backend=self.backend)
        if self.suggestion is None:
            self.suggestion = DklSuggestionModel(self.seed, self.beta,
                                                 backend=self.backend)

    def propose(self, k: int = 8) -> list[HwConfig]:
        if self.backend == "loop":
            return self._propose_loop(k)
        # the whole candidate batch as an [n, 7] value matrix: vectorized
        # draw, vectorized normalize, in-array area mask, one fused scoring
        # dispatch — HwConfig objects only materialize for the k winners
        vals = sample_config_values(self.n_sample, self.rng, self.cons)
        xq = normalize_params_batch(vals)
        area_ok = None
        if self.filter_model.trained():
            areas = self.filter_model.predict_area_x(xq)
            mask = areas <= self.cons.area_budget_mm2
            if mask.any():     # an all-reject filter would starve the search
                area_ok = mask
        scores = self.suggestion.rank_x(xq, area_ok=area_ok)
        # masked candidates score +inf and sort last; the valid mask stops
        # the dedup walk before it could surface one
        return configs_from_rows(vals, self.cons,
                                 np.argsort(scores, kind="stable"), k,
                                 valid=area_ok)

    def _propose_loop(self, k: int) -> list[HwConfig]:
        """The original list-based propose (scalar reference path)."""
        cands = sample_configs(self.n_sample, self.rng, self.cons)
        if self.filter_model.trained():
            areas = self.filter_model.predict_area(cands)
            keep = [c for c, a in zip(cands, areas)
                    if a <= self.cons.area_budget_mm2]
            if keep:
                cands = keep
        scores = self.suggestion.rank(cands)
        order = np.argsort(scores, kind="stable")
        seen, out = set(), []
        for i in order:
            t = cands[i].as_tuple()
            if t not in seen:
                seen.add(t)
                out.append(cands[i])
            if len(out) >= k:
                break
        return out

    def observe(self, cfg: HwConfig, area_mm2: float,
                cost: float | None) -> None:
        self.filter_model.add(cfg, area_mm2)
        if cost is not None:
            self.suggestion.add(cfg, cost)

    def fit(self) -> dict:
        return {"filter": self.filter_model.fit(),
                "dkl": self.suggestion.fit()}
