"""2-D mesh NoC model: XY dimension-order routing + per-link load accounting.

The data-transfer latency of a scheduled communication pattern is set by the
most-loaded link (paper Eq. 4); energy is 1.1 pJ/bit/hop (Sec. VIII-B).
Nodes are flat indices ``r * cols + c``; links are directed.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

import numpy as np


@dataclass(frozen=True)
class MeshNoc:
    rows: int
    cols: int

    def node(self, r: int, c: int) -> int:
        return r * self.cols + c

    def coord(self, n: int) -> tuple[int, int]:
        return divmod(n, self.cols)

    @property
    def n_nodes(self) -> int:
        return self.rows * self.cols

    def n_links(self) -> int:
        # directed horizontal + vertical mesh links
        return 2 * (self.rows * (self.cols - 1) + self.cols * (self.rows - 1))

    @lru_cache(maxsize=64)
    def _link_index(self) -> dict[tuple[int, int], int]:
        idx: dict[tuple[int, int], int] = {}
        for r in range(self.rows):
            for c in range(self.cols):
                n = self.node(r, c)
                if c + 1 < self.cols:
                    idx[(n, self.node(r, c + 1))] = len(idx)
                    idx[(self.node(r, c + 1), n)] = len(idx)
                if r + 1 < self.rows:
                    idx[(n, self.node(r + 1, c))] = len(idx)
                    idx[(self.node(r + 1, c), n)] = len(idx)
        return idx

    @lru_cache(maxsize=65536)
    def route(self, src: int, dst: int) -> tuple[int, ...]:
        """XY dimension-order route: along the row (X) first, then column (Y)."""
        (sr, sc), (dr, dc) = self.coord(src), self.coord(dst)
        idx = self._link_index()
        links = []
        r, c = sr, sc
        step = 1 if dc > sc else -1
        while c != dc:
            links.append(idx[(self.node(r, c), self.node(r, c + step))])
            c += step
        step = 1 if dr > sr else -1
        while r != dr:
            links.append(idx[(self.node(r, c), self.node(r + step, c))])
            r += step
        return tuple(links)

    def hops(self, src: int, dst: int) -> int:
        (sr, sc), (dr, dc) = self.coord(src), self.coord(dst)
        return abs(sr - dr) + abs(sc - dc)

    @lru_cache(maxsize=64)
    def route_incidence(self, nodes: tuple[int, ...]
                        ) -> dict[tuple[int, int], np.ndarray]:
        """Per-pair XY-route link indices for every ordered pair of ``nodes``.

        The precomputed (sparse — XY routes touch ~sqrt(n_links) links, so a
        dense [pairs, links] matrix would be ~100x larger) incidence the
        Data-Scheduler's batched 2-opt uses to score candidate moves as load
        delta-updates instead of rebuilding all transfers.
        """
        return {(a, b): np.asarray(self.route(a, b), dtype=np.intp)
                for a in nodes for b in nodes if a != b}

    @lru_cache(maxsize=64)
    def route_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Dense per-pair route arrays ``(route_pad, hops)``.

        ``route_pad[a, b]`` holds the XY-route link indices of ``a -> b``
        padded with the dummy index ``n_links()`` to the mesh's route-length
        bound (rows + cols - 2); ``hops[a, b]`` is the true route length.
        This is the whole-mesh gather form of :meth:`route` that the
        vectorized load accounting below and the engine scheduler's jitted
        2-opt (``engine.scheduler_opt``) index into — accumulating into
        ``n_links() + 1`` bins and dropping the dummy bin replaces the
        per-transfer Python route walk.
        """
        nn = self.n_nodes
        lmax = max(1, self.rows + self.cols - 2)
        pad = np.full((nn, nn, lmax), self.n_links(), dtype=np.int32)
        hops = np.zeros((nn, nn), dtype=np.int32)
        for a in range(nn):
            for b in range(nn):
                if a == b:
                    continue
                r = self.route(a, b)
                pad[a, b, :len(r)] = r
                hops[a, b] = len(r)
        pad.setflags(write=False)
        hops.setflags(write=False)
        return pad, hops

    # -- load accounting -----------------------------------------------------
    def link_loads_np(self, transfers) -> np.ndarray:
        """Bytes per directed link as a float64 array — the primary path.

        One gather of the cached :meth:`route_table` + one ``np.add.at``
        replaces the per-transfer Python route loop; padded route slots
        accumulate into a dummy bin that is dropped.
        """
        loads = np.zeros(self.n_links() + 1)
        if transfers:
            tr = np.asarray(transfers, dtype=np.float64).reshape(-1, 3)
            src = tr[:, 0].astype(np.intp)
            dst = tr[:, 1].astype(np.intp)
            nbytes = tr[:, 2]
            keep = (src != dst) & (nbytes > 0)
            if keep.any():
                idx = self.route_table()[0][src[keep], dst[keep]]
                np.add.at(loads, idx.ravel(),
                          np.broadcast_to(nbytes[keep, None],
                                          idx.shape).ravel())
        return loads[:-1]

    def link_loads(self, transfers: list[tuple[int, int, float]]) -> list[float]:
        """Bytes per directed link for ``(src, dst, nbytes)`` transfers."""
        return self.link_loads_np(transfers).tolist()

    def max_link_load(self, transfers: list[tuple[int, int, float]]) -> float:
        loads = self.link_loads_np(transfers)
        return float(loads.max()) if loads.size else 0.0

    def transfer_latency_s(self, transfers, link_bw_bytes: float,
                           freq_hz: float, router_cycles: int = 2) -> float:
        """Serialization on the hottest link + a hop-latency term."""
        if not transfers:
            return 0.0
        max_load = self.max_link_load(transfers)
        tr = np.asarray(transfers, dtype=np.float64).reshape(-1, 3)
        src = tr[:, 0].astype(np.intp)
        dst = tr[:, 1].astype(np.intp)
        hops = self.route_table()[1][src, dst]
        max_hops = int(hops[tr[:, 2] > 0].max()) if (tr[:, 2] > 0).any() else 0
        return max_load / link_bw_bytes + max_hops * router_cycles / freq_hz

    def transfer_energy_pj(self, transfers, pj_per_bit_hop: float) -> float:
        if not transfers:
            return 0.0
        tr = np.asarray(transfers, dtype=np.float64).reshape(-1, 3)
        src = tr[:, 0].astype(np.intp)
        dst = tr[:, 1].astype(np.intp)
        hops = self.route_table()[1][src, dst]
        return float((tr[:, 2] * 8 * hops).sum() * pj_per_bit_hop)
