"""Moonshot-v1-16B-A3B (Moonlight): MoE decoder, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (kv=16)
d_ff=1408 (per expert) vocab=163840, MoE 64e top-6.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    moe_experts=64,
    moe_top_k=6,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
