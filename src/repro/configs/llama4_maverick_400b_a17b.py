"""Llama-4-Maverick-400B-A17B: MoE decoder, 128 experts top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    moe_experts=128,
    moe_top_k=1,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
