"""RWKV6 (Finch) 1.6B: attention-free linear-recurrence mixer with
data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
Head dim 64 -> 32 wkv heads.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    head_dim=64,
    attn_free=True,
    source="arXiv:2404.05892; unverified",
)
