"""StableLM-3B: dense MHA decoder (kv heads == heads).

[hf:stabilityai/stablelm-2-1_6b; unverified]  32L d_model=2560 32H (kv=32)
d_ff=6912 vocab=50304.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
