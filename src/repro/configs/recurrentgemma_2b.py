"""RecurrentGemma-2B: RG-LRU recurrent blocks + local attention, 1:2 pattern.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Two recurrent (RG-LRU) blocks per local-attention block; window 2048.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rglru_pattern=2,        # 2 recurrent : 1 local-attention
    local_window=2048,
    conv1d_width=4,
    tie_embeddings=True,
    source="arXiv:2402.19427; hf",
)
