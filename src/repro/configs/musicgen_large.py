"""MusicGen-large: decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend is a STUB: ``input_specs`` supplies precomputed frame
embeddings; the backbone is the assignment's transformer.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    frontend="audio",
    frontend_seq=1024,
    source="arXiv:2306.05284; hf",
)
