"""Pixtral-12B: Pixtral-ViT frontend (STUB) + Mistral-Nemo-style backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072.  ``input_specs`` supplies precomputed patch
embeddings for the vision prefix.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1000000.0,
    frontend="vision",
    frontend_seq=1024,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)
