"""Architecture configuration schema, shape specs and the config registry.

Every assigned architecture provides one module ``repro/configs/<id>.py``
exposing ``CONFIG: ArchConfig`` built from the public-literature numbers in
the task brief.  ``ArchConfig.reduced()`` yields the shrunken same-family
config used by CPU smoke tests; the full config is exercised only via the
AOT dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import importlib
import math
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"
    needs_subquadratic: bool = False


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode",
                           needs_subquadratic=True),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "einsum"          # einsum (Mesh-TF) | scatter
    # token mixer variants
    attn_free: bool = False           # rwkv6: no attention at all
    rglru_pattern: int = 0            # recurrentgemma: N recurrent per 1 attn
    local_window: int = 0             # sliding-window attention size
    conv1d_width: int = 4             # temporal conv in recurrent blocks
    # modality frontend stub ("none" | "audio" | "vision")
    frontend: str = "none"
    frontend_seq: int = 1024          # patch/frame positions for vlm/audio
    # numerics / structure
    dtype: str = "bfloat16"
    remat: str = "none"               # none | block
    scan_layers: bool = True
    attention_impl: str = "xla"       # xla | pallas
    # citation tag from the assignment table
    source: str = ""

    @property
    def d_head(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def is_subquadratic(self) -> bool:
        return self.attn_free or (self.rglru_pattern > 0 and
                                  self.local_window > 0)

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads * dh) + 2 * d * self.kv_dim \
            + (self.n_heads * dh) * d
        if self.attn_free:  # rwkv6 time/channel mix projections
            attn = 4 * d * d + d * d // 2
        if self.rglru_pattern > 0:
            # mix of recurrent blocks and local-attention blocks
            rec = 2 * d * d + 3 * d * d // 4
            n_attn = self.n_layers // (self.rglru_pattern + 1)
            n_rec = self.n_layers - n_attn
            blocks = n_rec * rec + n_attn * attn
        else:
            blocks = self.n_layers * attn
        if self.moe_experts > 1:
            ffn = self.moe_experts * 3 * d * self.d_ff + d * self.moe_experts
        else:
            ffn = 3 * d * self.d_ff
        blocks += self.n_layers * ffn + self.n_layers * 2 * d
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return blocks + embed + d

    @property
    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of the experts)."""
        if self.moe_experts <= 1:
            return self.param_count
        d = self.d_model
        inactive = (self.moe_experts - self.moe_top_k) * 3 * d * self.d_ff \
            * self.n_layers
        return self.param_count - inactive

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        return replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            d_ff=256,
            head_dim=32,
            vocab=512,
            moe_experts=min(self.moe_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            local_window=min(self.local_window, 64) or self.local_window,
            frontend_seq=16,
            scan_layers=self.scan_layers,
        )


ARCH_IDS = [
    "recurrentgemma_2b",
    "qwen2_1_5b",
    "mistral_nemo_12b",
    "stablelm_3b",
    "qwen2_0_5b",
    "musicgen_large",
    "pixtral_12b",
    "rwkv6_1_6b",
    "llama4_maverick_400b_a17b",
    "moonshot_v1_16b_a3b",
]


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, skipping long_500k for quadratic
    archs (documented in DESIGN.md §Arch-applicability)."""
    cells = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s, spec in SHAPES.items():
            if spec.needs_subquadratic and not cfg.is_subquadratic:
                continue
            cells.append((a, s))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s, spec in SHAPES.items():
            if spec.needs_subquadratic and not cfg.is_subquadratic:
                out.append((a, s, "pure full attention; 500k-ctx decode "
                                  "requires sub-quadratic mixer"))
    return out
