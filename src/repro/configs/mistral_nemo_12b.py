"""Mistral-Nemo-12B: dense GQA decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072, head_dim=128.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1000000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407; hf",
)
