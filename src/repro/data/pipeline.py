"""Deterministic sharded data pipeline.

Two sources behind one iterator interface:

* ``SyntheticLM`` — seeded Zipfian token stream (steps are reproducible
  across restarts and across host counts: sample ``(step, host_shard)``
  addresses a unique, stateless batch — the property the fault-tolerance
  tests rely on);
* ``ByteCorpus`` — byte-level tokenizer over a text file with sequence
  packing (real-data path for the examples).

Batches are ``{"tokens", "targets", "mask"}`` with targets = tokens shifted
inside ``loss_fn`` (targets==tokens here); the loader emits the *host-local*
slice of the global batch (``host_index``/``host_count``), prefetched on a
background thread.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.3

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count


class SyntheticLM:
    """Stateless seeded stream: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_index]))
        z = rng.zipf(c.zipf_a, size=(c.host_batch, c.seq_len))
        toks = (z % (c.vocab - 2)).astype(np.int32) + 1
        return {"tokens": toks, "targets": toks,
                "mask": np.ones_like(toks, np.float32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class ByteCorpus:
    """Byte-level LM over a file with contiguous packing."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        data = np.frombuffer(open(path, "rb").read(), np.uint8)
        self.data = data.astype(np.int32) + 1          # 0 reserved for pad
        assert cfg.vocab >= 257, "byte tokenizer needs vocab >= 257"

    def batch(self, step: int) -> dict:
        c = self.cfg
        n = len(self.data) - c.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_index]))
        starts = rng.integers(0, n, size=c.host_batch)
        toks = np.stack([self.data[s:s + c.seq_len] for s in starts])
        return {"tokens": toks.astype(np.int32),
                "targets": toks.astype(np.int32),
                "mask": np.ones_like(toks, np.float32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of ``depth`` batches."""

    def __init__(self, source, depth: int = 2, start_step: int = 0):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self.q.put(self.source.batch(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
