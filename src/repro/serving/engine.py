"""Serving engine: batched prefill + decode with a managed KV cache.

A deliberately small but real engine: continuous batching over a fixed slot
count, greedy/temperature sampling, per-request state, and the same
``prefill``/``decode_step`` functions the dry-run lowers (so serving numbers
and roofline numbers describe the same HLO).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..nn import transformer as tfm


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


def pad_cache(cache: dict, target_len: int) -> dict:
    """Grow full-attention K/V caches along the time axis (dim 2)."""
    def one(path, x):
        leaf = path[-1].key
        if leaf in ("k", "v") and x.ndim == 5 and x.shape[2] < target_len:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, target_len - x.shape[2])
            return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(one, cache)


class Engine:
    """Batched LM serving over ``slots`` concurrent sequences."""

    def __init__(self, cfg, params, *, slots: int = 8, max_len: int = 512,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.rng = np.random.default_rng(seed)
        self._decode = jax.jit(partial(tfm.decode_step, cfg))
        self._prefill = jax.jit(partial(tfm.prefill, cfg))
        self.cache = tfm.init_cache(cfg, slots, max_len)
        self.pos = 0
        self.active: list[Request | None] = [None] * slots

    # -- batch-aligned serving: all slots share a position counter ---------
    def serve_batch(self, requests: list[Request],
                    max_steps: int | None = None) -> list[Request]:
        """Left-align a batch of same-length prompts, decode greedily."""
        assert len(requests) <= self.slots
        plen = len(requests[0].prompt)
        assert all(len(r.prompt) == plen for r in requests), \
            "serve_batch requires equal-length prompts"
        toks = np.zeros((self.slots, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i] = r.prompt
        last, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        if self.cfg.rglru_pattern == 0 and self.cfg.family != "ssm":
            cache = pad_cache(cache, self.max_len)
        pos = plen
        nxt = self._sample(last, requests)
        for i, r in enumerate(requests):
            r.out_tokens.append(int(nxt[i]))
        steps = max_steps or max(r.max_new_tokens for r in requests)
        for _ in range(steps - 1):
            logits, cache = self._decode(self.params, jnp.asarray(nxt), pos,
                                         cache)
            pos += 1
            nxt = self._sample(logits, requests)
            for i, r in enumerate(requests):
                if not r.done and len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(nxt[i]))
                else:
                    r.done = True
        for r in requests:
            r.done = True
        return requests

    def _sample(self, logits, requests) -> np.ndarray:
        logits = np.asarray(logits, np.float32)
        out = np.zeros(self.slots, np.int32)
        for i in range(min(len(requests), self.slots)):
            t = requests[i].temperature
            if t <= 0:
                out[i] = int(np.argmax(logits[i]))
            else:
                p = np.exp((logits[i] - logits[i].max()) / t)
                p /= p.sum()
                out[i] = int(self.rng.choice(len(p), p=p))
        return out

    def throughput_probe(self, prompt_len: int = 32,
                         new_tokens: int = 16) -> dict:
        """Tokens/s micro-benchmark on synthetic prompts."""
        reqs = [Request(i, list(self.rng.integers(
            0, self.cfg.vocab, prompt_len)), max_new_tokens=new_tokens)
            for i in range(self.slots)]
        t0 = time.time()
        self.serve_batch(reqs)
        dt = time.time() - t0
        total = sum(len(r.out_tokens) for r in reqs)
        return {"tokens": total, "seconds": dt,
                "tok_per_s": total / max(dt, 1e-9)}
