"""Batched DSE evaluation engine.

Turns the scalar per-candidate DSE hot path into a batched, JAX-native
pipeline:

* :mod:`.batch_cost` — vmapped/jitted reimplementation of the analytic
  tiling/DRAM/compute cost that scores ``[configs, part-layers]`` in one
  call (Pallas inner reduction, 1e-6 parity with ``core.costmodel``).
* :mod:`.tuner_train` — the PIM-Tuner's training/scoring engine: whole Adam
  trajectories in one jitted ``lax.scan`` over pow2-bucketed masked data,
  and fused one-dispatch candidate scoring (DKL features, RBF cross-kernel,
  GP mean/var, LCB, in-array area mask; Pallas ``lcb_rows`` reduction).
* :mod:`.scheduler_opt` — the Data-Scheduler's jitted multi-chain 2-opt:
  restarts as parallel chains in one ``lax.scan``, scatter-free flip-cumsum
  move deltas, and pow2-bucketed multi-problem ``schedule_many`` batching
  (Pallas ``delta_maxload_rows`` scoring on TPU).
* :mod:`.pipeline` — device-resident DSE iteration pipeline: the tuner's
  fused propose chained into in-array top-k selection with one host sync
  per proposal, deferred model fits, and cross-config scheduler prefill.
* :mod:`.overlap` — the overlapped wave executor: async paired-cost
  dispatch (device latency rows as futures) plus the FIFO generator
  interleaver that runs one wave's scheduling/accounting while the next
  wave's candidate costs are in flight, bitwise-identical to serial.
* :mod:`.pareto` — streaming latency/energy/area Pareto-frontier tracker.
* :mod:`.cache` — content-addressed memoization of mapper/scheduler results
  keyed by (HwConfig, DnnGraph) digests; :class:`PersistentEvalCache` backs
  the table with a multi-process-safe sqlite store.
* :mod:`.campaign` — multi-strategy, multi-workload DSE campaigns with JSON
  checkpoint/resume.
* :mod:`.sharded` — the mega-campaign runner: many tenant DSE streams with
  candidate rows sharded over a ``config`` device mesh, async wave overlap,
  and the shared persistent cache.
"""

from .batch_cost import (BatchCostResult, PartSpec, batch_area_mm2,
                         batch_max_link_load, batch_part_cost)
from .cache import (EvalCache, PersistentEvalCache, cons_digest,
                    graph_digest, hw_digest)
from .jit_registry import register_jit, register_jits
from .overlap import (OverlapExecutor, PendingPairedCost,
                      dispatch_paired_latency, serial_dispatch)
from .pareto import ParetoFront, ParetoPoint
from .scheduler_opt import schedule_many
from .tuner_train import (compiled_program_count, fit_dkl, fit_filter,
                          pad_dataset, pow2_bucket, score_candidates,
                          score_candidates_raw)
from .campaign import Campaign, CampaignResult
from .pipeline import DsePipeline
from .sharded import (ShardedCampaign, ShardedProposer, TenantSpec,
                      campaign_mesh, shard_config_rows)


def engine_program_counts() -> dict[str, int]:
    """XLA cache sizes of every registered jit object, across all engine
    modules (``module.name`` keys; process-global — diff around a run).

    The per-module ``_JITTED`` dicts are the registry the static-analysis
    pass (``python -m repro.analysis``, rule PIM002) enforces: an engine
    jit object outside them is invisible here and to the program-count CI
    contract.  :func:`compiled_program_count` keeps its historical
    tuner-only view; this is the whole-engine superset.
    """
    from . import (batch_cost, overlap, pipeline, scheduler_opt, sharded,
                   tuner_train)
    out: dict[str, int] = {}
    for mod in (batch_cost, overlap, pipeline, scheduler_opt, sharded,
                tuner_train):
        label = mod.__name__.rsplit(".", 1)[-1]
        for name, fn in mod._JITTED.items():
            try:
                out[f"{label}.{name}"] = int(fn._cache_size())
            except Exception:   # cache introspection is best-effort per jax
                out[f"{label}.{name}"] = -1
    return out


__all__ = [
    "BatchCostResult", "PartSpec", "batch_area_mm2", "batch_max_link_load",
    "batch_part_cost", "DsePipeline", "EvalCache", "OverlapExecutor",
    "PendingPairedCost", "PersistentEvalCache",
    "cons_digest", "dispatch_paired_latency",
    "graph_digest", "hw_digest", "ParetoFront", "ParetoPoint", "Campaign",
    "CampaignResult", "ShardedCampaign", "ShardedProposer", "TenantSpec",
    "campaign_mesh", "compiled_program_count", "engine_program_counts",
    "fit_dkl", "fit_filter",
    "pad_dataset", "pow2_bucket", "register_jit", "register_jits",
    "schedule_many", "score_candidates",
    "score_candidates_raw", "serial_dispatch", "shard_config_rows",
]
