"""Batched (vmapped/jitted) reimplementation of ``core.costmodel``.

``batch_part_cost`` scores a ``[N configs] x [L part-layers]`` grid through
the analytic tiling/DRAM/compute model in one JAX call instead of ``N * L``
scalar Python calls.  The computation mirrors ``costmodel.part_layer_cost``
operation-for-operation in float64 (``jax.experimental.enable_x64``), so the
batched result matches the scalar reference within 1e-6 relative tolerance —
including the chosen tiling and loop order — which the engine tests enforce.

Host-side preprocessing builds, per part-layer, the same power-of-two tiling
candidate grid the scalar model searches (padded to a common ``T`` with a
validity mask); the per-candidate ``max(compute, dram)`` bottleneck and the
first-argmin over candidates run in the Pallas kernel
``kernels.dse_eval.tile_select`` (``interpret=True`` off-TPU).

Batch axes:
  * configs vary ``pea_row/pea_col``, the three buffer sizes, and the
    DRAM port geometry (``burst_words`` / ``row_words``) — everything a
    Fig. 9 sweep explores;
  * part-layers vary the full conv loop nest plus the in/out
    :class:`~repro.core.layout.DataLayout`.

All configs in one batch must share the same :class:`PimConstraints`
(true for any single DSE campaign).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core.costmodel import (MAC_ENERGY_PJ, PartCost, _sram_pj_per_bit,
                              _tile_candidates)
from ..core.hardware import HwConfig
from ..core.ir import Layer
from ..core.layout import DataLayout
from ..kernels import dse_eval
from ..obs.trace import traced

INF = float("inf")


@dataclass(frozen=True)
class PartSpec:
    """One row of the layer axis: a part-layer plus its DRAM layouts."""

    layer: Layer
    dl_in: DataLayout
    dl_out: DataLayout


# ---------------------------------------------------------------------------
# Host-side preprocessing
# ---------------------------------------------------------------------------


@lru_cache(maxsize=4096)
def _candidate_grid(layer: Layer):
    """The exact candidate tiling grid of ``part_layer_cost`` (same order).

    Cached (layers repeat massively across mapper candidate sweeps); callers
    treat the returned array as read-only.
    """
    tks = np.array(_tile_candidates(layer.K), dtype=np.int64)
    tcs = np.array(_tile_candidates(layer.C), dtype=np.int64)
    tps = np.array(_tile_candidates(layer.P), dtype=np.int64)
    tqs = np.array([layer.Q], dtype=np.int64) if layer.Q <= 64 else \
        np.array(_tile_candidates(layer.Q, cap=4), dtype=np.int64)
    tbs = np.array(_tile_candidates(layer.B, cap=4), dtype=np.int64)
    tb, tk, tc, tp, tq = [a.reshape(-1) for a in
                          np.meshgrid(tbs, tks, tcs, tps, tqs, indexing="ij")]
    return np.stack([tb, tk, tc, tp, tq], axis=0)  # [5, T_l]


def _dl_fields(dl: DataLayout, channels: int) -> tuple[bool, int, int]:
    """(is_bhwc, effective group, alignment) for a fmap with ``channels``."""
    if dl.order == "BHWC":
        return True, channels, channels
    g = min(max(1, dl.group), channels)
    return False, g, g


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


_INT_KEYS = ("B", "C", "H", "W", "K", "HK", "WK", "stride", "P", "Q",
             "in_g", "in_align", "out_g", "out_align")
_FLAG_KEYS = ("heavy", "in_bhwc", "out_bhwc")
_FLOAT_KEYS = ("macs", "w_vals", "i_vals", "o_vals")


@lru_cache(maxsize=65536)
def _spec_static(layer: Layer):
    """The DL-independent row of one part-layer (mapper sweeps repeat them)."""
    g = _candidate_grid(layer)
    tb, tk, tc, tp, tq = g
    th = (tp - 1) * layer.stride + layer.HK
    tw = (tq - 1) * layer.stride + layer.WK
    ints = tuple(getattr(layer, k) for k in _INT_KEYS[:10])
    floats = (float(layer.macs), float(layer.weight_count),
              float(layer.B * layer.C * layer.H * layer.W),
              float(layer.B * layer.K * layer.P * layer.Q))
    return g, int(np.argmin(tb * tc * th * tw)), ints, layer.is_heavy, floats


def _prep_specs(specs: Sequence[PartSpec], *, t_pad: int | None = None):
    """Pack L part-layer specs into padded numpy arrays.

    ``t_pad`` fixes the candidate axis to a caller-chosen bucket width
    (padding is masked invalid) so spec-chunked callers compile one XLA
    program per ``(L, T-bucket)`` pair instead of one per distinct
    tiling-grid size.
    """
    statics = [_spec_static(s.layer) for s in specs]
    t_max = max(st[0].shape[1] for st in statics)
    if t_pad is not None:
        assert t_pad >= t_max, "t_pad below the largest candidate grid"
        t_max = t_pad
    L = len(specs)
    tiles = np.ones((5, L, t_max), dtype=np.int64)
    valid = np.zeros((L, t_max), dtype=bool)
    int_rows, flag_rows, float_rows, fallback = [], [], [], []
    for i, (s, (g, fb, ints, heavy, floats)) in enumerate(zip(specs, statics)):
        t = g.shape[1]
        tiles[:, i, :t] = g
        valid[i, :t] = True
        fallback.append(fb)
        in_bhwc, gi, ali = _dl_fields(s.dl_in, s.layer.C)
        out_bhwc, go, alo = _dl_fields(s.dl_out, s.layer.K)
        int_rows.append(ints + (gi, ali, go, alo))
        flag_rows.append((heavy, in_bhwc, out_bhwc))
        float_rows.append(floats)
    int_arr = np.array(int_rows, dtype=np.int64)
    flag_arr = np.array(flag_rows, dtype=bool)
    float_arr = np.array(float_rows, dtype=np.float64)
    ints = {k: np.ascontiguousarray(int_arr[:, j])
            for j, k in enumerate(_INT_KEYS)}
    flags = {k: np.ascontiguousarray(flag_arr[:, j])
             for j, k in enumerate(_FLAG_KEYS)}
    floats = {k: np.ascontiguousarray(float_arr[:, j])
              for j, k in enumerate(_FLOAT_KEYS)}
    return {"tiles": tiles, "valid": valid,
            "fallback": np.array(fallback, dtype=np.int64),
            **ints, **flags, **floats}


def _prep_configs(configs: Sequence[HwConfig]):
    cons = configs[0].cons
    # dedupe first: paired pair-lists repeat each config once per spec, so
    # the per-config field extraction must not scale with the pair count
    uniq: dict[HwConfig, int] = {}
    idx = np.empty(len(configs), dtype=np.intp)
    for i, c in enumerate(configs):
        j = uniq.get(c)
        if j is None:
            if c.cons != cons:
                raise ValueError(
                    "all configs in a batch must share PimConstraints")
            j = uniq[c] = len(uniq)
        idx[i] = j
    n = len(uniq)
    out = {k: np.zeros(n, dtype=np.int64) for k in
           ("pea_row", "pea_col", "ibuf_kib", "wbuf_kib", "obuf_kib",
            "burst_words", "row_words", "width_bits")}
    sram = {k: np.zeros(n, dtype=np.float64) for k in
            ("sram_i", "sram_w", "sram_o")}
    dbytes = cons.data_bits // 8
    for c, i in uniq.items():
        out["pea_row"][i] = c.pea_row
        out["pea_col"][i] = c.pea_col
        out["ibuf_kib"][i] = c.ibuf_kib
        out["wbuf_kib"][i] = c.wbuf_kib
        out["obuf_kib"][i] = c.obuf_kib
        bw = max(1, c.node_dram_width_bits // cons.data_bits)
        out["burst_words"][i] = bw
        out["row_words"][i] = max(
            bw, cons.dram_row_bytes * c.banks_per_node // dbytes)
        out["width_bits"][i] = c.node_dram_width_bits
        sram["sram_i"][i] = _sram_pj_per_bit(c.ibuf_kib)
        sram["sram_w"][i] = _sram_pj_per_bit(c.wbuf_kib)
        sram["sram_o"][i] = _sram_pj_per_bit(c.obuf_kib)
    gathered = {k: v[idx] for k, v in {**out, **sram}.items()}
    return gathered, cons


# ---------------------------------------------------------------------------
# The jitted [N, L, T] cost pipeline
# ---------------------------------------------------------------------------


def _mean_bursts(run, align, burst):
    """JAX port of ``layout.mean_bursts`` (closed form, identical math)."""
    g = jnp.gcd(jnp.maximum(align, 1), burst)
    m = (burst // g).astype(run.dtype)
    burst_f = burst.astype(run.dtype)
    g_f = g.astype(run.dtype)
    q = jnp.ceil(run / burst_f) - 1.0
    r = run - q * burst_f
    over = m - 1.0 - jnp.floor((burst_f - r) / g_f)
    return q + 1.0 + over / m


def _access_cost(fmap, tb, tc, th, tw, is_bhwc, group, align,
                 burst, row_words):
    """JAX port of ``layout.tile_cost_vec`` covering both orders via select.

    ``fmap`` is ``(B, C, H, W)`` as f64 arrays broadcastable against the tile
    arrays; ``is_bhwc/group/align`` are per-layer, ``burst/row_words`` per
    config.
    """
    B, C, H, W = fmap
    tb = jnp.minimum(tb, B)
    tc = jnp.minimum(tc, C)
    th = jnp.minimum(th, H)
    tw = jnp.minimum(tw, W)
    full_w = tw >= W
    full_h = th >= H
    full_c = tc >= C

    # ---- BHWC: linear index ((b*H + h)*W + w)*C + c ------------------------
    run_p = jnp.where(full_c, tw * C, tc)
    nruns_p = jnp.where(full_c, tb * th, tb * th * tw)
    run_p = jnp.where(full_c & full_w, th * W * C, run_p)
    nruns_p = jnp.where(full_c & full_w, tb, nruns_p)
    whole_p = full_c & full_w & full_h
    run_p = jnp.where(whole_p, tb * H * W * C, run_p)
    nruns_p = jnp.where(whole_p, 1.0, nruns_p)
    span_p = jnp.where(whole_p, tb * H * W * C, ((th - 1) * W + tw) * C)
    next_p = jnp.where(whole_p, 1.0, tb)

    # ---- BCHW[Cg]: linear index (((b*(C/g) + cg)*H + h)*W + w)*g + c -------
    g = group
    c_groups = jnp.ceil(tc / g)
    run_c = tw * g * jnp.ones_like(tc)
    nruns_c = tb * c_groups * th
    run_c = jnp.where(full_w, tw * g * th, run_c)
    nruns_c = jnp.where(full_w, tb * c_groups, nruns_c)
    plane = full_w & full_h
    run_c = jnp.where(plane, H * W * g * c_groups, run_c)
    nruns_c = jnp.where(plane, tb, nruns_c)
    whole = plane & full_c
    run_c = jnp.where(whole, tb * C * H * W, run_c)
    nruns_c = jnp.where(whole, 1.0, nruns_c)
    span_c = jnp.where(plane, run_c, ((th - 1) * W + tw) * g)
    next_c = jnp.where(plane, nruns_c, tb * c_groups)

    run = jnp.where(is_bhwc, run_p, run_c)
    n_runs = jnp.where(is_bhwc, nruns_p, nruns_c)
    span = jnp.where(is_bhwc, span_p, span_c)
    n_extents = jnp.where(is_bhwc, next_p, next_c)

    bursts = n_runs * _mean_bursts(run, align, burst)
    rows = n_extents * jnp.maximum(1.0, span / row_words)
    return bursts, rows


@partial(jax.jit, static_argnames=("data_bits", "psum_bits", "dram_row_miss",
                                   "interpret", "paired"))
def _batch_cost(cfg, lay, *, data_bits: int, psum_bits: int,
                dram_row_miss: int, interpret: bool, paired: bool = False):
    """Score every (config, part-layer, candidate-tiling) point.

    ``cfg`` arrays are [N], ``lay`` per-layer arrays [L] and tile arrays
    [5, L, T].  Returns per-(config, layer) selections, all [N, L].

    ``paired=True`` aligns the config axis WITH the layer axis (``cfg``
    arrays are [L], one config per part-layer): the result is the [1, L]
    diagonal of the grid, costing exactly the requested pairs instead of the
    full cross product — the multi-config mapper sweep, where every config
    brings its own mostly-disjoint spec set.
    """
    f64 = jnp.float64

    def c3(name):  # config axis -> [N, 1, 1]; paired: [1, L, 1]
        v = cfg[name]
        return v[None, :, None] if paired else v[:, None, None]

    def c2(name):  # config axis -> [N, 1]; paired: [1, L]
        v = cfg[name]
        return v[None, :] if paired else v[:, None]

    def l3(name):  # layer axis -> [1, L, 1]
        return lay[name][None, :, None]

    dbytes = data_bits // 8
    pbytes = psum_bits // 8

    TB, TK, TC, TP, TQ = [lay["tiles"][i][None] for i in range(5)]  # [1,L,T]
    stride, HK, WK = l3("stride"), l3("HK"), l3("WK")
    TH = (TP - 1) * stride + HK
    TW = (TQ - 1) * stride + WK

    # ---- capacity filter (int64, exactly as the scalar model) --------------
    fits = ((TB * TC * TH * TW * dbytes * 2 <= c3("ibuf_kib") * 1024)
            & (TK * TC * HK * WK * dbytes * 2 <= c3("wbuf_kib") * 1024)
            & (TB * TK * TP * TQ * pbytes <= c3("obuf_kib") * 1024))
    eligible = fits & lay["valid"][None]
    any_fit = eligible.any(axis=-1, keepdims=True)
    t = TB.shape[-1]
    onehot = (jnp.arange(t)[None, None, :] == l3("fallback"))
    mask = jnp.where(any_fit, eligible, onehot)

    # ---- float views -------------------------------------------------------
    TBf, TKf, TCf = TB.astype(f64), TK.astype(f64), TC.astype(f64)
    TPf, TQf = TP.astype(f64), TQ.astype(f64)
    THf, TWf = TH.astype(f64), TW.astype(f64)
    B, C, H, W = [l3(k).astype(f64) for k in ("B", "C", "H", "W")]
    K, P, Q = [l3(k).astype(f64) for k in ("K", "P", "Q")]
    HKf, WKf = HK.astype(f64), WK.astype(f64)

    n_k = jnp.ceil(K / TKf)
    n_c = jnp.ceil(C / TCf)
    n_bpq = jnp.ceil(B / TBf) * jnp.ceil(P / TPf) * jnp.ceil(Q / TQf)
    n_tiles_i = jnp.ceil(B / TBf) * n_c * jnp.ceil(P / TPf) * jnp.ceil(Q / TQf)
    n_tiles_o = jnp.ceil(B / TBf) * n_k * jnp.ceil(P / TPf) * jnp.ceil(Q / TQf)

    # ---- compute cycles ----------------------------------------------------
    pea_row = c3("pea_row").astype(f64)
    pea_col = c3("pea_col").astype(f64)
    cyc_tile = (jnp.ceil(TCf / pea_row) * jnp.ceil(TKf / pea_col)
                * HKf * WKf * TPf * TQf * TBf)
    compute_cycles = cyc_tile * n_k * n_c * n_bpq

    # ---- DRAM traffic under the two loop orders ----------------------------
    burst = c3("burst_words")
    row_words = c3("row_words").astype(f64)
    ib, ir = _access_cost((B, C, H, W), TBf, TCf, THf, TWf,
                          l3("in_bhwc"), l3("in_g").astype(f64),
                          l3("in_align"), burst, row_words)
    ob, orow = _access_cost((B, K, P, Q), TBf, TKf, TPf, TQf,
                            l3("out_bhwc"), l3("out_g").astype(f64),
                            l3("out_align"), burst, row_words)
    w_vals = l3("w_vals")
    w_bursts = jnp.ceil(w_vals / burst.astype(f64))
    w_rows = jnp.maximum(1.0, w_vals / row_words)

    all_w_fit = (l3("K") * l3("C") * HK * WK * dbytes * 2
                 <= c3("wbuf_kib") * 1024)
    all_i_fit = (l3("B") * l3("C") * l3("H") * l3("W") * dbytes * 2
                 <= c3("ibuf_kib") * 1024)
    i_passes_ko = jnp.where(all_i_fit, 1.0, n_k)
    i_passes_bo = jnp.ones_like(n_k)
    w_passes_ko = jnp.ones_like(n_bpq)
    w_passes_bo = jnp.where(all_w_fit, 1.0, n_bpq)

    i_vals, o_vals = l3("i_vals"), l3("o_vals")

    def dram_terms(i_passes, w_passes):
        bursts = (ib * n_tiles_i * i_passes + w_bursts * w_passes
                  + ob * n_tiles_o)
        rows = (ir * n_tiles_i * i_passes + w_rows * w_passes
                + orow * n_tiles_o)
        values = i_vals * i_passes + w_vals * w_passes + o_vals
        return bursts, rows, values

    b_ko, r_ko, v_ko = dram_terms(i_passes_ko, w_passes_ko)
    b_bo, r_bo, v_bo = dram_terms(i_passes_bo, w_passes_bo)
    dram_cycles_ko = b_ko + r_ko * dram_row_miss
    dram_cycles_bo = b_bo + r_bo * dram_row_miss
    use_bo = dram_cycles_bo < dram_cycles_ko
    dram_cycles = jnp.where(use_bo, dram_cycles_bo, dram_cycles_ko)
    bursts = jnp.where(use_bo, b_bo, b_ko)
    rows = jnp.where(use_bo, r_bo, r_ko)
    values = jnp.where(use_bo, v_bo, v_ko)

    # ---- Pallas inner reduction: bottleneck + first-argmin -----------------
    n, l_dim = compute_cycles.shape[0], compute_cycles.shape[1]
    shape3 = (n, l_dim, t)
    # one grid step: in interpret mode the row-block loop runs sequentially,
    # so a full-batch block keeps the reduction a single vectorized op
    total_flat, best_flat = dse_eval.tile_select(
        jnp.broadcast_to(compute_cycles, shape3).reshape(n * l_dim, t),
        jnp.broadcast_to(dram_cycles, shape3).reshape(n * l_dim, t),
        jnp.broadcast_to(mask, shape3).reshape(n * l_dim, t),
        block_r=n * l_dim, interpret=interpret)
    total = total_flat.reshape(n, l_dim)
    best = best_flat.reshape(n, l_dim)

    def pick(arr):
        full = jnp.broadcast_to(arr, shape3)
        return jnp.take_along_axis(full, best[:, :, None], axis=-1)[:, :, 0]

    def pick_tile(arr):  # config-independent [1, L, T]: cheap [L, T] gather
        return arr[0][jnp.arange(l_dim)[None, :], best]

    tb_, tk_, tc_ = pick_tile(TB), pick_tile(TK), pick_tile(TC)
    tp_, tq_ = pick_tile(TP), pick_tile(TQ)
    compute_best = pick(compute_cycles)
    dram_best = pick(dram_cycles)
    bursts_best = pick(bursts)
    rows_best = pick(rows)
    values_best = pick(values)
    use_bo_best = pick(use_bo)

    # ---- energies at the chosen tiling -------------------------------------
    macs = lay["macs"][None, :]
    e_mac = macs * MAC_ENERGY_PJ
    pea_row2 = c2("pea_row")
    pea_col2 = c2("pea_col")
    ibuf_reads = macs / jnp.maximum(1, jnp.minimum(tk_, pea_col2)).astype(f64)
    wbuf_reads = macs / jnp.maximum(1, tb_ * tp_ * tq_).astype(f64)
    obuf_acc = 2.0 * macs / jnp.maximum(
        1, jnp.minimum(tc_, pea_row2)).astype(f64)
    e_sram = (ibuf_reads * data_bits * c2("sram_i")
              + wbuf_reads * data_bits * c2("sram_w")
              + obuf_acc * psum_bits * c2("sram_o"))

    width_bits = c2("width_bits").astype(f64)
    moved_bits = bursts_best * width_bits
    useful_bits = values_best * data_bits
    heavy = lay["heavy"][None, :]

    out = {
        "total_cycles": total,
        "compute_cycles": compute_best,
        "dram_cycles": dram_best,
        "dram_values": values_best,
        "rows": rows_best,
        "moved_bits": moved_bits,
        "useful_bits": useful_bits,
        "e_mac": e_mac,
        "e_sram": e_sram,
        "use_bo": use_bo_best,
        "tb": tb_, "tk": tk_, "tc": tc_, "tp": tp_, "tq": tq_,
    }
    zero = jnp.zeros_like(total)
    for k in ("total_cycles", "compute_cycles", "dram_cycles", "dram_values",
              "rows", "moved_bits", "useful_bits", "e_mac", "e_sram"):
        out[k] = jnp.where(heavy, out[k], zero)
    for k in ("tb", "tk", "tc", "tp", "tq"):
        out[k] = jnp.where(heavy, out[k], 1)
    out["use_bo"] = jnp.where(heavy, out["use_bo"], False)
    return out


#: module-level jit objects, keyed for ``compiled_program_count``-style
#: introspection (see :func:`repro.engine.engine_program_counts`)
_JITTED = {
    "batch_cost": _batch_cost,
}


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


# array fields of BatchCostResult, in merge order — shared by the grid
# (batch_part_cost) and paired (batch_part_cost_paired) block/bucket
# merge scaffolding so the two paths cannot drift apart
_RESULT_FIELDS = ("latency_s", "energy_pj", "compute_s", "dram_s",
                  "dram_bytes", "e_mac_pj", "e_sram_pj", "e_dram_pj",
                  "tiling", "use_bpq_outer")


@dataclass
class BatchCostResult:
    """Per-(config, part-layer) costs; every array is ``[N, L]``."""

    configs: list[HwConfig]
    specs: list[PartSpec]
    latency_s: np.ndarray
    energy_pj: np.ndarray
    compute_s: np.ndarray
    dram_s: np.ndarray
    dram_bytes: np.ndarray
    e_mac_pj: np.ndarray
    e_sram_pj: np.ndarray
    e_dram_pj: np.ndarray
    tiling: np.ndarray           # [N, L, 5] int
    use_bpq_outer: np.ndarray    # [N, L] bool

    def part_cost(self, i: int, j: int) -> PartCost:
        """Reconstruct the scalar :class:`PartCost` view of one cell."""
        return PartCost(
            latency_s=float(self.latency_s[i, j]),
            energy_pj=float(self.energy_pj[i, j]),
            compute_s=float(self.compute_s[i, j]),
            dram_s=float(self.dram_s[i, j]),
            dram_bytes=float(self.dram_bytes[i, j]),
            e_mac_pj=float(self.e_mac_pj[i, j]),
            e_sram_pj=float(self.e_sram_pj[i, j]),
            e_dram_pj=float(self.e_dram_pj[i, j]),
            tiling=tuple(int(v) for v in self.tiling[i, j]),
            loop_order="BPQ_outer" if self.use_bpq_outer[i, j] else "K_outer",
        )


@traced("batch_cost", argspec=lambda configs, specs, **kw:
        {"configs": len(configs), "specs": len(specs)})
def batch_part_cost(configs: Sequence[HwConfig],
                    specs: Sequence[PartSpec | tuple],
                    *, chunk: int = 32, spec_chunk: int | None = None,
                    interpret: bool | None = None) -> BatchCostResult:
    """Score ``[len(configs), len(specs)]`` part-layer costs in one pipeline.

    ``chunk`` bounds the config-axis block handed to one jit call (the
    candidate axis is materialized per block, so memory scales with
    ``chunk * L * T``).  Configs are padded to a full final chunk so XLA
    compiles exactly one program per (L, T, chunk) shape.

    ``spec_chunk`` additionally blocks the *spec* axis — the mapper's
    candidate sweeps batch thousands of part-layers against one config, so
    memory must scale with ``spec_chunk * T`` instead of ``L * T``.  Blocks
    are padded to a full ``spec_chunk`` (repeating the last spec) and the
    candidate axis is bucketed to a power of two, bounding XLA compiles to
    one program per (spec_chunk, T-bucket) pair.
    """
    specs = [s if isinstance(s, PartSpec) else PartSpec(*s) for s in specs]
    if not configs or not specs:
        raise ValueError("need at least one config and one spec")
    fields = _RESULT_FIELDS
    t_pad = None
    if spec_chunk is not None:
        # group by candidate-axis bucket first: a mixed batch otherwise pads
        # every small tiling grid to the largest one in the batch.  The
        # bucket key is per-spec (floor 128: padding tiny grids up is cheaper
        # than another dispatch round-trip), so a spec always lands in the
        # same (spec_chunk, T) program whatever batch it arrives in.
        buckets = {}
        for i, s in enumerate(specs):
            buckets.setdefault(
                _next_pow2(max(128, _candidate_grid(s.layer).shape[1])),
                []).append(i)
        t_pad = max(buckets)
        if len(buckets) > 1:
            merged: dict[str, np.ndarray] = {}
            for tb in sorted(buckets):
                idxs = buckets[tb]
                sub = batch_part_cost(configs, [specs[i] for i in idxs],
                                      chunk=chunk, spec_chunk=spec_chunk,
                                      interpret=interpret)
                for f in fields:
                    v = getattr(sub, f)
                    if f not in merged:
                        merged[f] = np.zeros((v.shape[0], len(specs))
                                             + v.shape[2:], v.dtype)
                    merged[f][:, idxs] = v
            return BatchCostResult(configs=list(configs), specs=specs,
                                   **merged)
    if spec_chunk is not None and len(specs) > spec_chunk:
        blocks = []
        for s in range(0, len(specs), spec_chunk):
            block = specs[s:s + spec_chunk]
            n_real = len(block)
            block = block + [block[-1]] * (spec_chunk - n_real)
            res = batch_part_cost(configs, block, chunk=chunk,
                                  spec_chunk=spec_chunk, interpret=interpret)
            blocks.append((res, n_real))
        merged = {f: np.concatenate([getattr(r, f)[:, :n] for r, n in blocks],
                                    axis=1) for f in fields}
        return BatchCostResult(configs=list(configs), specs=specs, **merged)
    lay_np = _prep_specs(specs, t_pad=t_pad)
    cfg_np, cons = _prep_configs(configs)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    n = len(configs)
    chunk = max(1, min(chunk, n))
    pad = (-n) % chunk
    if pad:
        cfg_np = {k: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                  for k, v in cfg_np.items()}

    outs: dict[str, list[np.ndarray]] = {}
    with enable_x64():
        lay = {k: jnp.asarray(v) for k, v in lay_np.items()}
        for s in range(0, n + pad, chunk):
            cfg = {k: jnp.asarray(v[s:s + chunk]) for k, v in cfg_np.items()}
            res = _batch_cost(cfg, lay, data_bits=cons.data_bits,
                              psum_bits=cons.psum_bits,
                              dram_row_miss=cons.dram_row_miss_cycles,
                              interpret=interpret)
            for k, v in res.items():
                # this per-chunk pull IS the dispatch boundary: chunks must
                # land on host to be concatenated, and each pull overlaps
                # the next chunk's dispatch
                # pimlint: disable-next-line=host-sync -- sanctioned per-chunk boundary pull
                outs.setdefault(k, []).append(np.asarray(v))
    res = {k: np.concatenate(v, axis=0)[:n] for k, v in outs.items()}
    return _finalize_result(res, configs, specs, cons)


def _finalize_result(res: dict, configs, specs, cons) -> BatchCostResult:
    """Host-side energies/units for raw ``_batch_cost`` outputs ([N, L])."""
    freq = cons.freq_hz
    dbytes = cons.data_bits // 8
    e_dram = (np.maximum(res["moved_bits"], res["useful_bits"])
              * cons.dram_energy_pj_per_bit
              + res["rows"] * cons.dram_row_act_energy_pj)
    heavy = np.array([s.layer.is_heavy for s in specs])[None, :]
    e_dram = np.where(heavy, e_dram, 0.0)
    tiling = np.stack([res["tb"], res["tk"], res["tc"], res["tp"], res["tq"]],
                      axis=-1)
    e_mac = np.broadcast_to(res["e_mac"], res["total_cycles"].shape)
    return BatchCostResult(
        configs=list(configs), specs=specs,
        latency_s=res["total_cycles"] / freq,
        energy_pj=e_mac + res["e_sram"] + e_dram,
        compute_s=res["compute_cycles"] / freq,
        dram_s=res["dram_cycles"] / freq,
        dram_bytes=res["dram_values"] * dbytes,
        e_mac_pj=e_mac,
        e_sram_pj=res["e_sram"],
        e_dram_pj=e_dram,
        tiling=tiling,
        use_bpq_outer=res["use_bo"].astype(bool),
    )


@traced("batch_cost", argspec=lambda configs, specs, **kw:
        {"pairs": len(specs), "mode": "paired"})
def batch_part_cost_paired(configs: Sequence[HwConfig],
                           specs: Sequence[PartSpec | tuple],
                           *, spec_chunk: int = 1024,
                           interpret: bool | None = None) -> BatchCostResult:
    """Score aligned ``(config, part-layer)`` PAIRS: cell ``j`` costs
    ``specs[j]`` on ``configs[j]``.

    The multi-config mapper sweep batches many configs whose candidate spec
    sets are mostly disjoint (region shapes follow each config's node-array
    geometry); the ``[N, L]`` grid of :func:`batch_part_cost` would compute —
    and pay for — the full cross product.  Here the config fields ride the
    spec axis instead ([L] arrays broadcast per pair), so compute scales with
    the number of requested pairs, exactly like the per-config calls it
    replaces, while keeping one fused engine dispatch.

    Pair blocks are chunked to ``spec_chunk`` and padded to power-of-two
    lengths (floor 128, repeating the last pair), and the candidate axis is
    bucketed like the spec-chunked grid path, so XLA compiles one program per
    (pair-bucket, T-bucket) shape instead of one per distinct pair count.
    Result arrays are ``[1, L]`` (``res.latency_s[0][j]`` etc.); every config
    must share one :class:`PimConstraints`.  Values match the corresponding
    ``batch_part_cost([cfg], [spec])`` cells exactly — the operations are the
    same elementwise float64 pipeline.
    """
    specs = [s if isinstance(s, PartSpec) else PartSpec(*s) for s in specs]
    configs = list(configs)
    if len(configs) != len(specs):
        raise ValueError("paired costing needs len(configs) == len(specs)")
    if not specs:
        raise ValueError("need at least one (config, spec) pair")
    # same per-spec T-bucket key as batch_part_cost's spec-chunked path: a
    # pair always lands in the same (pair-bucket, T) program whatever batch
    # it arrives in
    buckets: dict[int, list[int]] = {}
    for i, s in enumerate(specs):
        buckets.setdefault(
            _next_pow2(max(128, _candidate_grid(s.layer).shape[1])),
            []).append(i)
    if len(buckets) > 1:
        merged: dict[str, np.ndarray] = {}
        for tb in sorted(buckets):
            idxs = buckets[tb]
            sub = batch_part_cost_paired([configs[i] for i in idxs],
                                         [specs[i] for i in idxs],
                                         spec_chunk=spec_chunk,
                                         interpret=interpret)
            for f in _RESULT_FIELDS:
                v = getattr(sub, f)
                if f not in merged:
                    merged[f] = np.zeros((1, len(specs)) + v.shape[2:],
                                         v.dtype)
                merged[f][:, idxs] = v
        return BatchCostResult(configs=configs, specs=specs, **merged)
    t_pad = max(buckets)
    if len(specs) > spec_chunk:
        blocks = []
        for s in range(0, len(specs), spec_chunk):
            blocks.append(batch_part_cost_paired(
                configs[s:s + spec_chunk], specs[s:s + spec_chunk],
                spec_chunk=spec_chunk, interpret=interpret))
        merged = {f: np.concatenate([getattr(b, f) for b in blocks], axis=1)
                  for f in _RESULT_FIELDS}
        return BatchCostResult(configs=configs, specs=specs, **merged)
    n_real = len(specs)
    n_pad = min(spec_chunk, _next_pow2(max(128, n_real)))
    if n_pad > n_real:  # pow2 pair-bucket: bounded XLA program count
        configs = configs + [configs[-1]] * (n_pad - n_real)
        specs = specs + [specs[-1]] * (n_pad - n_real)
    lay_np = _prep_specs(specs, t_pad=t_pad)
    cfg_np, cons = _prep_configs(configs)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    with enable_x64():
        lay = {k: jnp.asarray(v) for k, v in lay_np.items()}
        cfg = {k: jnp.asarray(v) for k, v in cfg_np.items()}
        res = _batch_cost(cfg, lay, data_bits=cons.data_bits,
                          psum_bits=cons.psum_bits,
                          dram_row_miss=cons.dram_row_miss_cycles,
                          interpret=interpret, paired=True)
    res = {k: np.asarray(v)[:, :n_real] for k, v in res.items()}
    return _finalize_result(res, configs[:n_real], specs[:n_real], cons)


def batch_area_mm2(configs: Sequence[HwConfig]) -> np.ndarray:
    """Vectorized ``HwConfig.area_mm2`` for a whole proposal batch."""
    if not configs:
        return np.zeros(0)
    cons = configs[0].cons
    t = np.array([c.as_tuple() for c in configs], dtype=np.float64)
    na = t[:, 0] * t[:, 1]
    pe = t[:, 2] * t[:, 3] * cons.mac_area_um2 * 1e-6
    buf_mib = (t[:, 4] + t[:, 5] + t[:, 6]) / 1024
    return na * (pe + buf_mib * cons.sram_area_mm2_per_mib
                 + cons.node_fixed_area_mm2)


def batch_max_link_load(loads: np.ndarray, valid: np.ndarray | None = None,
                        *, interpret: bool | None = None) -> np.ndarray:
    """Max-link-load (Eq. 4) for a batch of candidate schedules.

    ``loads`` is ``[S, E]`` — one row per candidate schedule, one column per
    directed mesh link (``MeshNoc.link_loads`` order).  Runs the Pallas
    ``max_rows`` reduction; returns ``[S]``.
    """
    with enable_x64():
        out = dse_eval.max_rows(jnp.asarray(np.asarray(loads, np.float64)),
                                None if valid is None else jnp.asarray(valid),
                                interpret=interpret)
        return np.asarray(out)
