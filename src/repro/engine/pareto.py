"""Streaming latency/energy/area Pareto-frontier tracker.

The DSE cost function (Eq. 1) collapses the objectives into one scalar; a
production exploration system also wants the full trade-off surface.
:class:`ParetoFront` ingests evaluated design points one at a time (any
order) and maintains the set of non-dominated points.  Properties the engine
tests enforce:

* no point in :meth:`front` is dominated by any other;
* the final front is invariant to insertion order (duplicates collapse to
  the first-seen payload);
* every dominated offer is rejected and every rejected offer is dominated.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any

OBJECTIVES = ("latency_s", "energy_pj", "area_mm2")


@dataclass(frozen=True)
class ParetoPoint:
    latency_s: float
    energy_pj: float
    area_mm2: float
    payload: Any = None          # e.g. the HwConfig tuple that scored this

    @property
    def key(self) -> tuple[float, float, float]:
        return (self.latency_s, self.energy_pj, self.area_mm2)

    def dominates(self, other: "ParetoPoint") -> bool:
        """<= in every objective and < in at least one."""
        a, b = self.key, other.key
        return all(x <= y for x, y in zip(a, b)) and a != b


@dataclass
class ParetoFront:
    points: list[ParetoPoint] = field(default_factory=list)
    offered: int = 0
    rejected: int = 0

    def offer(self, point: ParetoPoint) -> bool:
        """Insert if non-dominated; evict points the newcomer dominates.

        Returns True iff the point joined the front.  An exact duplicate of
        a frontier point is rejected (first seen wins), keeping the front a
        set regardless of arrival order.
        """
        self.offered += 1
        for p in self.points:
            if p.dominates(point) or p.key == point.key:
                self.rejected += 1
                return False
        self.points = [p for p in self.points if not point.dominates(p)]
        self.points.append(point)
        return True

    def offer_all(self, points) -> int:
        return sum(self.offer(p) for p in points)

    def front(self) -> list[ParetoPoint]:
        """Frontier sorted by latency (ties by energy then area)."""
        return sorted(self.points, key=lambda p: p.key)

    def __len__(self) -> int:
        return len(self.points)

    def dominated(self, point: ParetoPoint) -> bool:
        return any(p.dominates(point) for p in self.points)

    # -- persistence (campaign checkpoints) ---------------------------------
    def to_jsonable(self) -> list[dict]:
        return [{"latency_s": p.latency_s, "energy_pj": p.energy_pj,
                 "area_mm2": p.area_mm2, "payload": p.payload}
                for p in self.front()]

    @classmethod
    def from_jsonable(cls, rows: list[dict]) -> "ParetoFront":
        fr = cls()
        for r in rows:
            payload = r.get("payload")
            fr.offer(ParetoPoint(r["latency_s"], r["energy_pj"],
                                 r["area_mm2"],
                                 tuple(payload) if isinstance(payload, list)
                                 else payload))
        return fr

    def save(self, path: str | Path) -> None:
        Path(path).write_text(json.dumps(self.to_jsonable(), indent=1))

    @classmethod
    def load(cls, path: str | Path) -> "ParetoFront":
        return cls.from_jsonable(json.loads(Path(path).read_text()))
