"""Device-resident DSE iteration pipeline (the fused Fig. 7 hot path).

``run_dse``'s staged path round-trips through the host between every tuner
stage of an iteration: the filter model's predicted areas are pulled back
and exponentiated in numpy, the suggestion model's scores come back as a
numpy array for ``np.argsort``, the dedup-to-k walk runs over Python
tuples, and each ``fit`` blocks on ``float(losses[-1])`` before the next
iteration starts.  :class:`DsePipeline` chains the SAME jitted stage
functions — the filter forward pass, the fused
:func:`repro.engine.tuner_train.score_candidates` dispatch, and an
in-array top-k selection replicating
:func:`repro.core.hardware.configs_from_rows` — with device arrays flowing
between them:

* every stage input is an explicit ``jax.device_put`` (no implicit
  host->device transfers; ``tests/test_pipeline.py`` pins this under
  ``jax.transfer_guard("disallow")``),
* the area mask, candidate scores, stable sort, stop-at-first-invalid
  walk, duplicate suppression, and top-k scatter all stay on device,
* exactly ONE host sync per proposal — the ``device_get`` of the winner
  indices — after which the k ``HwConfig`` objects materialize from the
  host-side sample matrix, and
* :meth:`fit` uses the models' ``fit_arrays`` hooks, so both Adam
  trajectories are enqueued asynchronously and the host never blocks on a
  loss scalar (the staged path syncs twice per iteration here).

Selection semantics are bit-compatible with the staged path: the same
sampled value matrix (identical RNG stream), the same jitted scoring
program, a stable argsort, and a walk that stops at the first
area-rejected row — so a shared seed yields identical proposals, pinned by
the parity tests and the ``benchmarks/pipeline_throughput.py`` contract.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hardware import (HwConfig, normalize_params_batch,
                             sample_config_values)
from ..obs import trace
from .jit_registry import register_jits
from .tuner_train import mlp_forward, score_candidates


@jax.jit
def _area_mask(params, xq, budget):
    """Filter-model area mask with the all-reject fallback folded in-array.

    Mirrors the staged ``FilterModel.predict_area_x`` + budget comparison
    (same MLP forward, same ``exp(pred) * budget <= budget`` test) and the
    staged propose's "an all-reject filter would starve the search" escape:
    when no candidate passes, every candidate does.
    """
    pred = mlp_forward(params, xq)[:, 0]
    mask = jnp.exp(pred) * budget <= budget
    return jnp.where(jnp.any(mask), mask, True)


@jax.jit
def _masked_zeros(ok):
    """Scores for an untrained suggestion model: zeros, masked to +inf."""
    return jnp.where(ok, jnp.zeros(ok.shape, jnp.float32), jnp.inf)


# jitted so the trajectory's last loss is picked on device: eager indexing
# (even a static a[-1:]) dispatches dynamic_slice with a host index scalar,
# which a transfer guard rejects
_last = jax.jit(lambda a: a[-1])


@partial(jax.jit, static_argnames=("k",))
def _select_topk(vals, scores, valid, *, k: int):
    """In-array twin of :func:`repro.core.hardware.configs_from_rows`.

    Stable-sorts the candidate rows by score, walks them best-first
    stopping at the first invalid row (``cumprod`` over the sorted mask),
    suppresses rows whose exact value tuple already appeared earlier in
    the walk (pairwise-equality against the strict lower triangle), and
    scatters the first ``k`` survivors' ORIGINAL row indices into rank
    order.  Returns ``(indices [k], count)``; unfilled slots are -1.
    """
    order = jnp.argsort(scores)             # stable, like np kind="stable"
    v = vals[order]
    alive = jnp.cumprod(valid[order].astype(jnp.int32)).astype(bool)
    dup = jnp.tril(jnp.all(v[:, None, :] == v[None, :, :], axis=-1),
                   -1).any(axis=1)
    keep = alive & ~dup
    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
    take = keep & (rank < k)
    # ranks of taken rows are unique and < k; everything else piles into
    # the sacrificial slot k, which the trim below discards
    slot = jnp.where(take, rank, k)
    sel = jnp.full((k + 1,), -1, jnp.int32).at[slot].set(
        order.astype(jnp.int32))
    return sel[:k], jnp.sum(take.astype(jnp.int32))


#: module-level jit objects, keyed for ``compiled_program_count``-style
#: introspection (see :func:`repro.engine.engine_program_counts`),
#: registered at creation time
_JITTED = register_jits(
    area_mask=_area_mask,
    masked_zeros=_masked_zeros,
    last=_last,
    select_topk=_select_topk,
)


class ProposalHandle:
    """An in-flight fused propose: device winners, resolvable late.

    ``run_dse``'s double-buffered pipeline holds one of these across the
    iteration boundary — the propose chain dispatched at iteration ``k``'s
    ingest tail resolves (one small ``device_get``) at the top of
    iteration ``k+1``.
    """

    __slots__ = ("_vals", "_dev", "_cons", "_props")

    def __init__(self, vals, dev: dict, cons):
        self._vals = vals
        self._dev = dev
        self._cons = cons
        self._props: list[HwConfig] | None = None

    def resolve(self) -> list[HwConfig]:
        """Block on the winner indices and materialize the HwConfigs."""
        if self._props is None:
            with trace.span("propose_resolve", cat="engine") as sp:
                got = jax.device_get(self._dev)
                sel, cnt = got["sel"], int(got["cnt"])
                sp["selected"] = cnt
                if "mask_legal" in got:   # sharded wave stats ride along
                    sp["mask_legal"] = int(got["mask_legal"])
                    sp["best_score"] = float(got["best_score"])
            self._props = [
                HwConfig.from_tuple(tuple(int(x) for x in self._vals[i]),
                                    cons=self._cons)
                for i in sel[:cnt]]
            self._vals = self._dev = None
        return self._props


class DsePipeline:
    """Strategy adapter running a scan-backend :class:`PimTuner` fused.

    Drop-in for the tuner anywhere ``run_dse`` accepts a strategy (or pass
    ``run_dse(..., pipeline=True)`` to wrap transparently): ``propose`` is
    the device-resident chain above, ``observe`` delegates, and ``fit``
    defers the loss sync.  The evaluator side of the iteration batches its
    scheduler work through ``prefill_schedules_many`` when the evaluator's
    ``batch_prefill`` flag is on (``run_dse(pipeline=True)`` enables it for
    the duration of the run).
    """

    def __init__(self, tuner):
        missing = [a for a in ("filter_model", "suggestion", "rng",
                               "n_sample", "cons")
                   if not hasattr(tuner, a)]
        if missing:
            raise ValueError(f"DsePipeline needs a PimTuner-like strategy; "
                             f"{type(tuner).__name__} lacks {missing}")
        if getattr(tuner, "backend", None) != "scan":
            raise ValueError("DsePipeline requires a scan-backend tuner "
                             f"(got backend={getattr(tuner, 'backend', None)!r})")
        # lazy: core.tuner imports this package's tuner_train at its top
        # level, so a module-level import here would be circular
        from ..core.tuner import _USE_PALLAS
        self.tuner = tuner
        self.name = getattr(tuner, "name", "nicepim")
        self._use_pallas = _USE_PALLAS
        # scalars/constants the jitted stages consume, pre-staged once so
        # steady-state proposals perform no implicit host->device transfer
        self._beta = jax.device_put(np.float32(tuner.suggestion.beta))
        self._budget = jax.device_put(
            np.float32(tuner.cons.area_budget_mm2))
        self._ones = self._put_rows(np.ones(tuner.n_sample, bool))

    def _put_rows(self, x):
        """Host->device placement for ``[n_sample, ...]`` row arrays.

        The sharded campaign runner (:mod:`repro.engine.sharded`) overrides
        this with a config-axis :class:`~jax.sharding.NamedSharding` put;
        the row-local stage math is placement-independent, so overriding
        placement alone keeps proposals bitwise identical.
        """
        return jax.device_put(x)

    # -- the fused propose chain -------------------------------------------

    def propose_dispatch(self, k: int = 8) -> ProposalHandle:
        """Enqueue the fused propose chain; NO host sync happens here.

        Returns a :class:`ProposalHandle` whose ``resolve()`` performs the
        iteration's one ``device_get`` (k winner indices + a count) —
        callers choose when to pay it, so the chain's compute can hide
        under unrelated host work.
        """
        t = self.tuner
        with trace.span("fused_propose", cat="engine",
                        n=t.n_sample, k=k):
            # stage 0 (host): vectorized draw + normalize, then ONE put
            vals = sample_config_values(t.n_sample, t.rng, t.cons)
            xq = self._put_rows(normalize_params_batch(vals))
            ok = (_area_mask(t.filter_model.params, xq, self._budget)
                  if t.filter_model.trained() else self._ones)
            scores = self._scores(xq, ok)
            sel, cnt = _select_topk(self._put_rows(vals), scores, ok, k=k)
        return ProposalHandle(vals, {"sel": sel, "cnt": cnt}, t.cons)

    def propose(self, k: int = 8) -> list[HwConfig]:
        return self.propose_dispatch(k).resolve()

    def _scores(self, xq, ok):
        sg = self.tuner.suggestion
        if len(sg._y) < 3:
            return _masked_zeros(ok)
        if sg._dirty or sg._train is None:
            sg.fit_arrays()          # same refit-when-stale rule as rank_x
        xp, yp, mask = sg._train
        return score_candidates(sg.params, xp, yp, mask, xq, ok,
                                self._beta, use_pallas=self._use_pallas)

    # -- the strategy protocol ---------------------------------------------

    def observe(self, cfg: HwConfig, area_mm2: float,
                cost: float | None) -> None:
        self.tuner.observe(cfg, area_mm2, cost)

    def fit(self) -> dict:
        """Refit both models WITHOUT blocking on their losses.

        Returns device scalars (or NaN before the models have enough
        observations); ``run_dse`` only formats them under ``verbose``, so
        the non-verbose loop never waits for a fit to finish — the next
        iteration's host-side sampling and mapper work overlap with the
        enqueued Adam scans.
        """
        nan = float("nan")
        fl = self.tuner.filter_model.fit_arrays()
        dl = self.tuner.suggestion.fit_arrays()
        return {"filter": nan if fl is None else _last(fl),
                "dkl": nan if dl is None else _last(dl)}
