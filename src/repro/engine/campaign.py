"""Campaign orchestration: multi-strategy, multi-workload DSE runs.

A *campaign* runs several proposal strategies (the NicePIM tuner plus the
Fig. 9 comparison baselines) over a shared workload set, concurrently, with:

* one shared content-addressed :class:`EvalCache` — strategies converging on
  the same promising region never re-map an identical hardware point;
* a shared :class:`ParetoFront` fed by every legal evaluated observation;
* JSON checkpointing after every DSE iteration (throttle with
  ``checkpoint_every_n``; the final state is always written) and resume:
  completed
  strategies are loaded from the checkpoint verbatim; a partially-finished
  strategy is replayed (its saved observations re-fed to a fresh model) and
  continued from the first missing iteration.

Replayed strategies see their history in one batch instead of iteration by
iteration, so a resumed stochastic strategy is statistically — not bitwise —
equivalent to the uninterrupted run; cached evaluations ARE bitwise stable.
"""

from __future__ import annotations

import json
import os
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from contextlib import nullcontext
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

from ..core.dse import DseResult, Observation, WorkloadEvaluator, run_dse
from ..core.hardware import DEFAULT_CONSTRAINTS, HwConfig, PimConstraints
from ..core.ir import DnnGraph
from ..core.surrogates import make_strategy
from ..obs import metrics as obs_metrics
from ..obs import trace
from ..obs.metrics import collect_engine_metrics
from .cache import EvalCache, _sha, cons_digest, workloads_digest
from .pareto import ParetoFront, ParetoPoint


def _obs_to_json(o: Observation) -> dict:
    return {"iteration": o.iteration, "cfg": list(o.cfg.as_tuple()),
            "area_mm2": o.area_mm2, "legal": o.legal, "cost": o.cost,
            "latency_s": o.latency_s, "energy_pj": o.energy_pj}


def _obs_from_json(d: dict, cons: PimConstraints) -> Observation:
    return Observation(
        iteration=d["iteration"],
        cfg=HwConfig.from_tuple(d["cfg"], cons=cons),
        area_mm2=d["area_mm2"], legal=d["legal"], cost=d["cost"],
        latency_s=d.get("latency_s") or {}, energy_pj=d.get("energy_pj") or {})


@dataclass
class CampaignResult:
    """Outcome of a campaign run.

    ``timings_s`` is per-strategy *thread CPU* time (GIL-fair across the
    concurrent strategies); ``wall_s`` is per-strategy wall-clock time,
    which additionally counts time blocked on XLA dispatch and on the
    other strategies.  ``metrics`` is a flat snapshot of the metrics
    registry taken at the end of the run (cache hit rates, compiled
    program counts, bucket occupancy, per-strategy best cost, ...).
    """

    results: dict[str, DseResult]
    pareto: ParetoFront
    cache_stats: dict
    resumed: list[str] = field(default_factory=list)
    timings_s: dict[str, float] = field(default_factory=dict)
    wall_s: dict[str, float] = field(default_factory=dict)
    metrics: dict = field(default_factory=dict)

    def best(self) -> Observation:
        cands = [o for r in self.results.values() for o in r.observations
                 if o.cost is not None]
        if not cands:
            raise ValueError("no legal observations")
        return min(cands, key=lambda o: o.cost)


class Campaign:
    """Run ``strategies x workloads`` DSE concurrently with checkpointing."""

    def __init__(self, workloads: Sequence[DnnGraph],
                 strategies: Sequence[str] = ("nicepim", "random"),
                 *, iterations: int = 20, propose_k: int = 8, seed: int = 0,
                 n_sample: int = 512,
                 cons: PimConstraints = DEFAULT_CONSTRAINTS,
                 evaluator_kwargs: dict | None = None,
                 strategy_kwargs: dict | None = None,
                 mapper_backend: str | None = None,
                 scheduler_backend: str | None = None,
                 evaluate_all_legal: bool = False,
                 checkpoint: str | Path | None = None,
                 max_workers: int | None = None,
                 cache: EvalCache | None = None,
                 checkpoint_every_n: int = 1,
                 tracer: trace.Tracer | None = None,
                 metrics: obs_metrics.MetricsRegistry | None = None,
                 verbose: bool = False):
        self.workloads = list(workloads)
        self.strategies = list(strategies)
        self.iterations = iterations
        self.propose_k = propose_k
        self.seed = seed
        self.n_sample = n_sample
        self.cons = cons
        self.evaluate_all_legal = evaluate_all_legal
        self.evaluator_kwargs = dict(evaluator_kwargs or {})
        # extra make_strategy kwargs (e.g. backend="loop" for the tuner's
        # scalar reference path in ablation runs)
        self.strategy_kwargs = dict(strategy_kwargs or {})
        if mapper_backend is not None:
            self.evaluator_kwargs["mapper_backend"] = mapper_backend
        if scheduler_backend is not None:
            self.evaluator_kwargs["scheduler_backend"] = scheduler_backend
        self.checkpoint = Path(checkpoint) if checkpoint else None
        if checkpoint_every_n < 1:
            raise ValueError("checkpoint_every_n must be >= 1")
        self.checkpoint_every_n = checkpoint_every_n
        self.max_workers = max_workers or min(4, max(1, len(self.strategies)))
        self.cache = cache if cache is not None else EvalCache()
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else obs_metrics.METRICS
        self.verbose = verbose
        self.pareto = ParetoFront()
        self._obs: dict[str, list[Observation]] = {}
        self._lock = threading.Lock()
        # serializes checkpoint serialization+rename across strategy
        # threads without holding the observation lock (they share a .tmp)
        self._ckpt_lock = threading.Lock()
        self._iters_since_ckpt = 0

    # -- checkpoint I/O ------------------------------------------------------
    def _fingerprint(self) -> str:
        """Everything that must match for saved observations to be reusable.

        The constraints digest matters as much as the workloads: an
        observation's ``legal`` flag and cost were judged against one
        :class:`PimConstraints` (area budget, substrate energies, bank
        geometry) — resuming it under another would silently replay stale
        legality decisions.
        """
        return _sha({
            "workloads": workloads_digest(self.workloads),
            "cons": cons_digest(self.cons),
            "iterations": self.iterations, "seed": self.seed,
            "propose_k": self.propose_k, "n_sample": self.n_sample,
            "evaluate_all_legal": self.evaluate_all_legal,
            "evaluator_kwargs": repr(sorted(self.evaluator_kwargs.items())),
            "strategy_kwargs": repr(sorted(self.strategy_kwargs.items())),
        })

    def _discard_checkpoint(self, reason: str, detail: str) -> None:
        """Record that a checkpoint exists but cannot be resumed from.

        ``reason`` is one of ``"unreadable"`` (truncated / corrupt JSON, or
        an I/O error) and ``"fingerprint_mismatch"`` (a valid checkpoint
        from a *different* campaign: other workloads, constraints, seed or
        iteration budget).  Silent discards cost users entire re-runs, so
        this is deliberately loud: a RuntimeWarning, a
        ``campaign.checkpoint_discarded`` counter (plus a per-reason one)
        and an instant trace event.
        """
        warnings.warn(
            f"discarding campaign checkpoint {self.checkpoint} "
            f"({reason}): {detail}; starting fresh",
            RuntimeWarning, stacklevel=3)
        self.metrics.counter("campaign.checkpoint_discarded").inc()
        self.metrics.counter(f"campaign.checkpoint_discarded.{reason}").inc()
        trace.instant("checkpoint_discarded", cat="campaign",
                      reason=reason, path=str(self.checkpoint))

    def _load_checkpoint(self) -> dict[str, list[Observation]]:
        if not self.checkpoint or not self.checkpoint.exists():
            return {}
        try:
            state = json.loads(self.checkpoint.read_text())
        except (json.JSONDecodeError, OSError) as e:
            self._discard_checkpoint("unreadable", str(e))
            return {}
        if state.get("fingerprint") != self._fingerprint():
            self._discard_checkpoint(
                "fingerprint_mismatch",
                "checkpoint was written by a campaign with different "
                "workloads, constraints or parameters")
            return {}
        return {name: [_obs_from_json(d, self.cons) for d in rows]
                for name, rows in state.get("strategies", {}).items()}

    def _maybe_checkpoint(self) -> None:
        """Per-iteration hook honouring the ``checkpoint_every_n`` knob."""
        with self._lock:
            self._iters_since_ckpt += 1
            due = self._iters_since_ckpt >= self.checkpoint_every_n
            if due:
                self._iters_since_ckpt = 0
        if due:
            self._write_checkpoint()

    def _write_checkpoint(self) -> None:
        if not self.checkpoint:
            return
        with trace.span("checkpoint", cat="campaign") as sp, self._ckpt_lock:
            # snapshot shared state under the lock, but serialize and hit
            # the filesystem OUTSIDE it — json.dumps over a long campaign's
            # observation table is O(obs) work that would otherwise stall
            # every concurrent strategy's observe/offer path
            with self._lock:
                obs_copy = {n: list(obs) for n, obs in self._obs.items()}
                pareto = self.pareto.to_jsonable()
            state = {
                "fingerprint": self._fingerprint(),
                "iterations": self.iterations, "seed": self.seed,
                "strategies": {n: [_obs_to_json(o) for o in obs]
                               for n, obs in obs_copy.items()},
                "pareto": pareto,
                "metrics": self.metrics.snapshot(),
            }
            tmp = self.checkpoint.with_suffix(".tmp")
            tmp.write_text(json.dumps(state))
            os.replace(tmp, self.checkpoint)
            sp["observations"] = sum(len(obs) for obs in obs_copy.values())

    # -- the run -------------------------------------------------------------
    def _completed_iters(self, obs: list[Observation]) -> int:
        return max((o.iteration for o in obs), default=-1) + 1

    def _offer_pareto(self, obs: list[Observation]) -> None:
        # build the points lock-free, then offer the whole batch under ONE
        # acquisition — per-observation acquire/release was pure overhead
        # on the concurrent strategies' hot observe path
        points = [ParetoPoint(sum(o.latency_s.values()),
                              sum(o.energy_pj.values()), o.area_mm2,
                              payload=list(o.cfg.as_tuple()))
                  for o in obs if o.cost is not None and o.cost == o.cost]
        if not points:
            return
        with self._lock:
            for p in points:
                self.pareto.offer(p)

    def _run_strategy(self, name: str, evaluator: WorkloadEvaluator,
                      saved: list[Observation]
                      ) -> tuple[str, DseResult, bool, float, float]:
        # thread CPU time: strategies run concurrently under the GIL, so
        # wall time would charge each strategy for the others' bytecode.
        # Wall time is still recorded alongside — it is what the user
        # waits for, and the gap to CPU time shows blocking on XLA
        # dispatch (which releases the GIL) and on sibling strategies.
        t0_cpu = time.thread_time()
        t0_wall = time.perf_counter()
        trace.set_thread_name(f"strategy:{name}")
        with trace.span("strategy", cat="campaign", strategy=name) as sp:
            res, resumed = self._run_strategy_body(name, evaluator, saved)
            sp["observations"] = len(res.observations)
            sp["resumed"] = resumed
        return (name, res, resumed,
                time.thread_time() - t0_cpu, time.perf_counter() - t0_wall)

    def _run_strategy_body(self, name: str, evaluator: WorkloadEvaluator,
                           saved: list[Observation]
                           ) -> tuple[DseResult, bool]:
        start = self._completed_iters(saved)
        if start >= self.iterations:
            with self._lock:
                self._obs[name] = saved
            self._offer_pareto(saved)
            return DseResult(saved), True
        strat = make_strategy(name, cons=self.cons, seed=self.seed,
                              n_sample=self.n_sample, **self.strategy_kwargs)
        resumed = bool(saved)
        if saved:  # replay history into the fresh model, then continue
            for o in saved:
                strat.observe(o.cfg, o.area_mm2,
                              o.cost if o.legal else None)
            strat.fit()
        with self._lock:
            self._obs[name] = list(saved)
        self._offer_pareto(saved)

        def on_iteration(it: int, new_obs: list[Observation]) -> None:
            with self._lock:
                self._obs[name].extend(new_obs)
            self._offer_pareto(new_obs)
            self._maybe_checkpoint()

        res = run_dse(strat, evaluator, iterations=self.iterations,
                      propose_k=self.propose_k, cons=self.cons,
                      verbose=self.verbose, start_iteration=start,
                      on_iteration=on_iteration,
                      evaluate_all_legal=self.evaluate_all_legal)
        return DseResult(saved + res.observations), resumed

    def run(self) -> CampaignResult:
        ctx = trace.activate(self.tracer) if self.tracer is not None \
            else nullcontext()
        with ctx:
            trace.set_thread_name("campaign")
            saved = self._load_checkpoint()
            # campaigns walk many hardware configs: drop the hw-keyed mapper
            # memos after each one so memory stays flat over long runs (a
            # clear only costs re-derivation if another strategy is
            # mid-evaluation)
            kwargs = dict(self.evaluator_kwargs)
            kwargs.setdefault("clear_caches_between_configs", True)
            evaluator = WorkloadEvaluator(self.workloads, cache=self.cache,
                                          **kwargs)
            results: dict[str, DseResult] = {}
            resumed: list[str] = []
            timings: dict[str, float] = {}
            walls: dict[str, float] = {}
            with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                futs = [pool.submit(self._run_strategy, name, evaluator,
                                    saved.get(name, []))
                        for name in self.strategies]
                for fut in futs:
                    name, res, was_resumed, cpu_s, wall_s = fut.result()
                    results[name] = res
                    timings[name] = cpu_s
                    walls[name] = wall_s
                    if was_resumed:
                        resumed.append(name)
            snapshot = collect_engine_metrics(
                self.metrics, cache=self.cache, pareto=self.pareto)
            self._write_checkpoint()
        return CampaignResult(results=results, pareto=self.pareto,
                              cache_stats=dict(self.cache.stats),
                              resumed=resumed, timings_s=timings,
                              wall_s=walls, metrics=snapshot)
