"""Sharded mega-campaigns: many tenants, one mesh, one shared eval table.

ROADMAP item 1's "DSE-as-a-service" runner.  A *tenant* is one DSE stream
— a (workloads, strategy, seed, constraints) tuple, exactly what
``run_dse`` runs single-stream — and a :class:`ShardedCampaign` drives many
of them against shared infrastructure:

* **config-axis sharding** — :class:`ShardedProposer` re-places the fused
  propose chain's ``[n_sample, ...]`` candidate rows with a
  ``NamedSharding`` over a 1-D ``config`` device mesh
  (:func:`campaign_mesh`, built on :func:`repro.distributed.shardings.
  make_mesh`; ``--xla_force_host_platform_device_count`` makes it
  CPU-testable).  The jitted stages — area mask, fused candidate scoring,
  in-array top-k — are row-local, so GSPMD partitions them across the mesh
  and the proposals stay BITWISE identical to the single-device pipeline
  (pinned by ``tests/test_sharded.py``); per-wave legality stats reduce on
  device through an explicit ``shard_map`` kernel.

* **async wave overlap** — the run loop is a bounded producer/consumer:
  the main thread proposes/ingests/fits (per-tenant sequential semantics,
  which is what keeps each tenant's observation stream identical to its
  single-stream run) while up to ``queue_depth`` waves of mapper/scheduler
  evaluation are in flight on executor threads.  Tenant A's wave N+1
  propose overlaps tenant B's wave N mapping; ``jax.block_until_ready``
  happens only at tenant-completion observation boundaries.

* **persistent shared cache** — hand the campaign a
  :class:`repro.engine.cache.PersistentEvalCache` and every evaluation is
  one durable sqlite commit: concurrent eval workers, killed-and-resumed
  campaigns, and repeated submissions of the same tenant all dedupe
  against one content-addressed table (``benchmarks/campaign_throughput``
  gates the resulting >=2x wall-clock and the zero-re-evaluation resume).

Checkpoint/resume mirrors :class:`repro.engine.campaign.Campaign`'s file
format (JSON observations per tenant behind a campaign fingerprint), but
recovery is *replay-by-re-proposal*: a resumed tenant re-drives its whole
wave sequence from iteration 0.  Every strategy here is deterministic
given its seed, so the re-run proposes the exact configs of the original
run; with a shared :class:`PersistentEvalCache` each already-evaluated
point is served from the durable table (the mapper never re-runs —
``reeval_preexisting`` stays 0) and the continued stream is BITWISE
identical to an uninterrupted run, not merely statistically equivalent.
The re-run pays only the cheap propose/fit host work per completed wave.
"""

from __future__ import annotations

import json
import math
import os
import time
import warnings
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from contextlib import nullcontext
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.dse import (DseResult, Observation, WorkloadEvaluator,
                        ingest_results, propose_screen)
from ..core.hardware import (DEFAULT_CONSTRAINTS, HwConfig, PimConstraints,
                             normalize_params_batch, sample_config_values)
from ..core.ir import DnnGraph
from ..core.surrogates import make_strategy
from ..obs import metrics as obs_metrics
from ..obs import trace
from ..obs.metrics import collect_engine_metrics
from .batch_cost import batch_area_mm2
from .cache import EvalCache, _sha, cons_digest, workloads_digest
from .campaign import CampaignResult, _obs_from_json, _obs_to_json
from .pareto import ParetoFront
from .jit_registry import register_jit
from .pipeline import (DsePipeline, ProposalHandle, _area_mask,
                       _masked_zeros, _select_topk)
from .tuner_train import score_candidates

#: module jit registry (PIM002 / ``engine_program_counts`` contract).  The
#: shard_map wave-stats kernel closes over a concrete mesh, so it is built
#: lazily per mesh and registered here under ``wave_stats[<ndev>]``.
_JITTED: dict = {}

_WAVE_STATS_MESHES: dict = {}


# --------------------------------------------------------------------------
# mesh + row placement
# --------------------------------------------------------------------------

def campaign_mesh(n_devices: int | None = None):
    """A 1-D ``config`` mesh over (a prefix of) the host's devices.

    On CPU, set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    *before* the first jax import to get an N-device mesh for tests and
    benchmarks (the same trick ``launch/mesh.py`` documents).
    """
    from ..distributed.shardings import make_mesh
    n = n_devices or len(jax.devices())
    return make_mesh((n,), ("config",))


def shard_config_rows(mesh, x):
    """``device_put`` a ``[rows, ...]`` array row-sharded over ``config``.

    Falls back to mesh-wide replication when the device count does not
    divide the row count (divisibility-guarded like every rule in
    ``distributed/shardings.py``) — results are placement-independent
    either way, only the partitioning changes.
    """
    x = np.asarray(x)
    ndev = mesh.devices.size
    spec = P("config") if ndev > 1 and x.shape[0] % ndev == 0 else P()
    return jax.device_put(x, NamedSharding(mesh, spec))


def _wave_stats_for(mesh):
    """Per-mesh ``shard_map`` kernel reducing wave legality stats on device.

    Each device reduces its own row block, then ``psum``/``pmin`` combine
    across the ``config`` axis — both order-independent, so the stats are
    deterministic under any device count.
    """
    fn = _WAVE_STATS_MESHES.get(mesh)
    if fn is None:
        def _stats(scores, ok):
            legal = jax.lax.psum(jnp.sum(ok.astype(jnp.int32)), "config")
            best = jax.lax.pmin(
                jnp.min(jnp.where(ok, scores, jnp.inf)), "config")
            return legal, best
        fn = jax.jit(shard_map(_stats, mesh=mesh,
                               in_specs=(P("config"), P("config")),
                               out_specs=(P(), P())))
        if len(_WAVE_STATS_MESHES) >= 8:   # bounded: meshes are few
            _WAVE_STATS_MESHES.clear()
        _WAVE_STATS_MESHES[mesh] = fn
        register_jit(_JITTED, f"wave_stats[{mesh.devices.size}]", fn)
    return fn


# --------------------------------------------------------------------------
# the sharded propose chain
# --------------------------------------------------------------------------

class ShardedProposer(DsePipeline):
    """:class:`DsePipeline` with candidate rows sharded over a mesh.

    Same RNG stream, same jitted stage programs, same selection walk — the
    ONLY change is placement: candidate row arrays enter the chain sharded
    ``P("config")`` and the model/train-set arrays enter replicated, so
    GSPMD partitions the row-local stage math across the mesh.  Proposals
    are bitwise identical to the base pipeline (row-local elementwise ops
    and matmul rows don't change under partitioning; the top-k sort sees
    identical scores), which is what lets a sharded campaign share one
    observation stream with its single-stream twin.
    """

    def __init__(self, tuner, mesh=None):
        self.mesh = mesh if mesh is not None else campaign_mesh()
        self._rep = NamedSharding(self.mesh, P())
        super().__init__(tuner)
        # jit-closure scalars replicate on the mesh (super() committed them
        # to the default device, which a sharded jit would reject)
        self._beta = jax.device_put(np.float32(tuner.suggestion.beta),
                                    self._rep)
        self._budget = jax.device_put(
            np.float32(tuner.cons.area_budget_mm2), self._rep)
        self._wave_stats = _wave_stats_for(self.mesh)
        self._sharded = (self.mesh.devices.size > 1
                         and tuner.n_sample % self.mesh.devices.size == 0)

    def _put_rows(self, x):
        return shard_config_rows(self.mesh, x)

    def _replicate(self, tree):
        """Mesh-replicate a (possibly committed single-device) pytree."""
        return jax.tree.map(lambda a: jax.device_put(a, self._rep), tree)

    def propose_dispatch(self, k: int = 8) -> ProposalHandle:
        """Sharded fused-propose dispatch: winner indices and the
        device-reduced legality stats ride one handle, so the wave still
        pays exactly one host sync — at ``resolve()`` time."""
        t = self.tuner
        with trace.span("fused_propose", cat="engine", n=t.n_sample, k=k,
                        devices=self.mesh.devices.size):
            vals = sample_config_values(t.n_sample, t.rng, t.cons)
            xq = self._put_rows(normalize_params_batch(vals))
            ok = (_area_mask(self._replicate(t.filter_model.params), xq,
                             self._budget)
                  if t.filter_model.trained() else self._ones)
            scores = self._scores(xq, ok)
            sel, cnt = _select_topk(self._put_rows(vals), scores, ok, k=k)
            dev = {"sel": sel, "cnt": cnt}
            if self._sharded:
                legal, best = self._wave_stats(scores, ok)
                dev["mask_legal"], dev["best_score"] = legal, best
        return ProposalHandle(vals, dev, t.cons)

    def _scores(self, xq, ok):
        sg = self.tuner.suggestion
        if len(sg._y) < 3:
            return _masked_zeros(ok)
        if sg._dirty or sg._train is None:
            sg.fit_arrays()
        xp, yp, mask = self._replicate(sg._train)
        return score_candidates(self._replicate(sg.params), xp, yp, mask,
                                xq, ok, self._beta,
                                use_pallas=self._use_pallas)


# --------------------------------------------------------------------------
# tenants
# --------------------------------------------------------------------------

@dataclass
class TenantSpec:
    """One DSE stream of a mega-campaign (the unit ``run_dse`` runs solo).

    ``name`` keys checkpoints and results, so it must be unique within the
    campaign.  Two specs with identical search parameters and workloads
    (e.g. a nightly resubmission) produce identical observation streams —
    the shared persistent cache then serves the repeat entirely from disk.
    """

    name: str
    workloads: Sequence[DnnGraph]
    strategy: str = "nicepim"
    seed: int = 0
    iterations: int = 8
    propose_k: int = 4
    n_sample: int = 256
    cons: PimConstraints = DEFAULT_CONSTRAINTS
    evaluate_all_legal: bool = False
    evaluator_kwargs: dict = field(default_factory=dict)
    strategy_kwargs: dict = field(default_factory=dict)

    def fingerprint(self) -> dict:
        return {
            "workloads": workloads_digest(self.workloads),
            "cons": cons_digest(self.cons),
            "strategy": self.strategy, "seed": self.seed,
            "iterations": self.iterations, "propose_k": self.propose_k,
            "n_sample": self.n_sample,
            "evaluate_all_legal": self.evaluate_all_legal,
            "evaluator_kwargs": repr(sorted(self.evaluator_kwargs.items())),
            "strategy_kwargs": repr(sorted(self.strategy_kwargs.items())),
        }


@dataclass
class _TenantState:
    spec: TenantSpec
    strategy: object
    evaluator: WorkloadEvaluator
    it: int = 0
    obs: list = field(default_factory=list)
    resumed: bool = False
    active_s: float = 0.0
    t_start: float = 0.0
    wall_s: float = 0.0

    @property
    def done(self) -> bool:
        return self.it >= self.spec.iterations


@dataclass
class _Wave:
    it: int
    props: list
    it_obs: list
    to_eval: list
    legal_n: int
    t0: float


# --------------------------------------------------------------------------
# the campaign runner
# --------------------------------------------------------------------------

class ShardedCampaign:
    """Run many tenant DSE streams overlapped on one mesh + shared cache.

    The main thread owns every strategy (propose / observe / fit — the
    per-tenant sequential order that pins parity with single-stream runs);
    ``eval_workers`` executor threads own the mapper/scheduler waves; at
    most ``queue_depth`` waves are in flight.  ``cache`` is shared by every
    tenant's evaluator — pass a :class:`PersistentEvalCache` for the
    cross-process / kill-and-resume dedup story.

    Each worker's ``evaluate_batch`` additionally runs the per-tenant
    overlapped executor (:class:`repro.engine.overlap.OverlapExecutor`):
    within a wave, one workload's scheduling/accounting runs while the
    next workload's candidate costs are in flight.  The executor is
    per-call and the serial-dispatch flag is thread-local, so per-tenant
    overlap composes with the cross-tenant wave loop with no shared state
    beyond the already-locked mapper memos.

    Worker loss: evaluation results only enter tenant state on the main
    thread, so a lost eval worker (or a whole lost process — see the
    kill-and-resume benchmark) costs at most the in-flight waves; every
    completed evaluation is already durable in the persistent cache and is
    served from it on resume, never re-mapped.
    """

    def __init__(self, tenants: Sequence[TenantSpec], *,
                 mesh=None, cache: EvalCache | None = None,
                 queue_depth: int = 2, eval_workers: int | None = None,
                 checkpoint: str | Path | None = None,
                 checkpoint_every_waves: int = 1,
                 pipeline: bool = True,
                 tracer: trace.Tracer | None = None,
                 metrics: obs_metrics.MetricsRegistry | None = None,
                 verbose: bool = False):
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique: {names}")
        if queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if checkpoint_every_waves < 1:
            raise ValueError("checkpoint_every_waves must be >= 1")
        self.tenants = list(tenants)
        self.mesh = mesh if mesh is not None else campaign_mesh()
        self.cache = cache if cache is not None else EvalCache()
        self.queue_depth = queue_depth
        self.eval_workers = eval_workers or min(4, queue_depth)
        self.checkpoint = Path(checkpoint) if checkpoint else None
        self.checkpoint_every_waves = checkpoint_every_waves
        self.pipeline = pipeline
        self.tracer = tracer
        self.metrics = metrics if metrics is not None else obs_metrics.METRICS
        self.verbose = verbose
        self.pareto = ParetoFront()
        self._waves_since_ckpt = 0
        self._states: list[_TenantState] = []

    # -- checkpoint I/O ----------------------------------------------------

    def _fingerprint(self) -> str:
        # queue_depth / eval_workers / mesh size are deliberately NOT part
        # of the fingerprint: they change scheduling, not any tenant's
        # observation stream, so a checkpoint resumes across them
        return _sha([t.fingerprint() for t in self.tenants])

    def _discard_checkpoint(self, reason: str, detail: str) -> None:
        warnings.warn(
            f"discarding sharded-campaign checkpoint {self.checkpoint} "
            f"({reason}): {detail}; starting fresh",
            RuntimeWarning, stacklevel=3)
        self.metrics.counter("campaign.checkpoint_discarded").inc()
        self.metrics.counter(f"campaign.checkpoint_discarded.{reason}").inc()
        trace.instant("checkpoint_discarded", cat="campaign",
                      reason=reason, path=str(self.checkpoint))

    def _load_checkpoint(self) -> dict[str, list[Observation]]:
        if not self.checkpoint or not self.checkpoint.exists():
            return {}
        try:
            state = json.loads(self.checkpoint.read_text())
        except (json.JSONDecodeError, OSError) as e:
            self._discard_checkpoint("unreadable", str(e))
            return {}
        if state.get("fingerprint") != self._fingerprint():
            self._discard_checkpoint(
                "fingerprint_mismatch",
                "checkpoint was written by a campaign with different "
                "tenants, workloads, constraints or parameters")
            return {}
        cons = {t.name: t.cons for t in self.tenants}
        return {name: [_obs_from_json(d, cons[name]) for d in rows]
                for name, rows in state.get("tenants", {}).items()
                if name in cons}

    def _write_checkpoint(self) -> None:
        if not self.checkpoint:
            return
        with trace.span("checkpoint", cat="campaign") as sp:
            state = {
                "fingerprint": self._fingerprint(),
                "tenants": {s.spec.name: [_obs_to_json(o) for o in s.obs]
                            for s in self._states},
            }
            tmp = self.checkpoint.with_suffix(".tmp")
            tmp.write_text(json.dumps(state))
            os.replace(tmp, self.checkpoint)
            sp["observations"] = sum(len(s.obs) for s in self._states)

    def _maybe_checkpoint(self) -> None:
        self._waves_since_ckpt += 1
        if self._waves_since_ckpt >= self.checkpoint_every_waves:
            self._waves_since_ckpt = 0
            self._write_checkpoint()

    # -- tenant setup ------------------------------------------------------

    def _make_strategy(self, spec: TenantSpec):
        strat = make_strategy(spec.strategy, cons=spec.cons, seed=spec.seed,
                              n_sample=spec.n_sample, **spec.strategy_kwargs)
        tuner_like = all(hasattr(strat, a) for a in
                         ("filter_model", "suggestion", "rng", "n_sample",
                          "cons")) and getattr(strat, "backend",
                                               None) == "scan"
        if self.pipeline and tuner_like:
            return ShardedProposer(strat, self.mesh), True
        return strat, False

    def _tenant_state(self, spec: TenantSpec,
                      saved: list[Observation]) -> _TenantState:
        strat, piped = self._make_strategy(spec)
        kw = dict(spec.evaluator_kwargs)
        kw.setdefault("clear_caches_between_configs", True)
        if piped:
            kw.setdefault("batch_prefill", True)
        ev = WorkloadEvaluator(list(spec.workloads), cache=self.cache, **kw)
        # replay-by-re-proposal: a resumed tenant restarts at iteration 0
        # and re-drives every wave.  Its seeded strategy re-proposes the
        # exact configs of the interrupted run, the shared cache serves
        # their evaluations (persistent table: zero re-mapping), and the
        # continued stream comes out bitwise identical — feeding the saved
        # observations into a fresh model instead would leave the RNG
        # stream behind by the replayed waves' draws and fork the tail
        if saved:
            trace.instant("tenant_resumed", cat="sharded", tenant=spec.name,
                          saved_observations=len(saved))
        return _TenantState(spec=spec, strategy=strat, evaluator=ev,
                            resumed=bool(saved))

    def _offer_pareto(self, obs: list[Observation]) -> None:
        # main-thread only (ingest + replay both run there): no lock needed
        from .pareto import ParetoPoint
        for o in obs:
            if o.cost is None or o.cost != o.cost or math.isinf(o.cost):
                continue
            self.pareto.offer(ParetoPoint(sum(o.latency_s.values()),
                                          sum(o.energy_pj.values()),
                                          o.area_mm2,
                                          payload=list(o.cfg.as_tuple())))

    # -- wave phases -------------------------------------------------------

    def _propose_wave(self, st: _TenantState) -> _Wave:
        spec = st.spec
        t0 = time.time()
        ta = time.perf_counter()
        with trace.span("wave_propose", cat="sharded", tenant=spec.name,
                        it=st.it):
            props, it_obs, to_eval, legal_n = propose_screen(
                st.strategy, st.it, spec.propose_k, spec.cons, spec.name,
                spec.evaluate_all_legal, batch_area_mm2)
        st.active_s += time.perf_counter() - ta
        return _Wave(it=st.it, props=props, it_obs=it_obs, to_eval=to_eval,
                     legal_n=legal_n, t0=t0)

    def _evaluate_wave(self, st: _TenantState, wave: _Wave):
        """Executor-thread phase: map/schedule the wave's legal configs."""
        trace.set_thread_name("eval-worker")
        ta = time.perf_counter()
        with trace.span("wave_evaluate", cat="sharded",
                        tenant=st.spec.name, it=wave.it,
                        configs=len(wave.to_eval)):
            if not wave.to_eval:
                out = []
            elif st.spec.evaluate_all_legal:
                results = st.evaluator.evaluate_batch(
                    [cfg for cfg, _ in wave.to_eval])
                out = [(cfg, area, res) for (cfg, area), res
                       in zip(wave.to_eval, results)]
            else:
                cfg, area = wave.to_eval[0]
                out = [(cfg, area, st.evaluator(cfg))]
        st.active_s += time.perf_counter() - ta
        return out

    def _ingest_wave(self, st: _TenantState, wave: _Wave,
                     evaluated: list) -> None:
        spec = st.spec
        ta = time.perf_counter()
        with trace.span("wave_ingest", cat="sharded", tenant=spec.name,
                        it=wave.it):
            best_gauge = self.metrics.gauge(f"dse.{spec.name}.best_cost")
            legal_hist = self.metrics.histogram(
                f"dse.{spec.name}.legal_fraction")
            ingest_results(st.strategy, wave.it, wave.it_obs, evaluated,
                           self.pareto, spec.name, best_gauge, legal_hist,
                           wave.legal_n, len(wave.props), None,
                           self.verbose, wave.t0)
        st.obs.extend(wave.it_obs)
        st.it = wave.it + 1
        st.active_s += time.perf_counter() - ta
        self._maybe_checkpoint()

    def _finish_tenant(self, st: _TenantState) -> None:
        st.wall_s = time.perf_counter() - st.t_start
        strat = st.strategy
        if isinstance(strat, DsePipeline):
            # tenant-completion observation boundary: drain the deferred
            # Adam fits so the tenant's reported wall time covers its model
            # state (the run loop itself never blocks on a fit)
            t = strat.tuner
            jax.block_until_ready((t.filter_model.params,
                                   t.suggestion.params))
        trace.instant("tenant_done", cat="sharded", tenant=st.spec.name,
                      observations=len(st.obs))

    # -- the run -----------------------------------------------------------

    def run(self) -> CampaignResult:
        ctx = trace.activate(self.tracer) if self.tracer is not None \
            else nullcontext()
        with ctx:
            trace.set_thread_name("sharded-campaign")
            saved = self._load_checkpoint()
            self._states = [self._tenant_state(t, saved.get(t.name, []))
                            for t in self.tenants]
            now = time.perf_counter()
            for s in self._states:
                s.t_start = now
            ready = deque(s for s in self._states if not s.done)
            for s in self._states:
                if s.done:
                    self._finish_tenant(s)
            pending: dict = {}
            with ThreadPoolExecutor(
                    max_workers=self.eval_workers) as pool:
                while ready or pending:
                    # producer: keep up to queue_depth waves in flight —
                    # each tenant has at most one (sequential semantics)
                    while ready and len(pending) < self.queue_depth:
                        st = ready.popleft()
                        wave = self._propose_wave(st)
                        fut = pool.submit(self._evaluate_wave, st, wave)
                        pending[fut] = (st, wave)
                    done, _ = wait(pending, return_when=FIRST_COMPLETED)
                    for fut in done:
                        st, wave = pending.pop(fut)
                        self._ingest_wave(st, wave, fut.result())
                        if st.done:
                            self._finish_tenant(st)
                        else:
                            ready.append(st)
            self._write_checkpoint()
            snapshot = collect_engine_metrics(
                self.metrics, cache=self.cache, pareto=self.pareto)
        return CampaignResult(
            results={s.spec.name: DseResult(s.obs) for s in self._states},
            pareto=self.pareto, cache_stats=dict(self.cache.stats),
            resumed=[s.spec.name for s in self._states if s.resumed],
            timings_s={s.spec.name: s.active_s for s in self._states},
            wall_s={s.spec.name: s.wall_s for s in self._states},
            metrics=snapshot)
