"""Jitted tuner engine: scan-based DKL/filter training and fused propose.

The PIM-Tuner's scalar path (Sec. V / Fig. 8) runs 200-300 host-side Adam
dispatches per DSE iteration and retraces both training steps on every
*growing* dataset shape — one fresh XLA program per iteration.  This module
moves the whole tuner/surrogate stack onto the engine layer:

* :func:`fit_filter` / :func:`fit_dkl` run the entire Adam trajectory inside
  ONE jitted ``lax.scan`` — no per-step host round-trips — with the training
  set padded into power-of-two buckets and a validity mask threaded through
  the masked MSE and the masked GP negative log marginal likelihood, so XLA
  compiles O(log n) distinct programs across a whole campaign instead of one
  per dataset size;
* :func:`score_candidates` (deep-kernel model) and
  :func:`score_candidates_raw` (the Fig. 9 raw-parameter GP ablation) score a
  full candidate batch in a single dispatch: MLP features, RBF cross-kernel,
  GP posterior mean/variance, and the LCB, with the filter-model area mask
  applied in-array (masked-out candidates score ``+inf``).  The
  pairwise-distance + LCB reduction can run in the Pallas kernel
  :func:`repro.kernels.dse_eval.lcb_rows` (``use_pallas=True``, the on-TPU
  default in the models; interpret-mode fallback off-TPU).

Masking contract (the jitter-on-the-padded-diagonal trick): padded
rows/columns of the training kernel are zeroed and their diagonal pinned to
1, so the Cholesky factor is block-diagonal and its valid block is exactly
the unpadded factor; padded targets are zeroed so ``alpha = K^-1 y`` has
zero padded entries, and the padded block of ``K^-1`` is the identity —
which the masked cross-kernel never touches.  Masked losses and predictions
therefore equal the unpadded exact values up to float reassociation
(``tests/test_tuner_engine.py`` pins both the scan-vs-loop trajectories and
the padded-vs-unpadded predictions).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dse_eval
from ..obs import metrics, trace
from ..training.optim import Adam

MIN_BUCKET = 8


# ---------------------------------------------------------------------------
# pow2 bucketing
# ---------------------------------------------------------------------------


def pow2_bucket(n: int, minimum: int = MIN_BUCKET) -> int:
    """Smallest power-of-two >= n (floored at ``minimum``)."""
    return max(minimum, 1 << max(0, (int(n) - 1).bit_length()))


def pad_dataset(x, y) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad ``(x [n,d], y [n])`` to the pow2 bucket; returns (x, y, mask).

    Padded rows are zero (harmless through the masked losses) and masked
    invalid; the bucket keeps the XLA program count logarithmic in the
    number of accumulated observations.
    """
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    n = y.shape[0]
    p = pow2_bucket(n)
    xp = np.zeros((p, x.shape[1]), np.float32)
    yp = np.zeros((p,), np.float32)
    mask = np.zeros((p,), bool)
    xp[:n] = x
    yp[:n] = y
    mask[:n] = True
    return xp, yp, mask


# ---------------------------------------------------------------------------
# Model primitives (shared with core/tuner.py's scalar-loop reference)
# ---------------------------------------------------------------------------


def mlp_init(key, sizes: list[int]) -> list[dict]:
    layers = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (a, b), jnp.float32) * math.sqrt(2.0 / a)
        layers.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
    return layers


def mlp_forward(layers: list[dict], x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for i, l in enumerate(layers):
        h = h @ l["w"] + l["b"]
        if i < len(layers) - 1:
            h = jax.nn.relu(h)
    return h


def dkl_features(params: dict, x: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Normalized MLP features (the deep kernel's learned embedding).

    ``mask`` marks valid rows of a padded batch.  Padded rows produce the
    zero vector, where the norm's gradient is NaN; the double-where trick
    routes them through a safe constant instead (their value never matters:
    every downstream kernel entry involving a padded row is masked out, and
    the constant blocks the NaN from poisoning the whole gradient).
    """
    z = mlp_forward(params["mlp"], x)
    if mask is not None:
        z = jnp.where(mask[:, None], z, 1.0)
    zn = z / (jnp.linalg.norm(z, axis=-1, keepdims=True) + 1e-6)
    if mask is not None:
        zn = jnp.where(mask[:, None], zn, 0.0)
    return zn


def kernel_scalars(params: dict):
    """(effective lengthscale^2, signal var, noise var) of the DKL kernel."""
    ls2 = jnp.exp(params["log_ls"]) ** 2 + 1e-8
    sf2 = jnp.exp(2 * params["log_sf"])
    sn2 = jnp.exp(2 * params["log_sn"]) + 1e-6
    return ls2, sf2, sn2


def pairwise_sq_dists(za, zb):
    """``|za[i] - zb[j]|^2`` as [A, B] via the gram trick.

    One matmul instead of materializing the [A, B, D] broadcast difference —
    the hot op of both the per-step NLML kernel and the 2048-candidate
    propose cross-kernel.  Clamped at 0 (the expansion can go epsilon-
    negative in float32).
    """
    sq_a = jnp.sum(za * za, axis=-1)
    sq_b = jnp.sum(zb * zb, axis=-1)
    d2 = sq_a[:, None] + sq_b[None, :] - 2.0 * (za @ zb.T)
    return jnp.maximum(d2, 0.0)


def rbf_cross(za, zb, ls2, sf2):
    """RBF cross-kernel ``sf2 * exp(-|za - zb|^2 / (2 ls2))`` as [A, B]."""
    return sf2 * jnp.exp(-0.5 * pairwise_sq_dists(za, zb) / ls2)


def masked_kernel(z, mask, ls2, sf2, sn2):
    """Masked training kernel: valid block exact, padded block = identity."""
    k = rbf_cross(z, z, ls2, sf2)
    m2 = mask[:, None] & mask[None, :]
    k = jnp.where(m2, k, 0.0)
    return k + jnp.diag(jnp.where(mask, sn2, jnp.ones_like(sn2)))


# ---------------------------------------------------------------------------
# Masked losses
# ---------------------------------------------------------------------------


def masked_mse(params, x, y, mask):
    """Filter-model loss; equals ``mean((pred - y)^2)`` over the valid rows."""
    pred = mlp_forward(params, x)[:, 0]
    se = jnp.where(mask, (pred - y) ** 2, 0.0)
    return jnp.sum(se) / jnp.sum(mask.astype(se.dtype))


def masked_nlml(params, x, y, mask):
    """Masked GP NLML; equals the exact unpadded NLML of the valid subset."""
    z = dkl_features(params, x, mask)
    ls2, sf2, sn2 = kernel_scalars(params)
    k = masked_kernel(z, mask, ls2, sf2, sn2)
    chol = jnp.linalg.cholesky(k)
    ym = jnp.where(mask, y, 0.0)
    alpha = jax.scipy.linalg.cho_solve((chol, True), ym)
    nv = jnp.sum(mask.astype(ym.dtype))
    logdet = jnp.sum(jnp.where(mask, jnp.log(jnp.diag(chol)), 0.0))
    return (0.5 * ym @ alpha + logdet
            + 0.5 * nv * jnp.log(2 * jnp.pi)) / nv


# ---------------------------------------------------------------------------
# Scan-based training (one dispatch per fit, not one per Adam step)
# ---------------------------------------------------------------------------


def _scan_fit(loss_fn, opt: Adam, params, opt_state, args, steps: int):
    def step(carry, _):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p, *args)
        p, s = opt.apply(grads, s, p)
        return (p, s), loss
    # the per-step graph is hundreds of tiny CPU ops; a modest unroll
    # amortizes the loop bookkeeping without exploding compile time
    (params, opt_state), losses = jax.lax.scan(
        step, (params, opt_state), None, length=steps,
        unroll=min(4, steps))
    return params, opt_state, losses


# params/opt_state are donated: every trajectory returns a same-shaped
# (params, opt_state), so XLA updates the optimizer state in place.  Callers
# hand ownership over — the model classes reassign from the return value;
# anything re-running a fit from the SAME initial state must pass copies
# (``tests/test_pipeline.py`` pins that donated fits still match the loop
# references and that the inputs really are consumed).
@partial(jax.jit, static_argnames=("opt", "steps"), donate_argnums=(0, 1))
def _fit_filter_jit(params, opt_state, x, y, mask, *, opt: Adam, steps: int):
    return _scan_fit(masked_mse, opt, params, opt_state, (x, y, mask), steps)


@partial(jax.jit, static_argnames=("opt", "steps"), donate_argnums=(0, 1))
def _fit_dkl_jit(params, opt_state, x, y, mask, *, opt: Adam, steps: int):
    return _scan_fit(masked_nlml, opt, params, opt_state, (x, y, mask), steps)


def _record_bucket(kind: str, y, mask) -> None:
    """Pow2-bucket occupancy + padding-waste metrics for one fit dispatch.

    ``mask`` arrives concrete (the host built it in ``pad_dataset``), so
    summing it never blocks on an in-flight computation.
    """
    bucket = int(y.shape[0])
    valid = int(np.asarray(mask).sum())
    metrics.METRICS.gauge(f"tuner.bucket.{kind}").set(bucket)
    metrics.METRICS.histogram(f"tuner.bucket_fill.{kind}").observe(
        valid / bucket if bucket else 0.0)
    metrics.METRICS.counter(f"tuner.padded_rows.{kind}").inc(bucket - valid)


def fit_filter(params, opt_state, x, y, mask, *, opt: Adam, steps: int):
    """Whole filter-MLP Adam trajectory in one jitted scan.

    Returns ``(params, opt_state, losses [steps])``; matches ``steps``
    sequential ``core.tuner._filter_step`` calls on the unpadded data.
    """
    _record_bucket("filter", y, mask)
    with trace.span("fit_filter", cat="engine", bucket=int(y.shape[0]),
                    steps=int(steps)):
        return _fit_filter_jit(params, opt_state, x, y, mask,
                               opt=opt, steps=steps)


def fit_dkl(params, opt_state, x, y, mask, *, opt: Adam, steps: int):
    """Whole DKL (MLP + GP hyperparameter) trajectory in one jitted scan."""
    _record_bucket("dkl", y, mask)
    with trace.span("fit_dkl", cat="engine", bucket=int(y.shape[0]),
                    steps=int(steps)):
        return _fit_dkl_jit(params, opt_state, x, y, mask,
                            opt=opt, steps=steps)


# ---------------------------------------------------------------------------
# Fused propose scoring
# ---------------------------------------------------------------------------


def _posterior_state(z, y, mask, ls2, sf2, sn2):
    """(alpha, kinv) of the masked training kernel for posterior queries."""
    k = masked_kernel(z, mask, ls2, sf2, sn2)
    chol = jnp.linalg.cholesky(k)
    ym = jnp.where(mask, y, 0.0)
    alpha = jax.scipy.linalg.cho_solve((chol, True), ym)
    kinv = jax.scipy.linalg.cho_solve(
        (chol, True), jnp.eye(k.shape[0], dtype=k.dtype))
    return alpha, kinv


def _lcb(zq, zt, alpha, kinv, mask, ls2, sf2, beta, use_pallas: bool):
    if use_pallas:
        return dse_eval.lcb_rows(zq, zt, alpha, kinv, mask, ls2, sf2, beta)
    kq = rbf_cross(zq, zt, ls2, sf2)
    kq = jnp.where(mask[None, :], kq, 0.0)
    mean = kq @ alpha
    var = sf2 - jnp.sum((kq @ kinv) * kq, axis=-1)
    return mean - beta * jnp.sqrt(jnp.clip(var, 1e-9))


@jax.jit
def dkl_predict(params, xt, yt, mask, xq):
    """Masked GP posterior (mean, var) — the padded twin of ``_dkl_predict``."""
    ls2, sf2, sn2 = kernel_scalars(params)
    zt = dkl_features(params, xt, mask)
    zq = dkl_features(params, xq)
    alpha, kinv = _posterior_state(zt, yt, mask, ls2, sf2, sn2)
    kq = jnp.where(mask[None, :], rbf_cross(zq, zt, ls2, sf2), 0.0)
    mean = kq @ alpha
    var = sf2 - jnp.sum((kq @ kinv) * kq, axis=-1)
    return mean, jnp.clip(var, 1e-9)


@partial(jax.jit, static_argnames=("use_pallas",))
def _score_candidates_jit(params, xt, yt, mask, xq, area_ok, beta, *,
                          use_pallas: bool = False):
    ls2, sf2, sn2 = kernel_scalars(params)
    zt = dkl_features(params, xt, mask)
    zq = dkl_features(params, xq)
    alpha, kinv = _posterior_state(zt, yt, mask, ls2, sf2, sn2)
    lcb = _lcb(zq, zt, alpha, kinv, mask, ls2, sf2, beta, use_pallas)
    return jnp.where(area_ok, lcb, jnp.inf)


def score_candidates(params, xt, yt, mask, xq, area_ok, beta, *,
                     use_pallas: bool = False):
    """Fused DKL propose: one dispatch over the whole candidate batch.

    Computes the deep-kernel features of both the (padded, masked) training
    set and the query batch, the RBF cross-kernel, the GP posterior
    mean/variance, and the LCB ``mean - beta * sqrt(var)``; candidates with
    ``area_ok=False`` (the filter model's in-array area mask) score ``+inf``
    so they sort last without any Python-side list filtering.
    """
    with trace.span("score_candidates", cat="engine",
                    bucket=int(yt.shape[0]), candidates=int(xq.shape[0])):
        return _score_candidates_jit(params, xt, yt, mask, xq, area_ok,
                                     beta, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("use_pallas",))
def _score_candidates_raw_jit(xt, yt, mask, xq, area_ok, beta, *,
                              noise_var: float = 1e-3,
                              use_pallas: bool = False):
    d2 = jnp.sum((xt[:, None, :] - xt[None, :, :]) ** 2, -1)
    m2 = (mask[:, None] & mask[None, :]) & (d2 > 0)
    ls2 = jnp.nanmedian(jnp.where(m2, d2, jnp.nan))
    ls2 = jnp.where(jnp.isnan(ls2), jnp.ones_like(ls2), ls2)
    nv = jnp.sum(mask.astype(yt.dtype))
    mu = jnp.sum(jnp.where(mask, yt, 0.0)) / nv
    var_y = jnp.sum(jnp.where(mask, (yt - mu) ** 2, 0.0)) / nv
    sd = jnp.sqrt(var_y) + 1e-9
    yn = jnp.where(mask, (yt - mu) / sd, 0.0)
    one = jnp.ones((), xt.dtype)
    alpha, kinv = _posterior_state(xt, yn, mask, ls2, one,
                                   jnp.asarray(noise_var, xt.dtype))
    lcb = _lcb(xq, xt, alpha, kinv, mask, ls2, one, beta, use_pallas)
    return jnp.where(area_ok, lcb, jnp.inf)


def score_candidates_raw(xt, yt, mask, xq, area_ok, beta, *,
                         noise_var: float = 1e-3,
                         use_pallas: bool = False):
    """Raw-parameter GP scoring (Fig. 9 ``gp`` ablation), same primitives.

    Median-heuristic lengthscale on the raw normalized parameters, unit
    signal variance, ``noise_var`` jitter, y standardized over the valid
    rows — the exact model of ``GPSurrogate``'s numpy reference, expressed
    on the shared masked-Cholesky / LCB primitives.
    """
    with trace.span("score_candidates", cat="engine",
                    bucket=int(yt.shape[0]), candidates=int(xq.shape[0])):
        return _score_candidates_raw_jit(xt, yt, mask, xq, area_ok, beta,
                                         noise_var=noise_var,
                                         use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# XLA program-count introspection (the O(log n) recompile contract)
# ---------------------------------------------------------------------------

_JITTED = {
    "fit_filter": _fit_filter_jit,
    "fit_dkl": _fit_dkl_jit,
    "score_candidates": _score_candidates_jit,
    "score_candidates_raw": _score_candidates_raw_jit,
    "dkl_predict": dkl_predict,
}


def compiled_program_count() -> dict[str, int]:
    """Per-entry-point XLA cache sizes (process-global; diff around a run).

    ``benchmarks/tuner_throughput.py`` asserts the growth across a DSE run
    stays logarithmic in the number of accumulated observations — the pow2
    bucketing contract.
    """
    out = {}
    for name, fn in _JITTED.items():
        try:
            out[name] = int(fn._cache_size())
        except Exception:       # cache introspection is best-effort per jax
            out[name] = -1
    return out
