"""Creation-time registration of module-level jitted programs.

Every engine module exposes a ``_JITTED`` dict mapping a stable label to
its jitted callables so ``engine.engine_program_counts()`` can report
compiled-program counts (retrace detection) and pimlint's PIM002 rule can
verify nothing jitted escapes the registry.  Before this helper, a new jit
had to be added to the dict *post hoc* — easy to forget, and PIM002 only
caught the omission after the fact.

``register_jits`` builds the registry at jit-creation time::

    _cycles_to_latency = jax.jit(...)
    _JITTED = register_jits(cycles_to_latency=_cycles_to_latency)

The keyword-argument form keeps the callables visible as names in the
``_JITTED = ...`` assignment, which is exactly what PIM002's registry scan
reads — so registration and lint-visibility are one act, not two.

``register_jit`` covers the lazy case (programs specialized at first use,
e.g. per-mesh-size wave kernels): it inserts into an existing registry and
returns the function so the call can wrap the ``jax.jit`` site directly.
"""

from __future__ import annotations

from typing import Callable


def register_jits(**jits: Callable) -> dict[str, Callable]:
    """Build a module ``_JITTED`` registry from keyword-named jits."""
    for name, fn in jits.items():
        if not callable(fn):
            raise TypeError(f"jit registry entry {name!r} is not callable")
    return dict(jits)


def register_jit(registry: dict[str, Callable], name: str,
                 fn: Callable) -> Callable:
    """Insert one lazily-created jit into ``registry`` and return it."""
    if not callable(fn):
        raise TypeError(f"jit registry entry {name!r} is not callable")
    registry[name] = fn
    return fn


__all__ = ["register_jits", "register_jit"]
