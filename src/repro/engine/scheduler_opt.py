"""Jitted Data-Scheduler engine: multi-chain 2-opt as one ``lax.scan``.

Array form of the Sec. VII joint min-max-link-load Hamilton-cycle search
(:func:`repro.core.scheduler.solve_ilp_ls`):

* cycle state is a padded ``[rows, sets, max_n]`` int array where each *row*
  is one (problem, restart-chain) pair — restarts run as parallel chains, and
  :func:`schedule_many` packs a whole batch of sharing problems (pow2-bucketed
  by set count / set size / mesh) into the rows of ONE jitted solve;
* per-pair XY routes come from a dense 0/1 incidence table
  (:func:`_mesh_incidence`, derived from :meth:`MeshNoc.route_table`); a
  cumulative sum of edge-*flip* incidence rows along each cycle turns a
  move's interior link-load delta into two gathers (``flipcum[j] -
  flipcum[i]``) plus four boundary gathers — no scatter, no Python per-edge
  walk;
* each round draws ``moves_per_round`` jax-PRNG proposals per row (uniform
  over the valid ``i < j`` reversal pairs, the degenerate full-cycle reversal
  excluded by rank arithmetic rather than rejection), scores every proposal's
  max-link-load against the current loads (Pallas ``delta_maxload_rows`` on
  TPU, plain ``jnp`` otherwise), applies the best non-worsening move of
  every sharing-set jointly, and exactly re-checks the combined objective —
  falling back to the single globally best move when overlapping routes make
  the combination worse, so the objective is monotone non-increasing like
  the loop reference's sequential best-first rule.

Randomness is batch-independent by construction: every problem's stream is
``fold_in(PRNGKey(Random(seed).getrandbits(32)), crc32(problem))``, so a
problem solved alone (``solve_ilp_ls(backend="scan")``) and the same problem
inside a ``schedule_many`` batch produce bit-identical schedules — which the
mapper's memoized :func:`~repro.core.mapper._sharing_latency` relies on.

Quality contracts (pinned by tests/test_scheduler_engine.py and the
``scheduler_throughput`` benchmark): exact brute-force parity on the small
single-set path, objective <= the loop reference across the Fig. 12 suite,
and per-seed determinism.
"""

from __future__ import annotations

import functools
import random
import zlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core.noc import MeshNoc
from ..core.scheduler import (ScheduleResult, _all_transfers, _finish,
                              _initial_cycles, _solve_exact)
from ..obs import metrics, trace
from .jit_registry import register_jits
from .tuner_train import pow2_bucket

_USE_PALLAS = jax.default_backend() == "tpu"

# pad mesh-dependent shapes (node count, link count) to pow2 so every mesh
# with the same padded envelope reuses ONE compiled program — per-mesh
# recompiles, not device compute, dominate a cold campaign's scheduling
# time.  schedule_many(pad_shapes=False) restores the PR 6 exact-shape
# programs (the staged baseline pipeline_throughput measures against);
# results are bit-identical either way (padded links carry zero loads and
# zero deltas through an exact max).
_PAD_SHAPES = True


@functools.lru_cache(maxsize=8)
def _mesh_incidence(noc: MeshNoc, nn_pad: int | None = None,
                    e_pad: int | None = None) -> jax.Array:
    """Dense 0/1 XY-route incidence ``[NN', NN', E']`` int8 for one mesh.

    ``inc[a, b, e] = 1`` iff link ``e`` lies on the XY route ``a -> b`` —
    the gather form of :meth:`MeshNoc.route_table` the jitted 2-opt scores
    deltas against (int8: the largest paper mesh, 16x16, stays at 63 MB).
    ``nn_pad`` / ``e_pad`` zero-pad the node and link axes to a shared
    pow2 envelope (node ids never reach the padded rows; padded links have
    no incidence, so their loads stay exactly zero).  Cached as a
    device-resident ``jax.Array`` so repeat solves on one mesh reuse the
    buffer instead of re-transferring it per dispatch.
    """
    route_pad, _ = noc.route_table()
    nn, e = noc.n_nodes, noc.n_links()
    nn_pad = nn if nn_pad is None else nn_pad
    e_pad = e if e_pad is None else e_pad
    flat = np.zeros((nn * nn, e + 1), dtype=np.int8)
    rows = np.repeat(np.arange(nn * nn), route_pad.shape[2])
    np.add.at(flat, (rows, route_pad.reshape(nn * nn, -1).ravel()), 1)
    inc = np.zeros((nn_pad, nn_pad, e_pad), dtype=np.int8)
    inc[:nn, :nn, :e] = flat[:, :e].reshape(nn, nn, e)
    return jax.device_put(inc)


def _mesh_pads(noc: MeshNoc, pad: bool) -> tuple[int, int]:
    """(node, link) axis sizes for one mesh's jitted state."""
    if not pad:
        return noc.n_nodes, noc.n_links()
    return (pow2_bucket(noc.n_nodes, minimum=4),
            pow2_bucket(noc.n_links(), minimum=8))


# -- the jitted multi-chain search --------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("rounds", "n_moves", "use_pallas"),
                   donate_argnums=(0, 3))
def _scan_solve(cycles0, lens, weights, loads0, keys, inc, *,
                rounds: int, n_moves: int, use_pallas: bool):
    """The whole multi-round 2-opt search as one ``lax.scan``.

    ``cycles0 [R, S, N]`` int32 node ids (row = one problem x chain),
    ``lens [R, S]`` true set sizes (0 for padded sets), ``weights [R, S]``
    per-cycle-edge byte weights, ``loads0 [R, E]`` the initial link loads,
    ``keys [R, 2]`` per-row PRNG keys, ``inc [NN, NN, E]`` the mesh's dense
    0/1 route incidence (:func:`_mesh_incidence`).  Every row must have at
    least one eligible (``len >= 4``) set — the host resolves the rest
    without entering the scan.

    Move deltas are scatter-free: reversing ``cyc[i:j+1]`` flips every
    interior edge, and the per-link count of flipping edge ``(a, b)`` is
    ``inc[b, a] - inc[a, b]`` — so one cumulative sum of flip rows along
    each cycle turns a move's interior delta into ``flipcum[j] -
    flipcum[i]`` (two gathers), leaving only the four boundary-edge
    incidence gathers.  Applying is scatter-free too: the best
    non-worsening move per sharing-set is applied jointly (deltas across
    sets add), with an exact re-check of the combined objective — if the
    combination worsens it (overlapping routes), the round falls back to
    the single globally best move, so the objective never increases, the
    same monotonicity the loop reference's sequential best-first rule has.

    ``cycles0`` / ``loads0`` are donated: the caller packs fresh buffers
    per bucket (never the cached ``inc``), so XLA aliases the large padded
    state with the returned ``(cycles, loads)`` instead of allocating a
    second copy.  ``keys`` has no same-shape output to alias and stays
    un-donated.
    """
    R, S, N = cycles0.shape
    E = loads0.shape[1]
    M = n_moves
    ridx = jnp.arange(R)

    def round_body(carry, _):
        cycles, loads, obj, keys = carry
        ks = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
        keys_next, k_si, k_r = ks[:, 0], ks[:, 1], ks[:, 2]
        # -- propose: uniform eligible set, uniform valid (i, j) reversal --
        # width-independent set draw: ONE uniform per proposal, rank-indexed
        # into the eligible sets.  (random.categorical would consume bits
        # shaped [M, S], tying every row's stream to the bucket's padded set
        # axis; this consumes [M] regardless of padding, so the canonical
        # pow4/chunked bucket shapes leave each schedule bit-identical.)
        elig = lens >= 4                                        # [R, S]
        n_elig = jnp.sum(elig, axis=1)                          # [R]
        u = jax.vmap(lambda k: jax.random.uniform(k, (M,)))(k_si)
        idx = jnp.minimum((u * n_elig[:, None]).astype(jnp.int32),
                          n_elig[:, None] - 1)                  # [R, M]
        rank = jnp.cumsum(elig, axis=1) - 1                     # [R, S]
        si = jnp.argmax((rank[:, None, :] == idx[:, :, None])
                        & elig[:, None, :], axis=2)             # [R, M]
        n = jnp.take_along_axis(lens, si, axis=1)               # [R, M]
        # ranks over i<j pairs in (i, j) lexicographic order; the full
        # reversal (0, n-1) has rank n-2 and is skipped by shifting — every
        # draw lands on a real 2-opt move, honoring the move budget
        cnt = n * (n - 1) // 2 - 1
        r = jax.vmap(lambda k, c: jax.random.randint(k, (M,), 0, c))(
            k_r, jnp.maximum(cnt, 1))
        r = r + (r >= n - 2)
        t = jnp.minimum(jnp.arange(1, N)[None, None, :], (n - 1)[..., None])
        cum = t * (n[..., None] - 1) - t * (t - 1) // 2
        i = jnp.sum(r[..., None] >= cum, axis=-1)
        j = i + 1 + (r - (i * (n - 1) - i * (i - 1) // 2))
        # -- flip-cumsum per (row, set): interior deltas become gathers ---
        ca, cb = cycles[..., :-1], cycles[..., 1:]              # [R, S, N-1]
        flip = (inc[cb, ca] - inc[ca, cb]).astype(jnp.int16)
        # log-depth associative scan: XLA CPU lowers plain cumsum along a
        # middle axis pathologically (~12x slower here).  int16 halves the
        # memory traffic of the [R, S, N, E] prefix again vs f32 (2.3x on
        # the 960-link 16x16 case) and stays exact: the counts are bounded
        # by the cycle length, far inside the int16 range
        flipcum = jnp.concatenate(
            [jnp.zeros_like(flip[..., :1, :]),
             jax.lax.associative_scan(jnp.add, flip, axis=2)],
            axis=2)                                             # [R, S, N, E]
        fflat = flipcum.reshape(R, S * N, E)

        def fc(pos):   # [R, M] position -> [R, M, E] flipcum row
            return jnp.take_along_axis(fflat, (si * N + pos)[..., None],
                                       axis=1)

        c = jnp.take_along_axis(cycles, si[..., None], axis=1)  # [R, M, N]

        def at(pos):
            return jnp.take_along_axis(c, pos[..., None], axis=2)[..., 0]

        prv = at(jnp.where(i > 0, i - 1, n - 1))
        nxt = at(jnp.where(j + 1 < n, j + 1, 0))
        ci, cj = at(i), at(j)
        bterm = (inc[prv, cj] + inc[ci, nxt]
                 - inc[prv, ci] - inc[cj, nxt]).astype(jnp.int16)
        w = jnp.take_along_axis(weights, si, axis=1)            # [R, M]
        # per-link counts are small exact ints carried in int16; scoring
        # scales them by the set weight in f32 — acceptance is protected
        # by the exact-f64 gate below, never by these scores
        cnt = fc(j) - fc(i) + bterm                             # [R, M, E]
        loads32 = loads.astype(jnp.float32)
        w32 = w.astype(jnp.float32)
        # -- score every proposal against the current loads ---------------
        if use_pallas:
            from ..kernels import dse_eval
            # streamed link tiles + in-kernel count scaling: the f32
            # [R, M, E] delta tensor is never materialized
            objs = dse_eval.delta_maxload_rows(loads32, cnt, w32)
        else:
            delta = cnt.astype(jnp.float32) * w32[..., None]
            objs = jnp.max(loads32[:, None, :] + delta, axis=-1)
        # -- best non-worsening move per set, joint apply with fallback ---
        obj32 = obj.astype(jnp.float32)
        on_set = si[..., None] == jnp.arange(S)[None, None, :]  # [R, M, S]
        objs_s = jnp.where(on_set, objs[..., None], jnp.inf)
        best_m = jnp.argmin(objs_s, axis=1)                     # [R, S]
        valid_s = jnp.min(objs_s, axis=1) <= obj32[:, None]
        m_star = jnp.argmin(objs, axis=1)                       # [R]
        # exact per-set counts of the chosen moves, f64-weighted (the
        # int16 counts convert exactly)
        cnt_s = jnp.take_along_axis(cnt, best_m[..., None],
                                    axis=1).astype(loads.dtype)
        w_s = jnp.where(valid_s, weights, 0.0)                  # [R, S]
        comb = jnp.einsum('rs,rse->re', w_s, cnt_s)             # exact f64
        take_comb = jnp.max(loads + comb, axis=-1) <= obj
        take_single = ~take_comb & (objs[ridx, m_star] <= obj32)
        apply_s = jnp.where(
            take_comb[:, None], valid_s,
            take_single[:, None] & (si[ridx, m_star][:, None]
                                    == jnp.arange(S)[None, :]))
        w_s = jnp.where(apply_s, weights, 0.0)
        cand = loads + jnp.einsum('rs,rse->re', w_s, cnt_s)
        # exact final gate: whatever the scoring precision, a round never
        # leaves the row with a worse objective than it entered with
        new_obj = jnp.max(cand, axis=-1)
        ok = new_obj <= obj
        apply_s = apply_s & ok[:, None]
        loads = jnp.where(ok[:, None], cand, loads)
        obj = jnp.where(ok, new_obj, obj)
        # -- reverse the applied segments in-array ------------------------
        i_s = jnp.take_along_axis(i, best_m, axis=1)            # [R, S]
        j_s = jnp.take_along_axis(j, best_m, axis=1)
        kk = jnp.arange(N)[None, None, :]
        seg = ((kk >= i_s[..., None]) & (kk <= j_s[..., None])
               & apply_s[..., None])
        rev = jnp.where(seg, i_s[..., None] + j_s[..., None] - kk, kk)
        cycles = jnp.take_along_axis(cycles, rev, axis=2)
        return (cycles, loads, obj, keys_next), None

    obj0 = jnp.max(loads0, axis=-1)
    (cycles, loads, obj, _), _ = jax.lax.scan(
        round_body, (cycles0, loads0, obj0, keys), None, length=rounds)
    return cycles, loads, obj


# -- host-side problem packing ------------------------------------------------


@dataclass
class _Setup:
    """One problem either pre-resolved or packed for the jitted search."""

    noc: MeshNoc
    sets: tuple[tuple[int, ...], ...]
    chunks: tuple[float, ...]
    resolve: str | None = None             # "exact" | "inits" | None (scan)
    inits: list[list[list[int]]] | None = None   # [chain][set] node order
    seed_eff: int = 0                      # Random(seed).getrandbits(32)
    digest: int = 0                        # crc32 problem stream id


def _problem_digest(noc: MeshNoc, sets, chunks, restarts: int, iters: int,
                    moves_per_round: int) -> int:
    """Stable per-problem stream id — batch composition must not matter."""
    return zlib.crc32(repr((noc.rows, noc.cols, sets, chunks, restarts,
                            iters, moves_per_round)).encode())


def _best_of(noc: MeshNoc, candidates, chunks) -> ScheduleResult | None:
    """First-strict-best candidate cycles by exact recomputed objective."""
    best, best_obj = None, np.inf
    for cycles in candidates:
        obj = noc.max_link_load(_all_transfers(cycles, list(chunks)))
        if obj < best_obj:
            best, best_obj = cycles, obj
    return best


def _setup_problem(noc: MeshNoc, sets, chunks, *, rng: random.Random,
                   restarts: int, iters: int,
                   moves_per_round: int) -> _Setup:
    """Normalize one problem; resolve it host-side when the scan can't help.

    Mirrors ``solve_ilp_ls``'s structure: the small single-set path is
    exhaustive, and a problem with no 2-opt-eligible set (every cycle
    shorter than 4 nodes) reduces to picking the best restart
    initialization — exactly what the loop reference does when
    ``_propose_moves`` comes back empty.
    """
    sets = tuple(tuple(s) for s in sets)
    chunks = tuple(float(c) for c in chunks)
    setup = _Setup(noc=noc, sets=sets, chunks=chunks)
    seed_eff = rng.getrandbits(32)
    if len(sets) == 1 and len(sets[0]) <= 7:
        setup.resolve = "exact"   # sentinel: caller runs _solve_exact
        return setup
    chains = max(3, restarts)
    inits = [_initial_cycles(noc, [list(s) for s in sets], r, rng)
             for r in range(chains)]
    if not any(len(s) >= 4 for s in sets):
        setup.resolve = "inits"   # sentinel: caller picks the best init
        setup.inits = inits
        return setup
    setup.inits = inits
    setup.seed_eff = seed_eff
    setup.digest = _problem_digest(noc, sets, chunks, restarts, iters,
                                   moves_per_round)
    return setup


def _rounds(iters: int, moves_per_round: int) -> int:
    return max(1, -(-iters // moves_per_round))


# fixed row-axis size for canonical (pad_shapes) buckets: bigger buckets run
# as several 32-row dispatches of ONE program, smaller ones pad up to it
_R_CHUNK = 32
_SOLO_EXACT_LINKS = 512   # solo solves on meshes at least this wide get
                          # exact (pow2) rows instead of the canonical chunk


def _pow4_bucket(n: int, minimum: int) -> int:
    """Next power of FOUR >= max(n, minimum) — the coarse program-key class.

    Under ``pad_shapes`` the set-count and set-size axes quantize to pow4
    instead of pow2: both axes are fully masked (padded sets carry zero
    weight and zero length, padded tail slots sit past every row's true
    length), so coarser padding is bit-safe and halves the number of
    distinct compiled programs per mesh envelope — at most 4x padded work
    on one axis, against a ~1.4 s XLA compile saved per collapsed shape.
    """
    p = pow2_bucket(n, minimum=minimum)
    return p * 2 if (p.bit_length() - 1) % 2 else p


def _bucket_key(st: _Setup, pad_shapes: bool) -> tuple:
    """(mesh, padded set count, padded max set size) — one jit program each.

    With ``pad_shapes`` the class bounds are pow4 (see :func:`_pow4_bucket`)
    so problems with nearby shapes share a bucket AND a compiled program;
    without it they are exact pow2, the PR 6 per-shape behavior.
    """
    pad = _pow4_bucket if pad_shapes else pow2_bucket
    return (st.noc, pad(len(st.sets), minimum=1),
            pad(max(len(s) for s in st.sets), minimum=4))


def _resolve_host(st: _Setup, link_bw: float, freq: float,
                  pj_per_bit_hop: float) -> ScheduleResult | None:
    """Finish a pre-resolved (small/no-eligible-move) setup; None if it
    needs the jitted search."""
    if st.resolve == "exact":
        return _solve_exact(st.noc, [list(s) for s in st.sets],
                            list(st.chunks), link_bw, freq, pj_per_bit_hop)
    if st.resolve == "inits":
        best = _best_of(st.noc, st.inits, st.chunks)
        return _finish(st.noc, best, list(st.chunks), link_bw, freq,
                       pj_per_bit_hop)
    return None


def _finish_chains(st: _Setup, per_chain, link_bw: float, freq: float,
                   pj_per_bit_hop: float) -> ScheduleResult:
    """Pick a setup's best chain by exact recompute and build the result.

    Re-deriving every chain's objective from the transfers themselves (the
    loop reference's restart comparison) keeps the winner free of any
    accumulated in-array delta round-off.
    """
    best = _best_of(st.noc, per_chain, st.chunks)
    return _finish(st.noc, best, list(st.chunks), link_bw, freq,
                   pj_per_bit_hop)


@jax.jit
def _fold_keys(seeds, digests, chains):
    """Per-row PRNG keys ``fold_in(fold_in(PRNGKey(seed), digest), chain)``.

    One vmapped dispatch per bucket instead of two ``fold_in`` round-trips
    per (problem, chain) row — the derivation itself (and therefore every
    schedule) is unchanged.
    """
    def one(se, dg, c):
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(se), dg), c)
    return jax.vmap(one)(seeds, digests, chains)


#: module-level jit objects, keyed for ``compiled_program_count``-style
#: introspection (see :func:`repro.engine.engine_program_counts`),
#: registered at creation time
_JITTED = register_jits(
    scan_solve=_scan_solve,
    fold_keys=_fold_keys,
)


def _run_bucket(setups: list[_Setup], *, rounds: int, moves_per_round: int,
                s_pad: int, n_pad: int, use_pallas: bool,
                pad_shapes: bool = True) -> list[list]:
    """Solve one bucket's problems in lockstep; returns per-problem chains
    (each a ``[chain][set] -> node order`` nested list).

    Every problem in a bucket shares the mesh and the padded (sets, set
    size) envelope; rows of the jitted state are (problem x chain) pairs.
    With ``pad_shapes`` the row axis is CANONICAL: buckets run as chunks of
    exactly ``_R_CHUNK`` rows (larger buckets become several dispatches of
    one program, smaller ones pad up), and the mesh axes are pow2-padded,
    so different meshes with the same padded envelope share ONE compiled
    program (the incidence table is a runtime argument — only shapes key
    the jit cache).  Without it the row axis is the exact pow2 bucket of
    the batch, the PR 6 per-shape behavior.  Rows are independent (one
    PRNG stream each; padded rows burn copies of row 0), so chunking and
    padding leave every problem's schedule bit-identical.
    """
    chains = len(setups[0].inits)
    solo_exact = (len(setups) == 1
                  and setups[0].noc.n_links() >= _SOLO_EXACT_LINKS)
    if not pad_shapes or chains > _R_CHUNK or solo_exact:
        # exact rows when canonicalization is off, when one problem's
        # chains overflow a chunk, or for a SOLO solve on a big mesh: a
        # single 6-chain Fig. 12 16x16 solve (960 links) is memory-bound
        # in its dense link state and must not burn 26 padded rows.  On
        # small meshes burner rows are nearly free, so solos keep the
        # canonical chunk width and share the batched bucket's program.
        # (Row count never shifts a chain's PRNG stream — each row folds
        # its own key — so this only changes cost, never results.)
        r_pad = pow2_bucket(len(setups) * chains, minimum=4)
        per = len(setups)
    else:
        r_pad = _R_CHUNK
        per = max(1, _R_CHUNK // chains)
    results: list[list] = []
    for lo in range(0, len(setups), per):
        results.extend(_pack_solve(
            setups[lo:lo + per], rounds=rounds,
            moves_per_round=moves_per_round, s_pad=s_pad, n_pad=n_pad,
            r_pad=r_pad, use_pallas=use_pallas, pad_shapes=pad_shapes))
    return results


def _pack_solve(setups: list[_Setup], *, rounds: int, moves_per_round: int,
                s_pad: int, n_pad: int, r_pad: int, use_pallas: bool,
                pad_shapes: bool) -> list[list]:
    """Pack one row-chunk of setups and run the jitted search at ``r_pad``.

    All inputs go through explicit ``jax.device_put``: the engine performs
    no implicit host->device transfers (``tests/test_pipeline.py`` runs
    this under ``jax.transfer_guard("disallow")``).
    """
    noc = setups[0].noc
    chains = len(setups[0].inits)
    _, e_pad = _mesh_pads(noc, pad_shapes)
    rows = len(setups) * chains
    metrics.METRICS.histogram("scheduler.bucket_fill").observe(rows / r_pad)
    metrics.METRICS.counter("scheduler.padded_rows").inc(r_pad - rows)
    cycles0 = np.zeros((r_pad, s_pad, n_pad), dtype=np.int32)
    lens = np.zeros((r_pad, s_pad), dtype=np.int32)
    weights = np.zeros((r_pad, s_pad))
    loads0 = np.zeros((r_pad, e_pad))
    keys = np.zeros((r_pad, 2), dtype=np.uint32)
    e = noc.n_links()
    for p, st in enumerate(setups):
        for c, init in enumerate(st.inits):
            row = p * chains + c
            for si, cyc in enumerate(init):
                cycles0[row, si, :len(cyc)] = cyc
                lens[row, si] = len(cyc)
                weights[row, si] = (len(cyc) - 1) * st.chunks[si]
            loads0[row, :e] = noc.link_loads_np(
                _all_transfers(init, list(st.chunks)))
    # keys feed the host-side packed arrays: one pull per bucket, before
    # the scan dispatch
    # pimlint: disable-next-line=host-sync -- sanctioned per-bucket key pull
    keys[:rows] = np.asarray(_fold_keys(
        jax.device_put(np.array(
            [st.seed_eff for st in setups for _ in range(chains)],
            dtype=np.uint32)),
        jax.device_put(np.array(
            [st.digest for st in setups for _ in range(chains)],
            dtype=np.uint32)),
        jax.device_put(np.arange(rows, dtype=np.uint32) % chains)),
        dtype=np.uint32)
    for row in range(rows, r_pad):   # padded rows: burn a copy of row 0
        cycles0[row], lens[row] = cycles0[0], lens[0]
        weights[row], loads0[row], keys[row] = (weights[0], loads0[0],
                                                keys[0])
    with enable_x64():
        inc = (_mesh_incidence(noc, *_mesh_pads(noc, True)) if pad_shapes
               else _mesh_incidence(noc))
        # cycles0/loads0 are donated by _scan_solve — freshly packed per
        # bucket, so handing the buffers over is safe
        out_cycles, _, _ = _scan_solve(
            jax.device_put(cycles0), jax.device_put(lens),
            jax.device_put(weights), jax.device_put(loads0),
            jax.device_put(keys), inc,
            rounds=rounds, n_moves=moves_per_round, use_pallas=use_pallas)
    # pimlint: disable-next-line=host-sync -- the one result pull per bucket
    out_cycles = np.asarray(out_cycles)
    results = []
    for p, st in enumerate(setups):
        per_chain = []
        for c in range(chains):
            row = p * chains + c
            per_chain.append([
                [int(v) for v in out_cycles[row, si, :len(s)]]
                for si, s in enumerate(st.sets)])
        results.append(per_chain)
    return results


def schedule_many(problems, link_bw: float, freq: float,
                  pj_per_bit_hop: float, *, seed: int = 0,
                  restarts: int = 4, iters: int = 400,
                  moves_per_round: int = 32,
                  use_pallas: bool | None = None,
                  pad_shapes: bool | None = None) -> list[ScheduleResult]:
    """Solve a batch of ``(noc, sharing_sets, chunk_bytes)`` problems.

    Problems are pow2-bucketed by (mesh, set count, max set size) and each
    bucket runs through ONE jitted multi-chain search; small or
    no-eligible-move problems resolve host-side exactly like
    ``solve_ilp_ls``.  Each element equals the single-problem
    ``solve_ilp_ls(..., backend="scan", seed=seed)`` result bit-for-bit —
    per-problem PRNG streams make results independent of batch composition,
    so the mapper's schedule memo can be prefilled batch-wise.

    ``pad_shapes`` (default: the module's ``_PAD_SHAPES``, True) pow2-pads
    the mesh axes AND canonicalizes the bucket shape — pow4 set-count/
    set-size classes, fixed ``_R_CHUNK``-row dispatches — so distinct
    meshes and nearby problem shapes share compiled programs; results are
    bit-identical with or without padding — only compile count changes.
    """
    use_pallas = _USE_PALLAS if use_pallas is None else use_pallas
    pad_shapes = _PAD_SHAPES if pad_shapes is None else pad_shapes
    rounds = _rounds(iters, moves_per_round)
    with trace.span("schedule_many", cat="engine",
                    problems=len(problems)) as sp:
        results: list[ScheduleResult | None] = [None] * len(problems)
        buckets: dict[tuple, list[tuple[int, _Setup]]] = {}
        for pi, (noc, sets, chunks) in enumerate(problems):
            st = _setup_problem(noc, sets, chunks, rng=random.Random(seed),
                                restarts=restarts, iters=iters,
                                moves_per_round=moves_per_round)
            results[pi] = _resolve_host(st, link_bw, freq, pj_per_bit_hop)
            if results[pi] is None:
                buckets.setdefault(_bucket_key(st, pad_shapes),
                                   []).append((pi, st))
        for (mesh, s_pad, n_pad), entries in buckets.items():
            nn_pad, e_pad = _mesh_pads(mesh, pad_shapes)
            with trace.span("schedule", cat="engine",
                            bucket=f"{mesh}:{s_pad}x{n_pad}",
                            envelope=f"{nn_pad}n{e_pad}e",
                            problems=len(entries)):
                chains = _run_bucket([st for _, st in entries],
                                     rounds=rounds,
                                     moves_per_round=moves_per_round,
                                     s_pad=s_pad, n_pad=n_pad,
                                     use_pallas=use_pallas,
                                     pad_shapes=pad_shapes)
            for (pi, st), per_chain in zip(entries, chains):
                results[pi] = _finish_chains(st, per_chain, link_bw, freq,
                                             pj_per_bit_hop)
        sp["buckets"] = len(buckets)
        sp["host_resolved"] = len(problems) - sum(
            len(v) for v in buckets.values())
    return results


def _solve_one_scan(noc: MeshNoc, sharing_sets, chunk_bytes, link_bw: float,
                    freq: float, pj_per_bit_hop: float, *,
                    rng: random.Random, restarts: int, iters: int,
                    moves_per_round: int) -> ScheduleResult:
    """``solve_ilp_ls``'s scan backend: one problem through the engine.

    Identical resolution sequence to :func:`schedule_many` (shared helpers)
    — only the RNG comes from the caller, so an explicit ``rng`` keeps
    working like the loop backend's contract.
    """
    st = _setup_problem(noc, sharing_sets, chunk_bytes, rng=rng,
                        restarts=restarts, iters=iters,
                        moves_per_round=moves_per_round)
    got = _resolve_host(st, link_bw, freq, pj_per_bit_hop)
    if got is not None:
        return got
    _, s_pad, n_pad = _bucket_key(st, _PAD_SHAPES)
    per_chain = _run_bucket([st], rounds=_rounds(iters, moves_per_round),
                            moves_per_round=moves_per_round, s_pad=s_pad,
                            n_pad=n_pad, use_pallas=_USE_PALLAS,
                            pad_shapes=_PAD_SHAPES)[0]
    return _finish_chains(st, per_chain, link_bw, freq, pj_per_bit_hop)
