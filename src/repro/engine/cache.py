"""Content-addressed memoization of mapper/scheduler evaluations.

The DSE loop re-costs any (hardware config, workload set) pair every time a
strategy proposes it; across a multi-strategy campaign the same points recur
constantly (strategies converge on the same promising region).  This cache
keys results on a content digest of the :class:`HwConfig` (including its
:class:`PimConstraints`) and the :class:`DnnGraph` structure — not on object
identity — so repeated strategies, restarted campaigns, and checkpoint
resumes never re-run the mapper for an identical point.

Digests are SHA-256 over a canonical JSON encoding; thread-safe for the
campaign orchestrator's concurrent strategy runners.
"""

from __future__ import annotations

import hashlib
import json
import threading
from pathlib import Path
from typing import Any, Iterable

from ..core.hardware import HwConfig
from ..core.ir import DnnGraph

_LAYER_FIELDS = ("name", "kind", "B", "C", "H", "W", "K", "HK", "WK",
                 "stride", "pad")


def _sha(obj: Any) -> str:
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()[:32]


def hw_digest(cfg: HwConfig) -> str:
    """Digest of the full hardware point: variables + substrate constants."""
    cons = cfg.cons
    return _sha({
        "var": cfg.as_tuple(),
        "cons": {k: getattr(cons, k) for k in (
            "tech_nm", "ba_row", "ba_col", "width_bank_bits",
            "cap_bank_bytes", "area_budget_mm2", "freq_hz", "data_bits",
            "psum_bits", "dram_energy_pj_per_bit", "dram_row_bytes",
            "dram_row_act_energy_pj", "dram_row_miss_cycles",
            "noc_energy_pj_per_bit_hop", "router_latency_cycles",
            "mac_area_um2", "sram_area_mm2_per_mib", "node_fixed_area_mm2")},
    })


def graph_digest(graph: DnnGraph) -> str:
    """Digest of a workload DNN: layer fields + DAG edges (name-stable)."""
    layers = [{f: getattr(l, f) for f in _LAYER_FIELDS}
              for l in graph.layers]
    edges = [(n, p) for n in (l.name for l in graph.layers)
             for p in graph.preds(n)]
    return _sha({"name": graph.name, "layers": layers, "edges": edges})


def workloads_digest(graphs: Iterable[DnnGraph]) -> str:
    return _sha([graph_digest(g) for g in graphs])


class EvalCache:
    """Thread-safe content-addressed result store with optional persistence.

    Values must be JSON-serializable (the evaluator stores
    ``(cost, lats, ens)`` tuples).  ``save``/``load`` let a campaign carry
    its evaluation table across checkpoint/resume cycles.
    """

    def __init__(self):
        self._data: dict[str, Any] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(cfg: HwConfig, workloads: Iterable[DnnGraph]) -> str:
        return hw_digest(cfg) + ":" + workloads_digest(workloads)

    def get(self, key: str) -> Any | None:
        with self._lock:
            if key in self._data:
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key: str, value: Any) -> None:
        with self._lock:
            self._data[key] = value

    def __len__(self) -> int:
        return len(self._data)

    @property
    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._data)}

    # -- persistence ---------------------------------------------------------
    def save(self, path: str | Path) -> None:
        with self._lock:
            Path(path).write_text(json.dumps(self._data))

    @classmethod
    def load(cls, path: str | Path) -> "EvalCache":
        cache = cls()
        p = Path(path)
        if p.exists():
            cache._data = json.loads(p.read_text())
        return cache
