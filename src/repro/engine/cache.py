"""Content-addressed memoization of mapper/scheduler evaluations.

The DSE loop re-costs any (hardware config, workload set) pair every time a
strategy proposes it; across a multi-strategy campaign the same points recur
constantly (strategies converge on the same promising region).  This cache
keys results on a content digest of the :class:`HwConfig` (including its
:class:`PimConstraints`) and the :class:`DnnGraph` structure — not on object
identity — so repeated strategies, restarted campaigns, and checkpoint
resumes never re-run the mapper for an identical point.

Digests are SHA-256 over a canonical JSON encoding; thread-safe for the
campaign orchestrator's concurrent strategy runners.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import sqlite3
import threading
import warnings
from pathlib import Path
from typing import Any, Iterable

from ..core.hardware import HwConfig, PimConstraints
from ..core.ir import DnnGraph
from ..obs import metrics as obs_metrics
from ..obs import trace

_LAYER_FIELDS = ("name", "kind", "B", "C", "H", "W", "K", "HK", "WK",
                 "stride", "pad")

# every PimConstraints field keys evaluation results: the substrate constants
# feed the cost model (freq, DRAM/NoC energies, row geometry), the mapper
# (capacity via cap_bank_bytes / ba_*), and legality (area_budget_mm2)
_CONS_FIELDS = tuple(f.name for f in dataclasses.fields(PimConstraints))


def _sha(obj: Any) -> str:
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()[:32]


def _cons_dict(cons: PimConstraints) -> dict:
    return {k: getattr(cons, k) for k in _CONS_FIELDS}


def cons_digest(cons: PimConstraints) -> str:
    """Digest of the substrate constants alone.

    Campaign checkpoints fold this into their fingerprint: observations whose
    legality/cost was judged under one :class:`PimConstraints` (say a
    different ``area_budget_mm2``) must never be replayed under another.
    """
    return _sha(_cons_dict(cons))


def hw_digest(cfg: HwConfig) -> str:
    """Digest of the full hardware point: variables + substrate constants."""
    return _sha({"var": cfg.as_tuple(), "cons": _cons_dict(cfg.cons)})


def graph_digest(graph: DnnGraph) -> str:
    """Digest of a workload DNN: layer fields + DAG edges (name-stable)."""
    layers = [{f: getattr(l, f) for f in _LAYER_FIELDS}
              for l in graph.layers]
    edges = [(n, p) for n in (l.name for l in graph.layers)
             for p in graph.preds(n)]
    return _sha({"name": graph.name, "layers": layers, "edges": edges})


def workloads_digest(graphs: Iterable[DnnGraph]) -> str:
    return _sha([graph_digest(g) for g in graphs])


class EvalCache:
    """Thread-safe content-addressed result store with optional persistence.

    Values must be JSON-serializable (the evaluator stores
    ``(cost, lats, ens)`` tuples).  ``save``/``load`` let a campaign carry
    its evaluation table across checkpoint/resume cycles.
    """

    def __init__(self):
        self._data: dict[str, Any] = {}
        self._lock = threading.Lock()
        # single-flight admission: key -> Event set when the owning
        # evaluation commits (or abandons), see lease()/complete()
        self._flight_lock = threading.Lock()
        self._inflight: dict[str, threading.Event] = {}
        self.hits = 0
        self.misses = 0
        self.flight_waits = 0

    @staticmethod
    def key(cfg: HwConfig, workloads: Iterable[DnnGraph]) -> str:
        return hw_digest(cfg) + ":" + workloads_digest(workloads)

    def get(self, key: str) -> Any | None:
        with self._lock:
            if key in self._data:
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key: str, value: Any) -> None:
        with self._lock:
            self._data[key] = value

    # -- single-flight admission ---------------------------------------------
    #
    # Concurrent evaluators (the sharded campaign's eval workers, duplicated
    # tenant submissions) race to compute the same key: both miss, both run
    # the mapper, the second put is wasted work.  lease() closes the race:
    # exactly one caller becomes the key's *owner* (computes, puts,
    # complete()s); everyone else blocks until the owner commits, then reads
    # the cached value.  Owners MUST call complete(key) in a finally — an
    # abandoned lease (owner raised) wakes the waiters, and whoever re-leases
    # first becomes the new owner.

    def lease(self, key: str,
              timeout_s: float = 60.0) -> tuple[Any | None, bool]:
        """Hit, or admission to compute: returns ``(value, owner)``.

        ``(value, False)`` — cached (possibly after waiting out another
        caller's in-flight evaluation); ``(None, True)`` — this caller now
        owns computing ``key`` and must ``put`` + ``complete`` it.
        ``timeout_s`` bounds each wait on the owner; on timeout the state is
        simply re-checked, so a stuck owner delays waiters but cannot wedge
        them permanently once it abandons.
        """
        while True:
            with self._flight_lock:
                ev = self._inflight.get(key)
                if ev is None:
                    hit = self.get(key)
                    if hit is not None:
                        return hit, False
                    self._inflight[key] = threading.Event()
                    return None, True
                self.flight_waits += 1
            ev.wait(timeout_s)

    def complete(self, key: str) -> None:
        """Release a lease()d key, waking waiters (idempotent)."""
        with self._flight_lock:
            ev = self._inflight.pop(key, None)
        if ev is not None:
            ev.set()

    def __len__(self) -> int:
        return len(self._data)

    @property
    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._data),
                "flight_waits": self.flight_waits}

    # -- persistence ---------------------------------------------------------
    #
    # Infeasible evaluations store ``float('inf')`` costs, which json.dumps
    # would emit as the non-RFC literal ``Infinity`` (unreadable to strict
    # parsers).  Persisted values swap +inf for a ``None`` sentinel on save
    # and back on load; ``allow_nan=False`` keeps any regression (-inf, nan,
    # or a new inf-carrying field this recursion misses) loud at save time
    # rather than silently corrupted at load.  Values are the evaluator's
    # ``(cost, lats, ens)`` tuples, which never contain a legitimate None.

    @staticmethod
    def _inf_to_none(v: Any) -> Any:
        if isinstance(v, float) and math.isinf(v) and v > 0:
            return None
        if isinstance(v, (list, tuple)):
            return [EvalCache._inf_to_none(x) for x in v]
        if isinstance(v, dict):
            return {k: EvalCache._inf_to_none(x) for k, x in v.items()}
        return v

    @staticmethod
    def _none_to_inf(v: Any) -> Any:
        if v is None:
            return math.inf
        if isinstance(v, list):
            return [EvalCache._none_to_inf(x) for x in v]
        if isinstance(v, dict):
            return {k: EvalCache._none_to_inf(x) for k, x in v.items()}
        return v

    def save(self, path: str | Path) -> None:
        with self._lock:
            payload = {k: self._inf_to_none(v) for k, v in self._data.items()}
            Path(path).write_text(json.dumps(payload, allow_nan=False))

    @classmethod
    def load(cls, path: str | Path) -> "EvalCache":
        """Load a persisted table; a corrupt file starts empty — loudly.

        A truncated / garbled JSON file (half-written save, disk trouble)
        must not take the whole campaign down, but silently dropping a
        warm evaluation table costs users entire re-runs, so this mirrors
        ``Campaign._discard_checkpoint``: RuntimeWarning, a
        ``cache.discarded`` counter and an instant trace event.
        """
        cache = cls()
        p = Path(path)
        if not p.exists():
            return cache
        try:
            data = json.loads(p.read_text())
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            warnings.warn(
                f"discarding eval cache {p} (unreadable): {e}; "
                "starting empty", RuntimeWarning, stacklevel=2)
            obs_metrics.METRICS.counter("cache.discarded").inc()
            trace.instant("cache_discarded", cat="cache", path=str(p),
                          error=str(e))
            return cache
        cache._data = {k: cls._none_to_inf(v) for k, v in data.items()}
        return cache


class PersistentEvalCache(EvalCache):
    """Cross-process :class:`EvalCache` backed by a sqlite file.

    The file is the shared evaluation table of a *mega-campaign*: eval-shard
    worker threads in one process, concurrent campaign processes, and
    repeated submissions of the same campaign all read and write one store,
    so an identical (config, workloads) point is mapped at most once
    fleet-wide.  Design points:

    * every ``put`` is one committed sqlite transaction (WAL journal,
      ``busy_timeout`` retries) — atomic under concurrent writers and
      durable against ``SIGKILL`` mid-campaign, which is what makes
      kill-and-resume lose zero evaluations;
    * values keep the JSON encoding of the base class (``+inf`` ↔ ``None``
      sentinel, ``allow_nan=False``) so a store written by one backend
      version stays strict-RFC readable;
    * reads fill the in-memory table, so a key is decoded once per process;
    * ``stats`` additionally reports ``persistent_hits`` (served from disk,
      not memory) and ``reeval_preexisting`` — puts that overwrote a key
      already present when the store was opened.  A resume run asserting
      ``reeval_preexisting == 0`` has proven that no already-evaluated
      point was re-mapped (the BENCH 9 kill-and-resume contract).

    Thread-safe: sqlite connections are per-thread (``threading.local``);
    the in-memory side reuses the base class lock.
    """

    _SCHEMA = ("CREATE TABLE IF NOT EXISTS entries ("
               "key TEXT PRIMARY KEY, value TEXT NOT NULL)")

    def __init__(self, path: str | Path, *, timeout_s: float = 30.0):
        super().__init__()
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._timeout_s = timeout_s
        self._tls = threading.local()
        self.persistent_hits = 0
        self.reeval_preexisting = 0
        try:
            con = self._con()
            self._preexisting = {row[0] for row in
                                 con.execute("SELECT key FROM entries")}
        except sqlite3.DatabaseError:
            # not a sqlite store (truncated, corrupt, or a foreign file) —
            # sideline it and start fresh; an unreadable cache must never
            # be the reason a campaign cannot start
            stale = getattr(self._tls, "con", None)
            if stale is not None:
                stale.close()
                self._tls.con = None
            quarantine = self.path.with_suffix(self.path.suffix + ".corrupt")
            self.path.replace(quarantine)
            warnings.warn(
                f"unreadable eval cache {self.path}: sidelined to "
                f"{quarantine}, starting fresh", RuntimeWarning,
                stacklevel=2)
            con = self._con()
            self._preexisting = set()

    def _con(self) -> sqlite3.Connection:
        con = getattr(self._tls, "con", None)
        if con is None:
            con = sqlite3.connect(self.path, timeout=self._timeout_s)
            # WAL lets concurrent processes read while one writes; NORMAL
            # synchronous keeps the post-commit durability we need (a
            # committed put survives SIGKILL) without a full fsync storm
            con.execute("PRAGMA journal_mode=WAL")
            con.execute("PRAGMA synchronous=NORMAL")
            con.execute(self._SCHEMA)
            con.commit()
            self._tls.con = con
        return con

    def get(self, key: str) -> Any | None:
        with self._lock:
            if key in self._data:
                self.hits += 1
                return self._data[key]
        row = self._con().execute(
            "SELECT value FROM entries WHERE key = ?", (key,)).fetchone()
        with self._lock:
            if row is None:
                self.misses += 1
                return None
            value = self._none_to_inf(json.loads(row[0]))
            self._data[key] = value
            self.hits += 1
            self.persistent_hits += 1
            return value

    def put(self, key: str, value: Any) -> None:
        payload = json.dumps(self._inf_to_none(value), allow_nan=False)
        con = self._con()
        con.execute("INSERT OR REPLACE INTO entries (key, value) "
                    "VALUES (?, ?)", (key, payload))
        con.commit()
        with self._lock:
            self._data[key] = value
            if key in self._preexisting:
                self.reeval_preexisting += 1

    def __len__(self) -> int:
        row = self._con().execute("SELECT COUNT(*) FROM entries").fetchone()
        return int(row[0])

    @property
    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self),
                "flight_waits": self.flight_waits,
                "persistent_hits": self.persistent_hits,
                "preexisting": len(self._preexisting),
                "reeval_preexisting": self.reeval_preexisting}

    def save(self, path: str | Path | None = None) -> None:
        """No-op for the backing store (every put already committed);
        with an explicit ``path``, exports a plain-JSON snapshot."""
        if path is not None:
            self._fill_from_db()
            super().save(path)

    def _fill_from_db(self) -> None:
        rows = self._con().execute("SELECT key, value FROM entries")
        with self._lock:
            for k, v in rows:
                self._data.setdefault(k, self._none_to_inf(json.loads(v)))

    def close(self) -> None:
        con = getattr(self._tls, "con", None)
        if con is not None:
            con.close()
            self._tls.con = None
