"""Content-addressed memoization of mapper/scheduler evaluations.

The DSE loop re-costs any (hardware config, workload set) pair every time a
strategy proposes it; across a multi-strategy campaign the same points recur
constantly (strategies converge on the same promising region).  This cache
keys results on a content digest of the :class:`HwConfig` (including its
:class:`PimConstraints`) and the :class:`DnnGraph` structure — not on object
identity — so repeated strategies, restarted campaigns, and checkpoint
resumes never re-run the mapper for an identical point.

Digests are SHA-256 over a canonical JSON encoding; thread-safe for the
campaign orchestrator's concurrent strategy runners.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import threading
from pathlib import Path
from typing import Any, Iterable

from ..core.hardware import HwConfig, PimConstraints
from ..core.ir import DnnGraph

_LAYER_FIELDS = ("name", "kind", "B", "C", "H", "W", "K", "HK", "WK",
                 "stride", "pad")

# every PimConstraints field keys evaluation results: the substrate constants
# feed the cost model (freq, DRAM/NoC energies, row geometry), the mapper
# (capacity via cap_bank_bytes / ba_*), and legality (area_budget_mm2)
_CONS_FIELDS = tuple(f.name for f in dataclasses.fields(PimConstraints))


def _sha(obj: Any) -> str:
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()[:32]


def _cons_dict(cons: PimConstraints) -> dict:
    return {k: getattr(cons, k) for k in _CONS_FIELDS}


def cons_digest(cons: PimConstraints) -> str:
    """Digest of the substrate constants alone.

    Campaign checkpoints fold this into their fingerprint: observations whose
    legality/cost was judged under one :class:`PimConstraints` (say a
    different ``area_budget_mm2``) must never be replayed under another.
    """
    return _sha(_cons_dict(cons))


def hw_digest(cfg: HwConfig) -> str:
    """Digest of the full hardware point: variables + substrate constants."""
    return _sha({"var": cfg.as_tuple(), "cons": _cons_dict(cfg.cons)})


def graph_digest(graph: DnnGraph) -> str:
    """Digest of a workload DNN: layer fields + DAG edges (name-stable)."""
    layers = [{f: getattr(l, f) for f in _LAYER_FIELDS}
              for l in graph.layers]
    edges = [(n, p) for n in (l.name for l in graph.layers)
             for p in graph.preds(n)]
    return _sha({"name": graph.name, "layers": layers, "edges": edges})


def workloads_digest(graphs: Iterable[DnnGraph]) -> str:
    return _sha([graph_digest(g) for g in graphs])


class EvalCache:
    """Thread-safe content-addressed result store with optional persistence.

    Values must be JSON-serializable (the evaluator stores
    ``(cost, lats, ens)`` tuples).  ``save``/``load`` let a campaign carry
    its evaluation table across checkpoint/resume cycles.
    """

    def __init__(self):
        self._data: dict[str, Any] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(cfg: HwConfig, workloads: Iterable[DnnGraph]) -> str:
        return hw_digest(cfg) + ":" + workloads_digest(workloads)

    def get(self, key: str) -> Any | None:
        with self._lock:
            if key in self._data:
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key: str, value: Any) -> None:
        with self._lock:
            self._data[key] = value

    def __len__(self) -> int:
        return len(self._data)

    @property
    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._data)}

    # -- persistence ---------------------------------------------------------
    #
    # Infeasible evaluations store ``float('inf')`` costs, which json.dumps
    # would emit as the non-RFC literal ``Infinity`` (unreadable to strict
    # parsers).  Persisted values swap +inf for a ``None`` sentinel on save
    # and back on load; ``allow_nan=False`` keeps any regression (-inf, nan,
    # or a new inf-carrying field this recursion misses) loud at save time
    # rather than silently corrupted at load.  Values are the evaluator's
    # ``(cost, lats, ens)`` tuples, which never contain a legitimate None.

    @staticmethod
    def _inf_to_none(v: Any) -> Any:
        if isinstance(v, float) and math.isinf(v) and v > 0:
            return None
        if isinstance(v, (list, tuple)):
            return [EvalCache._inf_to_none(x) for x in v]
        if isinstance(v, dict):
            return {k: EvalCache._inf_to_none(x) for k, x in v.items()}
        return v

    @staticmethod
    def _none_to_inf(v: Any) -> Any:
        if v is None:
            return math.inf
        if isinstance(v, list):
            return [EvalCache._none_to_inf(x) for x in v]
        if isinstance(v, dict):
            return {k: EvalCache._none_to_inf(x) for k, x in v.items()}
        return v

    def save(self, path: str | Path) -> None:
        with self._lock:
            payload = {k: self._inf_to_none(v) for k, v in self._data.items()}
            Path(path).write_text(json.dumps(payload, allow_nan=False))

    @classmethod
    def load(cls, path: str | Path) -> "EvalCache":
        cache = cls()
        p = Path(path)
        if p.exists():
            cache._data = {k: cls._none_to_inf(v)
                           for k, v in json.loads(p.read_text()).items()}
        return cache
