"""Overlapped wave executor: async paired-cost dispatch + late resolve.

The warm DSE iteration is dominated by ``map_many`` costing: every mapper
phase calls ``batch_part_cost_paired``, which pulls its result to host at
the dispatch site (``np.asarray`` right after ``_batch_cost``), so the
backtracking walk, ``_sharing_problem_list`` extraction, and
``schedule_many`` bucket dispatch all serialize behind device work that
XLA would happily run on background threads.  This module splits the
paired sweep into the two halves JAX's async dispatch already supports:

* :func:`dispatch_paired_latency` — the *dispatch* half.  It mirrors
  ``batch_part_cost_paired``'s bucketing exactly (same T-buckets, same
  ``spec_chunk`` blocks, same pow2 pair padding, the same ``_batch_cost``
  programs on the same inputs), but returns a :class:`PendingPairedCost`
  holding the ``[1, n_pad]`` device latency rows instead of blocking.
  The cycles→seconds division runs on device (f64 under ``enable_x64``,
  IEEE-correctly-rounded like the numpy division it replaces), so the
  values that eventually land on host are bitwise identical to the
  serial path's.
* :class:`PendingPairedCost` — the *resolve* half.  ``latency_row()``
  blocks once, stitches the per-block rows back into pair order, and
  caches the host array.

:class:`OverlapExecutor` interleaves the two across waves: ``drive``
runs a phase generator (``PimMapper.map_many_phases``) that yields right
after each dispatch, and at every yield the executor advances the oldest
*deferred* generator (wave k−1's scheduling/accounting) by one step —
host work runs while wave k's costs are in flight.  Deferred generators
retire strictly FIFO and each is exhausted before its successor starts,
so cost accumulation order — and therefore every float result — matches
the serial schedule bit for bit.

``serial_dispatch()`` restores the status-quo timing (sync at the
dispatch site) for baseline benchmarking and A/B tests; the flag is
thread-local so per-tenant overlap composes with
``ShardedCampaign.eval_workers`` threads.
"""

from __future__ import annotations

import threading
from collections import deque
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..obs import trace
from .batch_cost import (PartSpec, _batch_cost, _candidate_grid, _next_pow2,
                         _prep_configs, _prep_specs)
from .jit_registry import register_jits

_STATE = threading.local()


def overlap_enabled() -> bool:
    """True unless the calling thread is inside :func:`serial_dispatch`."""
    return getattr(_STATE, "serial", 0) == 0


@contextmanager
def serial_dispatch():
    """Force dispatches on this thread to resolve at the dispatch site."""
    _STATE.serial = getattr(_STATE, "serial", 0) + 1
    try:
        yield
    finally:
        _STATE.serial -= 1


def _cycles_to_latency_fn(cycles, freq):
    return cycles / freq


_cycles_to_latency = jax.jit(_cycles_to_latency_fn)

_JITTED = register_jits(cycles_to_latency=_cycles_to_latency)


class PendingPairedCost:
    """In-flight latency row of one paired sweep; resolve once, late."""

    __slots__ = ("n", "_parts", "_row")

    def __init__(self, n: int, parts: list):
        self.n = n
        self._parts = parts
        self._row: np.ndarray | None = None

    @property
    def resolved(self) -> bool:
        return self._row is not None

    @property
    def ready(self) -> bool:
        """True when pulling the row would no longer block (non-blocking)."""
        if self._row is not None:
            return True
        return all(dev.is_ready() for _, dev, _ in self._parts)

    def latency_row(self) -> np.ndarray:
        """Block on the device rows (once) and return ``[n]`` seconds."""
        if self._row is None:
            out = np.empty(self.n, np.float64)
            for idxs, dev, n_real in self._parts:
                out[idxs] = np.asarray(dev)[0, :n_real]
            self._row = out
            self._parts = None
        return self._row


def _dispatch_block(configs, specs, idxs, t_pad, spec_chunk, interpret):
    """One ``_batch_cost`` leaf — same padding/programs as the serial path."""
    n_real = len(specs)
    n_pad = min(spec_chunk, _next_pow2(max(128, n_real)))
    if n_pad > n_real:
        configs = configs + [configs[-1]] * (n_pad - n_real)
        specs = specs + [specs[-1]] * (n_pad - n_real)
    lay_np = _prep_specs(specs, t_pad=t_pad)
    cfg_np, cons = _prep_configs(configs)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    with enable_x64():
        lay = {k: jnp.asarray(v) for k, v in lay_np.items()}
        cfg = {k: jnp.asarray(v) for k, v in cfg_np.items()}
        res = _batch_cost(cfg, lay, data_bits=cons.data_bits,
                          psum_bits=cons.psum_bits,
                          dram_row_miss=cons.dram_row_miss_cycles,
                          interpret=interpret, paired=True)
        lat = _cycles_to_latency(res["total_cycles"],
                                 jnp.asarray(cons.freq_hz, dtype=jnp.float64))
    return idxs, lat, n_real


def dispatch_paired_latency(configs, specs, *, spec_chunk: int = 1024,
                            interpret: bool | None = None
                            ) -> PendingPairedCost:
    """Async twin of ``batch_part_cost_paired(...).latency_s[0]``.

    Enqueues the same (T-bucket, pair-block) programs on the same inputs
    and returns a :class:`PendingPairedCost` of device rows.  Under
    :func:`serial_dispatch` the pending resolves immediately, reproducing
    the sync-at-dispatch behaviour of the serial path.
    """
    specs = [s if isinstance(s, PartSpec) else PartSpec(*s) for s in specs]
    configs = list(configs)
    if len(configs) != len(specs):
        raise ValueError("paired costing needs len(configs) == len(specs)")
    if not specs:
        raise ValueError("need at least one (config, spec) pair")
    buckets: dict[int, list[int]] = {}
    for i, s in enumerate(specs):
        buckets.setdefault(
            _next_pow2(max(128, _candidate_grid(s.layer).shape[1])),
            []).append(i)
    parts = []
    with trace.span("dispatch_paired", cat="engine",
                    pairs=len(specs), buckets=len(buckets)):
        for tb in sorted(buckets):
            idxs = buckets[tb]
            for s in range(0, len(idxs), spec_chunk):
                blk = idxs[s:s + spec_chunk]
                parts.append(_dispatch_block(
                    [configs[i] for i in blk], [specs[i] for i in blk],
                    np.asarray(blk, np.intp), tb, spec_chunk, interpret))
    pending = PendingPairedCost(len(specs), parts)
    if not overlap_enabled():
        pending.latency_row()
    return pending


class OverlapExecutor:
    """Interleave dispatch-phase generators with deferred resolve work.

    ``drive(gen)`` exhausts a phase generator, advancing one deferred
    generator step at each yield (each yield marks "device work just
    went in flight — now is the time for host work").  ``defer(gen)``
    queues follow-up host work; deferred generators run strictly FIFO,
    each exhausted before the next starts, so any order-sensitive
    accumulation they perform matches the serial schedule exactly.
    """

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self._queue: deque = deque()

    def drive(self, gen):
        """Run ``gen`` to completion; returns its ``return`` value.

        When a yield hands back a pending (anything with a ``ready``
        property), deferred work keeps stepping until the pending's
        device rows are ready — the generator never waits on the device
        while host work is queued, and extra steps cannot reorder
        anything (deferred generators are strictly FIFO either way).
        """
        while True:
            try:
                pending = next(gen)
            except StopIteration as stop:
                return stop.value
            if self.enabled:
                self.step()
                while (self._queue and pending is not None
                       and not pending.ready):
                    self.step()

    def defer(self, gen) -> None:
        """Queue a generator of host work; runs inline when disabled."""
        if not self.enabled:
            for _ in gen:
                pass
            return
        self._queue.append(gen)

    def step(self) -> bool:
        """Advance the oldest deferred generator by one yield."""
        if not self._queue:
            return False
        try:
            next(self._queue[0])
        except StopIteration:
            self._queue.popleft()
        return True

    def drain(self) -> None:
        """Exhaust every deferred generator (the observation boundary)."""
        if not self._queue:
            return
        with trace.span("overlap_drain", cat="engine",
                        pending=len(self._queue)):
            while self._queue:
                self.step()


__all__ = ["OverlapExecutor", "PendingPairedCost", "dispatch_paired_latency",
           "overlap_enabled", "serial_dispatch"]
