"""Fault tolerance: restartable training, straggler detection, elasticity.

Designed for the 1000-node regime where *something* is always failing:

* :class:`RestartableLoop` — a crash-safe state machine around the train
  step: checkpoint every N steps (async, atomic via ckpt.CheckpointManager),
  preemption-signal hook that forces an emergency checkpoint, and a
  ``resume()`` that restores bit-exact state (data pipeline included —
  batches are a pure function of the step index, see data.pipeline).
* :class:`StragglerMonitor` — per-step wall-time ring buffer; flags steps
  slower than ``threshold x`` the running median.  On real multi-host
  topologies the flagged host's data shard is reassigned (hook provided);
  in tests the reassignment is simulated.
* :class:`ElasticPlan` — recompute (host_count, per-host batch) after a
  topology change so the global batch stays constant; combined with the
  elastic checkpoint restore this implements shrink/grow without changing
  the optimization trajectory.
"""

from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

from ..ckpt.checkpoint import CheckpointManager


@dataclass
class StragglerMonitor:
    window: int = 32
    threshold: float = 2.0
    times: deque = field(default_factory=lambda: deque(maxlen=64))
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(seconds)
        if len(self.times) < 8:
            return False
        med = statistics.median(self.times)
        if seconds > self.threshold * med:
            self.flagged.append((step, seconds, med))
            return True
        return False


@dataclass(frozen=True)
class ElasticPlan:
    global_batch: int
    host_count: int

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.host_count

    def rescale(self, new_host_count: int) -> "ElasticPlan":
        """Shrink/grow the host set; the global batch (and therefore the
        optimization trajectory) is preserved as long as it divides."""
        if self.global_batch % new_host_count:
            raise ValueError(
                f"global batch {self.global_batch} not divisible by "
                f"{new_host_count} hosts")
        return ElasticPlan(self.global_batch, new_host_count)


class Preempted(Exception):
    pass


class RestartableLoop:
    """Checkpoint-every-N crash-safe training driver."""

    def __init__(self, ckpt_dir, *, ckpt_every: int = 50, keep: int = 3,
                 monitor: StragglerMonitor | None = None,
                 on_straggler: Callable[[int], None] | None = None):
        self.mgr = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.monitor = monitor or StragglerMonitor()
        self.on_straggler = on_straggler
        self._preempt = False

    def signal_preemption(self) -> None:
        """SIGTERM-style hook: finish the current step, checkpoint, stop."""
        self._preempt = True

    def resume_step(self) -> int:
        return (self.mgr.latest_step() or 0)

    def run(self, state, step_fn, batch_fn, *, start_step: int,
            num_steps: int, state_template=None):
        """Run ``num_steps`` from ``start_step``; returns (state, metrics).

        ``step_fn(state, batch) -> (state, metrics)``;
        ``batch_fn(step) -> batch`` must be stateless (pure in step).
        Raises :class:`Preempted` after the emergency checkpoint when
        ``signal_preemption`` was called.
        """
        last_metrics = None
        try:
            for step in range(start_step, start_step + num_steps):
                t0 = time.time()
                state, last_metrics = step_fn(state, batch_fn(step))
                dt = time.time() - t0
                if self.monitor.record(step, dt) and self.on_straggler:
                    self.on_straggler(step)
                done = step + 1
                if done % self.ckpt_every == 0:
                    self.mgr.save_async(done, state)
                if self._preempt:
                    self.mgr.wait()
                    self.mgr.save(done, state)   # emergency checkpoint
                    raise Preempted(f"preempted at step {done}")
        finally:
            # a crash must never abandon an in-flight async checkpoint
            self.mgr.wait()
        self.mgr.save(start_step + num_steps, state)
        return state, last_metrics
