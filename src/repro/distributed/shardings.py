"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

The rules mirror NicePIM's LM dimension choices on the TPU mesh (DESIGN.md
§3): output-channel-style dims (attention head projections, FFN hidden,
MoE experts, vocab) shard over ``model``; the batch dim shards over
``pod`` x ``data``; with ``fsdp=True`` the contraction dim of each large
matrix additionally shards over ``data`` (ZeRO-3 style — GSPMD inserts the
per-layer all-gathers inside the scan body, which is the WR<full-replication
regime of the paper).

Every rule is divisibility-guarded: an axis that does not evenly divide the
tensor dim is dropped (replicated) rather than failing to lower.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------
# jax version compat: the ambient-mesh API (get_abstract_mesh / set_mesh /
# AxisType) moved into jax.sharding in 0.5.x; on 0.4.x the same machinery
# lives under jax._src.mesh.  Resolve whichever exists once at import.
# --------------------------------------------------------------------------

def _resolve_mesh_api():
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    setm = getattr(jax.sharding, "set_mesh", None)
    if get is None or setm is None:
        try:
            from jax._src import mesh as _jmesh
            get = get or getattr(_jmesh, "get_abstract_mesh", None)
            setm = setm or getattr(_jmesh, "set_mesh", None)
        except ImportError:  # pragma: no cover - future jax reorganisation
            pass
    return get, setm


_GET_ABSTRACT_MESH, _SET_MESH = _resolve_mesh_api()


def get_abstract_mesh():
    """The ambient abstract mesh, or None when unset/unsupported.

    Normalizes the 0.4.x sentinel (an empty tuple) and meshes without axis
    names to None so callers only need one "no ambient mesh" branch.
    """
    if _GET_ABSTRACT_MESH is None:
        return None
    mesh = _GET_ABSTRACT_MESH()
    if mesh is None or not getattr(mesh, "axis_names", None):
        return None
    return mesh


@contextlib.contextmanager
def set_mesh(mesh: Mesh):
    """Context manager entering ``mesh`` (jax.sharding.set_mesh compat).

    On 0.4.x the internal ``set_mesh`` installs only the abstract mesh;
    ``with_sharding_constraint`` with bare PartitionSpecs still reads the
    legacy resource env, so enter the physical mesh context too.
    """
    if _SET_MESH is None:  # pragma: no cover - no ambient-mesh support
        yield
        return
    if hasattr(jax.sharding, "set_mesh"):
        with _SET_MESH(mesh):
            yield
        return
    with mesh, _SET_MESH(mesh):
        yield


def make_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _fit(mesh: Mesh, dim: int, axes):
    """Return ``axes`` if they evenly divide ``dim`` else None (replicate)."""
    n = _axis_size(mesh, axes)
    return axes if (n > 1 and dim % n == 0) else None


def data_axes(mesh: Mesh):
    ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def constrain(x, *dims):
    """``with_sharding_constraint`` against the ambient abstract mesh.

    ``dims`` entries are axis names, tuples of axis names, or None; entries
    whose axes are absent from the ambient mesh or do not divide the dim are
    dropped.  No-op outside a ``jax.sharding.set_mesh`` scope, so model code
    can call this unconditionally (CPU tests see the identity).
    """
    mesh = get_abstract_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def fit(i, axes):
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in names)
        if not axes:
            return None
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return (axes if len(axes) > 1 else axes[0]) \
            if x.shape[i] % n == 0 and n > 1 else None

    spec = P(*(fit(i, a) for i, a in enumerate(dims)))
    return jax.lax.with_sharding_constraint(x, spec)


BATCH_AXES = ("pod", "data")


def attn_constraints(q, k, v):
    """Tensor-parallel layout for attention activations.

    Heads shard over ``model`` when they divide it (Megatron-style); when
    they don't (e.g. qwen2's 14 heads on a 16-way axis), the query *sequence*
    dim shards over ``model`` instead (sequence parallelism) and K/V
    replicate — attention work stays fully partitioned either way, instead
    of GSPMD silently replicating it (16x redundant FLOPs) or sharding the
    contraction dim (full-scores all-reduce).
    """
    mesh = get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return q, k, v
    msize = mesh.shape["model"]
    if msize <= 1:
        return q, k, v
    if q.shape[2] % msize == 0:
        q = constrain(q, BATCH_AXES, None, "model", None)
        k = constrain(k, BATCH_AXES, None, "model", None)
        v = constrain(v, BATCH_AXES, None, "model", None)
    elif q.shape[1] % msize == 0:
        q = constrain(q, BATCH_AXES, "model", None, None)
        k = constrain(k, BATCH_AXES, None, None, None)
        v = constrain(v, BATCH_AXES, None, None, None)
    return q, k, v


def param_specs(cfg, params: Any, mesh: Mesh, *, fsdp: bool = False,
                tp: bool = True):
    """PartitionSpec pytree matching ``params`` (from nn.init_params).

    ``tp=False`` drops the `model` axis everywhere (fully replicated
    parameters — the serving analogue of the paper's WR=full replication).
    """
    dp = data_axes(mesh) if fsdp else None

    def spec_for(path: str, x) -> P:
        shape = x.shape
        nd = x.ndim

        def d(i, axes):
            if not tp:
                if axes == "model":
                    return None
                if isinstance(axes, tuple) and "model" in axes:
                    axes = tuple(a for a in axes if a != "model") or None
            return _fit(mesh, shape[i], axes)

        if path.endswith("embed"):
            # vocab over model only: sharding the feature dim too turns the
            # token gather into an SPMD full-rematerialization
            return P(d(0, "model"), None)
        if path.endswith("head"):
            return P(d(0, dp), d(1, "model"))
        if "final_norm" in path:
            return P(None)
        # stacked per-layer params: axis 0 is the layer axis
        leaf = path.split("/")[-1]
        if nd == 3 and leaf in ("wq", "wk", "wv", "w1", "w3", "ck",
                                "wx", "wy", "wr", "wk", "wv", "wg", "wd1"):
            return P(None, d(1, dp), d(2, "model"))
        if nd == 3 and leaf in ("wo", "w2", "cv", "wd2"):
            return P(None, d(1, "model"), d(2, dp))
        if nd == 4 and leaf in ("we1", "we3", "we2"):   # MoE experts
            return P(None, d(1, "model"), d(2, dp), None)
        if nd == 3 and leaf == "router":
            return P(None, d(1, dp), None)
        if nd == 3 and leaf == "conv_w":
            return P(None, None, d(2, "model"))
        if nd == 3 and leaf == "u":                     # rwkv bonus (L,H,dh)
            return P(None, d(1, "model"), None)
        if nd == 2 and leaf in ("bq", "bk", "bv"):
            return P(None, d(1, "model"))
        if nd == 2 and leaf in ("wr_diag", "wi_diag", "br", "bi", "lambda"):
            return P(None, d(1, "model"))
        return P(*([None] * nd))  # norms, mus, scalars

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}

    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}/{k}") for k, v in tree.items()}
        return spec_for(prefix, tree)

    return build(params)


def shardings_for(cfg, params, mesh: Mesh, *, fsdp: bool = False):
    specs = param_specs(cfg, params, mesh, fsdp=fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    dp = data_axes(mesh)
    return P(_fit(mesh, global_batch, dp), None)


def batch_specs(cfg, mesh: Mesh, batch: Any, *, is_embeds: bool = False):
    """Specs for a train/prefill batch dict (tokens/targets/embeds...)."""
    def one(x):
        dp = data_axes(mesh)
        b = _fit(mesh, x.shape[0], dp)
        if x.ndim == 3:   # precomputed frontend embeddings (B, S, D)
            return P(b, None, _fit(mesh, x.shape[-1], "model"))
        return P(*([b] + [None] * (x.ndim - 1)))
    return jax.tree.map(one, batch)


def cache_specs(cfg, mesh: Mesh, cache: Any):
    """Decode-cache specs: batch over data axes, heads/channels over model."""
    dp = data_axes(mesh)

    def one(path, x):
        leaf = path[-1].key if path else ""
        s = x.shape
        if leaf in ("k", "v"):          # (L, B, T, Hkv, dh)
            heads = _fit(mesh, s[3], "model")
            # GQA caches whose few KV heads don't divide the model axis
            # shard the time dim instead (32k-ctx caches are 10s of GB/chip
            # if replicated); softmax reductions over sharded T are handled
            # by GSPMD.
            time_ax = _fit(mesh, s[2], "model") if heads is None else None
            return P(None, _fit(mesh, s[1], dp), time_ax, heads, None)
        if leaf == "kpos":              # (L, B, T)
            return P(None, _fit(mesh, s[1], dp), None)
        if leaf == "S":                 # (L, B, H, dh, dh)
            return P(None, _fit(mesh, s[1], dp),
                     _fit(mesh, s[2], "model"), None, None)
        if leaf in ("shift_t", "shift_c", "h"):   # (L, B, D)
            return P(None, _fit(mesh, s[1], dp), _fit(mesh, s[2], "model"))
        if leaf == "conv":              # (L, B, W-1, D)
            return P(None, _fit(mesh, s[1], dp), None,
                     _fit(mesh, s[3], "model"))
        return P(*([None] * x.ndim))

    return jax.tree_util.tree_map_with_path(one, cache)


def opt_state_specs(param_spec_tree, opt_state):
    """Adam mu/nu shard exactly like their parameters; step is replicated."""
    from repro.training.optim import AdamState
    return AdamState(P(), param_spec_tree, param_spec_tree)
