"""Sharded checkpointing: atomic, async, elastic.

TensorStore-free design that still has the properties a 1000-node run needs:

* **atomic commit** — writes go to ``step_<N>.tmp/`` and are renamed to
  ``step_<N>/`` only after every array and the manifest are fsync'd; a crash
  mid-write can never leave a readable-but-corrupt checkpoint;
* **async save** — ``save_async`` snapshots device arrays to host memory
  synchronously (cheap) and does the serialization on a background thread so
  training continues;
* **sharded layout** — each host writes only the shards it owns
  (``process_index``-keyed filenames); restore reads whatever subset the new
  topology needs;
* **elastic restore** — arrays are saved with their *global* shape; on load
  they are re-placed under the *current* mesh/sharding, so a 512-chip
  checkpoint restores onto a 256-chip (or 1-chip CPU test) mesh;
* retention of the last ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)) or hasattr(tree, "_fields"):
        items = tree._asdict().items() if hasattr(tree, "_asdict") \
            else enumerate(tree)
        for k, v in items:
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree) -> Path:
        """Synchronous atomic save; returns the committed path."""
        arrays = _flatten(tree)
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "arrays": {}}
        host = {k: np.asarray(v) for k, v in arrays.items()}
        pidx = jax.process_index()
        npz_path = tmp / f"shard_{pidx:05d}.npz"
        np.savez(npz_path, **{k.replace("/", "."): v for k, v in host.items()})
        for k, v in host.items():
            manifest["arrays"][k] = {"shape": list(v.shape),
                                     "dtype": str(v.dtype),
                                     "file": npz_path.name}
        mpath = tmp / "manifest.json"
        mpath.write_text(json.dumps(manifest))
        with open(mpath) as f:       # fsync the manifest before commit
            os.fsync(f.fileno())
        os.rename(tmp, final)        # atomic commit
        self._gc()
        return final

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host, serialize on a background thread."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None, *,
                shardings=None):
        """Load into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` re-places arrays on the current
        mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())
        data = {}
        for f in cdir.glob("shard_*.npz"):
            with np.load(f) as z:
                for k in z.files:
                    data[k.replace(".", "/")] = z[k]
        flat_t = _flatten(template)
        missing = set(flat_t) - set(data)
        if missing:
            raise KeyError(f"checkpoint step {step} missing arrays: "
                           f"{sorted(missing)[:5]}...")
        flat_s = _flatten(shardings) if shardings is not None else {}

        def rebuild(tree, prefix=""):
            if isinstance(tree, dict):
                return {k: rebuild(tree[k], f"{prefix}{k}/")
                        for k in tree}
            if hasattr(tree, "_fields"):
                vals = {k: rebuild(v, f"{prefix}{k}/")
                        for k, v in tree._asdict().items()}
                return type(tree)(**vals)
            if isinstance(tree, (tuple, list)):
                return type(tree)(rebuild(v, f"{prefix}{i}/")
                                  for i, v in enumerate(tree))
            if tree is None:
                return None
            key = prefix[:-1]
            arr = data[key]
            want_dtype = tree.dtype
            out = arr.astype(want_dtype)
            sh = flat_s.get(key)
            if sh is not None:
                return jax.device_put(out, sh)
            return jnp.asarray(out)

        return rebuild(template), step
