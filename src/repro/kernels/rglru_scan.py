"""RG-LRU diagonal linear recurrence in Pallas.

Computes ``h_t = a_t * h_{t-1} + x_t`` over the time axis.  The recurrence
is elementwise in the channel dim, so the kernel tiles ``(batch, channel)``
across the grid's parallel axes and walks sequence chunks on the innermost
(sequential) axis, carrying ``h`` in VMEM scratch — HBM traffic is exactly
one read of (a, x) and one write of h, the streaming minimum.

Channel tiles are lane-aligned (multiples of 128 when the width allows).
The time loop inside a chunk is a ``fori_loop`` over VREG-resident rows —
on TPU this is the idiomatic replacement for the GPU block-parallel-scan
formulation (HW-adaptation note in DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, o_ref, h_ref, *, block_s: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)      # (block_s, block_d)
    x = x_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + x[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_s", "block_d",
                                             "interpret"))
def rglru_scan(a, x, *, block_s: int = 256, block_d: int = 512,
               interpret: bool = True):
    """a, x: (B, S, D) -> h (B, S, D) with h_t = a_t*h_{t-1} + x_t."""
    b, s, d = a.shape
    block_s = min(block_s, s)
    block_d = min(block_d, d)
    grid = (b, pl.cdiv(d, block_d), pl.cdiv(s, block_s))
    spec = pl.BlockSpec((1, block_s, block_d),
                        lambda bi, di, si: (bi, si, di))
    return pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(a, x)
