"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernels in
interpret mode); on a TPU backend the compiled kernels run natively.  The
model code (nn/attention.py, nn/rwkv6.py, nn/rglru.py) calls these when
``cfg.attention_impl == "pallas"``.
"""

from __future__ import annotations

import jax

from . import flash_attention as _fa
from . import rglru_scan as _rg
from . import rwkv6_wkv as _wkv


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        interpret=_default_interpret() if interpret is None else interpret)


def rglru(a, x, *, block_s: int = 256, block_d: int = 512,
          interpret: bool | None = None):
    return _rg.rglru_scan(
        a, x, block_s=block_s, block_d=block_d,
        interpret=_default_interpret() if interpret is None else interpret)


def rwkv6(r, k, v, w, u, *, block_s: int = 128,
          interpret: bool | None = None):
    return _wkv.rwkv6_wkv(
        r, k, v, w, u, block_s=block_s,
        interpret=_default_interpret() if interpret is None else interpret)
