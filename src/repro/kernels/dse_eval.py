"""Pallas reductions for the batched DSE engine (engine/batch_cost).

Two row-wise reductions sit on the engine's hot path:

* ``tile_select`` — the inner tiling-search reduction: for every
  (config, part-layer) row holding ``T`` candidate tilings, fuse the
  double-buffering bottleneck ``total = max(compute_cycles, dram_cycles)``
  with a masked first-argmin over candidates.
* ``max_rows`` — the max-link-load reduction: row-wise masked max, used to
  score batches of candidate NoC schedules (one row per schedule, one column
  per directed mesh link).
* ``delta_maxload_rows`` — the engine Data-Scheduler's move scoring: fuse
  the ``base + delta`` link-load accumulation of a whole 2-opt proposal
  batch with the per-proposal max-link reduction (one row per search chain,
  one slab per proposed segment reversal).
* ``minplus_rows`` — the Algorithm-2 *segment* min-plus convolution: fuse the
  ``a[i] + b[r, i]`` broadcast-add with the row-wise min + first-argmin that
  combines per-segment DP tables under one shared capacity budget.
* ``lcb_rows`` — the PIM-Tuner's fused propose reduction: for every query
  feature row, the pairwise squared distance to the (masked) training
  features, the RBF cross-kernel, the GP posterior mean/variance against a
  precomputed ``K^-1`` / ``K^-1 y``, and the lower-confidence-bound score,
  all in one pass.

The kernels tile rows across the grid; most keep the full reduction axis in
one VMEM block, while ``delta_maxload_rows`` *streams* the link axis (the
innermost grid dimension walks link tiles with a running max in the
revisited output block, double-buffered by the Pallas pipeline).  Off-TPU
they run in ``interpret=True`` mode (this container's validation path),
matching the pure-jnp semantics bit-for-bit — which the engine relies on
for its 1e-6 parity contract with the scalar cost model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tile_select_kernel(c_ref, d_ref, v_ref, tot_ref, idx_ref):
    total = jnp.maximum(c_ref[...], d_ref[...])
    total = jnp.where(v_ref[...], total, jnp.inf)
    tot_ref[...] = jnp.min(total, axis=-1)
    # first occurrence of the min, matching np.argmin in the scalar model
    idx_ref[...] = jnp.argmin(total, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def _tile_select(compute_cycles, dram_cycles, valid, *, block_r: int,
                 interpret: bool):
    r, t = compute_cycles.shape
    grid = (pl.cdiv(r, block_r),)
    in_spec = pl.BlockSpec((block_r, t), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_r,), lambda i: (i,))
    return pl.pallas_call(
        _tile_select_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((r,), compute_cycles.dtype),
                   jax.ShapeDtypeStruct((r,), jnp.int32)],
        interpret=interpret,
    )(compute_cycles, dram_cycles, valid)


def tile_select(compute_cycles, dram_cycles, valid, *, block_r: int = 8,
                interpret: bool | None = None):
    """``[R, T] -> ([R] total, [R] idx)`` fused max + masked first-argmin.

    Rows with no valid candidate return ``inf`` / index 0 — the caller
    (engine/batch_cost) guarantees at least the fallback tiling is valid.
    """
    interpret = _default_interpret() if interpret is None else interpret
    r, t = compute_cycles.shape
    block_r = max(1, min(block_r, r))
    pad = (-r) % block_r
    if pad:
        compute_cycles = jnp.pad(compute_cycles, ((0, pad), (0, 0)))
        dram_cycles = jnp.pad(dram_cycles, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
    tot, idx = _tile_select(compute_cycles, dram_cycles, valid,
                            block_r=block_r, interpret=interpret)
    return tot[:r], idx[:r]


def _argmin_rows_kernel(x_ref, v_ref, min_ref, idx_ref):
    x = jnp.where(v_ref[...], x_ref[...], jnp.inf)
    min_ref[...] = jnp.min(x, axis=-1)
    # first occurrence of the min, matching the scalar DP's strict-< update
    idx_ref[...] = jnp.argmin(x, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def _argmin_rows(x, valid, *, block_r: int, interpret: bool):
    r, t = x.shape
    grid = (pl.cdiv(r, block_r),)
    in_spec = pl.BlockSpec((block_r, t), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_r,), lambda i: (i,))
    return pl.pallas_call(
        _argmin_rows_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((r,), x.dtype),
                   jax.ShapeDtypeStruct((r,), jnp.int32)],
        interpret=interpret,
    )(x, valid)


def argmin_rows(x, valid=None, *, block_r: int = 128,
                interpret: bool | None = None):
    """``[R, T] -> ([R] min, [R] idx)`` row-wise masked min + first-argmin.

    The Algorithm-2 knapsack inner reduction: one row per capacity cell, one
    column per layer candidate.  Rows with no valid (finite) candidate return
    ``inf`` / index 0; the caller maps those back to "no choice".
    """
    interpret = _default_interpret() if interpret is None else interpret
    x = jnp.asarray(x)
    if valid is None:
        valid = jnp.ones(x.shape, dtype=bool)
    r, t = x.shape
    block_r = max(1, min(block_r, r))
    pad = (-r) % block_r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
    mn, idx = _argmin_rows(x, valid, block_r=block_r, interpret=interpret)
    return mn[:r], idx[:r]


def _minplus_rows_kernel(a_ref, b_ref, min_ref, idx_ref):
    x = a_ref[...][None, :] + b_ref[...]
    min_ref[...] = jnp.min(x, axis=-1)
    # first occurrence of the min, matching the sequential segment DP's
    # strict-< update order (i ascending)
    idx_ref[...] = jnp.argmin(x, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def _minplus_rows(a, b, *, block_r: int, interpret: bool):
    r, t = b.shape
    grid = (pl.cdiv(r, block_r),)
    return pl.pallas_call(
        _minplus_rows_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((t,), lambda i: (0,)),
                  pl.BlockSpec((block_r, t), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_r,), lambda i: (i,)),
                   pl.BlockSpec((block_r,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((r,), b.dtype),
                   jax.ShapeDtypeStruct((r,), jnp.int32)],
        interpret=interpret,
    )(a, b)


def minplus_rows(a, b, *, block_r: int = 128, interpret: bool | None = None):
    """``([T] a, [R, T] b) -> ([R] min, [R] idx)`` fused min-plus reduction.

    Row ``r`` scores ``a + b[r]`` elementwise and reduces with a masked-free
    min + first-argmin — the Algorithm-2 *segment* min-plus convolution: ``a``
    is the running multi-segment DP table, ``b[r]`` the current segment's
    best-perf column reversed/shifted so that column ``i`` holds the segment's
    cost at budget ``r - i`` (``inf`` where ``i > r``).  Rows whose min is
    ``inf`` (no feasible split) return index 0; the caller maps those back to
    "no choice", exactly like :func:`argmin_rows`.
    """
    interpret = _default_interpret() if interpret is None else interpret
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    r, t = b.shape
    block_r = max(1, min(block_r, r))
    pad = (-r) % block_r
    if pad:
        b = jnp.pad(b, ((0, pad), (0, 0)))
    mn, idx = _minplus_rows(a, b, block_r=block_r, interpret=interpret)
    return mn[:r], idx[:r]


def _lcb_rows_kernel(zq_ref, zt_ref, alpha_ref, kinv_ref, v_ref, par_ref,
                     out_ref):
    zq = zq_ref[...]                                          # [bq, D]
    zt = zt_ref[...]                                          # [N, D]
    d2 = jnp.sum((zq[:, None, :] - zt[None, :, :]) ** 2, -1)  # [bq, N]
    ls2, sf2, beta = par_ref[0], par_ref[1], par_ref[2]
    kq = sf2 * jnp.exp(-0.5 * d2 / ls2)
    # padded training rows contribute nothing: their cross-kernel column is
    # zeroed, and the padded block of kinv is the identity by construction
    kq = jnp.where(v_ref[...][None, :], kq, 0.0)
    mean = kq @ alpha_ref[...]
    t = jnp.dot(kq, kinv_ref[...], preferred_element_type=kq.dtype)
    var = sf2 - jnp.sum(t * kq, axis=-1)
    out_ref[...] = mean - beta * jnp.sqrt(jnp.clip(var, 1e-9))


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def _lcb_rows(zq, zt, alpha, kinv, valid, params, *, block_q: int,
              interpret: bool):
    q, d = zq.shape
    n = zt.shape[0]
    grid = (pl.cdiv(q, block_q),)
    return pl.pallas_call(
        _lcb_rows_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, d), lambda i: (i, 0)),
                  pl.BlockSpec((n, d), lambda i: (0, 0)),
                  pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((n, n), lambda i: (0, 0)),
                  pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_q,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), zq.dtype),
        interpret=interpret,
    )(zq, zt, alpha, kinv, valid, params)


def lcb_rows(zq, zt, alpha, kinv, valid, ls2, sf2, beta, *,
             block_q: int = 256, interpret: bool | None = None):
    """``([Q,D] zq, [N,D] zt, [N] alpha, [N,N] kinv, [N] valid) -> [Q] lcb``.

    Fused GP-LCB scoring of a candidate batch: pairwise squared distances,
    RBF cross-kernel ``kq = sf2 * exp(-d2 / (2 ls2))``, posterior mean
    ``kq @ alpha`` and variance ``sf2 - kq @ kinv @ kq^T`` (clipped at 1e-9),
    and the lower confidence bound ``mean - beta * sqrt(var)``.  ``alpha`` and
    ``kinv`` are the precomputed ``K^-1 y`` / ``K^-1`` of the (masked)
    training kernel; invalid (padded) training rows are dropped via ``valid``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    zq = jnp.asarray(zq)
    zt = jnp.asarray(zt)
    params = jnp.stack([jnp.asarray(ls2, zq.dtype), jnp.asarray(sf2, zq.dtype),
                        jnp.asarray(beta, zq.dtype)])
    q = zq.shape[0]
    block_q = max(1, min(block_q, q))
    pad = (-q) % block_q
    if pad:
        zq = jnp.pad(zq, ((0, pad), (0, 0)))
    out = _lcb_rows(zq, zt, jnp.asarray(alpha), jnp.asarray(kinv),
                    jnp.asarray(valid), params, block_q=block_q,
                    interpret=interpret)
    return out[:q]


def _delta_maxload_rows_kernel(b_ref, d_ref, w_ref, o_ref):
    # streaming running-max: the link (E) axis is the innermost grid dim,
    # so the output block is revisited across link tiles — Pallas
    # double-buffers the (base, delta) tile loads while the previous tile
    # reduces, and the full E axis never has to fit in one VMEM block
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref[...], -jnp.inf)
    d = d_ref[...].astype(o_ref.dtype) * w_ref[...][..., None]
    part = jnp.max(b_ref[...][:, None, :] + d, axis=-1)
    o_ref[...] = jnp.maximum(o_ref[...], part)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_e", "interpret"))
def _delta_maxload_rows(base, deltas, weights, *, block_m: int,
                        block_e: int, interpret: bool):
    r, m, e = deltas.shape
    grid = (r, pl.cdiv(m, block_m), pl.cdiv(e, block_e))
    return pl.pallas_call(
        _delta_maxload_rows_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_e), lambda i, j, k: (i, k)),
                  pl.BlockSpec((1, block_m, block_e),
                               lambda i, j, k: (i, j, k)),
                  pl.BlockSpec((1, block_m), lambda i, j, k: (i, j))],
        out_specs=pl.BlockSpec((1, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, m), base.dtype),
        interpret=interpret,
    )(base, deltas, weights)


def delta_maxload_rows(base, deltas, weights=None, *, block_m: int = 128,
                       block_e: int = 512, interpret: bool | None = None):
    """``([R, E] base, [R, M, E] deltas) -> [R, M] max(base + delta)``.

    The engine Data-Scheduler's fused move-scoring reduction: row ``r`` is
    one 2-opt chain's current link loads, ``deltas[r, m]`` the link-load
    delta of its ``m``-th proposed segment reversal, and the output the
    proposal's Eq. 4 objective — the broadcast add and the max-link
    reduction fused in one pass instead of materializing ``base + delta``.

    ``weights [R, M]`` optionally scales each proposal's delta slab
    in-kernel (``base + deltas * w``): the scheduler passes its small-int
    flip *counts* (int16) plus the per-set byte weight, so the f32 ``[R, M,
    E]`` delta tensor is never materialized in memory (XLA may fuse the
    scale-and-add into an FMA, so this path can differ from the unfused
    two-op reference by 1 ulp — scheduler acceptance is protected by its
    exact-f64 gate, never by these scores).  The link axis is
    *streamed*: the grid's innermost dimension walks ``block_e``-wide link
    tiles with a running max in the revisited output block, so the 960-link
    16x16 mesh no longer needs the whole E axis resident per block.
    """
    interpret = _default_interpret() if interpret is None else interpret
    base = jnp.asarray(base)
    deltas = jnp.asarray(deltas)
    r, m, e = deltas.shape
    if weights is None:
        weights = jnp.ones((r, m), base.dtype)
    weights = jnp.asarray(weights, base.dtype)
    block_m = max(1, min(block_m, m))
    block_e = max(1, min(block_e, e))
    pad_m = (-m) % block_m
    if pad_m:
        deltas = jnp.pad(deltas, ((0, 0), (0, pad_m), (0, 0)))
        weights = jnp.pad(weights, ((0, 0), (0, pad_m)))
    pad_e = (-e) % block_e
    if pad_e:
        # padded links must not win the max: -inf base, zero delta
        base = jnp.pad(base, ((0, 0), (0, pad_e)),
                       constant_values=-jnp.inf)
        deltas = jnp.pad(deltas, ((0, 0), (0, 0), (0, pad_e)))
    out = _delta_maxload_rows(base, deltas, weights, block_m=block_m,
                              block_e=block_e, interpret=interpret)
    return out[:, :m]


def _max_rows_kernel(x_ref, v_ref, o_ref):
    x = jnp.where(v_ref[...], x_ref[...], -jnp.inf)
    o_ref[...] = jnp.max(x, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def _max_rows(x, valid, *, block_r: int, interpret: bool):
    r, t = x.shape
    grid = (pl.cdiv(r, block_r),)
    in_spec = pl.BlockSpec((block_r, t), lambda i: (i, 0))
    return pl.pallas_call(
        _max_rows_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), x.dtype),
        interpret=interpret,
    )(x, valid)


def max_rows(x, valid=None, *, block_r: int = 8,
             interpret: bool | None = None):
    """Row-wise masked max — the Eq. 4 max-link-load reduction, batched."""
    interpret = _default_interpret() if interpret is None else interpret
    x = jnp.asarray(x)
    if valid is None:
        valid = jnp.ones(x.shape, dtype=bool)
    r, t = x.shape
    block_r = max(1, min(block_r, r))
    pad = (-r) % block_r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
    out = _max_rows(x, valid, block_r=block_r, interpret=interpret)
    return out[:r]
