"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the direct mathematical definition with no blocking or
numerically clever tricks beyond f32 softmax — the kernels must match these
within bf16/f32 tolerance across the shape/dtype sweeps in
tests/test_kernels_*.py.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset: int = 0):
    """(B,S,H,dh) x (B,T,Hkv,dh) GQA attention; f32 softmax."""
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    qi = jnp.arange(s)[:, None] + q_offset
    kj = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = mask & (kj <= qi)
    if window:
        mask = mask & (kj > qi - window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", p, v)
    return out.reshape(b, s, h, dh)


def rglru(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + x_t over axis 1.

    a, x: (B, S, D) f32; returns (B, S, D) f32.
    """
    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    a_t = jnp.moveaxis(a, 1, 0)
    x_t = jnp.moveaxis(x, 1, 0)
    h0 = jnp.zeros_like(x[:, 0])
    _, hs = jax.lax.scan(step, h0, (a_t, x_t))
    return jnp.moveaxis(hs, 0, 1)


def wkv6(r, k, v, w, u):
    """RWKV6 recurrence (see nn.rwkv6.wkv6_scan); all (B,S,H,dh), u (H,dh)."""
    b, s, h, dh = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         state + uf[None, :, :, None] * kv)
        return wt[..., None] * state + kv, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, jnp.zeros((b, h, dh, dh), jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1)
