"""RWKV6 WKV recurrence in Pallas (data-dependent per-channel decay).

State is one (dh x dh) f32 matrix per (batch, head); the grid tiles
``(batch, head)`` in parallel and walks time chunks sequentially, carrying
the state in VMEM scratch.  Inside a chunk each timestep performs rank-1
state updates (outer product k_t v_t^T) and a row-gather-free readout
``r_t^T (S + u k_t v_t^T)`` — all VREG-sized ops with dh = 64 (the RWKV6
head size), so the working set is 16 KiB/head and the kernel is purely
HBM-streaming in (r,k,v,w) and out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                block_s: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # (block_s, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)          # (dh,)

    def step(t, state):
        kv = k[t][:, None] * v[t][None, :]       # (dh, dh) rank-1
        out = jnp.sum(r[t][:, None] * (state + u[:, None] * kv), axis=0)
        o_ref[0, t, 0, :] = out.astype(o_ref.dtype)
        return w[t][:, None] * state + kv

    s_ref[...] = jax.lax.fori_loop(0, block_s, step, s_ref[...])


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def rwkv6_wkv(r, k, v, w, u, *, block_s: int = 128, interpret: bool = True):
    """r,k,v,w: (B, S, H, dh); u: (H, dh) -> (B, S, H, dh)."""
    b, s, h, dh = r.shape
    block_s = min(block_s, s)
    grid = (b, h, pl.cdiv(s, block_s))
    spec = pl.BlockSpec((1, block_s, 1, dh),
                        lambda bi, hi, si: (bi, si, hi, 0))
    u_spec = pl.BlockSpec((1, dh), lambda bi, hi, si: (hi, 0))
    return pl.pallas_call(
        functools.partial(_wkv_kernel, block_s=block_s),
        grid=grid,
        in_specs=[spec, spec, spec, spec, u_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
