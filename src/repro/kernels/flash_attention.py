"""Flash attention for TPU in Pallas: causal + sliding-window + GQA.

Online-softmax blocked attention (Dao et al., adapted to the TPU memory
hierarchy): the grid is ``(batch, q_head, q_blocks, k_blocks)`` with the
k-block axis innermost — TPU grids execute sequentially over the trailing
axis, so the running max/denominator/accumulator live in VMEM scratch and
carry across k-blocks (the canonical TPU formulation; there is no shared
memory or warp shuffling to port — HW-adaptation note in DESIGN.md).

Block shapes are MXU-aligned (multiples of 128 on the q/k dims when the
sequence allows; head_dim is the lane dim).  K/V BlockSpec index maps fold
grouped-query attention (q head h reads kv head ``h // group``), so no
repeated-KV materialization happens in HBM.

Fully-masked k-blocks (beyond the causal frontier or outside the sliding
window) are skipped with ``@pl.when`` — for long sequences causal skipping
halves the work, and a 2048-window at 32k context touches 1/16 of the
blocks.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_k: int, causal: bool,
                 window: int, q_offset: int, seq_k: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    n_kb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this block's queries/keys
    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0) \
        + q_offset
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)

    # block-level skip: any overlap with the visible band?
    q_lo = qb * block_q + q_offset
    q_hi = q_lo + block_q - 1
    k_lo = kb * block_k
    visible = jnp.asarray(True)
    if causal:
        visible = jnp.logical_and(visible, k_lo <= q_hi)
    if window:
        k_hi = k_lo + block_k - 1
        visible = jnp.logical_and(visible, k_hi > q_lo - window)

    @pl.when(visible)
    def _block():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        # ragged tail blocks are padded with undefined values: a NaN in a
        # padded V row would survive `0 * NaN` in the p@v matmul
        valid_k = (kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < seq_k
        v = jnp.where(valid_k, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < seq_k
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q (B,S,H,dh); k/v (B,T,Hkv,dh) -> (B,S,H,dh).

    ``interpret=True`` runs the kernel body in Python on CPU (validation
    path in this container); on TPU pass ``interpret=False``.
    """
    b, s, h, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    n_qb = pl.cdiv(s, block_q)
    n_kb = pl.cdiv(t, block_k)
    scale = 1.0 / math.sqrt(dh)

    grid = (b, h, n_qb, n_kb)
    q_spec = pl.BlockSpec((1, block_q, 1, dh),
                          lambda bi, hi, qi, ki: (bi, qi, hi, 0))
    kv_spec = pl.BlockSpec((1, block_k, 1, dh),
                           lambda bi, hi, qi, ki: (bi, ki, hi // group, 0))
    o_spec = pl.BlockSpec((1, block_q, 1, dh),
                          lambda bi, hi, qi, ki: (bi, qi, hi, 0))
    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, q_offset=q_offset, seq_k=t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # softmax denominator
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
