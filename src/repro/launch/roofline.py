"""Roofline-term derivation from the compiled dry-run artifact.

Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis()`` supplies FLOPs and bytes accessed; collective bytes are
parsed out of the compiled HLO text (operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).  Hardware
constants: TPU v5e-class, 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format: [num_groups, group_size]
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


def collective_bytes(hlo_text: str, default_group: int = 2) -> dict[str, float]:
    """Per-chip ICI link bytes for every collective in the compiled HLO.

    The SPMD-partitioned module prints per-device buffer types but not
    operand types, so bytes are derived from the *result* type(s) with a
    ring-algorithm model over the replica group size g:

        all-gather         (g-1)/g * out      (out = gathered buffer)
        all-reduce         2*(g-1)/g * out    (reduce-scatter + all-gather)
        reduce-scatter     (g-1)   * out      (input = g * out)
        all-to-all         (g-1)/g * out
        collective-permute out
    """
    out = {c: 0.0 for c in _COLLECTIVES}
    out["total"] = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.search(r"=\s*([^=]*?)\b(" + "|".join(_COLLECTIVES)
                      + r")(-start|-done)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        if m.group(3) == "-done":
            continue  # -done pairs with -start; count once
        result_types = m.group(1)
        shapes = _SHAPE_RE.findall(result_types)
        out_bytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        g = _group_size(s, default_group)
        ring = (g - 1) / g
        nbytes = {
            "all-gather": ring * out_bytes,
            "all-reduce": 2 * ring * out_bytes,
            "reduce-scatter": (g - 1) * out_bytes,
            "all-to-all": ring * out_bytes,
            "collective-permute": float(out_bytes),
        }[kind]
        out[kind] += nbytes
        out["total"] += nbytes
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs
    bytes_per_device: float
    coll_breakdown: dict
    note: str = ""

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline-limited step time."""
        if self.step_s <= 0:
            return 0.0
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.step_s

    def to_json(self) -> dict:
        d = asdict(self)
        d["step_s"] = self.step_s
        d["roofline_fraction"] = self.roofline_fraction
        return d


def model_flops_for(cfg, shape, *, kind: str) -> float:
    """6*N*D (dense train) / 2*N*D (fwd-only); MoE uses active params."""
    n = cfg.active_param_count
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch          # one token per sequence
    return 2.0 * n * tokens


def _rwkv_scan_correction(cfg, shape, kind: str) -> float:
    """Analytic FLOPs for the wkv6 sequential scan (B,S,H,dh,dh recurrence).

    The scan over time is an HLO while loop whose body XLA's cost model
    counts once; the correction adds the remaining (S-1)/S of the work:
    ~6 flops per (token, head, dh, dh) state element per layer.
    """
    if not getattr(cfg, "attn_free", False) or kind == "decode":
        return 0.0
    tokens = shape.global_batch * shape.seq_len
    per_layer = 6.0 * tokens * cfg.n_heads * cfg.d_head * cfg.d_head
    total = per_layer * cfg.n_layers
    if kind == "train":
        total *= 3.0  # fwd + bwd recurrence
    return total * (shape.seq_len - 1) / shape.seq_len


def derive(arch: str, shape_name: str, mesh_name: str, chips: int,
           cost: dict, hlo_text: str, cfg, shape, kind: str,
           bytes_per_device: float, note: str = "") -> RooflineTerms:
    return derive_from_parts(arch, shape_name, mesh_name, chips, cost,
                             collective_bytes(hlo_text), cfg, shape, kind,
                             bytes_per_device, note)


def derive_from_parts(arch: str, shape_name: str, mesh_name: str, chips: int,
                      cost: dict, coll: dict, cfg, shape, kind: str,
                      bytes_per_device: float,
                      note: str = "") -> RooflineTerms:
    # cost_analysis runs on the SPMD-partitioned module: per-DEVICE numbers.
    flops_dev = float(cost.get("flops", 0.0))
    # exact key only: per-operand keys ('bytes accessed0{}', ...) are already
    # folded into the total and would double-count
    nbytes_dev = float(cost.get("bytes accessed", 0.0))
    corr = _rwkv_scan_correction(cfg, shape, kind)
    if corr:
        note = (note + " " if note else "") + \
            f"+{corr:.2e} analytic wkv-scan flops (while-body counted once)"
    flops = flops_dev * chips + corr           # global
    nbytes = nbytes_dev * chips
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = nbytes / (chips * HBM_BW)
    collective_s = coll["total"] / LINK_BW     # per-chip bytes over its link
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_for(cfg, shape, kind=kind)
    return RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes,
        coll_bytes=coll["total"] * chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf,
        useful_ratio=mf / flops if flops else 0.0,
        bytes_per_device=bytes_per_device,
        coll_breakdown={k: v for k, v in coll.items() if k != "total"},
        note=note)
