"""Serving launcher: batched requests against a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b \
        --slots 4 --new-tokens 16
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.nn import transformer as T
from repro.serving.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2_0_5b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                 seed=args.seed)
    probe = eng.throughput_probe(prompt_len=args.prompt_len,
                                 new_tokens=args.new_tokens)
    print(f"[serve:{args.arch}] {probe['tokens']} tokens in "
          f"{probe['seconds']:.2f}s -> {probe['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
