"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls it.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the ``pod``
axis carries data parallelism across pods (gradient all-reduce crosses the
inter-pod links once per step).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    from repro.distributed.shardings import make_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int | None = None):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    from repro.distributed.shardings import make_mesh
    n = len(jax.devices())
    m = model_axis or 1
    return make_mesh((n // m, m), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a == "model")
