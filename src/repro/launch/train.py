"""End-to-end training launcher.

Runs real steps on whatever devices exist (CPU in this container; the same
code path drives a pod via the production mesh), with checkpoint/restart,
straggler monitoring and async checkpointing from distributed.fault.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.fault import RestartableLoop, StragglerMonitor
from repro.launch.mesh import make_host_mesh
from repro.training.train_loop import TrainConfig, init_state, make_train_step


def train(arch: str, *, reduced: bool = True, steps: int = 20,
          global_batch: int = 8, seq_len: int = 128, lr: float = 3e-4,
          microbatches: int = 1, ckpt_dir: str | None = None,
          ckpt_every: int = 50, seed: int = 0, log_every: int = 10,
          grad_compression: str = "none", resume: bool = True,
          d_model: int | None = None, n_layers: int | None = None,
          verbose: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    over = {}
    if d_model:
        over["d_model"] = d_model
        over["head_dim"] = max(32, d_model // cfg.n_heads)
        over["d_ff"] = int(d_model * 8 / 3) // 64 * 64 or 256
    if n_layers:
        over["n_layers"] = n_layers
    if over:
        cfg = dataclasses.replace(cfg, **over)
    tcfg = TrainConfig(lr=lr, microbatches=microbatches, total_steps=steps,
                       warmup_steps=max(1, steps // 10),
                       grad_compression=grad_compression)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                  global_batch=global_batch, seed=seed))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    state = init_state(cfg, tcfg, jax.random.PRNGKey(seed))
    start = 0
    loop = None
    if ckpt_dir:
        loop = RestartableLoop(ckpt_dir, ckpt_every=ckpt_every)
        if resume and loop.resume_step() > 0:
            state, start = loop.mgr.restore(state)
            if verbose:
                print(f"[train] resumed from step {start}")

    history = []

    def batch_fn(step):
        return {k: jax.numpy.asarray(v) for k, v in data.batch(step).items()}

    def logged_step(st, batch):
        t0 = time.time()
        st, m = step_fn(st, batch)
        m = {k: float(v) for k, v in m.items()}
        history.append(m)
        if verbose and int(m["step"]) % log_every == 0:
            print(f"[train:{arch}] step={int(m['step'])} "
                  f"loss={m['loss']:.4f} gnorm={m['grad_norm']:.3f} "
                  f"({time.time() - t0:.2f}s)")
        return st, m

    if loop is not None:
        state, metrics = loop.run(state, logged_step, batch_fn,
                                  start_step=start, num_steps=steps - start)
    else:
        for step in range(start, steps):
            state, metrics = logged_step(state, batch_fn(step))
    return state, history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2_0_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none", choices=["none", "int8"])
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    args = ap.parse_args()
    _, history = train(args.arch, reduced=args.reduced, steps=args.steps,
                       global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                       microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every,
                       grad_compression=args.compression,
                       d_model=args.d_model, n_layers=args.n_layers)
    print(f"[train] done: first loss {history[0]['loss']:.4f} "
          f"-> last {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
