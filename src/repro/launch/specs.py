"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation happens here: parameters/optimizer state come from
``jax.eval_shape`` over the init closures, batches are hand-built structs.
``[audio]``/``[vlm]`` configs get precomputed frame/patch embeddings from the
stub frontend, as the assignment prescribes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..nn import transformer as tfm
from ..training.train_loop import TrainConfig, init_state


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs_for(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Train/prefill batch structure for one cell."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        batch = {"embeds": sds((b, s, cfg.d_model), cfg.dtype)}
        if shape.kind == "train":
            batch["targets"] = sds((b, s), jnp.int32)
        return batch
    if cfg.frontend == "vision":
        fs = min(cfg.frontend_seq, s // 2)
        batch = {
            "tokens": sds((b, s - fs), jnp.int32),
            "patch_embeds": sds((b, fs, cfg.d_model), cfg.dtype),
        }
        if shape.kind == "train":
            batch["targets"] = sds((b, s - fs), jnp.int32)
        return batch
    batch = {"tokens": sds((b, s), jnp.int32)}
    if shape.kind == "train":
        batch["targets"] = sds((b, s), jnp.int32)
    return batch


def decode_specs_for(cfg: ArchConfig, shape: ShapeSpec):
    """(tokens, pos, cache) structure for a serve_step cell."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(partial(tfm.init_cache, cfg, b, s))
    if cfg.frontend == "audio":
        tokens = sds((b, cfg.d_model), cfg.dtype)  # frame embedding stub
    else:
        tokens = sds((b,), jnp.int32)
    return tokens, sds((), jnp.int32), cache


def state_specs_for(cfg: ArchConfig, tcfg: TrainConfig):
    """TrainState structure via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda: init_state(cfg, tcfg, jax.random.PRNGKey(0)))


def param_specs_for(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                tcfg: TrainConfig | None = None) -> dict:
    """Everything the lowered step consumes, as ShapeDtypeStructs."""
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        return {"state": state_specs_for(cfg, tcfg),
                "batch": batch_specs_for(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": param_specs_for(cfg),
                "batch": batch_specs_for(cfg, shape)}
    tokens, pos, cache = decode_specs_for(cfg, shape)
    return {"params": param_specs_for(cfg), "tokens": tokens,
            "pos": pos, "cache": cache}
