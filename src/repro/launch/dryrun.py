import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the production 16x16 / 2x16x16
# meshes out of 512 host placeholder devices; smoke tests and benches see
# the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the real step function (train_step /
prefill / serve_step) against ShapeDtypeStruct inputs with production
shardings, proving the distribution config is coherent:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
            .lower(**input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())
        print(compiled.cost_analysis())

Results (memory, FLOPs, collective schedule, roofline terms) are written to
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` and summarized into
EXPERIMENTS.md by ``benchmarks/report.py``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_1_5b \
        --shape train_4k [--multi-pod] [--fsdp] [--out DIR]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, get_config, runnable_cells)
from repro.distributed import shardings as shd
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.nn import transformer as tfm
from repro.training.train_loop import TrainConfig, make_train_step

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _train_overrides(cfg, shape):
    """Per-cell model-config tweaks needed to fit/train at scale."""
    over = {}
    if shape.kind == "train":
        over["remat"] = "block"
    return dataclasses.replace(cfg, **over) if over else cfg


def _microbatches(cfg, shape) -> int:
    # keep per-microbatch activations bounded: ~2 sequences per data shard
    if shape.kind != "train":
        return 1
    per_shard = max(1, shape.global_batch // 16)
    return max(1, min(per_shard // 2, 16))


def _lower_one(cfg, shape, mesh, *, fsdp: bool, tcfg, microbatches: int,
               tp: bool = True):
    """Build + lower the cell's step function under the given mesh."""
    t0 = time.time()
    with shd.set_mesh(mesh):
        lowered = _build_lowered(cfg, shape, mesh, fsdp=fsdp, tcfg=tcfg,
                                 microbatches=microbatches, tp=tp)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    return lowered, compiled, t_lower, t_compile


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               fsdp: bool = True, tp: bool = True, cfg=None,
               tcfg: TrainConfig | None = None,
               extra_note: str = "", cost_pass: bool = True):
    """Lower + compile one cell; returns (result dict, compiled)."""
    shape = SHAPES[shape_name]
    if cfg is None:
        cfg = _train_overrides(get_config(arch), shape)
    # an explicitly-supplied cfg (hillclimb plans) is used verbatim
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    mb = _microbatches(cfg, shape) if tcfg is None else tcfg.microbatches

    # production pass: scan-over-layers program (the one that would run)
    lowered, compiled, t_lower, t_compile = _lower_one(
        cfg, shape, mesh, fsdp=fsdp, tcfg=tcfg, microbatches=mb, tp=tp)
    mem = compiled.memory_analysis()

    # cost-fidelity pass: XLA's cost_analysis counts while-loop bodies once
    # (see nn.transformer._scan), so FLOPs/bytes/collectives are measured on
    # UNROLLED modules.  Full unroll of 40-128-expert stacks takes tens of
    # minutes on this CPU, so two shallow unrolled compiles (L1/L2 layers)
    # are linearly extrapolated per layer — exact for uniform block stacks.
    if cost_pass:
        cost, hlo_colls, cost_note = _extrapolated_cost(
            cfg, shape, mesh, fsdp=fsdp, tcfg=tcfg, tp=tp)
        extra_note = (extra_note + " " + cost_note).strip()
    else:
        cost = compiled.cost_analysis()
        hlo_colls = roofline.collective_bytes(compiled.as_text())

    bytes_per_dev = _bytes_per_device(mem)
    terms = roofline.derive_from_parts(
        arch, shape_name, mesh_name, chips, cost, hlo_colls, cfg, shape,
        shape.kind, bytes_per_dev, note=extra_note)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "kind": shape.kind, "fsdp": fsdp,
        "microbatches": mb,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        "flops": terms.hlo_flops,
        "bytes": terms.hlo_bytes,
        "collectives": terms.coll_breakdown,
        "roofline": terms.to_json(),
        "note": extra_note,
    }
    return result, compiled


def _layer_counts_for_extrapolation(cfg) -> tuple[int, int]:
    """Two shallow depths aligned to the block period (hybrid: 3)."""
    period = (cfg.rglru_pattern + 1) if cfg.rglru_pattern else 1
    l1 = 2 * period
    l2 = 4 * period
    return l1, l2


def _shallow(cfg, n_layers: int):
    return dataclasses.replace(cfg, n_layers=n_layers, scan_layers=False)


def _measure(cfg, shape, mesh, *, fsdp, tcfg, tp=True):
    _, compiled, _, _ = _lower_one(cfg, shape, mesh, fsdp=fsdp, tcfg=tcfg,
                                   microbatches=1, tp=tp)
    ca = compiled.cost_analysis()
    colls = roofline.collective_bytes(compiled.as_text())
    return ({"flops": float(ca.get("flops", 0.0)),
             "bytes accessed": float(ca.get("bytes accessed", 0.0))},
            colls)


def _extrapolated_cost(cfg, shape, mesh, *, fsdp, tcfg, tp=True):
    """(cost dict, collective bytes dict, note) with per-layer
    linear extrapolation from two shallow unrolled compiles."""
    l1, l2 = _layer_counts_for_extrapolation(cfg)
    if cfg.n_layers <= l2:
        cost, colls = _measure(_shallow(cfg, cfg.n_layers), shape, mesh,
                               fsdp=fsdp, tcfg=tcfg, tp=tp)
        return cost, colls, "cost: full unroll"
    c1, k1 = _measure(_shallow(cfg, l1), shape, mesh, fsdp=fsdp, tcfg=tcfg,
                      tp=tp)
    c2, k2 = _measure(_shallow(cfg, l2), shape, mesh, fsdp=fsdp, tcfg=tcfg,
                      tp=tp)
    scale = (cfg.n_layers - l1) / (l2 - l1)
    cost = {k: c1[k] + (c2[k] - c1[k]) * scale for k in c1}
    colls = {k: k1.get(k, 0.0) + (k2.get(k, 0.0) - k1.get(k, 0.0)) * scale
             for k in set(k1) | set(k2)}
    return cost, colls, f"cost: unrolled L={l1},{l2} extrapolated"


def _build_lowered(cfg, shape, mesh, *, fsdp: bool, tcfg, microbatches: int,
                   tp: bool = True):
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig(microbatches=microbatches, fsdp=fsdp)
        tcfg = dataclasses.replace(tcfg, microbatches=microbatches)
        specs = input_specs(cfg, shape, tcfg)
        state, batch = specs["state"], specs["batch"]
        pspec = shd.param_specs(cfg, state.params, mesh, fsdp=tcfg.fsdp,
                                tp=tp)
        sspec = type(state)(P(), pspec,
                            type(state.opt)(P(), pspec, pspec),
                            None if state.err is None else pspec)
        bspec = shd.batch_specs(cfg, mesh, batch)
        step = make_train_step(
            cfg, tcfg, param_specs=pspec if tcfg.grad_sharding else None)
        jitted = jax.jit(step,
                         in_shardings=(_ns(mesh, sspec), _ns(mesh, bspec)),
                         out_shardings=(_ns(mesh, sspec), None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state, batch)
    elif shape.kind == "prefill":
        specs = input_specs(cfg, shape)
        params, batch = specs["params"], specs["batch"]
        pspec = shd.param_specs(cfg, params, mesh, fsdp=False, tp=tp)
        bspec = shd.batch_specs(cfg, mesh, batch)
        cache_shape = jax.eval_shape(partial(tfm.prefill, cfg), params, batch)
        cspec = shd.cache_specs(cfg, mesh, cache_shape[1])
        jitted = jax.jit(partial(tfm.prefill, cfg),
                         in_shardings=(_ns(mesh, pspec), _ns(mesh, bspec)),
                         out_shardings=(None, _ns(mesh, cspec)))
        lowered = jitted.lower(params, batch)
    else:  # decode
        specs = input_specs(cfg, shape)
        params, tokens, pos, cache = (specs["params"], specs["tokens"],
                                      specs["pos"], specs["cache"])
        pspec = shd.param_specs(cfg, params, mesh, fsdp=False, tp=tp)
        cspec = shd.cache_specs(cfg, mesh, cache)
        tspec = shd.batch_specs(cfg, mesh, tokens)
        jitted = jax.jit(partial(tfm.decode_step, cfg),
                         in_shardings=(_ns(mesh, pspec), _ns(mesh, tspec),
                                       None, _ns(mesh, cspec)),
                         out_shardings=(None, _ns(mesh, cspec)),
                         donate_argnums=(3,))
        lowered = jitted.lower(params, tokens, pos, cache)
    return lowered


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _bytes_per_device(mem) -> float:
    try:
        return float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    except Exception:
        return 0.0


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             fsdp: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    try:
        result, compiled = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                      fsdp=fsdp)
        result["status"] = "ok"
        print(f"[dryrun] {tag}: OK compile={result['compile_s']}s "
              f"flops={result['flops']:.3e} "
              f"coll={result['roofline']['coll_bytes']:.3e}B "
              f"bottleneck={result['roofline']['bottleneck']}")
    except Exception as e:  # failures here are bugs in the system
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "fail", "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
        print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {e}")
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for a, s in runnable_cells():
            cells.append((a, s, False))
            cells.append((a, s, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    n_ok = 0
    for arch, shape_name, mp in cells:
        r = run_cell(arch, shape_name, mp, args.out, fsdp=not args.no_fsdp)
        n_ok += r.get("status") == "ok"
    print(f"[dryrun] {n_ok}/{len(cells)} cells OK")
    if n_ok < len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
