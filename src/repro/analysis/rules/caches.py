"""PIM004 cache-hygiene: unbounded memos and unregistered mapper caches.

PR 3's mapper memos grew without bound until campaigns leaked memory across
hardware configs; the fix was (a) bounds on every memo and (b) a central
``clear_mapper_caches()`` / ``mapper_cache_stats()`` registry the campaign
calls between configs and the metrics layer snapshots.  Two sub-checks keep
that true:

* ``lru_cache(maxsize=None)`` (or ``functools.cache``) anywhere in library
  code — an unbounded memo grows with every distinct key for the life of
  the process;
* module-level memos (``_BoundedCache`` instances, ``lru_cache``-decorated
  functions) in the module that defines ``clear_mapper_caches`` must be
  referenced by BOTH the clear function and ``mapper_cache_stats`` —
  a memo outside the registry silently survives config changes and is
  invisible to the ``mapper.memo.*`` gauges.
"""

from __future__ import annotations

import ast

from .base import Rule
from .common import call_name, names_in

_LRU_NAMES = {"lru_cache", "functools.lru_cache"}
_UNBOUNDED_CACHE = {"cache", "functools.cache"}


def _lru_call_unbounded(node: ast.Call) -> bool:
    if call_name(node) not in _LRU_NAMES:
        return False
    if node.args:
        a = node.args[0]
        return isinstance(a, ast.Constant) and a.value is None
    for kw in node.keywords:
        if kw.arg == "maxsize":
            return (isinstance(kw.value, ast.Constant)
                    and kw.value.value is None)
    return False


class CacheHygieneRule(Rule):
    id = "PIM004"
    name = "cache-hygiene"
    hint = ("give the memo an explicit maxsize (or use _BoundedCache) and, "
            "if it is keyed by hardware config, register it in "
            "clear_mapper_caches()/mapper_cache_stats() so long campaigns "
            "stay flat and the mapper.memo.* gauges can see it")

    def check_module(self, mod, ctx):
        findings = []
        if mod.is_library:
            findings += self._unbounded(mod)
        findings += self._registry(mod)
        return findings

    def _unbounded(self, mod):
        findings = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and _lru_call_unbounded(node):
                findings.append(mod.finding(
                    self, node,
                    "`lru_cache(maxsize=None)` is an unbounded memo — it "
                    "grows with every distinct key for the process "
                    "lifetime"))
            elif (isinstance(node, (ast.Name, ast.Attribute))
                  and self._is_cache_decorator(mod, node)):
                findings.append(mod.finding(
                    self, node,
                    "`functools.cache` is unbounded — use "
                    "lru_cache(maxsize=...) instead"))
        return findings

    @staticmethod
    def _is_cache_decorator(mod, node) -> bool:
        from .common import dotted
        if dotted(node) not in _UNBOUNDED_CACHE:
            return False
        # only when used as a decorator (a bare Name load of a local
        # variable called "cache" must not trip this)
        for fn in ast.walk(mod.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node in fn.decorator_list:
                return True
        return False

    # -- the clear/stats registry ------------------------------------------

    def _registry(self, mod):
        clear_fn = stats_fn = None
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.FunctionDef):
                if stmt.name == "clear_mapper_caches":
                    clear_fn = stmt
                elif stmt.name == "mapper_cache_stats":
                    stats_fn = stmt
        if clear_fn is None or stats_fn is None:
            return []
        # module-level memos: _BoundedCache(...) assignments and
        # lru_cache-decorated defs
        memos: list[tuple[str, int]] = []
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and isinstance(stmt.value, ast.Call) \
                    and (call_name(stmt.value) or "").endswith(
                        "_BoundedCache"):
                memos.append((stmt.targets[0].id, stmt.lineno))
            elif isinstance(stmt, ast.FunctionDef):
                for dec in stmt.decorator_list:
                    if isinstance(dec, ast.Call) \
                            and call_name(dec) in _LRU_NAMES:
                        memos.append((stmt.name, stmt.lineno))
        clear_names = names_in(clear_fn)
        stats_names = names_in(stats_fn)
        # one level of shim aliasing: ``fn.cache_clear = MEMO.clear`` makes
        # MEMO reachable through fn (the _sharing_latency pattern)
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Attribute) \
                    and isinstance(stmt.targets[0].value, ast.Name):
                via = stmt.targets[0].value.id
                aliased = names_in(stmt.value)
                if via in clear_names:
                    clear_names |= aliased
                if via in stats_names:
                    stats_names |= aliased
        # helper functions called by stats can reference the memo too
        helper_defs = {s.name: s for s in mod.tree.body
                       if isinstance(s, ast.FunctionDef)}
        for pool in (clear_names, stats_names):
            for name in list(pool):
                if name in helper_defs and name not in (
                        "clear_mapper_caches", "mapper_cache_stats"):
                    pool |= names_in(helper_defs[name])
        findings = []
        for name, lineno in memos:
            missing = [what for what, pool in
                       (("clear_mapper_caches", clear_names),
                        ("mapper_cache_stats", stats_names))
                       if name not in pool]
            if missing:
                findings.append(mod.finding(
                    self, lineno,
                    f"memo `{name}` is missing from {' and '.join(missing)}"
                    f" — it will survive config changes unseen"))
        return findings
