"""Shared AST helpers: dotted-name resolution and jit-object discovery."""

from __future__ import annotations

import ast
from dataclasses import dataclass, field


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> str | None:
    return dotted(call.func)


def is_jax_jit_expr(node: ast.AST) -> ast.Call | None:
    """The ``jax.jit(...)``/``partial(jax.jit, ...)`` call under ``node``.

    Returns the call whose keywords carry ``donate_argnums`` /
    ``static_argnames`` (the outer ``partial`` for the partial form), or
    None when ``node`` is not a jit-wrapping expression.  Covers::

        @jax.jit                    /  @partial(jax.jit, ...)
        @functools.partial(jax.jit, ...)
        f = jax.jit(g)              /  f = jax.jit(lambda ...)
    """
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in ("jax.jit", "jit"):
            return node
        if name in ("partial", "functools.partial") and node.args:
            inner = dotted(node.args[0])
            if inner in ("jax.jit", "jit"):
                return node
    elif dotted(node) in ("jax.jit",):
        # bare ``@jax.jit`` decorator: synthesize an argument-less call so
        # callers read donation/static info uniformly
        fake = ast.Call(func=node, args=[], keywords=[])
        return fake
    return None


def _int_tuple(node: ast.AST) -> tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
        return tuple(out)
    return ()


@dataclass
class JitObject:
    """One module-level jit-compiled object."""

    name: str
    node: ast.AST                     # the def or assign statement
    lineno: int
    donate: tuple[int, ...] = ()
    func_def: ast.FunctionDef | None = None   # body available for defs


@dataclass
class ModuleJits:
    """Module-level jit objects plus names importable as jitted."""

    objects: dict[str, JitObject] = field(default_factory=dict)
    imported: set[str] = field(default_factory=set)

    @property
    def names(self) -> set[str]:
        return set(self.objects) | self.imported


def collect_module_jits(tree: ast.Module) -> ModuleJits:
    """Find jit objects defined (or imported by ``_jit`` convention) here."""
    out = ModuleJits()
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in stmt.decorator_list:
                call = is_jax_jit_expr(dec)
                if call is not None:
                    donate = ()
                    for kw in call.keywords:
                        if kw.arg == "donate_argnums":
                            donate = _int_tuple(kw.value)
                    out.objects[stmt.name] = JitObject(
                        name=stmt.name, node=stmt, lineno=stmt.lineno,
                        donate=donate, func_def=stmt)
        elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            call = is_jax_jit_expr(stmt.value)
            if call is not None:
                name = stmt.targets[0].id
                donate = ()
                for kw in getattr(call, "keywords", []):
                    if kw.arg == "donate_argnums":
                        donate = _int_tuple(kw.value)
                out.objects[name] = JitObject(name=name, node=stmt,
                                              lineno=stmt.lineno,
                                              donate=donate)
        elif isinstance(stmt, ast.ImportFrom):
            for alias in stmt.names:
                local = alias.asname or alias.name
                if local.endswith("_jit"):
                    out.imported.add(local)
    return out


def jitted_registry_names(tree: ast.Module) -> set[str]:
    """Names registered in a module-level ``_JITTED = {...}`` dict literal."""
    names: set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id == "_JITTED" \
                and isinstance(stmt.value, ast.Dict):
            for v in stmt.value.values:
                name = dotted(v)
                if name:
                    names.add(name.split(".")[-1])
    return names


def walk_functions(tree: ast.Module):
    """Every (possibly nested) function definition in the module."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}
