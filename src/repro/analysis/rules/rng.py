"""PIM005 rng-seed: unseeded randomness in engine and benchmark code.

PR 1 shipped a tuner whose proposal sampler silently dropped its seed —
every campaign run produced different mappings and the fig-9 comparison was
unreproducible until it was found by hand.  Engine and benchmark code must
draw from an explicitly seeded generator: ``random.Random(seed)``,
``np.random.default_rng(seed)``, or a ``jax.random`` key threaded from the
config.

Flagged patterns (in ``engine/`` / ``benchmarks/`` scope):

* module-function draws on the global generators: ``random.random()``,
  ``random.randint(...)``, ``np.random.rand(...)``, ``np.random.choice``...
* ``random.Random()`` / ``np.random.default_rng()`` constructed with no
  seed argument.
"""

from __future__ import annotations

import ast

from .base import Rule
from .common import call_name

#: draws on the process-global stdlib generator
_GLOBAL_RANDOM = {"random", "randint", "randrange", "uniform", "choice",
                  "choices", "shuffle", "sample", "gauss", "normalvariate",
                  "seed", "betavariate", "expovariate"}
#: legacy numpy global-state draws
_GLOBAL_NP = {"rand", "randn", "randint", "random", "choice", "shuffle",
              "permutation", "uniform", "normal", "seed", "random_sample"}


class RngSeedRule(Rule):
    id = "PIM005"
    name = "rng-seed"
    hint = ("thread an explicit seed: random.Random(seed) / "
            "np.random.default_rng(seed) / a jax.random key from the "
            "config — global-state draws make campaigns unreproducible "
            "(the PR 1 dropped-seed bug)")

    def check_module(self, mod, ctx):
        if not mod.in_scope("engine", "benchmarks"):
            return []
        findings = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if not name:
                continue
            parts = name.split(".")
            if parts[0] == "random" and len(parts) == 2 \
                    and parts[1] in _GLOBAL_RANDOM:
                findings.append(mod.finding(
                    self, node,
                    f"`{name}()` draws from the process-global stdlib "
                    f"generator — unseeded and shared across the whole "
                    f"process"))
            elif parts[0] in ("np", "numpy") and len(parts) == 3 \
                    and parts[1] == "random" and parts[2] in _GLOBAL_NP:
                findings.append(mod.finding(
                    self, node,
                    f"`{name}()` uses numpy's legacy global RNG state — "
                    f"use np.random.default_rng(seed)"))
            elif name in ("random.Random", "Random") and not node.args:
                findings.append(mod.finding(
                    self, node,
                    "`random.Random()` with no seed falls back to OS "
                    "entropy — pass the campaign seed"))
            elif name.split(".")[-1] == "default_rng" and not node.args:
                findings.append(mod.finding(
                    self, node,
                    "`default_rng()` with no seed falls back to OS "
                    "entropy — pass the campaign seed"))
        return findings
