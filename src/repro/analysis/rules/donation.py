"""PIM003 use-after-donate: reads of a buffer after XLA took ownership.

``donate_argnums`` lets XLA alias an argument's buffer into the output
(the engine donates the Adam (params, opt_state) pairs and the scheduler's
(cycles, loads) hot state).  Reading the donated python reference afterward
returns a deleted array — an error at best, silent garbage under some
backends.  ``tests/test_pipeline.py`` pins donation at runtime with
``.is_deleted()``; this rule catches the misuse pattern at review time.

The checker collects every module-level jit definition carrying
``donate_argnums`` across the whole lint run, then flags call sites that
pass a bare name in a donated position and read that name again later in
the same function without rebinding it first.
"""

from __future__ import annotations

import ast

from .base import Rule
from .common import call_name, collect_module_jits


class UseAfterDonateRule(Rule):
    id = "PIM003"
    name = "use-after-donate"
    hint = ("rebind the name from the call's return value (params, state = "
            "fit(params, state, ...)) or pass a fresh copy; a donated "
            "buffer must never be read again")

    def finalize(self, ctx):
        # donating functions are resolved by simple name across the repo:
        # the engine's donating entry points have unique names and call
        # sites import them directly
        donors: dict[str, tuple[int, ...]] = {}
        for mod in ctx.modules:
            for obj in collect_module_jits(mod.tree).objects.values():
                if obj.donate:
                    donors[obj.name] = obj.donate
        if not donors:
            return []
        findings = []
        for mod in ctx.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    findings.extend(
                        self._check_function(mod, node, donors))
        return findings

    def _check_function(self, mod, fn, donors):
        findings = []
        # flat, line-ordered event stream of the function body: donation
        # call sites, name loads, name stores
        calls = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = (call_name(node) or "").split(".")[-1]
                if name in donors:
                    calls.append((node, name))
        if not calls:
            return findings
        loads: dict[str, list[int]] = {}
        stores: dict[str, list[int]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Name):
                target = (loads if isinstance(node.ctx, ast.Load)
                          else stores)
                target.setdefault(node.id, []).append(node.lineno)
        for call, name in calls:
            for pos in donors[name]:
                if pos >= len(call.args):
                    continue
                arg = call.args[pos]
                if not isinstance(arg, ast.Name):
                    continue   # temporaries (device_put(...), literals) die
                end = getattr(call, "end_lineno", call.lineno)
                # a store at the call's own line is the canonical rebind
                # from the return value (x, s = fit(x, s, ...))
                rebind = min((ln for ln in stores.get(arg.id, [])
                              if ln >= call.lineno), default=None)
                for ln in sorted(loads.get(arg.id, [])):
                    if ln <= end:
                        continue
                    if rebind is not None and ln >= rebind:
                        break
                    findings.append(mod.finding(
                        self, ln,
                        f"`{arg.id}` is read after being donated to "
                        f"`{name}` (donate_argnums position {pos}, call at "
                        f"line {call.lineno}) — the buffer belongs to XLA "
                        f"now"))
                    break   # one finding per donated arg is enough
        return findings
