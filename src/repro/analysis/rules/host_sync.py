"""PIM001 host-sync: device->host pulls on jit-produced values in hot paths.

Every ``float()`` / ``int()`` / ``.item()`` / ``np.asarray()`` applied to a
value that flows out of a jitted function blocks the host on the XLA
computation.  In ``engine/`` and ``kernels/`` — the per-dispatch hot paths —
those syncs are exactly what PRs 5-7 spent their effort removing (the
device-resident pipeline's contract is ONE host sync per proposal wave).

The checker runs a per-function forward taint walk: names assigned from a
call to a known-jitted object (module-level ``@jax.jit`` defs, ``x =
jax.jit(...)`` objects, imported ``*_jit`` names) are tainted, taint
propagates through ordinary assignments and ``for`` targets, and
``jax.device_get`` — the sanctioned sync API — clears it.  A sync call on a
tainted value (or directly on a jit call) is a finding.

The per-dispatch result pull at an engine boundary is sometimes the design
(e.g. chunked dispatch loops that must concatenate on host); those carry an
inline suppression with a rationale.
"""

from __future__ import annotations

import ast

from .base import Rule
from .common import call_name, collect_module_jits

#: calls that force a device->host sync when handed a device value
_SYNC_FUNCS = {"float", "int", "np.asarray", "numpy.asarray",
               "np.array", "numpy.array"}
#: the blessed sync API — clears taint instead of flagging
_SANCTIONED = {"jax.device_get", "device_get", "jax.block_until_ready"}


def _is_sanctioned(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and call_name(node) in _SANCTIONED


class HostSyncRule(Rule):
    id = "PIM001"
    name = "host-sync"
    hint = ("pull results once via jax.device_get at the dispatch boundary "
            "(or keep the value on device); if this IS the sanctioned "
            "per-dispatch pull, suppress with a rationale")

    def check_module(self, mod, ctx):
        if not mod.in_scope("engine", "kernels"):
            return []
        jits = collect_module_jits(mod.tree)
        if not jits.names:
            return []
        findings = []
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(self._check_function(mod, node, jits.names))
        return findings

    # -- the forward taint walk --------------------------------------------

    def _check_function(self, mod, fn, jit_names):
        tainted: set[str] = set()
        findings: list = []
        seen: set[int] = set()   # node ids already reported

        def expr_tainted(expr: ast.AST) -> bool:
            if _is_sanctioned(expr):
                return False
            for sub in ast.walk(expr):
                if _is_sanctioned(sub):
                    continue
                if isinstance(sub, ast.Call):
                    name = call_name(sub)
                    if name and (name in jit_names
                                 or name.split(".")[-1] in jit_names):
                        return True
                if isinstance(sub, ast.Name) and sub.id in tainted:
                    return True
            return False

        def target_names(target: ast.AST) -> list[str]:
            out = []
            for sub in ast.walk(target):
                if isinstance(sub, ast.Name):
                    out.append(sub.id)
            return out

        def check_syncs(expr: ast.AST):
            for sub in ast.walk(expr):
                if not isinstance(sub, ast.Call) or id(sub) in seen:
                    continue
                name = call_name(sub)
                if name in _SYNC_FUNCS and sub.args \
                        and expr_tainted(sub.args[0]):
                    seen.add(id(sub))
                    findings.append(mod.finding(
                        self, sub,
                        f"`{name}()` forces a host sync on a value produced "
                        f"by a jitted function (inside `{fn.name}`)"))
                elif isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr == "item" and not sub.args \
                        and expr_tainted(sub.func.value):
                    seen.add(id(sub))
                    findings.append(mod.finding(
                        self, sub,
                        f"`.item()` forces a host sync on a value produced "
                        f"by a jitted function (inside `{fn.name}`)"))

        def handle(stmt: ast.stmt):
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                value = stmt.value
                if value is None:
                    return
                check_syncs(value)
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                names = [n for t in targets for n in target_names(t)]
                # a sanctioned pull (device_get) or a flagged sync both
                # leave a HOST value behind — don't re-flag downstream
                produces_device = (expr_tainted(value)
                                   and not _is_sanctioned(value)
                                   and not (isinstance(value, ast.Call)
                                            and call_name(value)
                                            in _SYNC_FUNCS))
                for n in names:
                    (tainted.add if produces_device
                     else tainted.discard)(n)
            elif isinstance(stmt, ast.For):
                check_syncs(stmt.iter)
                if expr_tainted(stmt.iter):
                    for n in target_names(stmt.target):
                        tainted.add(n)
                walk_body(stmt.body)
                walk_body(stmt.orelse)
            elif isinstance(stmt, ast.While):
                check_syncs(stmt.test)
                walk_body(stmt.body)
                walk_body(stmt.orelse)
            elif isinstance(stmt, ast.If):
                check_syncs(stmt.test)
                walk_body(stmt.body)
                walk_body(stmt.orelse)
            elif isinstance(stmt, ast.With):
                for item in stmt.items:
                    check_syncs(item.context_expr)
                walk_body(stmt.body)
            elif isinstance(stmt, ast.Try):
                walk_body(stmt.body)
                for h in stmt.handlers:
                    walk_body(h.body)
                walk_body(stmt.orelse)
                walk_body(stmt.finalbody)
            elif isinstance(stmt, (ast.Return, ast.Expr)):
                if stmt.value is not None:
                    check_syncs(stmt.value)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                pass   # nested defs get their own walk
            else:
                for sub in ast.iter_child_nodes(stmt):
                    if isinstance(sub, ast.expr):
                        check_syncs(sub)

        def walk_body(body):
            # two passes so loop-carried taint reaches syncs earlier in the
            # body than the assignment that taints them
            for _ in range(2):
                for stmt in body:
                    handle(stmt)

        walk_body(fn.body)
        return findings
