"""PIM002 retrace: hazards that multiply XLA programs per campaign.

Three sub-checks, all rooted in bugs this repo has actually shipped:

* **weak-type scalars** — PR 4's ``log_sn`` bug: a Python scalar captured
  into a jitted callee without a dtype pin (``jnp.asarray(x)`` with no
  ``dtype=``) traces weak-typed and forces one spurious recompile when a
  strongly-typed value later flows through the same program.  Flagged
  inside jitted function bodies when the argument is a function parameter
  or a local bound to a numeric literal.

* **bucket bypass** — jit call sites whose argument shapes come straight
  from ``len(...)`` / ``.shape`` without passing through a bucketing helper
  (``pow2_bucket`` / ``_pow4_bucket`` / ``pad_dataset`` / ``_next_pow2``):
  every distinct data size then compiles a fresh program, the exact
  pathology the pow2 bucketing contract (PR 4/5/7) exists to prevent.

* **unregistered jit** — module-level jit objects in ``engine/`` missing
  from the module's ``_JITTED`` registry are invisible to
  ``compiled_program_count()``, so the program-count CI contract cannot see
  them recompiling.
"""

from __future__ import annotations

import ast

from .base import Rule
from .common import (call_name, collect_module_jits, jitted_registry_names,
                     names_in)

_ASARRAY = {"jnp.asarray", "jnp.array", "jax.numpy.asarray",
            "jax.numpy.array"}
#: a call through any of these names legitimizes a raw ``len``/``.shape``
_BUCKET_HELPERS = ("pow2_bucket", "_pow4_bucket", "pow4_bucket",
                   "pad_dataset", "_next_pow2", "next_pow2", "_bucket_key",
                   "_mesh_pads", "_rounds")


def _has_dtype(call: ast.Call) -> bool:
    if len(call.args) >= 2:
        return True
    return any(kw.arg == "dtype" for kw in call.keywords)


class RetraceRule(Rule):
    id = "PIM002"
    name = "retrace"
    hint = ("pin scalar closures with jnp.asarray(x, dtype=...), route "
            "dynamic sizes through the pow2/pow4 bucketing helpers, and "
            "register jit objects in the module's _JITTED dict so "
            "compiled_program_count() sees them")

    def check_module(self, mod, ctx):
        if not mod.in_scope("engine", "kernels"):
            return []
        jits = collect_module_jits(mod.tree)
        findings = []
        findings += self._weak_types(mod, jits)
        findings += self._bucket_bypass(mod, jits)
        if mod.in_scope("engine"):
            findings += self._unregistered(mod, jits)
        return findings

    # -- (a) weak-typed scalar pins ----------------------------------------

    def _weak_types(self, mod, jits):
        findings = []
        for obj in jits.objects.values():
            fn = obj.func_def
            if fn is None:
                continue
            params = {a.arg for a in (fn.args.args + fn.args.kwonlyargs
                                      + fn.args.posonlyargs)}
            numeric_locals = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) \
                        and isinstance(node.value, ast.Constant) \
                        and isinstance(node.value.value, (int, float)):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            numeric_locals.add(t.id)
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Call)
                        and call_name(node) in _ASARRAY):
                    continue
                if _has_dtype(node) or not node.args:
                    continue
                arg = node.args[0]
                if isinstance(arg, ast.Name) \
                        and arg.id in params | numeric_locals:
                    findings.append(mod.finding(
                        self, node,
                        f"`{call_name(node)}({arg.id})` inside jitted "
                        f"`{fn.name}` has no dtype pin — a Python scalar "
                        f"here traces weak-typed and forces a recompile "
                        f"(the PR 4 log_sn bug)"))
                elif isinstance(arg, ast.Constant) \
                        and isinstance(arg.value, (int, float)):
                    findings.append(mod.finding(
                        self, node,
                        f"`{call_name(node)}({arg.value!r})` inside jitted "
                        f"`{fn.name}` has no dtype pin — weak-typed scalar"))
        return findings

    # -- (b) dynamic shapes bypassing the bucketing helpers ----------------

    def _bucket_bypass(self, mod, jits):
        findings = []
        jit_names = jits.names
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if not name or name.split(".")[-1] not in jit_names:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                raw = None
                bucketed = False
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call):
                        sub_name = call_name(sub) or ""
                        leaf = sub_name.split(".")[-1]
                        if leaf in _BUCKET_HELPERS:
                            bucketed = True
                        elif leaf == "len":
                            raw = sub
                    elif isinstance(sub, ast.Attribute) \
                            and sub.attr == "shape":
                        raw = sub
                if raw is not None and not bucketed:
                    findings.append(mod.finding(
                        self, node,
                        f"jit call `{name.split('.')[-1]}` takes a raw "
                        f"dynamic size (len()/.shape) — every distinct data "
                        f"size compiles a fresh XLA program; bucket it "
                        f"first"))
                    break
        return findings

    # -- (c) jit objects missing from the _JITTED registry -----------------

    def _unregistered(self, mod, jits):
        if not jits.objects:
            return []
        registered = jitted_registry_names(mod.tree)
        # names a _JITTED dict references indirectly (e.g. values built by
        # helper calls) count as registered too
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "_JITTED"
                            for t in stmt.targets):
                registered |= names_in(stmt.value)
        findings = []
        for obj in jits.objects.values():
            if obj.name not in registered:
                findings.append(mod.finding(
                    self, obj.lineno,
                    f"jit object `{obj.name}` is not in this module's "
                    f"_JITTED registry — compiled_program_count() cannot "
                    f"see its recompiles"))
        return findings
