"""PIM007 overlap-sync: host syncs inside mapper/scheduler wave code.

PR 10's overlapped wave executor extends the one-sync-per-wave contract
into the mapper itself: dispatch-phase functions (``dispatch_*`` /
``*_dispatch``) and phase generators (the ``yield``-ing wave bodies the
:class:`repro.engine.overlap.OverlapExecutor` drives) must leave their
device values IN FLIGHT — ``block_until_ready`` / ``device_get`` /
``.item()`` landing inside them, or ``float()`` / ``np.asarray()``
applied to a pending dispatch result, collapses the overlap window back
to serial execution and silently erases the ≥1.3x warm-iteration win
pinned by ``benchmarks/overlap_throughput.py``.

The checker scopes to ``engine/`` plus the mapper/DSE hot-path modules
and looks only at *wave functions*: generators whose own body yields, or
functions with ``dispatch`` in their name.  Inside those, the hard sync
APIs are flagged unconditionally, and a forward taint walk (the PIM001
idiom) flags host-pull conversions applied to values that flow out of a
dispatcher call (``*dispatch*`` / ``*_phases``).  Taint stops at the
sanctioned resolver methods — ``.resolve()`` / ``.latency_row()`` —
because their return value is already on host; functions *named* for the
observation boundary (``resolve`` / ``latency_row`` / ``drain``) are the
sanctioned sites and are skipped entirely.
"""

from __future__ import annotations

import ast

from .base import Rule
from .common import call_name

#: sync APIs that are never legal while a wave is in flight
_HARD_SYNCS = {"jax.block_until_ready", "block_until_ready",
               "jax.device_get", "device_get"}
#: conversions that force a device->host pull when handed a device value
_SYNC_FUNCS = {"float", "int", "np.asarray", "numpy.asarray",
               "np.array", "numpy.array"}
#: observation-boundary functions — the sanctioned resolve sites
_SANCTIONED_FNS = {"resolve", "latency_row", "drain"}
#: resolver methods whose return value is a HOST value (taint stops)
_RESOLVERS = {"resolve", "latency_row"}


def _is_dispatcher(name: str | None) -> bool:
    if not name:
        return False
    leaf = name.split(".")[-1]
    return "dispatch" in leaf or leaf.endswith("_phases")


def _is_resolver_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _RESOLVERS)


def _own_body_yields(fn: ast.AST) -> bool:
    """True when ``fn``'s own body (nested defs excluded) yields."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.Yield, ast.YieldFrom)):
            return True
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))
    return False


class OverlapSyncRule(Rule):
    id = "PIM007"
    name = "overlap-sync"
    hint = ("keep wave dispatch results in flight: resolve pending costs "
            "via their .resolve()/.latency_row() at the observation "
            "boundary, not with a sync inside the dispatch/phase body")

    def check_module(self, mod, ctx):
        if not mod.in_scope("engine", "mapper.py", "dse.py"):
            return []
        findings = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name in _SANCTIONED_FNS:
                continue
            if "dispatch" not in node.name and not _own_body_yields(node):
                continue
            findings.extend(self._check_wave(mod, node))
        return findings

    # -- the forward taint walk (PIM001 idiom, dispatcher-sourced) ---------

    def _check_wave(self, mod, fn):
        tainted: set[str] = set()
        findings: list = []
        seen: set[int] = set()

        def expr_tainted(expr: ast.AST) -> bool:
            if _is_resolver_call(expr):
                return False
            for sub in ast.walk(expr):
                if _is_resolver_call(sub):
                    continue
                if isinstance(sub, ast.Call) \
                        and _is_dispatcher(call_name(sub)):
                    return True
                if isinstance(sub, ast.Name) and sub.id in tainted:
                    return True
            return False

        def target_names(target: ast.AST) -> list[str]:
            return [sub.id for sub in ast.walk(target)
                    if isinstance(sub, ast.Name)]

        def check_syncs(expr: ast.AST):
            for sub in ast.walk(expr):
                if not isinstance(sub, ast.Call) or id(sub) in seen:
                    continue
                name = call_name(sub)
                if name and (name in _HARD_SYNCS
                             or name.split(".")[-1] in _HARD_SYNCS):
                    seen.add(id(sub))
                    findings.append(mod.finding(
                        self, sub,
                        f"`{name}()` blocks inside wave function "
                        f"`{fn.name}` — syncs belong at the observation "
                        f"boundary (.resolve()/.latency_row())"))
                elif isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr == "item" and not sub.args:
                    seen.add(id(sub))
                    findings.append(mod.finding(
                        self, sub,
                        f"`.item()` blocks inside wave function "
                        f"`{fn.name}` — syncs belong at the observation "
                        f"boundary"))
                elif name in _SYNC_FUNCS and sub.args \
                        and expr_tainted(sub.args[0]):
                    seen.add(id(sub))
                    findings.append(mod.finding(
                        self, sub,
                        f"`{name}()` pulls an in-flight dispatch result "
                        f"to host inside wave function `{fn.name}` — "
                        f"resolve it at the observation boundary instead"))

        def handle(stmt: ast.stmt):
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                if stmt.value is None:
                    return
                check_syncs(stmt.value)
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                names = [n for t in targets for n in target_names(t)]
                produces_pending = (expr_tainted(stmt.value)
                                    and not _is_resolver_call(stmt.value))
                for n in names:
                    (tainted.add if produces_pending
                     else tainted.discard)(n)
            elif isinstance(stmt, ast.For):
                check_syncs(stmt.iter)
                if expr_tainted(stmt.iter):
                    for n in target_names(stmt.target):
                        tainted.add(n)
                walk_body(stmt.body)
                walk_body(stmt.orelse)
            elif isinstance(stmt, (ast.While, ast.If)):
                check_syncs(stmt.test)
                walk_body(stmt.body)
                walk_body(stmt.orelse)
            elif isinstance(stmt, ast.With):
                for item in stmt.items:
                    check_syncs(item.context_expr)
                walk_body(stmt.body)
            elif isinstance(stmt, ast.Try):
                walk_body(stmt.body)
                for h in stmt.handlers:
                    walk_body(h.body)
                walk_body(stmt.orelse)
                walk_body(stmt.finalbody)
            elif isinstance(stmt, (ast.Return, ast.Expr)):
                if stmt.value is not None:
                    check_syncs(stmt.value)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                pass   # nested defs are their own (non-wave) scope
            else:
                for sub in ast.iter_child_nodes(stmt):
                    if isinstance(sub, ast.expr):
                        check_syncs(sub)

        def walk_body(body):
            # two passes so loop-carried taint reaches syncs earlier in
            # the body than the assignment that taints them
            for _ in range(2):
                for stmt in body:
                    handle(stmt)

        walk_body(fn.body)
        return findings
