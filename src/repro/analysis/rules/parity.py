"""PIM006 kernel-parity: every exported Pallas kernel needs a numpy oracle.

The Pallas kernels in ``kernels/dse_eval.py`` run under three regimes
(compiled TPU path, ``interpret=True`` fallback, numpy reference) and the
repo's correctness story is the numpy-parity tests that pin all three
together.  A kernel exported without a parity test is a kernel whose
compiled behaviour nobody is checking.

The rule runs as a finalize pass: collect the public top-level functions of
``kernels/dse_eval.py``, then require each name to appear (word-bounded)
somewhere under ``tests/``.
"""

from __future__ import annotations

import ast
import re

from .base import Rule


class KernelParityRule(Rule):
    id = "PIM006"
    name = "kernel-parity"
    hint = ("add a numpy-parity test under tests/ that calls the kernel and "
            "compares against its _ref_* numpy oracle (see "
            "tests/test_dse_eval_kernels.py for the pattern)")

    def finalize(self, ctx):
        findings = []
        corpus = "\n".join(text for _, text in ctx.test_sources)
        for mod in ctx.modules:
            if not mod.relpath.endswith("kernels/dse_eval.py"):
                continue
            for stmt in mod.tree.body:
                if not isinstance(stmt, ast.FunctionDef):
                    continue
                if stmt.name.startswith("_"):
                    continue
                if not re.search(rf"\b{re.escape(stmt.name)}\b", corpus):
                    findings.append(mod.finding(
                        self, stmt.lineno,
                        f"exported kernel `{stmt.name}` has no reference "
                        f"under tests/ — its compiled behaviour is "
                        f"unchecked against the numpy oracle"))
        return findings
