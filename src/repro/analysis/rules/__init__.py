"""The seven pimlint rules, instantiated once."""

from __future__ import annotations

from .base import Rule
from .caches import CacheHygieneRule
from .donation import UseAfterDonateRule
from .host_sync import HostSyncRule
from .overlap_sync import OverlapSyncRule
from .parity import KernelParityRule
from .retrace import RetraceRule
from .rng import RngSeedRule

ALL_RULES: list[Rule] = [
    HostSyncRule(),
    RetraceRule(),
    UseAfterDonateRule(),
    CacheHygieneRule(),
    RngSeedRule(),
    KernelParityRule(),
    OverlapSyncRule(),
]


def rule_by_key(key: str) -> Rule | None:
    """Look a rule up by id (``PIM001``) or name (``host-sync``)."""
    key = key.lower()
    for rule in ALL_RULES:
        if key in (rule.id.lower(), rule.name.lower()):
            return rule
    return None


__all__ = ["ALL_RULES", "Rule", "rule_by_key", "HostSyncRule",
           "RetraceRule", "UseAfterDonateRule", "CacheHygieneRule",
           "RngSeedRule", "KernelParityRule", "OverlapSyncRule"]
