"""Rule protocol: per-module checks plus a cross-file finalize pass."""

from __future__ import annotations


class Rule:
    """One checker.  Subclasses set ``id``/``name``/``hint`` and override
    :meth:`check_module` (per file) and/or :meth:`finalize` (cross-file,
    runs once after every module was visited)."""

    id: str = "PIM000"
    name: str = "base"
    hint: str = ""

    def check_module(self, mod, ctx):
        return []

    def finalize(self, ctx):
        return []
