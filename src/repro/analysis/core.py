"""Checker framework: file discovery, suppressions, baselines, findings.

The framework is deliberately stdlib-only (``ast`` + ``re`` + ``json``) so
the lint step costs nothing to run on a bare interpreter — CI runs it
before any heavyweight import.

Suppression syntax (matched by rule id ``PIM004`` or name
``cache-hygiene``, case-insensitive; ``all`` matches every rule):

* same line::

      @lru_cache(maxsize=None)   # pimlint: disable=cache-hygiene -- why

* next line::

      # pimlint: disable-next-line=host-sync -- the sanctioned pull
      out = np.asarray(jitted(x))

* whole file (anywhere in the file)::

      # pimlint: disable-file=rng-seed -- fuzzing entry point, unseeded on purpose

Baseline: ``pimlint.baseline.json`` holds fingerprints of grandfathered
findings.  A fingerprint hashes (rule, path, normalized source line) — NOT
the line number — so unrelated edits above a baselined finding don't
resurrect it.  ``--write-baseline`` refreshes the file.
"""

from __future__ import annotations

import ast
import hashlib
import json
import re
from dataclasses import dataclass, field
from pathlib import Path

#: directory names never descended into (fixture corpora must not lint the
#: real tree's rules against themselves)
EXCLUDED_DIRS = {"__pycache__", ".git", ".pytest_cache", "fixtures",
                 "node_modules", ".eggs", "build", "dist"}

_SUPPRESS_RE = re.compile(
    r"#\s*pimlint:\s*(disable|disable-next-line|disable-file)"
    r"\s*=\s*([A-Za-z0-9_,\- ]+)")


@dataclass(frozen=True)
class Finding:
    """One lint finding: file:line, rule id, message, and a fix hint."""

    rule: str                 # "PIM004"
    name: str                 # "cache-hygiene"
    path: str                 # posix relpath from the lint root
    line: int
    col: int
    message: str
    hint: str
    source_line: str = ""     # stripped text of the anchor line

    @property
    def fingerprint(self) -> str:
        """Stable id for baseline matching (line-number independent)."""
        basis = f"{self.rule}|{self.path}|{self.source_line}"
        return hashlib.sha1(basis.encode()).hexdigest()[:16]

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}"
                f"({self.name}) {self.message}\n    hint: {self.hint}")

    def to_dict(self) -> dict:
        return {"rule": self.rule, "name": self.name, "path": self.path,
                "line": self.line, "col": self.col, "message": self.message,
                "hint": self.hint, "fingerprint": self.fingerprint}


class _Suppressions:
    """Per-file suppression table parsed from the raw source."""

    def __init__(self, text: str):
        self.by_line: dict[int, set[str]] = {}
        self.whole_file: set[str] = set()
        for i, ln in enumerate(text.splitlines(), start=1):
            m = _SUPPRESS_RE.search(ln)
            if not m:
                continue
            kind = m.group(1)
            # everything after ``--`` is the human rationale, not a rule key
            keys = {k.strip().lower()
                    for k in m.group(2).split("--")[0].split(",") if k.strip()}
            if kind == "disable":
                self.by_line.setdefault(i, set()).update(keys)
            elif kind == "disable-next-line":
                self.by_line.setdefault(i + 1, set()).update(keys)
            else:
                self.whole_file.update(keys)

    def matches(self, finding: Finding) -> bool:
        keys = self.whole_file | self.by_line.get(finding.line, set())
        return bool(keys & {"all", finding.rule.lower(),
                            finding.name.lower()})


@dataclass
class LintModule:
    """One parsed source file plus the path-derived rule scopes."""

    path: Path
    relpath: str              # posix, relative to the lint root
    text: str
    tree: ast.Module
    lines: list[str] = field(default_factory=list)
    suppressions: _Suppressions | None = None

    @property
    def segments(self) -> set[str]:
        return set(Path(self.relpath).parts)

    def in_scope(self, *names: str) -> bool:
        """True if any path segment matches (``engine``, ``kernels``, ...)."""
        return bool(self.segments & set(names))

    @property
    def is_library(self) -> bool:
        """Library code = everything outside tests/ and benchmarks/."""
        return not self.in_scope("tests", "benchmarks")

    def source_line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule, node_or_line, message: str,
                col: int = 0) -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        col = (col if isinstance(node_or_line, int)
               else getattr(node_or_line, "col_offset", 0))
        return Finding(rule=rule.id, name=rule.name, path=self.relpath,
                       line=line, col=col, message=message, hint=rule.hint,
                       source_line=self.source_line(line))


@dataclass
class LintContext:
    """Everything the rules see: parsed modules + the tests reference corpus."""

    root: Path
    modules: list[LintModule]
    test_sources: list[tuple[str, str]]   # (relpath, text) under tests/


@dataclass
class LintResult:
    findings: list[Finding]               # new (not suppressed, not baselined)
    suppressed: list[Finding]
    baselined: list[Finding]
    files_scanned: int
    parse_errors: list[str] = field(default_factory=list)

    @property
    def all_active(self) -> list[Finding]:
        """Everything real in the tree right now (new + baselined)."""
        return self.baselined + self.findings

    def counts(self) -> dict[str, dict[str, int]]:
        out: dict[str, dict[str, int]] = {}
        for kind, items in (("new", self.findings),
                            ("suppressed", self.suppressed),
                            ("baselined", self.baselined)):
            for f in items:
                row = out.setdefault(f.rule, {"name": f.name, "new": 0,
                                              "suppressed": 0,
                                              "baselined": 0})
                row[kind] += 1
        return out


def iter_python_files(paths: list[Path]):
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for sub in sorted(p.rglob("*.py")):
                if not EXCLUDED_DIRS & set(sub.relative_to(p).parts[:-1]):
                    yield sub


def _load_module(path: Path, root: Path) -> LintModule | None:
    text = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError:
        return None
    rel = path.resolve().relative_to(root.resolve()).as_posix() \
        if path.resolve().is_relative_to(root.resolve()) else path.as_posix()
    mod = LintModule(path=path, relpath=rel, text=text, tree=tree,
                     lines=text.splitlines())
    mod.suppressions = _Suppressions(text)
    return mod


def default_targets(root: Path) -> list[Path]:
    """The repo's lintable surface: library sources + benchmarks."""
    out = []
    for cand in ("src", "benchmarks"):
        if (root / cand).is_dir():
            out.append(root / cand)
    return out or [root]


def load_context(root: Path, targets: list[Path] | None = None) -> LintContext:
    root = root.resolve()
    targets = targets or default_targets(root)
    modules, errors = [], []
    for path in iter_python_files(targets):
        mod = _load_module(path, root)
        if mod is None:
            errors.append(str(path))
        else:
            modules.append(mod)
    test_sources: list[tuple[str, str]] = []
    tests_dir = root / "tests"
    if tests_dir.is_dir():
        for path in iter_python_files([tests_dir]):
            rel = path.relative_to(root).as_posix()
            test_sources.append((rel, path.read_text(encoding="utf-8")))
    ctx = LintContext(root=root, modules=modules, test_sources=test_sources)
    ctx.parse_errors = errors  # type: ignore[attr-defined]
    return ctx


def run_lint(root: Path | str, targets: list[Path] | None = None, *,
             rules=None, baseline: dict | None = None) -> LintResult:
    """Run every rule over ``root`` and split findings by disposition."""
    from .rules import ALL_RULES
    root = Path(root)
    ctx = load_context(root, targets)
    rules = ALL_RULES if rules is None else rules
    raw: list[Finding] = []
    for rule in rules:
        for mod in ctx.modules:
            raw.extend(rule.check_module(mod, ctx))
        raw.extend(rule.finalize(ctx))
    raw.sort(key=lambda f: (f.path, f.line, f.rule))

    by_path = {m.relpath: m for m in ctx.modules}
    new, suppressed, baselined = [], [], []
    budget: dict[tuple[str, str, str], int] = {}
    for entry in (baseline or {}).get("findings", []):
        key = (entry["rule"], entry["path"], entry["fingerprint"])
        budget[key] = budget.get(key, 0) + 1
    for f in raw:
        mod = by_path.get(f.path)
        if mod is not None and mod.suppressions.matches(f):
            suppressed.append(f)
            continue
        key = (f.rule, f.path, f.fingerprint)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            baselined.append(f)
        else:
            new.append(f)
    return LintResult(findings=new, suppressed=suppressed,
                      baselined=baselined, files_scanned=len(ctx.modules),
                      parse_errors=getattr(ctx, "parse_errors", []))


# ---------------------------------------------------------------------------
# Baseline I/O
# ---------------------------------------------------------------------------

BASELINE_VERSION = 1


def load_baseline(path: Path | str) -> dict:
    path = Path(path)
    if not path.exists():
        return {"version": BASELINE_VERSION, "findings": []}
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{data.get('version')!r}")
    return data


def save_baseline(path: Path | str, findings: list[Finding]) -> None:
    """Persist the current findings as the new grandfathered set.

    Every entry carries a ``reason`` slot — fill it in before committing;
    an unexplained baseline entry defeats the point of the gate.
    """
    entries = [{"rule": f.rule, "path": f.path, "line": f.line,
                "fingerprint": f.fingerprint, "source": f.source_line,
                "reason": "TODO: justify this grandfathered finding"}
               for f in findings]
    Path(path).write_text(json.dumps(
        {"version": BASELINE_VERSION, "findings": entries}, indent=1) + "\n")
