"""``python -m repro.analysis`` — the pimlint CLI.

Exit codes:

* 0 — no new findings (suppressed / baselined ones are fine)
* 1 — new findings present
* 2 — usage or internal error (unreadable baseline, no files scanned)

``--json PATH`` additionally writes the machine-readable report CI uploads
as ``experiments/LINT_8.json``.  When ``repro.obs.metrics`` is importable
the per-rule totals are mirrored into ``lint.findings.{rule}`` counters so
lint volume shows up next to the campaign metrics.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import (default_targets, load_baseline, run_lint, save_baseline)
from .rules import ALL_RULES, rule_by_key


def _publish_metrics(result) -> None:
    """Best-effort mirror of per-rule counts into the obs metrics registry."""
    try:
        from repro.obs.metrics import METRICS
    except Exception:
        return
    for rule, row in result.counts().items():
        METRICS.counter(f"lint.findings.{row['name']}").inc(
            row["new"] + row["baselined"])


def _report(result, root: Path) -> dict:
    status = "clean" if not result.findings else "dirty"
    return {
        "schema": "nicepim-lint/1",
        "status": status,
        "files_scanned": result.files_scanned,
        "parse_errors": result.parse_errors,
        "rules": {r.id: {"name": r.name} for r in ALL_RULES},
        "counts": result.counts(),
        "new_findings": [f.to_dict() for f in result.findings],
        "baselined": len(result.baselined),
        "suppressed": len(result.suppressed),
    }


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="pimlint: jit/donation/cache invariant checks")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files or directories to lint "
                             "(default: <root>/src and <root>/benchmarks)")
    parser.add_argument("--root", type=Path, default=Path.cwd(),
                        help="repo root findings are reported relative to")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="baseline file "
                             "(default: <root>/pimlint.baseline.json)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline file entirely")
    parser.add_argument("--write-baseline", action="store_true",
                        help="grandfather every current finding and exit 0")
    parser.add_argument("--json", type=Path, default=None, metavar="PATH",
                        help="write the machine-readable report here")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    parser.add_argument("--rule", action="append", default=None,
                        metavar="KEY", help="run only this rule "
                        "(id or name; repeatable)")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id}  {rule.name}")
            print(f"    hint: {rule.hint}")
        return 0

    root = args.root.resolve()
    baseline_path = args.baseline or (root / "pimlint.baseline.json")
    baseline = None
    if not args.no_baseline and not args.write_baseline:
        try:
            baseline = load_baseline(baseline_path)
        except (ValueError, json.JSONDecodeError) as exc:
            print(f"pimlint: error: {exc}", file=sys.stderr)
            return 2

    rules = None
    if args.rule:
        rules = []
        for key in args.rule:
            rule = rule_by_key(key)
            if rule is None:
                print(f"pimlint: error: unknown rule {key!r}",
                      file=sys.stderr)
                return 2
            rules.append(rule)

    targets = [p.resolve() for p in args.paths] or default_targets(root)
    result = run_lint(root, targets, rules=rules, baseline=baseline)

    if result.files_scanned == 0:
        print("pimlint: error: no python files scanned", file=sys.stderr)
        return 2

    if args.write_baseline:
        save_baseline(baseline_path, result.findings + result.baselined)
        print(f"pimlint: wrote {len(result.findings) + len(result.baselined)}"
              f" finding(s) to {baseline_path}")
        return 0

    _publish_metrics(result)
    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(
            json.dumps(_report(result, root), indent=1) + "\n")

    for f in result.findings:
        print(f.render())
    summary = (f"pimlint: {result.files_scanned} files, "
               f"{len(result.findings)} new finding(s), "
               f"{len(result.baselined)} baselined, "
               f"{len(result.suppressed)} suppressed")
    print(summary)
    if result.parse_errors:
        for p in result.parse_errors:
            print(f"pimlint: warning: could not parse {p}", file=sys.stderr)
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
