"""pimlint: repo-specific static analysis for the engine's jit invariants.

The jitted DSE engine (PRs 1-7) rests on invariants that are only exercised
at runtime — donation pins, ``transfer_guard`` replays, pow2-bucketed
program-count bounds, Pallas parity tests — and several past bugs (the
weak-typed ``log_sn`` recompile in PR 4, the dropped seed in PR 1, the
unbounded mapper memos in PR 3) are exactly the class a linter catches
before CI runs.  This package is an AST-based lint pass with per-rule
checkers targeting this repo's specific hazards:

========  ==============  ====================================================
id        name            hazard
========  ==============  ====================================================
PIM001    host-sync       ``float()``/``int()``/``.item()``/``np.asarray`` on
                          values flowing out of jitted functions in
                          ``engine/`` / ``kernels/`` hot paths
PIM002    retrace         weak-typed scalar closures in jitted callees,
                          jit call sites bypassing the pow2/pow4 bucketing
                          helpers, jit objects missing from ``_JITTED``
PIM003    use-after-donate reads of an argument after it was passed in a
                          ``donate_argnums`` position
PIM004    cache-hygiene   ``lru_cache(maxsize=None)`` in library code; memos
                          missing from ``clear_mapper_caches()`` /
                          ``mapper_cache_stats()``
PIM005    rng-seed        unseeded ``random`` / ``np.random`` use in engine
                          or benchmark code
PIM006    kernel-parity   Pallas kernels exported from ``kernels/dse_eval.py``
                          without a numpy-parity test under ``tests/``
========  ==============  ====================================================

Run with ``python -m repro.analysis`` (stdlib only, no third-party deps).
Intentional cases carry an inline ``# pimlint: disable=<rule>`` suppression
with a rationale, or live in the committed baseline file
(``pimlint.baseline.json``); CI fails on any NEW finding.
"""

from .core import (Finding, LintModule, LintResult, load_baseline, run_lint,
                   save_baseline)
from .rules import ALL_RULES, rule_by_key

__all__ = [
    "ALL_RULES", "Finding", "LintModule", "LintResult", "load_baseline",
    "rule_by_key", "run_lint", "save_baseline",
]
