"""End-to-end driver: train a ~100M-param qwen2-family LM for a few hundred
steps on synthetic data, with checkpointing + restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

``--small`` trains the reduced config instead (seconds instead of hours on
this CPU container); the default config is ~100M params (d_model=512,
12 layers, vocab 32k approximation of the qwen2 family).
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import get_config
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.small:
        state, hist = train("qwen2_0_5b", reduced=True, steps=args.steps,
                            global_batch=8, seq_len=128,
                            ckpt_dir=args.ckpt_dir)
    else:
        # ~100M params: 12 x d512 blocks + 32k vocab embedding
        state, hist = train("qwen2_0_5b", reduced=False, steps=args.steps,
                            global_batch=16, seq_len=256, microbatches=2,
                            d_model=512, n_layers=12,
                            ckpt_dir=args.ckpt_dir)
    first = sum(h["loss"] for h in hist[:10]) / max(1, len(hist[:10]))
    last = sum(h["loss"] for h in hist[-10:]) / max(1, len(hist[-10:]))
    print(f"loss: {first:.4f} -> {last:.4f} over {len(hist)} steps")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
