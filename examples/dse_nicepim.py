"""The full NicePIM DSE loop (paper Fig. 7) on reduced workloads.

Iterates: PIM-Tuner samples + filters + ranks hardware configs -> the
area "simulator" validates -> PIM-Mapper + Data-Scheduler produce mapping
schemes and EDP costs -> the tuner's DKL/filter models are refit.

    PYTHONPATH=src python examples/dse_nicepim.py [--iters 8] [--all-legal]
                                                  [--tuner-backend loop]
                                                  [--scheduler-backend loop]
                                                  [--trace out.json]

``--all-legal`` maps EVERY legal proposal per iteration in one multi-config
batch (``WorkloadEvaluator.evaluate_batch`` / ``PimMapper.map_many``) instead
of the paper's first-legal-only walk — more observations per DKL refit.
``--tuner-backend loop`` swaps the jitted scan tuner engine for the scalar
per-step reference path (same-seed results match within float drift).
``--scheduler-backend loop`` swaps the jitted engine Data-Scheduler for the
host-Python 2-opt reference (different RNG streams: close, not identical).
``--trace out.json`` records propose/map/schedule/evaluate spans to a
Chrome-trace file — open it in Perfetto (https://ui.perfetto.dev) or
chrome://tracing to see where the loop spends its time.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.dse import WorkloadEvaluator, run_dse
from repro.core.mapper import mapper_cache_stats
from repro.core.tuner import PimTuner
from repro.core.workloads import bert_base, googlenet
from repro.engine.cache import EvalCache
from repro.engine.tuner_train import compiled_program_count
from repro.obs.trace import Tracer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--all-legal", action="store_true",
                    help="map every legal proposal per iteration "
                         "(multi-config batched mapping)")
    ap.add_argument("--tuner-backend", default="scan",
                    choices=("scan", "loop"),
                    help="jitted scan tuner engine (default) or the scalar "
                         "per-step reference loop")
    ap.add_argument("--scheduler-backend", default="scan",
                    choices=("scan", "loop"),
                    help="jitted engine Data-Scheduler (default) or the "
                         "host-Python 2-opt reference")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace of the run here "
                         "(Perfetto / chrome://tracing)")
    args = ap.parse_args()

    workloads = [googlenet(1, scale=4),
                 bert_base(1, seq=64, n_layers=2, n_heads=4)]
    cache = EvalCache()
    evaluator = WorkloadEvaluator(
        workloads, mapper_kwargs=dict(max_optim_iter=1, lm_cap=60, n_wr=3),
        scheduler_backend=args.scheduler_backend, cache=cache)
    tuner = PimTuner(n_sample=512, backend=args.tuner_backend)
    tracer = Tracer() if args.trace else None
    res = run_dse(tuner, evaluator, iterations=args.iters, verbose=True,
                  evaluate_all_legal=args.all_legal, tracer=tracer)
    if tracer is not None:
        tracer.save(args.trace)
    best = res.best()
    print("\nbest architecture found:")
    print(f"  node array : {best.cfg.na_row}x{best.cfg.na_col} "
          f"({best.cfg.banks_per_node} banks/node)")
    print(f"  PE array   : {best.cfg.pea_row}x{best.cfg.pea_col}")
    print(f"  buffers    : i={best.cfg.ibuf_kib} w={best.cfg.wbuf_kib} "
          f"o={best.cfg.obuf_kib} KiB")
    print(f"  area       : {best.area_mm2:.1f} mm^2 (budget 48)")
    print(f"  EDP cost   : {best.cost:.3e}")
    print(f"  quality curve: "
          f"{['%.2e' % q for q in res.quality_curve()]}")

    stats = cache.stats
    total = stats["hits"] + stats["misses"]
    memo = mapper_cache_stats()
    print("\nrun telemetry:")
    print(f"  eval cache : {stats['hits']}/{total} hits "
          f"({stats['entries']} entries)")
    print(f"  xla jit    : {sum(compiled_program_count().values())} "
          f"compiled programs {compiled_program_count()}")
    print(f"  mapper memo: {sum(memo.values())} entries {memo}")
    if args.trace:
        print(f"  trace      : {args.trace} "
              "(open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
