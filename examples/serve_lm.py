"""Serve a small LM with batched requests through the engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6_1_6b]

Uses the reduced config (random weights — this demonstrates the serving
path: batched prefill, KV/recurrent-state cache, greedy + temperature
sampling), then prints a throughput probe.
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.nn import transformer as T
from repro.serving.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2_0_5b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, slots=args.slots, max_len=128)

    reqs = [Request(i, prompt=[(7 * i + j) % cfg.vocab for j in range(16)],
                    max_new_tokens=args.new_tokens,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(args.slots)]
    eng.serve_batch(reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt[:4]={r.prompt[:4]} "
              f"-> out={r.out_tokens[:8]}...")

    probe = eng.throughput_probe(prompt_len=16,
                                 new_tokens=args.new_tokens)
    print(f"throughput: {probe['tok_per_s']:.1f} tok/s "
          f"({probe['tokens']} tokens in {probe['seconds']:.2f}s, "
          f"CPU interpret path)")


if __name__ == "__main__":
    main()
