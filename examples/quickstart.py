"""Quickstart: the NicePIM DSE core in ~40 lines.

Maps GoogLeNet onto the paper's 4x4 DRAM-PIM system, compares the
PIM-Mapper against the sequential baseline, and schedules the data-sharing
with the ILP-equivalent optimizer.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.baseline import BaselineMapper
from repro.core.hardware import PAPER_4X4
from repro.core.mapper import PimMapper, evaluate_mapping
from repro.core.workloads import googlenet


def main() -> None:
    g = googlenet(batch=1, scale=2)      # 112x112 input for a fast demo
    hw = PAPER_4X4
    print(f"workload: {g.name}  ({g.total_macs / 1e9:.2f} GMACs, "
          f"{g.total_weights / 1e6:.1f}M weights)")
    print(f"hardware: {hw.na_row}x{hw.na_col} PIM nodes, "
          f"{hw.pea_row}x{hw.pea_col} PEs, area {hw.area_mm2():.1f} mm^2")

    mapping = PimMapper(hw).map(g)
    rep = evaluate_mapping(mapping)
    base = evaluate_mapping(BaselineMapper(hw).map(g))

    print(f"\nPIM-Mapper : {rep.latency_s * 1e3:8.3f} ms   "
          f"{rep.energy_pj / 1e6:8.1f} uJ")
    print(f"baseline   : {base.latency_s * 1e3:8.3f} ms   "
          f"{base.energy_pj / 1e6:8.1f} uJ")
    print(f"reduction  : {1 - rep.latency_s / base.latency_s:9.1%} latency  "
          f"{1 - rep.energy_pj / base.energy_pj:8.1%} energy")

    print("\nper-layer choices (first 6):")
    for name, ch in list(mapping.choices.items())[:6]:
        print(f"  {name:12s} {ch.lm.short():30s} wr={ch.wr:3d} "
              f"region={ch.region.h_shape}x{ch.region.w_shape} "
              f"dl={ch.dl_in.short()}->{ch.dl_out.short()}")


if __name__ == "__main__":
    main()
