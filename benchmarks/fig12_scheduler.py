"""Fig. 12 reproduction: Data-Scheduler (ILP) vs TSP vs SHP.

Paper setup (Sec. VIII-E): PIM-node arrays of 4x4 / 8x8 / 16x16; sharing
sets of 16 nodes; on the larger arrays multiple sets interleaved with
strides 2 (8x8) and 4 (16x16); 8 KiB to share per node; 64-bit NoC flits
@ 400 MHz.

``--backend scan|loop`` picks the ILP-LS implementation: the jitted engine
search (default) or the host-Python reference; ``benchmarks/
scheduler_throughput.py`` pins their relative quality and speed.
"""

from __future__ import annotations

import time

from repro.core.noc import MeshNoc
from repro.core.scheduler import solve_ilp_ls, solve_shp, solve_tsp

FLIT_BW = 64 / 8 * 400e6     # bytes/s per link
FREQ = 400e6
EPJ = 1.1
CHUNK = 8192.0               # 8 KiB per node


def interleaved_sets(dim: int, stride: int) -> list[list[int]]:
    noc = MeshNoc(dim, dim)
    sets = []
    for oy in range(stride):
        for ox in range(stride):
            nodes = [noc.node(r * stride + oy, c * stride + ox)
                     for r in range(4) for c in range(4)]
            sets.append(nodes)
    return sets


def run(seed: int = 0, backend: str = "scan") -> list[dict]:
    rows = []
    for dim, stride in ((4, 1), (8, 2), (16, 4)):
        noc = MeshNoc(dim, dim)
        sets = interleaved_sets(dim, stride)
        lat = {}
        for name, solver in (("ilp", solve_ilp_ls), ("tsp", solve_tsp),
                             ("shp", solve_shp)):
            t0 = time.time()
            kw = {"seed": seed, "restarts": 6, "iters": 1200,
                  "backend": backend} if name == "ilp" else {}
            res = solver(noc, sets, [CHUNK] * len(sets), FLIT_BW, FREQ, EPJ,
                         **kw)
            lat[name] = res.latency_s
            rows.append({
                "table": "fig12", "array": f"{dim}x{dim}", "method": name,
                "backend": backend if name == "ilp" else "-",
                "latency_us": res.latency_s * 1e6,
                "max_link_bytes": res.max_link_bytes,
                "solve_s": time.time() - t0,
            })
        for r in rows[-3:]:
            r["norm_latency"] = r["latency_us"] / (lat["ilp"] * 1e6)
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="scan", choices=("scan", "loop"),
                    help="ILP-LS implementation: jitted engine (default) "
                         "or the host-Python reference")
    args = ap.parse_args()
    for r in run(backend=args.backend):
        print(f"fig12_{r['array']}_{r['method']},"
              f"{r['latency_us']:.2f},"
              f"norm={r['norm_latency']:.3f}")


if __name__ == "__main__":
    main()
