import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede any jax import (same contract as repro.launch.dryrun).

"""§Perf hillclimb driver: iterate on the dominant roofline term of a cell.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch moonshot_v1_16b_a3b \
        --shape train_4k [--multi-pod]

Runs the paper-faithful baseline plan first, then the candidate changes from
core.autoshard (microbatching, remat policy, FSDP/replication = WR, int8
gradient compression, MoE capacity), logging hypothesis -> change ->
before/after to experiments/perf/<cell>.json and a markdown §Perf entry.
"""

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.configs.base import ARCH_IDS, SHAPES
from repro.core.autoshard import hillclimb


def to_markdown(arch: str, shape: str, mesh: str, log: list[dict]) -> str:
    lines = [f"### Hillclimb: `{arch}` x `{shape}` x `{mesh}`", ""]
    base = next((e for e in log if "step_s" in e), None)
    lines += ["| plan | hypothesis | compute_s | memory_s | collective_s | "
              "step_s | mem/dev | vs baseline | verdict |",
              "|---|---|---|---|---|---|---|---|---|"]
    best = None
    for e in log:
        if "error" in e:
            lines.append(f"| `{e['plan']}` | {e['note']} | | | | | | "
                         f"FAILED: {e['error'][:50]} |")
            continue
        rel = e["step_s"] / base["step_s"] if base else 1.0
        verdict = "baseline" if e is base else \
            ("confirmed" if rel < 0.95 else
             "refuted" if rel > 1.05 else "neutral")
        if not e.get("fits_hbm", True):
            verdict += " (exceeds 16GB HBM)"
        elif best is None or e["step_s"] < best["step_s"]:
            best = e
        lines.append(
            f"| `{e['plan']}` | {e['note']} | {e['compute_s']:.4f} | "
            f"{e['memory_s']:.4f} | {e['collective_s']:.4f} | "
            f"{e['step_s']:.4f} | {e.get('mem_gb', 0):.1f}GB | "
            f"{rel:.2f}x | {verdict} |")
    if base and best and best is not base:
        gain = 1 - best["step_s"] / base["step_s"]
        lines += ["", f"**Result:** `{best['plan']}` cuts the roofline step "
                      f"time {gain:.0%} vs the paper-faithful baseline "
                      f"({base['step_s']:.4f}s -> {best['step_s']:.4f}s); "
                      f"bottleneck {base['bottleneck']} -> "
                      f"{best['bottleneck']}."]
    elif base:
        lines += ["", "**Result:** baseline plan remains best "
                      "(candidates refuted)."]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    mesh = "2x16x16" if args.multi_pod else "16x16"
    log = hillclimb(args.arch, args.shape, multi_pod=args.multi_pod,
                    out_dir=ROOT / "experiments" / "perf")
    md = to_markdown(args.arch, args.shape, mesh, log)
    tag = f"{args.arch}__{args.shape}__{mesh}"
    (ROOT / "experiments" / "perf" / f"{tag}.md").write_text(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
