"""PIM-Tuner propose+fit throughput: jitted scan engine vs scalar loop.

The tuner is the DSE loop's per-iteration fixed cost: refit the filter MLP
(200 Adam steps) and the DKL suggestion model (300 Adam steps), then sample
and score a fresh candidate batch.  The scalar reference path dispatches
every Adam step from the host AND retraces both training steps (plus the GP
predict) on every *growing* dataset shape — one fresh XLA program per DSE
iteration.  The engine path (``backend="scan"``) runs each fit as one jitted
``lax.scan`` over pow2-bucketed masked data and scores candidates in one
fused dispatch, so a whole campaign compiles O(log n) distinct programs.

``run()`` drives both backends through the same growing-dataset DSE schedule
(observations accumulate every iteration, exactly the shape pattern
``run_dse`` produces) and enforces two contracts outside ``--smoke``:

* >=5x propose+fit throughput once >=30 observations have accumulated
  (``assert_5x``), and
* the engine's XLA program count across the whole run stays within the
  pow2-bucket bound ``log2(final bucket) + 2`` per entry point
  (``repro.engine.tuner_train.compiled_program_count``).

Costs are synthetic (a smooth deterministic function of the config tuple) —
this benchmark isolates tuner throughput; mapper throughput has its own
harness.
"""

from __future__ import annotations

import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.hardware import sample_configs_batch
from repro.core.tuner import PimTuner
from repro.engine.tuner_train import compiled_program_count, pow2_bucket


def _synthetic_cost(cfg) -> float:
    """Smooth, deterministic stand-in for the mapper's Eq. 1 cost."""
    t = cfg.as_tuple()
    return float(np.exp(abs(np.log2(t[2] * t[3]) - 10)
                        + 0.2 * np.log2(t[4] + t[5] + t[6])
                        + 0.1 * np.log2(t[0] * t[1])))


def _warm_buckets(tuner, *, n_min: int, n_max: int, n_sample: int,
                  filter_steps: int, dkl_steps: int) -> None:
    """Compile the engine's pow2-bucket programs untimed.

    Compile is one-off per process, not throughput (the same policy
    ``mapper_throughput`` applies) — and the engine only HAS O(log n)
    programs to warm.  The scalar loop has no analogue: every growing
    dataset size is a fresh shape, so its per-iteration retraces are the
    measured pathology and stay inside the timed region.
    """
    from repro.core.tuner import _DKL_OPT, _FILTER_OPT, _USE_PALLAS
    from repro.engine.tuner_train import (fit_dkl, fit_filter,
                                          score_candidates)
    rng = np.random.default_rng(0)
    fm, sg = tuner.filter_model, tuner.suggestion
    xq = rng.normal(size=(n_sample, 7)).astype(np.float32)
    ok = np.ones(n_sample, bool)
    for b in sorted({pow2_bucket(n) for n in range(n_min, n_max + 1)}):
        x = rng.normal(size=(b, 7)).astype(np.float32)
        y = rng.normal(size=(b,)).astype(np.float32)
        mask = np.zeros(b, bool)
        mask[:max(3, b // 2)] = True
        # the fit entry points donate (params, opt_state); the models keep
        # using theirs afterwards, so the warm-up burns copies
        import jax.numpy as jnp
        from jax import tree_util
        copy = lambda t: tree_util.tree_map(jnp.array, t)  # noqa: E731
        fit_filter(copy(fm.params), copy(fm.opt_state), x, y, mask,
                   opt=_FILTER_OPT, steps=filter_steps)
        fit_dkl(copy(sg.params), copy(sg.opt_state), x, y, mask,
                opt=_DKL_OPT, steps=dkl_steps)
        score_candidates(sg.params, x, y, mask, xq, ok, tuner.beta,
                         use_pallas=_USE_PALLAS)


def _drive(backend: str, cfgs, areas, costs, *, iterations: int, n0: int,
           grow: int, n_sample: int, propose_k: int, filter_steps: int,
           dkl_steps: int, seed: int):
    """One growing-dataset DSE schedule; returns per-iteration (time, n_obs).

    ``grow=1`` mirrors the paper's Fig. 7 first-legal-only walk: each DSE
    iteration maps one architecture and feeds one observation back.  The
    engine's pow2-bucket programs are warmed untimed (see
    :func:`_warm_buckets`); the loop backend's per-iteration retraces — a
    fresh XLA program per dataset size — stay timed, because no warm-up can
    exist for shapes that never repeat.
    """
    tuner = PimTuner(seed=seed, n_sample=n_sample, backend=backend)
    feed = 0
    for _ in range(n0):
        tuner.observe(cfgs[feed], areas[feed], costs[feed])
        feed += 1
    if backend == "scan":
        _warm_buckets(tuner, n_min=n0, n_max=n0 + grow * iterations,
                      n_sample=n_sample, filter_steps=filter_steps,
                      dkl_steps=dkl_steps)
    # warm-up at the starting size: compile + one propose
    tuner.filter_model.fit(filter_steps)
    tuner.suggestion.fit(dkl_steps)
    tuner.propose(propose_k)
    times, n_obs = [], []
    for _ in range(iterations):
        for _ in range(grow):
            tuner.observe(cfgs[feed], areas[feed], costs[feed])
            feed += 1
        t0 = time.perf_counter()
        tuner.filter_model.fit(filter_steps)
        tuner.suggestion.fit(dkl_steps)
        tuner.propose(propose_k)
        times.append(time.perf_counter() - t0)
        n_obs.append(feed)
    return np.array(times), np.array(n_obs)


# the one CI smoke contract, shared by `--smoke` and `benchmarks.run --fast`:
# short schedule, soft 1.5x threshold (the full run enforces 5x); the pow2
# program-count bound is asserted in both modes
SMOKE_KW = dict(iterations=10, n0=24, grow=2, n_sample=256, filter_steps=60,
                dkl_steps=80, min_speedup=1.5)


def run(iterations: int = 40, n0: int = 16, grow: int = 1,
        n_sample: int = 2048, propose_k: int = 8, filter_steps: int = 200,
        dkl_steps: int = 300, seed: int = 0, min_speedup: float = 5.0,
        assert_5x: bool = True, min_obs: int = 30) -> list[dict]:
    rng = np.random.default_rng(seed)
    cfgs = sample_configs_batch(n0 + grow * iterations + 8, rng)
    areas = [c.area_mm2() for c in cfgs]
    costs = [_synthetic_cost(c) for c in cfgs]
    kw = dict(iterations=iterations, n0=n0, grow=grow, n_sample=n_sample,
              propose_k=propose_k, filter_steps=filter_steps,
              dkl_steps=dkl_steps, seed=seed)

    pc0 = compiled_program_count()
    eng_t, n_obs = _drive("scan", cfgs, areas, costs, **kw)
    pc1 = compiled_program_count()
    loop_t, _ = _drive("loop", cfgs, areas, costs, **kw)

    n_final = int(n_obs[-1])
    asserted = ("fit_filter", "fit_dkl", "score_candidates")
    unavailable = [k for k in asserted
                   if pc0.get(k, -1) < 0 or pc1.get(k, -1) < 0]
    # the bound must fail loudly, not vacuously: if a jax upgrade drops the
    # cache introspection, the contract can no longer be checked
    assert not unavailable, (
        f"jit cache introspection unavailable for {unavailable} — the "
        f"pow2 program-count contract cannot be verified on this jax")
    programs = {k: pc1[k] - pc0[k] for k in pc1
                if pc0[k] >= 0 and pc1[k] >= 0}
    program_bound = int(math.log2(pow2_bucket(n_final))) + 2
    for name in asserted:
        got = programs[name]
        assert got <= program_bound, (
            f"{name} compiled {got} XLA programs over a {iterations}-"
            f"iteration run (pow2-bucket bound: {program_bound} at "
            f"{n_final} observations) — the shape bucketing regressed")

    at = n_obs >= min_obs
    assert at.any(), f"schedule never reached {min_obs} observations"
    eng_s = float(eng_t[at].sum())
    loop_s = float(loop_t[at].sum())
    speedup = loop_s / eng_s
    if assert_5x:
        assert speedup >= min_speedup, (
            f"engine tuner only {speedup:.2f}x faster than the scalar loop "
            f"at >={min_obs} observations (contract: >={min_speedup}x)")
    n_at = int(at.sum())
    return [{
        "table": "tuner", "iterations": iterations, "n_obs_final": n_final,
        "n_sample": n_sample, "min_obs": min_obs,
        "loop_s": loop_s, "engine_s": eng_s,
        "loop_iters_per_s": n_at / loop_s,
        "engine_iters_per_s": n_at / eng_s,
        "loop_total_s": float(loop_t.sum()),
        "engine_total_s": float(eng_t.sum()),
        "speedup": speedup,
        "programs": programs, "program_bound": program_bound,
    }]


def main(smoke: bool = False) -> None:
    r = run(**SMOKE_KW)[0] if smoke else run()[0]
    print(f"tuner_loop,{1e6 / r['loop_iters_per_s']:.1f},"
          f"iters_per_s={r['loop_iters_per_s']:.2f}")
    print(f"tuner_engine,{1e6 / r['engine_iters_per_s']:.1f},"
          f"iters_per_s={r['engine_iters_per_s']:.2f} "
          f"speedup={r['speedup']:.1f}x "
          f"programs={sum(r['programs'].values())} "
          f"(bound {r['program_bound']}/fn at {r['n_obs_final']} obs)")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
