"""Mega-campaign throughput: sharded multi-tenant service vs single-stream.

Measures the PR 9 contract on the workload ROADMAP item 1 describes: a DSE
service receiving MANY tenant campaign submissions — several (workloads,
seed) streams, each submitted repeatedly (nightly re-runs, multiple users
sweeping the same design point).  Two ways to run the identical submission
list:

* **single-stream** (the PR 7 path): each submission runs
  ``run_dse(pipeline=True)`` sequentially with a fresh evaluator and no
  shared state — the only option before this PR;
* **sharded** (:class:`repro.engine.sharded.ShardedCampaign`): all
  submissions as tenants of one campaign on a >=4-device ``config`` mesh
  (candidate rows sharded via NamedSharding, per-wave shard_map stats),
  async wave overlap across tenants, and ONE shared
  :class:`PersistentEvalCache` — repeated submissions dedupe their
  mapper/scheduler work against the durable content-addressed table while
  still emitting their full observation streams.

Both sides run in their own subprocess (jit caches must not leak) with
``--xla_force_host_platform_device_count=4`` so the mesh exists even on a
single-CPU host; each warms shared programs untimed on a throwaway seed
first.  Contracts asserted here and gated in CI via
``benchmarks.bench_gate`` on ``experiments/BENCH_9.json``:

* the sharded service and the single-stream baseline produce IDENTICAL
  per-submission observation streams (hence identical multisets) and an
  identical Pareto front — the speedup is parity-pinned;
* sharded >= 2x end-to-end over single-stream (``--smoke`` softens to
  1.2x: short campaigns amortize less);
* kill-and-resume: a worker process is killed mid-campaign (``os._exit``
  after N ingested waves, no shutdown path runs) and the resumed run
  completes the exact reference stream with ZERO re-evaluations of
  already-cached points (``reeval_preexisting == 0`` — every pre-kill
  evaluation survived in sqlite and was served, not re-mapped).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

BENCH_ID = 9
BENCH_SCHEMA = "nicepim-bench/1"
N_DEVICES = 4

MAPPER_KW = dict(max_optim_iter=1, lm_cap=20, n_wr=2)
SEEDS = (11, 12)          # distinct tenants


def _specs(seeds, repeats: int, iterations: int, propose_k: int,
           n_sample: int):
    from repro.core.workloads import googlenet
    from repro.engine import TenantSpec
    nets = [googlenet(1, scale=8)]
    return [TenantSpec(name=f"t{seed}r{rep}", workloads=nets, seed=seed,
                       iterations=iterations, propose_k=propose_k,
                       n_sample=n_sample, evaluate_all_legal=True,
                       evaluator_kwargs=dict(mapper_kwargs=MAPPER_KW))
            for seed in seeds for rep in range(repeats)]


def _stream(observations):
    return [[o.iteration, list(o.cfg.as_tuple()), o.area_mm2, o.legal,
             o.cost] for o in observations]


def _pareto_points(front):
    return sorted((p.latency_s, p.energy_pj, p.area_mm2)
                  for p in front.points)


def _warm(iterations: int, propose_k: int, n_sample: int) -> None:
    """Untimed: run each UNIQUE tenant stream once, with no cache.

    One-time XLA compiles depend on the configs a stream actually proposes
    (bucket shapes), so warming a throwaway seed leaves the timed phase
    dominated by compile cost that the process-wide jit cache dedupes
    identically on BOTH sides.  Instead each worker warms the real unique
    streams — every jitted program the timed phase needs is compiled — and
    then drops the mapper memos.  Crucially NO persistent/eval cache is
    attached here: the timed sharded campaign starts with a cold table and
    earns its dedup from the campaign machinery alone.
    """
    from repro.core.dse import WorkloadEvaluator, run_dse
    from repro.core.mapper import _sharing_latency, clear_mapper_caches
    from repro.core.surrogates import make_strategy
    for spec in _specs(SEEDS, 1, iterations, propose_k, n_sample):
        ev = WorkloadEvaluator(list(spec.workloads), mapper_kwargs=MAPPER_KW,
                               clear_caches_between_configs=True,
                               batch_prefill=True)
        run_dse(make_strategy("nicepim", cons=spec.cons, seed=spec.seed,
                              n_sample=n_sample),
                ev, iterations=iterations, propose_k=propose_k,
                evaluate_all_legal=True, pipeline=True)
    clear_mapper_caches()
    _sharing_latency.cache_clear()


# ---------------------------------------------------------------------------
# workers (one subprocess each; --xla_force_host_platform_device_count set
# by the orchestrator before jax ever imports)
# ---------------------------------------------------------------------------


def worker_single(repeats, iterations, propose_k, n_sample) -> None:
    import jax
    assert len(jax.devices()) >= N_DEVICES
    from repro.core.dse import WorkloadEvaluator, run_dse
    from repro.core.surrogates import make_strategy
    from repro.engine.pareto import ParetoFront

    _warm(iterations, propose_k, n_sample)
    specs = _specs(SEEDS, repeats, iterations, propose_k, n_sample)
    pareto = ParetoFront()
    streams = {}
    t0 = time.perf_counter()
    for spec in specs:
        strat = make_strategy("nicepim", cons=spec.cons, seed=spec.seed,
                              n_sample=spec.n_sample)
        ev = WorkloadEvaluator(list(spec.workloads),
                               mapper_kwargs=MAPPER_KW,
                               clear_caches_between_configs=True)
        res = run_dse(strat, ev, iterations=spec.iterations,
                      propose_k=spec.propose_k, pareto=pareto,
                      evaluate_all_legal=True, pipeline=True)
        streams[spec.name] = _stream(res.observations)
    dt = time.perf_counter() - t0
    print(json.dumps({"mode": "single", "secs": dt, "streams": streams,
                      "pareto": _pareto_points(pareto)}), flush=True)


def worker_sharded(repeats, iterations, propose_k, n_sample,
                   workdir: str) -> None:
    import jax
    assert len(jax.devices()) >= N_DEVICES
    from repro.engine import PersistentEvalCache, ShardedCampaign
    from repro.obs.trace import Tracer

    _warm(iterations, propose_k, n_sample)
    specs = _specs(SEEDS, repeats, iterations, propose_k, n_sample)
    cache = PersistentEvalCache(Path(workdir) / "evals.sqlite")
    tracer = Tracer()
    camp = ShardedCampaign(specs, cache=cache, queue_depth=4,
                           eval_workers=2,
                           checkpoint=Path(workdir) / "ckpt.json",
                           tracer=tracer)
    t0 = time.perf_counter()
    out = camp.run()
    dt = time.perf_counter() - t0
    spans = [ev.get("name") for ev in tracer.events()]
    print(json.dumps({
        "mode": "sharded", "secs": dt,
        "streams": {n: _stream(r.observations)
                    for n, r in out.results.items()},
        "pareto": _pareto_points(out.pareto),
        "cache": out.cache_stats,
        "evaluations": sum(s.evaluator.evaluations for s in camp._states),
        "propose_spans": spans.count("fused_propose"),
        "eval_spans": spans.count("wave_evaluate"),
    }), flush=True)


def worker_kill(iterations, propose_k, n_sample, workdir: str,
                die_after: int) -> None:
    """Run one tenant sharded, then die mid-campaign without cleanup."""
    import jax
    assert len(jax.devices()) >= N_DEVICES
    from repro.engine import PersistentEvalCache, ShardedCampaign

    class DyingCampaign(ShardedCampaign):
        waves = 0

        def _ingest_wave(self, st, wave, evaluated):
            super()._ingest_wave(st, wave, evaluated)
            DyingCampaign.waves += 1
            if DyingCampaign.waves >= die_after:
                # simulate SIGKILL: no finally blocks, no cache close, no
                # final checkpoint — only per-wave durability survives
                os._exit(42)

    _warm(iterations, propose_k, n_sample)
    specs = _specs(SEEDS[:1], 1, iterations, propose_k, n_sample)
    cache = PersistentEvalCache(Path(workdir) / "evals.sqlite")
    DyingCampaign(specs, cache=cache,
                  checkpoint=Path(workdir) / "ckpt.json").run()
    print(json.dumps({"mode": "kill", "survived": True}), flush=True)


def worker_resume(iterations, propose_k, n_sample, workdir: str) -> None:
    import jax
    assert len(jax.devices()) >= N_DEVICES
    from repro.engine import PersistentEvalCache, ShardedCampaign

    _warm(iterations, propose_k, n_sample)
    specs = _specs(SEEDS[:1], 1, iterations, propose_k, n_sample)
    cache = PersistentEvalCache(Path(workdir) / "evals.sqlite")
    camp = ShardedCampaign(specs, cache=cache,
                           checkpoint=Path(workdir) / "ckpt.json")
    out = camp.run()
    print(json.dumps({
        "mode": "resume", "resumed": out.resumed,
        "streams": {n: _stream(r.observations)
                    for n, r in out.results.items()},
        "cache": cache.stats,
        "evaluations": sum(s.evaluator.evaluations for s in camp._states),
    }), flush=True)


def _run_worker(mode: str, extra: list[str]) -> tuple[dict, int]:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={N_DEVICES}"
            .strip())
    cmd = [sys.executable, "-m", "benchmarks.campaign_throughput",
           "--worker", mode] + extra
    proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT,
                          env=env)
    if mode == "kill":
        if proc.returncode != 42:
            raise RuntimeError(
                f"kill worker should die with os._exit(42), got "
                f"{proc.returncode}:\n{proc.stderr[-4000:]}")
        return {}, proc.returncode
    if proc.returncode != 0:
        raise RuntimeError(f"{mode} worker failed:\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1]), proc.returncode


# ---------------------------------------------------------------------------
# orchestration
# ---------------------------------------------------------------------------


def run(repeats: int = 4, iterations: int = 3, propose_k: int = 4,
        n_sample: int = 128, min_speedup: float = 2.0,
        die_after: int = 1, workdir: str | None = None) -> list[dict]:
    import tempfile
    base = Path(workdir) if workdir else Path(tempfile.mkdtemp(
        prefix="campaign_bench_"))
    (base / "sharded").mkdir(parents=True, exist_ok=True)
    (base / "faults").mkdir(parents=True, exist_ok=True)
    sizes = [str(repeats), str(iterations), str(propose_k), str(n_sample)]

    single, _ = _run_worker("single", sizes)
    sharded, _ = _run_worker("sharded", sizes + [str(base / "sharded")])

    # parity: identical per-submission streams => identical observation
    # multiset; identical Pareto front
    assert sharded["streams"] == single["streams"], (
        "sharded and single-stream observation streams diverged — the "
        "speedup would not be parity-pinned")
    assert sharded["pareto"] == single["pareto"], (
        "sharded and single-stream Pareto fronts diverged")
    assert sharded["propose_spans"] > 0 and sharded["eval_spans"] > 0, (
        "sharded run recorded no wave spans — the overlapped path was "
        "not taken")
    n_tenants = len(SEEDS) * repeats
    n_unique = len(SEEDS)
    # the structural contract: repeated submissions were deduped — the
    # mapper ran for the unique streams only
    assert sharded["evaluations"] <= single_evals_bound(
        sharded, n_unique, n_tenants), (
        f"sharded service re-evaluated duplicated submissions: "
        f"{sharded['evaluations']} mapper runs for {n_unique} unique "
        f"tenant streams")

    speedup = single["secs"] / sharded["secs"]
    rows = [{
        "table": "campaign", "case": "mega_campaign",
        "tenants": n_tenants, "unique": n_unique, "repeats": repeats,
        "iterations": iterations, "propose_k": propose_k,
        "n_sample": n_sample, "devices": N_DEVICES,
        "single_s": single["secs"], "sharded_s": sharded["secs"],
        "subs_per_s_single": n_tenants / single["secs"],
        "subs_per_s_sharded": n_tenants / sharded["secs"],
        "evaluations": sharded["evaluations"],
        "cache": sharded["cache"],
        "speedup": speedup, "min_speedup": min_speedup,
        "parity": "match",
    }]
    assert speedup >= min_speedup, (
        f"sharded mega-campaign only {speedup:.2f}x over the "
        f"single-stream path (contract: >={min_speedup}x)")

    # -- kill-and-resume ---------------------------------------------------
    _run_worker("kill", sizes + [str(base / "faults"), str(die_after)])
    resume, _ = _run_worker("resume", sizes + [str(base / "faults")])
    ref_name = f"t{SEEDS[0]}r0"
    assert resume["resumed"] == [ref_name], (
        f"resume did not pick up the killed tenant: {resume['resumed']}")
    assert resume["streams"][ref_name] == single["streams"][ref_name], (
        "resumed stream diverged from the uninterrupted reference")
    assert resume["cache"]["reeval_preexisting"] == 0, (
        f"resume re-evaluated {resume['cache']['reeval_preexisting']} "
        f"already-cached points — pre-kill evaluations were lost")
    rows.append({
        "table": "campaign", "case": "kill_and_resume",
        "die_after_waves": die_after,
        "resume_evaluations": resume["evaluations"],
        "reeval_preexisting": resume["cache"]["reeval_preexisting"],
        "preexisting": resume["cache"]["preexisting"],
    })
    return rows


def single_evals_bound(sharded: dict, n_unique: int, n_tenants: int) -> int:
    """Upper bound on legitimate mapper runs for the deduped service.

    Unique streams evaluate; repeats must be served from the shared cache.
    The bound is per-unique-stream work times the unique count (cache
    entries measure exactly that).
    """
    return sharded["cache"]["entries"]


SMOKE_KW = dict(repeats=3, iterations=2, propose_k=3, n_sample=64,
                min_speedup=1.2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short campaigns + soft thresholds (CI)")
    ap.add_argument("--worker", default=None,
                    help="internal: single|sharded|kill|resume")
    ap.add_argument("sizes", nargs="*", default=[])
    ap.add_argument("--out", default=None, metavar="BENCH_9.json",
                    help="write the perf artifact here (default "
                         "experiments/BENCH_9.json)")
    args = ap.parse_args()

    if args.worker:
        s = args.sizes
        if args.worker == "single":
            worker_single(int(s[0]), int(s[1]), int(s[2]), int(s[3]))
        elif args.worker == "sharded":
            worker_sharded(int(s[0]), int(s[1]), int(s[2]), int(s[3]), s[4])
        elif args.worker == "kill":
            worker_kill(int(s[1]), int(s[2]), int(s[3]), s[4], int(s[5]))
        elif args.worker == "resume":
            worker_resume(int(s[1]), int(s[2]), int(s[3]), s[4])
        else:
            raise SystemExit(f"unknown worker {args.worker!r}")
        return

    kw = dict(SMOKE_KW) if args.smoke else {}
    t0 = time.time()
    rows = run(**kw)
    total_s = time.time() - t0

    r = rows[0]
    print(f"campaign_single,{1e6 * r['single_s'] / r['tenants']:.0f},"
          f"subs_per_s={r['subs_per_s_single']:.3f}")
    print(f"campaign_sharded,{1e6 * r['sharded_s'] / r['tenants']:.0f},"
          f"subs_per_s={r['subs_per_s_sharded']:.3f} "
          f"speedup={r['speedup']:.2f}x parity={r['parity']} "
          f"evals={r['evaluations']}")
    k = rows[1]
    print(f"campaign_kill_resume,reeval={k['reeval_preexisting']},"
          f"resume_evals={k['resume_evaluations']} "
          f"preexisting={k['preexisting']}")

    tol = 0.40 if args.smoke else 0.25
    bench = {
        "schema": BENCH_SCHEMA,
        "bench_id": BENCH_ID,
        "mode": "smoke" if args.smoke else "full",
        "generated_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sections_s": {"campaign": total_s},
        "benchmarks": [
            {"name": "campaign_sharded",
             "us_per_call": 1e6 * r["sharded_s"] / r["tenants"],
             "derived": f"speedup={r['speedup']:.2f}x "
                        f"tenants={r['tenants']} evals={r['evaluations']}"},
            {"name": "campaign_kill_resume",
             "us_per_call": 0.0,
             "derived": f"reeval={k['reeval_preexisting']} "
                        f"preexisting={k['preexisting']}"},
        ],
        "gates": {
            "campaign_sharded_speedup": {"value": float(r["speedup"]),
                                         "tolerance": tol,
                                         "higher_is_better": True},
        },
    }
    out = Path(args.out) if args.out else (
        ROOT / "experiments" / f"BENCH_{BENCH_ID}.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(bench, indent=1) + "\n")
    print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    main()
