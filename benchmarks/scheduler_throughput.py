"""Data-Scheduler solve throughput: jitted scan engine vs host-Python loop.

Two workload families, both solved by ``backend="scan"`` (the jitted
multi-chain 2-opt in ``repro.engine.scheduler_opt``) and ``backend="loop"``
(the host-Python reference search):

* **Fig. 12 singles** — the paper's 4x4 / 8x8 / 16x16 interleaved-set
  arrays at the Fig. 12 budget (restarts=6, iters=1200), one solve each.
  Quality contract: the scan objective must be <= the loop objective on
  EVERY array (both start from the same deterministic restart seeds and
  only ever apply non-worsening moves, so each is also <= the TSP baseline).
* **Batched ``schedule_many``** — ``batch`` chunk-scaled variants of the
  4x4 and 8x8 sharing problems at the default solver budget, solved in ONE
  pow2-bucketed ``schedule_many`` call vs one loop solve per problem.  This
  is the shape of the mapper's real workload (``evaluate_mapping`` prefills
  a whole mapping's sharing problems per batch), and where the engine's
  one-dispatch-per-bucket structure pays off.

Throughput contract (outside ``--smoke``): the batched family must reach
>=5x solves/sec over the loop.  The scan's jit compiles are warmed untimed
(one-off per process, the same policy the mapper/tuner benchmarks apply);
the loop has no compile to warm — its per-round Python move building and
per-dispatch overhead ARE the measured pathology.  Of the single arrays,
4x4/8x8 run ~10-20x and only the 16x16 case carries its own floor: its 960
link loads make the scan's dense per-round state memory-bound on CPU, and
the int16 flip-cumsum + streamed delta scoring must keep it at >=1x the
loop there (the Pallas ``delta_maxload_rows`` streaming kernel targets
TPU; each row reports which path scored it).
"""

from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.noc import MeshNoc
from repro.core.scheduler import solve_ilp_ls
from repro.engine.scheduler_opt import schedule_many

FLIT_BW = 64 / 8 * 400e6     # bytes/s per link (Fig. 12 setup)
FREQ = 400e6
EPJ = 1.1
CHUNK = 8192.0


def fig12_problem(dim: int, stride: int):
    noc = MeshNoc(dim, dim)
    sets = [[noc.node(r * stride + oy, c * stride + ox)
             for r in range(4) for c in range(4)]
            for oy in range(stride) for ox in range(stride)]
    return noc, sets


# the one CI smoke contract, shared by `--smoke` and `benchmarks.run --fast`:
# smaller batch/budget, soft 1.5x threshold (the full run enforces 5x)
SMOKE_KW = dict(batch=8, single_iters=400, batch_iters=200, min_speedup=1.5)


def run(seed: int = 0, batch: int = 24, single_iters: int = 1200,
        batch_iters: int = 400, min_speedup: float = 5.0,
        assert_5x: bool = True, min_single16: float = 1.0) -> list[dict]:
    from repro.engine.scheduler_opt import _USE_PALLAS

    rows: list[dict] = []

    # -- Fig. 12 singles: quality contract + per-array speedups -----------
    for dim, stride in ((4, 1), (8, 2), (16, 4)):
        noc, sets = fig12_problem(dim, stride)
        chunks = [CHUNK] * len(sets)
        kw = dict(seed=seed, restarts=6, iters=single_iters)
        scan = solve_ilp_ls(noc, sets, chunks, FLIT_BW, FREQ, EPJ,
                            backend="scan", **kw)    # compile, untimed
        t0 = time.perf_counter()
        scan = solve_ilp_ls(noc, sets, chunks, FLIT_BW, FREQ, EPJ,
                            backend="scan", **kw)
        t_scan = time.perf_counter() - t0
        t0 = time.perf_counter()
        loop = solve_ilp_ls(noc, sets, chunks, FLIT_BW, FREQ, EPJ,
                            backend="loop", **kw)
        t_loop = time.perf_counter() - t0
        assert scan.max_link_bytes <= loop.max_link_bytes + 1e-9, (
            f"{dim}x{dim}: scan objective {scan.max_link_bytes} worse than "
            f"loop {loop.max_link_bytes} — the engine search regressed")
        rows.append({
            "table": "scheduler", "case": f"single_{dim}x{dim}",
            "path": "pallas-stream" if _USE_PALLAS else "jnp-dense",
            "scan_s": t_scan, "loop_s": t_loop,
            "speedup": t_loop / t_scan,
            "scan_obj": scan.max_link_bytes, "loop_obj": loop.max_link_bytes,
        })
        if dim == 16:
            # the 960-link memory-bound case: the int16 flip-cumsum +
            # streamed delta scoring must at least break even on CPU
            assert rows[-1]["speedup"] >= min_single16, (
                f"16x16 scan case {rows[-1]['speedup']:.2f}x vs loop "
                f"(contract: >={min_single16}x on the "
                f"{rows[-1]['path']} path)")

    # -- batched schedule_many: the >=5x throughput contract --------------
    total_scan = 0.0
    total_loop = 0.0
    n_solves = 0
    for dim, stride in ((4, 1), (8, 2)):
        noc, sets = fig12_problem(dim, stride)
        probs = [(noc, sets, [CHUNK * (1 + 0.05 * k)] * len(sets))
                 for k in range(batch)]
        kw = dict(seed=seed, restarts=4, iters=batch_iters)
        got = schedule_many(probs, FLIT_BW, FREQ, EPJ, **kw)  # compile
        t0 = time.perf_counter()
        got = schedule_many(probs, FLIT_BW, FREQ, EPJ, **kw)
        t_scan = time.perf_counter() - t0
        # batch-independence: any element equals its single-problem solve
        single = solve_ilp_ls(*probs[batch // 2], FLIT_BW, FREQ, EPJ,
                              backend="scan", **kw)
        assert single.cycles == got[batch // 2].cycles, (
            "schedule_many result differs from the single-problem scan — "
            "per-problem PRNG streams are no longer batch-independent")
        t0 = time.perf_counter()
        loop = [solve_ilp_ls(noc_, sets_, ch_, FLIT_BW, FREQ, EPJ,
                             backend="loop", **kw)
                for noc_, sets_, ch_ in probs]
        t_loop = time.perf_counter() - t0
        worse = sum(1 for a, b in zip(got, loop)
                    if a.max_link_bytes > b.max_link_bytes + 1e-9)
        rows.append({
            "table": "scheduler", "case": f"batched_{dim}x{dim}",
            "batch": batch, "scan_s": t_scan, "loop_s": t_loop,
            "speedup": t_loop / t_scan, "scan_worse": worse,
        })
        total_scan += t_scan
        total_loop += t_loop
        n_solves += batch

    speedup = total_loop / total_scan
    rows.append({
        "table": "scheduler", "case": "batched_total", "batch": batch,
        "n_solves": n_solves, "scan_s": total_scan, "loop_s": total_loop,
        "scan_solves_per_s": n_solves / total_scan,
        "loop_solves_per_s": n_solves / total_loop,
        "speedup": speedup, "min_speedup": min_speedup,
    })
    if assert_5x:
        assert speedup >= min_speedup, (
            f"batched engine scheduler only {speedup:.2f}x faster than the "
            f"loop reference (contract: >={min_speedup}x)")
    return rows


def main(smoke: bool = False) -> None:
    rows = run(**SMOKE_KW) if smoke else run()
    for r in rows:
        if r["case"].startswith("single"):
            print(f"scheduler_{r['case']},{r['scan_s'] * 1e6:.0f},"
                  f"speedup={r['speedup']:.1f}x path={r['path']} "
                  f"obj_ok={r['scan_obj'] <= r['loop_obj'] + 1e-9}")
        elif r["case"] == "batched_total":
            print(f"scheduler_batched,{1e6 * r['scan_s'] / r['n_solves']:.0f},"
                  f"solves_per_s={r['scan_solves_per_s']:.1f} "
                  f"speedup={r['speedup']:.1f}x "
                  f"(contract >={r['min_speedup']}x)")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
