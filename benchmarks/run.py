"""Benchmark harness entry point: one reproduction per paper figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip fig9,...]
    PYTHONPATH=src python -m benchmarks.run --lint   # pimlint, no figures

Prints ``name,us_per_call,derived`` CSV lines per the harness contract,
persists raw rows to experiments/paper_benchmarks.json, writes the
perf-trajectory artifact experiments/BENCH_6.json (consumed by
``benchmarks.bench_gate`` in CI to detect throughput regressions), and
regenerates EXPERIMENTS.md via benchmarks.report.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

BENCH_ID = 6
BENCH_SCHEMA = "nicepim-bench/1"
LINT_ID = 8


def main() -> None:
    # --lint short-circuits before the figure imports: it runs the same
    # code path as ``python -m repro.analysis`` (rules, baseline, exit
    # codes) and writes the experiments/LINT_8.json artifact CI uploads
    if "--lint" in sys.argv[1:]:
        from repro.analysis.__main__ import main as lint_main
        extra = [a for a in sys.argv[1:] if a != "--lint"]
        sys.exit(lint_main(["--root", str(ROOT), "--json",
                            str(ROOT / "experiments" / f"LINT_{LINT_ID}.json")]
                           + extra))

    from benchmarks import (engine_throughput, fig9_dse, fig10_mapper,
                            fig11_ddam, fig12_scheduler, mapper_throughput,
                            overlap_throughput, scheduler_throughput,
                            tuner_throughput)

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size Fig.9/11 workloads too")
    ap.add_argument("--fast", "--smoke", action="store_true", dest="fast",
                    help="reduced Fig.10 nets (CI); default runs the "
                         "paper-scale networks")
    ap.add_argument("--skip", default="", help="comma list: fig9,fig10,...")
    ap.add_argument("--fig9-iters", type=int, default=20)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace of the Fig. 9 campaign here")
    args = ap.parse_args()
    skip = set(filter(None, args.skip.split(",")))

    all_rows: list[dict] = []
    sections_s: dict[str, float] = {}
    emitted: list[dict] = []
    gates: dict[str, dict] = {}
    # smoke runs on loaded CI workers jitter far more than dedicated
    # full runs, so the regression band is wider there
    tol = 0.40 if args.fast else 0.25

    def gate(name: str, value: float):
        gates[name] = {"value": float(value), "tolerance": tol,
                       "higher_is_better": True}

    def emit(name: str, us: float, derived: str):
        print(f"{name},{us:.1f},{derived}", flush=True)
        emitted.append({"name": name, "us_per_call": us, "derived": derived})

    if "fig12" not in skip:
        t0 = time.time()
        rows = fig12_scheduler.run()
        all_rows += rows
        for r in rows:
            emit(f"fig12_{r['array']}_{r['method']}",
                 r["latency_us"], f"norm={r['norm_latency']:.3f}")
        sections_s["fig12"] = time.time() - t0
        print(f"# fig12 took {sections_s['fig12']:.1f}s", flush=True)

    if "scheduler" not in skip:
        t0 = time.time()
        # --fast (CI smoke): the shared SMOKE_KW schedule/threshold — the
        # full run enforces the >=5x batched solve-throughput contract
        rows = (scheduler_throughput.run(**scheduler_throughput.SMOKE_KW)
                if args.fast else scheduler_throughput.run())
        all_rows += rows
        for r in rows:
            if r["case"].startswith("single"):
                emit(f"scheduler_{r['case']}", r["scan_s"] * 1e6,
                     f"speedup={r['speedup']:.1f}x")
        r = next(x for x in rows if x["case"] == "batched_total")
        emit("scheduler_batched", 1e6 * r["scan_s"] / r["n_solves"],
             f"solves_per_s={r['scan_solves_per_s']:.1f} "
             f"speedup={r['speedup']:.1f}x")
        gate("scheduler_batched_speedup", r["speedup"])
        sections_s["scheduler"] = time.time() - t0
        print(f"# scheduler took {sections_s['scheduler']:.1f}s", flush=True)

    if "fig10" not in skip:
        t0 = time.time()
        rows = fig10_mapper.run(fast=args.fast)
        all_rows += rows
        for r in rows:
            if r.get("net") == "all":
                emit("fig10_avg", 0.0,
                     f"dLat={-r['latency_reduction']:.1%} "
                     f"dE={-r['energy_reduction']:.1%} "
                     f"(paper: -37%/-28%)")
            else:
                emit(f"fig10_{r['system']}_{r['net']}",
                     r["mapper_latency_ms"] * 1e3,
                     f"dLat={-r['latency_reduction']:.1%} "
                     f"dE={-r['energy_reduction']:.1%}")
        sections_s["fig10"] = time.time() - t0
        print(f"# fig10 took {sections_s['fig10']:.1f}s", flush=True)

    if "fig11" not in skip:
        t0 = time.time()
        rows = fig11_ddam.run(fast=not args.full)
        all_rows += rows
        for r in rows:
            emit(f"fig11_{r['net']}", r["mapper_latency_ms"] * 1e3,
                 f"thr_gain={r['throughput_gain']:+.1%} "
                 f"lat_ratio={r['latency_ratio']:.1f}x")
        sections_s["fig11"] = time.time() - t0
        print(f"# fig11 took {sections_s['fig11']:.1f}s", flush=True)

    if "mapper" not in skip:
        t0 = time.time()
        # --fast (CI smoke): tiny workload, throughput assertion relaxed —
        # the full run enforces the >=10x candidate-costing contract
        rows = (mapper_throughput.run(n_layers=8, n_sweeps=2,
                                      assert_10x=False, map_scale=8)
                if args.fast else mapper_throughput.run())
        all_rows += rows
        r = rows[0]
        emit("mapper_scalar", 1e6 / r["scalar_cands_per_s"],
             f"cands_per_s={r['scalar_cands_per_s']:.1f}")
        emit("mapper_batched", 1e6 / r["batched_cands_per_s"],
             f"cands_per_s={r['batched_cands_per_s']:.1f} "
             f"speedup={r['speedup']:.1f}x "
             f"map_speedup={r['map_speedup']:.2f}x")
        gate("mapper_batched_speedup", r["speedup"])
        # multi-config mode: map a whole proposal batch per map_many call;
        # --fast keeps the tiny net and the soft smoke threshold, the full
        # run enforces the >=3x end-to-end contract at batch >= 8
        rows = (mapper_throughput.run_multi(map_scale=8, best_of=2,
                                            min_speedup=1.5)
                if args.fast else mapper_throughput.run_multi())
        all_rows += rows
        r = rows[0]
        emit("mapper_multi_seq", 1e6 * r["seq_s"] / r["batch"],
             f"maps_per_s={r['maps_per_s_seq']:.2f}")
        emit("mapper_multi_batched", 1e6 * r["batched_s"] / r["batch"],
             f"maps_per_s={r['maps_per_s_batched']:.2f} "
             f"speedup={r['speedup']:.2f}x "
             f"vs_batched_seq={r['speedup_vs_batched_seq']:.2f}x")
        gate("mapper_multi_speedup", r["speedup"])
        sections_s["mapper"] = time.time() - t0
        print(f"# mapper took {sections_s['mapper']:.1f}s", flush=True)

    if "tuner" not in skip:
        t0 = time.time()
        # --fast (CI smoke): the shared SMOKE_KW schedule/threshold — the
        # full run enforces the >=5x propose+fit contract at >=30 obs
        rows = (tuner_throughput.run(**tuner_throughput.SMOKE_KW)
                if args.fast else tuner_throughput.run())
        all_rows += rows
        r = rows[0]
        emit("tuner_loop", 1e6 / r["loop_iters_per_s"],
             f"iters_per_s={r['loop_iters_per_s']:.2f}")
        emit("tuner_engine", 1e6 / r["engine_iters_per_s"],
             f"iters_per_s={r['engine_iters_per_s']:.2f} "
             f"speedup={r['speedup']:.1f}x "
             f"programs={sum(r['programs'].values())}")
        gate("tuner_engine_speedup", r["speedup"])
        sections_s["tuner"] = time.time() - t0
        print(f"# tuner took {sections_s['tuner']:.1f}s", flush=True)

    if "engine" not in skip:
        t0 = time.time()
        rows = engine_throughput.run(
            n_configs=64 if args.fast else 192,
            scalar_configs=16 if args.fast else None)
        all_rows += rows
        r = rows[0]
        emit("engine_scalar", 1e6 / r["scalar_configs_per_s"],
             f"configs_per_s={r['scalar_configs_per_s']:.1f}")
        emit("engine_batched", 1e6 / r["batched_configs_per_s"],
             f"configs_per_s={r['batched_configs_per_s']:.1f} "
             f"speedup={r['speedup']:.1f}x")
        gate("engine_batched_speedup", r["speedup"])
        sections_s["engine"] = time.time() - t0
        print(f"# engine took {sections_s['engine']:.1f}s", flush=True)

    if "overlap" not in skip:
        t0 = time.time()
        # --fast (CI smoke): the shared SMOKE_KW schedule/threshold — the
        # full run enforces the >=1.3x warm-iteration contract on
        # multi-core hosts (break-even on single-core; see the module doc)
        rows = (overlap_throughput.run(**overlap_throughput.SMOKE_KW)
                if args.fast else overlap_throughput.run())
        all_rows += rows
        r = rows[0]
        emit("overlap_serial", 1e6 * r["serial_s"] / r["iterations"],
             f"iters_per_s={r['iters_per_s_serial']:.3f}")
        emit("overlap_overlapped",
             1e6 * r["overlapped_s"] / r["iterations"],
             f"iters_per_s={r['iters_per_s_overlapped']:.3f} "
             f"speedup={r['speedup']:.2f}x cores={r['cores']} "
             f"parity={r['parity']}")
        gate("overlap_speedup", r["speedup"])
        sections_s["overlap"] = time.time() - t0
        print(f"# overlap took {sections_s['overlap']:.1f}s", flush=True)

    if "fig9" not in skip:
        t0 = time.time()
        rows = fig9_dse.run(iterations=args.fig9_iters, tiny=not args.full,
                            trace=args.trace)
        all_rows += rows
        curves = [r for r in rows if "quality_final" in r]
        base = next((r["quality_final"] for r in curves
                     if r["strategy"] == "random"), 1e-30)
        for r in curves:
            emit(f"fig9_{r['strategy']}",
                 r["solve_s"] * 1e6 / max(1, r["iterations"]),
                 f"quality={r['quality_final']:.3e} "
                 f"vs_random={r['quality_final'] / max(base, 1e-30):.2f}x")
        nice = next((r for r in curves if r["strategy"] == "nicepim"), None)
        if nice is not None:
            gate("fig9_nicepim_vs_random",
                 nice["quality_final"] / max(base, 1e-30))
        pareto = next((r for r in rows if r["strategy"] == "pareto"), None)
        if pareto:
            emit("fig9_pareto", 0.0,
                 f"front={pareto['pareto_size']} "
                 f"cache_hits={pareto['cache']['hits']} "
                 f"programs={sum(pareto['programs'].values())}")
        sections_s["fig9"] = time.time() - t0
        print(f"# fig9 took {sections_s['fig9']:.1f}s", flush=True)

    out = ROOT / "experiments" / "paper_benchmarks.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    merged = all_rows
    if out.exists() and skip:
        # keep rows for skipped figures from the previous run; prefix match
        # covers multi-table figures (skipping "mapper" also keeps the
        # "mapper_multi" rows)
        old = json.loads(out.read_text())
        kept = [r for r in old
                if any(str(r.get("table", "")).startswith(s) for s in skip)]
        merged = kept + all_rows
    out.write_text(json.dumps(merged, indent=1, default=str))

    bench = {
        "schema": BENCH_SCHEMA,
        "bench_id": BENCH_ID,
        "mode": "full" if args.full else ("smoke" if args.fast else "default"),
        "generated_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sections_s": sections_s,
        "benchmarks": emitted,
        "gates": gates,
    }
    bench_path = ROOT / "experiments" / f"BENCH_{BENCH_ID}.json"
    bench_path.write_text(json.dumps(bench, indent=1) + "\n")
    print(f"# wrote {bench_path}", flush=True)

    from benchmarks import report
    report.main()


if __name__ == "__main__":
    main()
