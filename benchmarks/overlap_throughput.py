"""Warm DSE-iteration throughput: overlapped wave executor vs serial.

Measures the PR 10 contract: a WARM scan-backend DSE campaign through
``run_dse(pipeline=True)`` with the overlapped wave executor — paired
cost sweeps dispatched async, wave *k*'s device costing in flight while
the host runs wave *k−1*'s backtracking / ``_sharing_problem_list``
extraction / ``schedule_many`` dispatch, and iteration *k+1*'s fused
propose chain double-buffered behind iteration *k*'s ingest — against
the identical campaign with ``overlap=False`` (sync at every dispatch
site, serial propose: the PR 9 status quo).

Framing
-------
Each side runs in its OWN subprocess (jit caches must not leak between
them) on a forced-multi-device CPU topology (the sharded-campaign
deployment shape).  A subprocess first runs the same campaign untimed —
that compiles every mapper / tuner / scheduler program — then clears the
mapper memo caches and times a second, jit-warm run: the warm iteration
is exactly where latency hiding pays, since nothing is waiting on
compiles.

Contracts (asserted here, gated in CI via ``benchmarks.bench_gate`` on
``experiments/BENCH_10.json``):

* the overlapped and serial observation streams AND Pareto fronts are
  IDENTICAL bit for bit (the speedup is parity-pinned, not bought with
  different search results);
* overlapped / serial >= 1.3x warm end-to-end on a multi-core host.
  Latency hiding needs a second core: XLA's CPU client computes on
  background threads, so the host-side backtracking/scheduling only
  truly runs concurrently when there is a core for it.  On a
  single-core host the contract degrades to break-even (>= 0.85x —
  parity still holds bit for bit, the executor just cannot hide
  anything), and each side is timed as the min over alternating
  repeats so minutes-scale machine jitter cannot fake a regression;
* the overlapped run actually overlapped (``dispatch_paired`` and
  ``map_wave`` spans recorded, ``fused_propose`` spans still present —
  double-buffering must not drop the fused chain).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

BENCH_ID = 10
BENCH_SCHEMA = "nicepim-bench/1"

MAPPER_KW = dict(max_optim_iter=1, lm_cap=40, n_wr=3)
DEVICES = 4


# ---------------------------------------------------------------------------
# worker: one warm campaign in a fresh process
# ---------------------------------------------------------------------------


def worker(mode: str, iterations: int, n_sample: int) -> None:
    from repro.core.dse import WorkloadEvaluator, run_dse
    from repro.core.mapper import _sharing_latency, clear_mapper_caches
    from repro.core.tuner import PimTuner
    from repro.core.workloads import googlenet
    from repro.engine.pareto import ParetoFront
    from repro.obs.trace import Tracer

    nets = [googlenet(1, scale=8), googlenet(2, scale=8)]
    overlap = mode == "overlapped"

    def campaign(tracer=None):
        ev = WorkloadEvaluator(nets, mapper_kwargs=MAPPER_KW,
                               overlap=overlap)
        front = ParetoFront()
        res = run_dse(PimTuner(seed=0, n_sample=n_sample, backend="scan"),
                      ev, iterations=iterations, propose_k=8,
                      pipeline=True, evaluate_all_legal=True,
                      pareto=front, tracer=tracer)
        return res, front

    # phase 1 (untimed): compile every program this campaign touches
    campaign()
    clear_mapper_caches()
    _sharing_latency.cache_clear()

    tracer = Tracer()
    t0 = time.perf_counter()
    res, front = campaign(tracer=tracer)
    dt = time.perf_counter() - t0

    stream = [(o.iteration, o.cfg.as_tuple(), o.area_mm2, o.legal, o.cost)
              for o in res.observations]
    pareto = sorted((p.latency_s, p.energy_pj, p.area_mm2)
                    for p in front.points)
    spans: dict = {}
    span_s: dict = {}
    for ev in tracer.events():
        if ev.get("ph") != "X":
            continue
        name = ev["name"]
        spans[name] = spans.get(name, 0) + 1
        span_s[name] = span_s.get(name, 0.0) + ev["dur"] / 1e6
    for name in ("dispatch_paired", "map_wave", "overlap_drain",
                 "fused_propose", "propose_resolve"):
        spans.setdefault(name, 0)
    print(json.dumps({
        "mode": mode, "secs": dt, "iterations": iterations,
        "spans": spans, "span_s": span_s,
        "stream": stream, "pareto": pareto,
    }), flush=True)


def _run_worker(mode: str, iterations: int, n_sample: int) -> dict:
    cmd = [sys.executable, "-m", "benchmarks.overlap_throughput",
           "--worker", mode, "--iters", str(iterations),
           "--n-sample", str(n_sample)]
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_"
                            f"count={DEVICES}").strip()
    proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"{mode} worker failed:\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# orchestration
# ---------------------------------------------------------------------------


def _cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:
        return os.cpu_count() or 1


def run(iterations: int = 6, n_sample: int = 256,
        min_speedup: float | None = 1.3, repeats: int = 2) -> list[dict]:
    cores = _cores()
    if cores <= 1:
        # one core: nothing to hide latency UNDER — hold break-even
        min_speedup = min(min_speedup or 1.3, 0.85)
    runs = {"overlapped": [], "serial": []}
    for _ in range(repeats):        # alternate sides: jitter hits both
        runs["overlapped"].append(
            _run_worker("overlapped", iterations, n_sample))
        runs["serial"].append(_run_worker("serial", iterations, n_sample))
    fast = min(runs["overlapped"], key=lambda r: r["secs"])
    slow = min(runs["serial"], key=lambda r: r["secs"])

    assert fast["stream"] == slow["stream"], (
        "overlapped and serial DSE observation streams diverged — the "
        "speedup would not be parity-pinned")
    assert fast["pareto"] == slow["pareto"], (
        "overlapped and serial Pareto fronts diverged")
    sp = fast["spans"]
    assert sp["dispatch_paired"] > 0 and sp["map_wave"] > 0, (
        f"overlapped run recorded no wave spans ({sp}) — the overlap "
        f"path was not taken")
    assert sp["fused_propose"] >= iterations, (
        f"only {sp['fused_propose']} fused_propose spans for {iterations} "
        f"iterations — double-buffering dropped the fused chain")
    assert slow["spans"]["overlap_drain"] == 0, (
        "serial run deferred work across the wave boundary")

    speedup = slow["secs"] / fast["secs"]
    rows = [{
        "table": "overlap", "case": "warm_campaign",
        "iterations": iterations, "n_sample": n_sample,
        "cores": cores, "repeats": repeats,
        "overlapped_s": fast["secs"], "serial_s": slow["secs"],
        "iters_per_s_overlapped": iterations / fast["secs"],
        "iters_per_s_serial": iterations / slow["secs"],
        "dispatch_spans": sp["dispatch_paired"],
        "drain_spans": sp["overlap_drain"],
        "speedup": speedup, "min_speedup": min_speedup,
        "parity": "match",
    }]
    assert speedup >= min_speedup, (
        f"overlapped executor only {speedup:.2f}x over the serial mapper "
        f"path (contract: >={min_speedup}x)")
    return rows


SMOKE_KW = dict(iterations=4, n_sample=128, min_speedup=1.0, repeats=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short campaign + soft thresholds (CI)")
    ap.add_argument("--worker", default=None, help="internal: run one side")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--n-sample", type=int, default=None)
    ap.add_argument("--out", default=None, metavar="BENCH_10.json",
                    help="write the perf artifact here (default "
                         "experiments/BENCH_10.json)")
    args = ap.parse_args()

    if args.worker:
        worker(args.worker, args.iters, args.n_sample)
        return

    kw = dict(SMOKE_KW) if args.smoke else {}
    if args.iters is not None:
        kw["iterations"] = args.iters
    if args.n_sample is not None:
        kw["n_sample"] = args.n_sample
    t0 = time.time()
    rows = run(**kw)
    total_s = time.time() - t0

    r = rows[0]
    print(f"overlap_serial,{1e6 * r['serial_s'] / r['iterations']:.0f},"
          f"iters_per_s={r['iters_per_s_serial']:.3f}")
    print(f"overlap_overlapped,"
          f"{1e6 * r['overlapped_s'] / r['iterations']:.0f},"
          f"iters_per_s={r['iters_per_s_overlapped']:.3f} "
          f"dispatches={r['dispatch_spans']} drains={r['drain_spans']} "
          f"cores={r['cores']} speedup={r['speedup']:.2f}x "
          f"parity={r['parity']}")

    tol = 0.40 if args.smoke else 0.25
    bench = {
        "schema": BENCH_SCHEMA,
        "bench_id": BENCH_ID,
        "mode": "smoke" if args.smoke else "full",
        "generated_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sections_s": {"overlap": total_s},
        "benchmarks": [
            {"name": "overlap_warm_iter",
             "us_per_call": 1e6 * r["overlapped_s"] / r["iterations"],
             "derived": f"speedup={r['speedup']:.2f}x "
                        f"cores={r['cores']} "
                        f"dispatches={r['dispatch_spans']}"},
        ],
        "gates": {
            "overlap_speedup": {"value": float(r["speedup"]),
                                "tolerance": tol,
                                "higher_is_better": True},
        },
    }
    out = Path(args.out) if args.out else (
        ROOT / "experiments" / f"BENCH_{BENCH_ID}.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(bench, indent=1) + "\n")
    print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    main()