"""Perf-trajectory regression gate over ``experiments/BENCH_*.json``.

``benchmarks.run`` writes a machine-readable artifact per run with named
*gates* — the headline speedup/quality numbers each PR promises (batched
mapper/scheduler/tuner/engine speedups, NicePIM-vs-random Fig. 9 quality).
This module compares the current artifact against a committed baseline and
fails (exit 1) when any gate regresses below its tolerance band:

    PYTHONPATH=src python -m benchmarks.bench_gate \
        --current experiments/BENCH_6.json --baseline /tmp/BENCH_6.json

Skips cleanly (exit 0 with a message) when there is no baseline yet, or
when baseline and current were produced in different modes (smoke vs
full) — those numbers are not comparable.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DEFAULT_CURRENT = ROOT / "experiments" / "BENCH_6.json"


def load(path: str | Path) -> dict:
    data = json.loads(Path(path).read_text())
    schema = str(data.get("schema", ""))
    if not schema.startswith("nicepim-bench/"):
        raise ValueError(f"{path}: unknown schema {schema!r}")
    return data


def compare(current: dict, baseline: dict) -> tuple[list[str], list[str]]:
    """Return ``(failures, report_lines)`` for current vs baseline gates.

    A gate regresses when ``value < base * (1 - tolerance)`` (all gates
    are higher-is-better ratios).  The *baseline's* tolerance is used: the
    committed artifact declares the band the repo promises to stay inside.
    Gates present on only one side are reported but never fail — they are
    new or retired promises, not regressions.
    """
    failures: list[str] = []
    lines: list[str] = []
    base_gates = baseline.get("gates", {})
    cur_gates = current.get("gates", {})
    for name in sorted(set(base_gates) | set(cur_gates)):
        if name not in cur_gates:
            lines.append(f"~ {name}: gate removed (was "
                         f"{base_gates[name]['value']:.2f})")
            continue
        if name not in base_gates:
            lines.append(f"+ {name}: new gate "
                         f"({cur_gates[name]['value']:.2f})")
            continue
        base = base_gates[name]
        cur = cur_gates[name]
        tol = float(base.get("tolerance", 0.25))
        floor = float(base["value"]) * (1.0 - tol)
        ratio = float(cur["value"]) / max(float(base["value"]), 1e-30)
        verdict = "ok" if float(cur["value"]) >= floor else "REGRESSED"
        lines.append(f"{'.' if verdict == 'ok' else '!'} {name}: "
                     f"{cur['value']:.2f} vs baseline {base['value']:.2f} "
                     f"({ratio:.2f}x, floor {floor:.2f}) {verdict}")
        if verdict != "ok":
            failures.append(name)
    return failures, lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=str(DEFAULT_CURRENT))
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH artifact to gate against; "
                         "omitted or missing => clean skip")
    args = ap.parse_args(argv)

    if not Path(args.current).exists():
        print(f"bench_gate: current artifact {args.current} not found")
        return 2
    current = load(args.current)

    if not args.baseline or not Path(args.baseline).exists():
        print(f"bench_gate: no baseline ({args.baseline or 'not given'}); "
              "skipping — commit the current artifact to start gating")
        return 0
    try:
        baseline = load(args.baseline)
    except (json.JSONDecodeError, ValueError, OSError) as e:
        print(f"bench_gate: unreadable baseline ({e}); skipping")
        return 0

    if current.get("mode") != baseline.get("mode"):
        print(f"bench_gate: mode mismatch (current={current.get('mode')}, "
              f"baseline={baseline.get('mode')}); skipping — smoke and "
              "full numbers are not comparable")
        return 0

    failures, lines = compare(current, baseline)
    for line in lines:
        print(line)
    if failures:
        print(f"bench_gate: {len(failures)} gate(s) regressed: "
              + ", ".join(failures))
        return 1
    print(f"bench_gate: all {len(lines)} gate(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
