"""Engine throughput: batched vs scalar cost-model evaluation (configs/sec).

The scalar baseline is exactly what the per-candidate DSE loop does today:
one ``part_layer_cost`` Python call per (config, part-layer) point.  The
batched path scores the same fig9-style sweep — N sampled hardware configs
x L part-layers from the workload nets — in one ``engine.batch_part_cost``
pipeline.  Reported ``configs/sec`` numbers feed the perf trajectory in
EXPERIMENTS.md; the engine tests separately pin the 1e-6 parity contract,
so this benchmark is purely about throughput.
"""

from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.costmodel import part_layer_cost
from repro.core.layout import DataLayout
from repro.core.partition import enumerate_lms, part_layer
from repro.core.tuner import sample_configs
from repro.core.workloads import googlenet, resnet50
from repro.engine.batch_cost import PartSpec, batch_part_cost


def _default_dl(channels: int) -> DataLayout:
    """Mirror of ``PimMapper._default_dl`` — the mapper's starting layout."""
    g = 1
    while g * 2 <= min(channels, 16):
        g *= 2
    return DataLayout("BCHW", g)


def make_specs(n_layers: int = 12) -> list[PartSpec]:
    """Representative part-layers: mapper-style partitions of real nets."""
    layers = []
    for g in (googlenet(1, scale=4), resnet50(1, scale=4)):
        layers += [l for l in g.layers if l.is_heavy]
    specs = []
    for l in layers[:n_layers]:
        lm = enumerate_lms(l, 4, 8, cap=3)[0]
        pl = part_layer(l, lm)
        specs.append(PartSpec(pl, _default_dl(pl.C), _default_dl(pl.K)))
    return specs


def _unique_configs(n: int, rng) -> list:
    seen, outs = set(), []
    while len(outs) < n:
        for c in sample_configs(n, rng):
            t = c.as_tuple()
            if t not in seen:
                seen.add(t)
                outs.append(c)
            if len(outs) >= n:
                break
    return outs


def run(n_configs: int = 192, n_layers: int = 12, seed: int = 0,
        chunk: int = 64, scalar_configs: int | None = None) -> list[dict]:
    """Time scalar loop vs batched engine on the same (config, layer) grid.

    ``scalar_configs`` caps how many configs the scalar loop times (it is
    the slow side; the measured per-config rate extrapolates linearly).
    """
    rng = np.random.default_rng(seed)
    configs = _unique_configs(n_configs, rng)
    specs = make_specs(n_layers)

    # ---- scalar per-candidate loop (the pre-engine DSE hot path) ----------
    n_scalar = min(scalar_configs or n_configs, n_configs)
    part_layer_cost.cache_clear()
    t0 = time.perf_counter()
    for c in configs[:n_scalar]:
        for s in specs:
            part_layer_cost(c, s.layer, s.dl_in, s.dl_out)
    scalar_s = time.perf_counter() - t0
    scalar_cps = n_scalar / scalar_s

    # ---- batched engine ----------------------------------------------------
    t0 = time.perf_counter()
    batch_part_cost(configs, specs, chunk=chunk)
    cold_s = time.perf_counter() - t0          # includes XLA compile
    t0 = time.perf_counter()
    batch_part_cost(configs, specs, chunk=chunk)
    warm_s = time.perf_counter() - t0
    warm_cps = n_configs / warm_s

    return [{
        "table": "engine", "n_configs": n_configs, "n_layers": n_layers,
        "scalar_s": scalar_s, "scalar_configs": n_scalar,
        "scalar_configs_per_s": scalar_cps,
        "batched_cold_s": cold_s, "batched_warm_s": warm_s,
        "batched_configs_per_s": warm_cps,
        "speedup": warm_cps / scalar_cps,
    }]


def main(n_configs: int = 192, n_layers: int = 12) -> None:
    r = run(n_configs=n_configs, n_layers=n_layers)[0]
    print(f"engine_scalar,{1e6 / r['scalar_configs_per_s']:.1f},"
          f"configs_per_s={r['scalar_configs_per_s']:.1f}")
    print(f"engine_batched,{1e6 / r['batched_configs_per_s']:.1f},"
          f"configs_per_s={r['batched_configs_per_s']:.1f} "
          f"speedup={r['speedup']:.1f}x")


if __name__ == "__main__":
    main()
